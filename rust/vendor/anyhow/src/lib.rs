//! Offline, API-compatible subset of [`anyhow`](https://docs.rs/anyhow) —
//! vendored so the crate builds in hermetic environments with no registry
//! access. Covers exactly the surface this workspace uses: [`Error`],
//! [`Result`], [`Context`], and the `anyhow!` / `bail!` / `ensure!`
//! macros, including `{:#}` context-chain formatting. Swap the path
//! dependency in `rust/Cargo.toml` for the crates.io release when a
//! registry is available; no source changes are required.

use std::fmt;

/// Error type: a message with a chain of context frames (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Push an outer context frame (what `Context` adds).
    fn wrap<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` = the whole cause chain, anyhow-style
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` alias with the usual default error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option` (the `anyhow::Context`
/// surface this workspace uses).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("Condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn chain_formatting() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn ensure_and_option_context() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            None::<i32>.with_context(|| format!("missing for {x}"))
        }
        assert_eq!(check(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(check(1).unwrap_err().to_string(), "missing for 1");
    }
}
