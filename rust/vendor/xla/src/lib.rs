//! Offline **stub** of the `xla` PJRT bindings used by
//! `rust/src/runtime/service.rs` — type-compatible with the surface the
//! runtime calls, but with no native XLA/PJRT backing. [`PjRtClient::cpu`]
//! fails cleanly, so `runtime::start_default` returns an error and every
//! caller takes its documented CPU fallback (examples print "PJRT
//! unavailable", `pjrt_parity` tests skip, the service rejects
//! `use_pjrt` requests with an actionable message).
//!
//! Swap the path dependency in `rust/Cargo.toml` for the real
//! `xla`/`xla-rs` bindings (plus `make artifacts`) to light up the PJRT
//! route; no source changes are required.

use std::path::Path;

/// Stub error: carries the message the runtime formats with `{e:?}`.
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "xla stub: PJRT is not available in this build \
         (link the real xla bindings to enable the accelerated route)"
            .to_string(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the stub — the one call every PJRT path goes
    /// through first, so failure here cleanly disables the whole route.
    pub fn cpu() -> Result<Self, Error> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self, Error> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_cleanly() {
        let err = PjRtClient::cpu().map(|_| ()).unwrap_err();
        assert!(format!("{err:?}").contains("PJRT"));
    }
}
