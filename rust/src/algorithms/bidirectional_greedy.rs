//! Bi-directional ("double") greedy of Buchbinder, Feldman, Naor & Schwartz
//! (FOCS 2012): tight randomized 1/2-approximation for *unconstrained*
//! non-monotone submodular maximization.
//!
//! The paper needs it twice: (a) solving Eq. (9) exactly-ish is what SS
//! replaces, so this is the "expensive alternative" ablation; (b) §3.4's
//! third improvement runs it on the SS output `V'` to shrink the reduced
//! set further. Requires removal support ([`SubmodularFn::bidir_state`]).

use super::Solution;
use crate::submodular::SubmodularFn;
use crate::util::rng::Rng;
use crate::util::stats::Timer;

/// Randomized double greedy over `candidates`. `deterministic = true` uses
/// the 1/3-approximate deterministic variant (no randomness, reproducible
/// across seeds; useful in tests).
pub fn bidirectional_greedy(
    f: &dyn SubmodularFn,
    candidates: &[usize],
    seed: u64,
    deterministic: bool,
) -> Solution {
    let timer = Timer::new();
    let mut rng = Rng::new(seed);
    let mut x = f
        .bidir_state(&[])
        .expect("bidirectional_greedy requires a bidir-capable objective");
    let mut y = f.bidir_state(candidates).expect("bidir state");
    let mut calls = 0u64;

    for &v in candidates {
        let a = x.gain_add(v); // f(X + v) − f(X)
        let b = y.gain_remove(v); // f(Y − v) − f(Y)
        calls += 2;
        let take = if deterministic {
            a >= b
        } else {
            let (ap, bp) = (a.max(0.0), b.max(0.0));
            if ap + bp == 0.0 {
                true // both zero: adding is value-neutral for X and Y
            } else {
                rng.f64() < ap / (ap + bp)
            }
        };
        if take {
            x.add(v);
        } else {
            y.remove(v);
        }
    }
    let set = x.members();
    debug_assert_eq!(set, y.members(), "X and Y must converge");
    Solution { value: x.value(), set, oracle_calls: calls, wall_s: timer.elapsed_s() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::{GraphCut, SparsificationObjective, SubmodularFn};
    use crate::util::rng::Rng;

    fn brute_force_unconstrained(f: &dyn SubmodularFn, m: usize) -> f64 {
        let mut best = 0.0f64;
        for mask in 0u32..(1 << m) {
            let s: Vec<usize> = (0..m).filter(|&i| mask >> i & 1 == 1).collect();
            best = best.max(f.eval(&s));
        }
        best
    }

    fn gc_instance(n: usize, seed: u64) -> GraphCut {
        let mut rng = Rng::new(seed);
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            for u in (i + 1)..n {
                let s = rng.f32();
                sim[i * n + u] = s;
                sim[u * n + i] = s;
            }
        }
        GraphCut::new(n, sim, 0.45)
    }

    #[test]
    fn randomized_half_guarantee_in_expectation() {
        // average over seeds ≥ 1/2·OPT (w/ slack for variance)
        for inst_seed in 0..3 {
            let f = gc_instance(12, inst_seed);
            let all: Vec<usize> = (0..12).collect();
            let opt = brute_force_unconstrained(&f, 12);
            let avg: f64 = (0..40)
                .map(|s| bidirectional_greedy(&f, &all, s, false).value)
                .sum::<f64>()
                / 40.0;
            assert!(
                avg >= 0.45 * opt,
                "instance {inst_seed}: E[f] ≈ {avg} < 0.45·OPT ({opt})"
            );
        }
    }

    #[test]
    fn deterministic_variant_reproducible_and_third_guarantee() {
        for inst_seed in 0..3 {
            let f = gc_instance(10, inst_seed + 10);
            let all: Vec<usize> = (0..10).collect();
            let a = bidirectional_greedy(&f, &all, 1, true);
            let b = bidirectional_greedy(&f, &all, 999, true);
            assert_eq!(a.set, b.set, "deterministic variant ignores the seed");
            let opt = brute_force_unconstrained(&f, 10);
            assert!(a.value >= opt / 3.0 - 1e-9, "1/3 guarantee: {} vs {opt}", a.value);
        }
    }

    #[test]
    fn works_on_sparsification_objective() {
        // §3.4: double greedy on Eq. 9's h over a reduced set
        let mut rng = Rng::new(5);
        let n = 12;
        let w: Vec<f64> = (0..n * n).map(|_| rng.f64() * 2.0 - 0.6).collect();
        let h = SparsificationObjective::from_weights(n, 0.3, move |u, v| w[u * n + v]);
        let all: Vec<usize> = (0..n).collect();
        let s = bidirectional_greedy(&h, &all, 3, false);
        assert!((s.value - h.eval(&s.set)).abs() < 1e-9);
        assert!(s.value >= 0.0);
    }

    #[test]
    fn candidate_subset_only() {
        let f = gc_instance(10, 77);
        let cands = vec![1, 4, 6];
        let s = bidirectional_greedy(&f, &cands, 0, true);
        assert!(s.set.iter().all(|v| cands.contains(v)));
    }
}
