//! PJRT-accelerated greedy: each step computes the whole marginal-gain
//! vector through the AOT `marginal_gains` artifact (the Layer-1 Pallas
//! batch kernel) and commits the argmax.
//!
//! This is the "greedy on the device" counterpart of the SS backend — on a
//! TPU the `(B, D)` gain batches stream through VMEM at memory bandwidth,
//! which is how the full pipeline (SS prune + greedy on V') stays on-device
//! end to end. It trades lazy greedy's eval-count savings for batched
//! regularity; on the CPU plugin it mainly serves as a correctness +
//! integration path (perf notes in EXPERIMENTS.md §Perf).

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::TiledRuntime;
use crate::submodular::FeatureBased;
use crate::util::stats::Timer;
use crate::util::vecmath::add_into;

use super::Solution;

pub fn accelerated_greedy(
    f: &FeatureBased,
    rt: &Arc<TiledRuntime>,
    candidates: &[usize],
    k: usize,
) -> Result<Solution> {
    let timer = Timer::new();
    let mut cov = vec![0.0f32; f.d()];
    let mut remaining: Vec<usize> = candidates.to_vec();
    let mut set = Vec::new();
    let mut value = 0.0f64;
    let mut calls = 0u64;
    for _ in 0..k.min(candidates.len()) {
        if remaining.is_empty() {
            break;
        }
        let gains = rt.marginal_gains(f.feats(), &cov, &remaining)?;
        calls += remaining.len() as u64;
        let (best_i, best_g) = gains
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .map(|(i, &g)| (i, g))
            .unwrap();
        if best_g <= 0.0 {
            break;
        }
        let v = remaining.swap_remove(best_i);
        // commit on the *CPU oracle* (f64) to avoid f32 drift accumulating
        value += f.gain_over_cov(&cov, v);
        add_into(&mut cov, f.feats().row(v));
        set.push(v);
    }
    Ok(Solution { set, value, oracle_calls: calls, wall_s: timer.elapsed_s() })
}

#[cfg(test)]
mod tests {
    // Device-dependent tests live in rust/tests/pjrt_parity.rs (they need
    // built artifacts). Here we only assert the module's CPU-side pieces.
    use crate::submodular::{FeatureBased, SubmodularFn};
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    #[test]
    fn gain_over_cov_matches_state_gain() {
        let mut rng = Rng::new(1);
        let mut m = FeatureMatrix::zeros(20, 8);
        for i in 0..20 {
            for j in 0..8 {
                m.row_mut(i)[j] = rng.f32();
            }
        }
        let f = FeatureBased::sqrt(m);
        let mut st = f.state();
        let mut cov = vec![0.0f32; 8];
        for &v in &[3usize, 7, 11] {
            assert!((f.gain_over_cov(&cov, v) - st.gain(v)).abs() < 1e-9);
            st.add(v);
            crate::util::vecmath::add_into(&mut cov, f.feats().row(v));
        }
    }
}
