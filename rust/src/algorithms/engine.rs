//! **Batched maximizer engine** — the shared substrate the greedy family
//! ([`lazy_greedy`], [`greedy`], [`stochastic_greedy`]) is built on.
//!
//! The paper's end-to-end pipeline is sparsify → greedy on the reduced set
//! `V'` (Alg. 2). PR 2 made the sparsify rounds kernel-bound and
//! allocation-free, which left the maximizer as the serial tail: one
//! scalar `state.gain(v)` oracle call at a time. The greedy family is
//! naturally restructured around evaluating *batches* of candidates per
//! commit ("Lazier Than Lazy Greedy", Mirzasoleiman et al.), and the
//! marginal-gain evaluations themselves vectorize through the objective's
//! structure (Lindgren et al.) — so the engine dispatches **cohorts**
//! through [`SolState::gains_into`] (blocked kernels for feature-based /
//! facility-location / mixture states, scalar fallback for everything
//! else) instead of per-element `gain` calls.
//!
//! Routes ([`GainRoute`]):
//! * [`Direct`](GainRoute::Direct) — the state's batched kernel inline on
//!   the calling thread (the CPU reference path);
//! * [`Backend`](GainRoute::Backend) — through
//!   [`DivergenceBackend::gains_into`], which the sharded coordinator
//!   overrides to fan large cohorts over its pool and meter them
//!   (`gain_evals`);
//! * [`Pjrt`](GainRoute::Pjrt) — the feature-based fast path through the
//!   AOT marginal-gain artifact (`runtime/tiled.rs`), CPU fallback for
//!   every other objective or on executor failure. Device gains are f32,
//!   so this route trades the bit-exactness guarantee below for batched
//!   regularity — same contract as
//!   [`accelerated_greedy`](super::accelerated_greedy).
//!
//! **Minoux-exactness.** On the CPU routes, batched lazy greedy returns
//! the bit-identical solution to the scalar reference
//! ([`lazy_greedy_reference`](super::lazy_greedy::lazy_greedy_reference)):
//! cohort re-evaluation only changes *when* cached gains are refreshed,
//! never the commit order. The argument: cached priorities are upper
//! bounds (diminishing returns), so a heap-top entry whose gain is exact
//! under the current solution dominates every other exact gain; ties
//! resolve by the heap's deterministic lowest-id-wins order, and a stale
//! tie partner re-enters at the same (bit-identical) priority and wins or
//! loses exactly as it would in the scalar schedule. Since
//! [`SolState::gains_into`] is bit-identical to scalar `gain`, every
//! quantity the commit decision reads is identical. The property suite
//! (`rust/tests/maximizer_equivalence.rs`) asserts this across objectives,
//! backends, thread counts and cohort sizes.
//!
//! Steady-state iterations are **zero-allocation**: the engine owns an
//! arena (heap, version/epoch maps, cohort buffers, gain buffer) sized
//! once per run, states reserve their solution vector via
//! [`SolState::reserve_additions`], and the blocked kernels keep their
//! tiles in thread-local scratch — asserted by the counting allocator in
//! `rust/tests/alloc_steady_state.rs`.
//!
//! [`lazy_greedy`]: super::lazy_greedy::lazy_greedy
//! [`greedy`]: super::greedy::greedy
//! [`stochastic_greedy`]: super::stochastic_greedy::stochastic_greedy
//! [`SolState::gains_into`]: crate::submodular::SolState::gains_into
//! [`DivergenceBackend::gains_into`]: super::ss::DivergenceBackend::gains_into

use crate::runtime::TiledRuntime;
use crate::submodular::{SolState, SubmodularFn};
use crate::trace::{EventKind, Tracer};
use crate::util::rng::Rng;
use crate::util::select::LazyMaxHeap;
use crate::util::stats::Timer;

use super::ss::{DivergenceBackend, Interrupt};
use super::Solution;

/// Default cohort size for lazy greedy's stale-entry re-evaluations: large
/// enough that the blocked kernels amortize their per-call setup (the
/// `g(cov)` row, tile zeroing), small enough that the overshoot past the
/// handful of re-evaluations the scalar schedule needs stays cheap.
pub const DEFAULT_COHORT: usize = 64;

/// How the engine evaluates a cohort of candidate gains.
pub enum GainRoute<'a> {
    /// The state's own batched kernel, inline on the calling thread.
    Direct,
    /// Through [`DivergenceBackend::gains_into`] — the sharded coordinator
    /// fans large cohorts over its pool and counts them in `gain_evals`.
    Backend(&'a dyn DivergenceBackend),
    /// The PJRT marginal-gain artifact for feature-based states; CPU
    /// fallback otherwise (f32 device gains — see the module docs).
    Pjrt(&'a TiledRuntime),
}

/// Oracle accounting for one engine run.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Per-element marginal-gain evaluations — the unit
    /// [`Solution::oracle_calls`] reports, comparable across the scalar
    /// references.
    pub gain_evals: u64,
    /// Batched kernel dispatches that produced them. The scalar references
    /// dispatch once per evaluation; the engine's whole point is
    /// `dispatches ≪ gain_evals`.
    pub dispatches: u64,
}

/// The engine: per-run arena + route. Construct once per maximization run
/// (or reuse across runs — buffers keep their capacity).
pub struct MaximizerEngine<'a> {
    f: &'a dyn SubmodularFn,
    route: GainRoute<'a>,
    cohort: usize,
    stats: EngineStats,
    /// span sink for cohort dispatches — the no-op tracer by default, so an
    /// un-instrumented engine pays one relaxed atomic load per dispatch
    tracer: &'a Tracer,
    // ---- arena (reused across runs, allocation-free within a run) ----
    heap: LazyMaxHeap,
    versions: Vec<u64>,
    evaluated_epoch: Vec<u64>,
    /// positions (into `candidates`) of the cohort being re-evaluated
    cohort_pos: Vec<usize>,
    /// gathered global candidate ids for the current batch
    cand_buf: Vec<usize>,
    /// batch gain output (f64, the oracle's width)
    gains: Vec<f64>,
    /// f32 staging for the PJRT route
    gains32: Vec<f32>,
    /// live candidate list for the naive / stochastic modes
    remaining: Vec<usize>,
    /// sampled probe positions for the stochastic mode
    probe_pos: Vec<usize>,
}

impl<'a> MaximizerEngine<'a> {
    pub fn new(f: &'a dyn SubmodularFn, route: GainRoute<'a>) -> Self {
        Self {
            f,
            route,
            cohort: DEFAULT_COHORT,
            stats: EngineStats::default(),
            tracer: Tracer::noop(),
            heap: LazyMaxHeap::new(),
            versions: Vec::new(),
            evaluated_epoch: Vec::new(),
            cohort_pos: Vec::new(),
            cand_buf: Vec::new(),
            gains: Vec::new(),
            gains32: Vec::new(),
            remaining: Vec::new(),
            probe_pos: Vec::new(),
        }
    }

    /// Override the lazy-mode cohort size (≥ 1; 1 reproduces the scalar
    /// re-evaluation schedule exactly, batch-dispatched).
    pub fn with_cohort(mut self, cohort: usize) -> Self {
        self.cohort = cohort.max(1);
        self
    }

    /// Record one [`EventKind::Cohort`] span per kernel dispatch on
    /// `tracer`: payload `[cohort_size, gain_evals, dispatches, _]` (the
    /// running totals *after* the dispatch). Spans never touch the gains,
    /// the heap or the RNG, so a traced run's solution is bit-identical to
    /// an untraced one.
    pub fn with_tracer(mut self, tracer: &'a Tracer) -> Self {
        self.tracer = tracer;
        self
    }

    /// Accounting for the most recent run.
    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// Minoux's lazy greedy, cohort-batched. Bit-identical solution to
    /// [`lazy_greedy_reference`](super::lazy_greedy::lazy_greedy_reference)
    /// on the CPU routes (module docs for the argument), with
    /// `stats().dispatches` kernel calls instead of one oracle dispatch
    /// per evaluation.
    pub fn lazy_greedy(&mut self, candidates: &[usize], k: usize) -> Solution {
        match self.lazy_greedy_with(candidates, k, &mut || None) {
            Ok(s) => s,
            Err(_) => unreachable!("the never-interrupting probe cannot fire"),
        }
    }

    /// Interruptible form of [`lazy_greedy`](Self::lazy_greedy). The probe
    /// is polled before the initial fill and at the top of every heap
    /// iteration, so a cancel or deadline lands within one cohort dispatch
    /// — the same round-boundary contract as
    /// [`sparsify_with`](super::ss::sparsify_with). A partial run's arena
    /// is left reusable; `Err` abandons the solution.
    pub fn lazy_greedy_with(
        &mut self,
        candidates: &[usize],
        k: usize,
        check: &mut dyn FnMut() -> Option<Interrupt>,
    ) -> Result<Solution, Interrupt> {
        let timer = Timer::new();
        let mut state = self.f.state();
        let k = k.min(candidates.len());
        state.reserve_additions(k);
        let n = candidates.len();
        self.stats = EngineStats::default();
        self.versions.clear();
        self.versions.resize(n, 0);
        self.evaluated_epoch.clear();
        self.evaluated_epoch.resize(n, 0);
        self.heap.clear();
        self.heap.reserve(n);
        self.gains.clear();
        self.gains.resize(n, 0.0);
        self.cohort_pos.clear();
        self.cohort_pos.reserve(self.cohort);
        self.cand_buf.clear();
        self.cand_buf.reserve(self.cohort);

        if n > 0 {
            if let Some(why) = check() {
                return Err(why);
            }
            // initial fill: the whole candidate set at S = ∅ in one batch
            // (the scalar reference's n push-time evaluations, 1 dispatch)
            batch_gains(
                &self.route,
                self.f,
                state.as_ref(),
                candidates,
                &mut self.gains[..n],
                &mut self.gains32,
                &mut self.stats,
                self.tracer,
            );
            for (i, &g) in self.gains[..n].iter().enumerate() {
                self.heap.push(i, g as f32, 0);
            }
        }

        let mut chosen = 0usize;
        // epoch = commits + 1; a gain computed in the current epoch is exact
        let mut epoch = 1u64;
        while chosen < k {
            if let Some(why) = check() {
                return Err(why);
            }
            let Some((i, cached)) = self.heap.pop_fresh(&self.versions) else { break };
            if self.evaluated_epoch[i] == epoch {
                // exact under the current solution: commit (or stop)
                if cached <= 0.0 {
                    break; // non-monotone early stop — same test as the reference
                }
                commit(&self.route, state.as_mut(), candidates[i]);
                self.versions[i] = u64::MAX; // never re-enters
                chosen += 1;
                epoch += 1;
                continue;
            }
            // stale: assemble a cohort of further stale entries and
            // re-evaluate them all in one kernel dispatch
            self.cohort_pos.clear();
            self.cohort_pos.push(i);
            while self.cohort_pos.len() < self.cohort {
                let Some((j, cj)) = self.heap.pop_fresh(&self.versions) else { break };
                if self.evaluated_epoch[j] == epoch {
                    // already exact — put it back untouched (same version,
                    // same priority); the refreshed cohort competes with it
                    // on the next pop
                    self.heap.push(j, cj, self.versions[j]);
                    break;
                }
                self.cohort_pos.push(j);
            }
            self.cand_buf.clear();
            self.cand_buf.extend(self.cohort_pos.iter().map(|&p| candidates[p]));
            let c = self.cohort_pos.len();
            batch_gains(
                &self.route,
                self.f,
                state.as_ref(),
                &self.cand_buf,
                &mut self.gains[..c],
                &mut self.gains32,
                &mut self.stats,
                self.tracer,
            );
            for (idx, &p) in self.cohort_pos.iter().enumerate() {
                self.versions[p] += 1;
                self.evaluated_epoch[p] = epoch;
                self.heap.push(p, self.gains[idx] as f32, self.versions[p]);
            }
        }

        Ok(Solution {
            set: state.set().to_vec(),
            value: state.value(),
            oracle_calls: self.stats.gain_evals,
            wall_s: timer.elapsed_s(),
        })
    }

    /// Naive greedy, one batch per commit. Bit-identical to
    /// [`greedy_reference`](super::greedy::greedy_reference): same strict-`>`
    /// first-maximal scan over the same `swap_remove`-mutated candidate
    /// order, over bit-identical gains.
    pub fn greedy(&mut self, candidates: &[usize], k: usize) -> Solution {
        let timer = Timer::new();
        let mut state = self.f.state();
        let k = k.min(candidates.len());
        state.reserve_additions(k);
        self.stats = EngineStats::default();
        self.remaining.clear();
        self.remaining.extend_from_slice(candidates);
        self.gains.clear();
        self.gains.resize(candidates.len(), 0.0);
        for _ in 0..k {
            let m = self.remaining.len();
            if m == 0 {
                break;
            }
            batch_gains(
                &self.route,
                self.f,
                state.as_ref(),
                &self.remaining,
                &mut self.gains[..m],
                &mut self.gains32,
                &mut self.stats,
                self.tracer,
            );
            let mut best_i = usize::MAX;
            let mut best_gain = f64::NEG_INFINITY;
            for (i, &g) in self.gains[..m].iter().enumerate() {
                // deterministic tie-break on position keeps greedy == lazy_greedy
                if g > best_gain {
                    best_gain = g;
                    best_i = i;
                }
            }
            if best_i == usize::MAX || best_gain <= 0.0 {
                break; // monotone f never hits this; non-monotone stops early
            }
            let v = self.remaining.swap_remove(best_i);
            commit(&self.route, state.as_mut(), v);
        }
        Solution {
            set: state.set().to_vec(),
            value: state.value(),
            oracle_calls: self.stats.gain_evals,
            wall_s: timer.elapsed_s(),
        }
    }

    /// Stochastic greedy (Mirzasoleiman et al.), one batch per sampled
    /// probe set. Bit-identical draws and solution to
    /// [`stochastic_greedy_reference`](super::stochastic_greedy::stochastic_greedy_reference):
    /// `sample_indices_into` reproduces `sample_indices`' draw sequence
    /// exactly, and the probe scan order is unchanged.
    pub fn stochastic_greedy(
        &mut self,
        candidates: &[usize],
        k: usize,
        eps: f64,
        seed: u64,
    ) -> Solution {
        match self.stochastic_greedy_with(candidates, k, eps, seed, &mut || None) {
            Ok(s) => s,
            Err(_) => unreachable!("the never-interrupting probe cannot fire"),
        }
    }

    /// Interruptible form of [`stochastic_greedy`](Self::stochastic_greedy):
    /// the probe is polled at the top of every sample round, bounding shed
    /// latency by one probe-set dispatch. The draw sequence up to the
    /// interrupt is identical to the uninterrupted run's.
    pub fn stochastic_greedy_with(
        &mut self,
        candidates: &[usize],
        k: usize,
        eps: f64,
        seed: u64,
        check: &mut dyn FnMut() -> Option<Interrupt>,
    ) -> Result<Solution, Interrupt> {
        assert!(eps > 0.0 && eps < 1.0);
        let timer = Timer::new();
        let mut rng = Rng::new(seed);
        let mut state = self.f.state();
        let k = k.min(candidates.len());
        state.reserve_additions(k);
        self.stats = EngineStats::default();
        self.remaining.clear();
        self.remaining.extend_from_slice(candidates);
        let sample_size = (((candidates.len() as f64 / k.max(1) as f64) * (1.0 / eps).ln())
            .ceil() as usize)
            .max(1);
        self.gains.clear();
        self.gains.resize(sample_size.min(candidates.len()).max(1), 0.0);
        for _ in 0..k {
            if let Some(why) = check() {
                return Err(why);
            }
            if self.remaining.is_empty() {
                break;
            }
            let m = sample_size.min(self.remaining.len());
            rng.sample_indices_into(self.remaining.len(), m, &mut self.probe_pos);
            self.cand_buf.clear();
            self.cand_buf.extend(self.probe_pos.iter().map(|&p| self.remaining[p]));
            batch_gains(
                &self.route,
                self.f,
                state.as_ref(),
                &self.cand_buf,
                &mut self.gains[..m],
                &mut self.gains32,
                &mut self.stats,
                self.tracer,
            );
            let mut best_pos = usize::MAX;
            let mut best_gain = f64::NEG_INFINITY;
            for (idx, &p) in self.probe_pos.iter().enumerate() {
                let g = self.gains[idx];
                if g > best_gain {
                    best_gain = g;
                    best_pos = p;
                }
            }
            if best_pos == usize::MAX || best_gain <= 0.0 {
                break;
            }
            let v = self.remaining.swap_remove(best_pos);
            commit(&self.route, state.as_mut(), v);
        }
        Ok(Solution {
            set: state.set().to_vec(),
            value: state.value(),
            oracle_calls: self.stats.gain_evals,
            wall_s: timer.elapsed_s(),
        })
    }
}

/// One commit through the configured route: the backend route may fan the
/// state's per-element bookkeeping walk over its pool
/// ([`DivergenceBackend::commit`] → [`SolState::add_pooled`]), the others
/// add inline — all bit-identical to `state.add(v)`, so route choice can
/// never change a solution.
fn commit(route: &GainRoute<'_>, state: &mut dyn SolState, v: usize) {
    match route {
        GainRoute::Backend(b) => b.commit(state, v),
        _ => state.add(v),
    }
}

/// One cohort dispatch through the configured route. Free-standing so the
/// engine can borrow its arena fields disjointly. The span brackets the
/// kernel call itself; with a disabled tracer it costs one relaxed load.
fn batch_gains(
    route: &GainRoute<'_>,
    f: &dyn SubmodularFn,
    state: &dyn SolState,
    cands: &[usize],
    out: &mut [f64],
    out32: &mut Vec<f32>,
    stats: &mut EngineStats,
    tracer: &Tracer,
) {
    debug_assert_eq!(cands.len(), out.len());
    let span = tracer.start();
    match route {
        GainRoute::Direct => state.gains_into(cands, out),
        GainRoute::Backend(b) => b.gains_into(state, cands, out),
        GainRoute::Pjrt(rt) => match (f.as_feature_based(), state.feature_coverage()) {
            (Some(fb), Some(cov)) => {
                out32.resize(cands.len(), 0.0);
                match rt.marginal_gains_into(fb.feats(), cov, cands, out32) {
                    Ok(()) => {
                        for (slot, &g) in out.iter_mut().zip(out32.iter()) {
                            *slot = g as f64;
                        }
                    }
                    // executor failure: fall back to the CPU kernel
                    Err(_) => state.gains_into(cands, out),
                }
            }
            _ => state.gains_into(cands, out),
        },
    }
    stats.gain_evals += cands.len() as u64;
    stats.dispatches += 1;
    tracer.record_since(
        EventKind::Cohort,
        span,
        cands.len() as u64,
        stats.gain_evals,
        stats.dispatches,
        0,
    );
}

#[cfg(test)]
mod tests {
    use super::super::greedy::greedy_reference;
    use super::super::lazy_greedy::lazy_greedy_reference;
    use super::super::stochastic_greedy::stochastic_greedy_reference;
    use super::*;
    use crate::submodular::FeatureBased;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feature_instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn lazy_bit_identical_to_scalar_reference_across_cohorts() {
        for seed in [1u64, 7, 23] {
            let f = feature_instance(120, 8, seed);
            let all: Vec<usize> = (0..120).collect();
            for k in [1usize, 5, 30, 120] {
                let want = lazy_greedy_reference(&f, &all, k);
                for cohort in [1usize, 2, 16, 64, 1024] {
                    let mut eng = MaximizerEngine::new(&f, GainRoute::Direct).with_cohort(cohort);
                    let got = eng.lazy_greedy(&all, k);
                    assert_eq!(got.set, want.set, "seed={seed} k={k} cohort={cohort}");
                    assert_eq!(
                        got.value.to_bits(),
                        want.value.to_bits(),
                        "value must be bit-identical (same commits in the same order)"
                    );
                }
            }
        }
    }

    #[test]
    fn strictly_fewer_dispatches_than_scalar_oracle_calls() {
        let f = feature_instance(300, 8, 3);
        let all: Vec<usize> = (0..300).collect();
        let want = lazy_greedy_reference(&f, &all, 20);
        let mut eng = MaximizerEngine::new(&f, GainRoute::Direct);
        let got = eng.lazy_greedy(&all, 20);
        assert_eq!(got.set, want.set);
        // the scalar reference dispatches once per evaluation
        assert!(
            eng.stats().dispatches < want.oracle_calls,
            "cohort dispatches {} must be strictly fewer than scalar oracle calls {}",
            eng.stats().dispatches,
            want.oracle_calls
        );
        assert_eq!(eng.stats().gain_evals, got.oracle_calls);
    }

    #[test]
    fn greedy_and_stochastic_bit_identical_to_references() {
        let f = feature_instance(90, 6, 5);
        let all: Vec<usize> = (0..90).collect();
        let mut eng = MaximizerEngine::new(&f, GainRoute::Direct);
        let g_want = greedy_reference(&f, &all, 12);
        let g_got = eng.greedy(&all, 12);
        assert_eq!(g_got.set, g_want.set);
        assert_eq!(g_got.value.to_bits(), g_want.value.to_bits());
        assert_eq!(g_got.oracle_calls, g_want.oracle_calls, "same per-element eval count");
        for seed in 0..4u64 {
            let s_want = stochastic_greedy_reference(&f, &all, 9, 0.2, seed);
            let s_got = eng.stochastic_greedy(&all, 9, 0.2, seed);
            assert_eq!(s_got.set, s_want.set, "seed={seed}");
            assert_eq!(s_got.oracle_calls, s_want.oracle_calls);
        }
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let f = feature_instance(10, 4, 9);
        let mut eng = MaximizerEngine::new(&f, GainRoute::Direct);
        let s = eng.lazy_greedy(&[], 5);
        assert!(s.set.is_empty());
        assert_eq!(s.value, 0.0);
        assert_eq!(s.oracle_calls, 0);
        assert_eq!(eng.stats().dispatches, 0);
        let s = eng.lazy_greedy(&[3], 0);
        assert!(s.set.is_empty());
        let s = eng.greedy(&[], 4);
        assert!(s.set.is_empty());
    }

    #[test]
    fn interrupt_probe_lands_at_a_round_boundary() {
        let f = feature_instance(120, 8, 17);
        let all: Vec<usize> = (0..120).collect();
        let mut eng = MaximizerEngine::new(&f, GainRoute::Direct).with_cohort(4);

        // fires immediately: no dispatch happens at all
        let err = eng.lazy_greedy_with(&all, 20, &mut || Some(Interrupt::Cancelled)).unwrap_err();
        assert_eq!(err, Interrupt::Cancelled);
        assert_eq!(eng.stats().dispatches, 0);

        // fires after a fixed number of polls: the run stops mid-greedy,
        // having dispatched fewer cohorts than the full run needs
        let full = eng.lazy_greedy(&all, 20);
        let full_dispatches = eng.stats().dispatches;
        let mut polls = 0u32;
        let err = eng
            .lazy_greedy_with(&all, 20, &mut || {
                polls += 1;
                (polls > 3).then_some(Interrupt::DeadlineExceeded)
            })
            .unwrap_err();
        assert_eq!(err, Interrupt::DeadlineExceeded);
        assert!(
            eng.stats().dispatches < full_dispatches,
            "interrupted run dispatched {} of the full run's {}",
            eng.stats().dispatches,
            full_dispatches
        );

        // the engine arena stays reusable after an abandoned run
        let again = eng.lazy_greedy(&all, 20);
        assert_eq!(again.set, full.set);
        assert_eq!(again.value.to_bits(), full.value.to_bits());

        // stochastic: same contract, per sample round
        let mut polls = 0u32;
        let err = eng
            .stochastic_greedy_with(&all, 10, 0.2, 7, &mut || {
                polls += 1;
                (polls > 2).then_some(Interrupt::Cancelled)
            })
            .unwrap_err();
        assert_eq!(err, Interrupt::Cancelled);
        let s_full = eng.stochastic_greedy(&all, 10, 0.2, 7);
        let s_ref = stochastic_greedy_reference(&f, &all, 10, 0.2, 7);
        assert_eq!(s_full.set, s_ref.set, "interrupted runs must not disturb reuse");
    }

    #[test]
    fn tracing_is_inert_and_records_cohort_spans() {
        let f = feature_instance(120, 8, 21);
        let all: Vec<usize> = (0..120).collect();
        let mut plain = MaximizerEngine::new(&f, GainRoute::Direct);
        let want = plain.lazy_greedy(&all, 15);

        let tracer = Tracer::disabled();
        tracer.enable("engine-test", 256);
        let mut traced = MaximizerEngine::new(&f, GainRoute::Direct).with_tracer(&tracer);
        let got = traced.lazy_greedy(&all, 15);
        assert_eq!(got.set, want.set, "a traced run must be bit-identical");
        assert_eq!(got.value.to_bits(), want.value.to_bits());

        let evs = tracer.events();
        assert_eq!(evs.len() as u64, traced.stats().dispatches, "one span per dispatch");
        assert!(evs.iter().all(|e| e.kind == EventKind::Cohort));
        let last = evs.last().unwrap();
        assert_eq!(last.b, traced.stats().gain_evals, "running totals ride in the payload");
        assert_eq!(last.c, traced.stats().dispatches);
    }

    #[test]
    fn engine_reuse_across_runs_is_clean() {
        // arena reuse must not leak state between runs (versions, heap,
        // epoch maps are all reset)
        let f1 = feature_instance(60, 6, 11);
        let f2 = feature_instance(40, 6, 12);
        let all1: Vec<usize> = (0..60).collect();
        let all2: Vec<usize> = (0..40).collect();
        let mut eng = MaximizerEngine::new(&f1, GainRoute::Direct);
        let a1 = eng.lazy_greedy(&all1, 10);
        let a2 = eng.lazy_greedy(&all1, 10);
        assert_eq!(a1.set, a2.set, "same run twice on a reused engine");
        let mut eng2 = MaximizerEngine::new(&f2, GainRoute::Direct);
        let b_fresh = eng2.lazy_greedy(&all2, 7);
        let mut eng_smaller = MaximizerEngine::new(&f2, GainRoute::Direct);
        let _warm = eng_smaller.lazy_greedy(&all2, 3); // warm with different k
        let b_reused = eng_smaller.lazy_greedy(&all2, 7);
        assert_eq!(b_reused.set, b_fresh.set);
    }
}
