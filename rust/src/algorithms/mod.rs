//! Submodular maximization algorithms: the paper's SS pruning plus every
//! baseline its evaluation compares against.
//!
//! All maximizers share the same calling convention: a [`SubmodularFn`], a
//! slice of candidate (global) indices forming the effective ground set,
//! and a cardinality budget `k`; they return a [`Solution`] carrying the
//! chosen set, its objective value and oracle-call accounting.
//!
//! * [`greedy`] — the textbook 1−1/e greedy (Nemhauser et al.).
//! * [`lazy_greedy`] — Minoux's accelerated greedy; identical output,
//!   priority-queue laziness (the paper's main quality baseline).
//! * [`stochastic_greedy`] — "lazier than lazy greedy" (Mirzasoleiman et al.).
//!
//! The greedy family is built on the batched [`MaximizerEngine`]
//! ([`engine`]): marginal gains are evaluated in cohorts through the
//! objective's blocked kernels ([`crate::submodular::SolState::gains_into`])
//! instead of one scalar oracle call per element, bit-identically to the
//! frozen scalar references ([`lazy_greedy_reference`],
//! [`greedy_reference`], [`stochastic_greedy_reference`]).
//! * [`sieve_streaming`] — Badanidiyuru et al.'s 1/2−ε streaming algorithm
//!   (the paper's low-memory baseline).
//! * [`bidirectional_greedy`] — Buchbinder et al.'s randomized 1/2 double
//!   greedy for unconstrained non-monotone maximization (used on Eq. 9's
//!   sparsification objective, §3.4).
//! * [`wei_prune`] — the f(v|V∖v)-based safe pruning of Wei et al. [27]
//!   (§3.4's first improvement).
//! * [`ss`] — the paper's contribution: submodular sparsification
//!   (Algorithm 1) with uniform/importance sampling and optional
//!   post-reduction.
//! * [`baselines`] — random and top-k-singleton controls.

pub mod accelerated_greedy;
pub mod baselines;
pub mod conditional_ss;
pub mod constrained;
pub mod bidirectional_greedy;
pub mod engine;
pub mod greedy;
pub mod lazy_greedy;
pub mod sieve_filter;
pub mod sieve_streaming;
pub mod ss;
pub mod stochastic_greedy;
pub mod wei_prune;

pub use accelerated_greedy::accelerated_greedy;
pub use baselines::{random_subset, top_k_singleton};
pub use conditional_ss::{sparsify_conditional, ConditionalCpuBackend};
pub use constrained::{knapsack_greedy, matroid_greedy, PartitionMatroid};
pub use bidirectional_greedy::bidirectional_greedy;
pub use engine::{EngineStats, GainRoute, MaximizerEngine, DEFAULT_COHORT};
pub use greedy::{greedy, greedy_reference};
pub use lazy_greedy::{lazy_greedy, lazy_greedy_reference};
pub use sieve_filter::{SieveFilter, SieveSet};
pub use sieve_streaming::{
    sieve_streaming, sieve_streaming_with_stats, SieveParams, SieveStats,
};
pub use ss::{
    sparsify, sparsify_candidates, sparsify_candidates_reference, sparsify_candidates_traced,
    sparsify_candidates_with, sparsify_traced, sparsify_with, ss_then_greedy, CpuBackend,
    DivergenceBackend, Interrupt, Sampling, SsParams, SsResult,
};
pub use stochastic_greedy::{stochastic_greedy, stochastic_greedy_reference};
pub use wei_prune::wei_prune;

use crate::submodular::SubmodularFn;

/// Outcome of a maximization run.
#[derive(Clone, Debug)]
pub struct Solution {
    /// Selected elements (global indices), in selection order.
    pub set: Vec<usize>,
    /// Objective value f(set).
    pub value: f64,
    /// Number of marginal-gain / objective oracle calls.
    pub oracle_calls: u64,
    /// Wall-clock seconds.
    pub wall_s: f64,
}

impl Solution {
    pub fn empty() -> Self {
        Self { set: Vec::new(), value: 0.0, oracle_calls: 0, wall_s: 0.0 }
    }
}

/// Exhaustive maximum over all subsets of size ≤ k — test oracle, n ≤ ~20.
pub fn brute_force(f: &dyn SubmodularFn, candidates: &[usize], k: usize) -> Solution {
    assert!(candidates.len() <= 22, "brute force blows up beyond ~22 elements");
    let m = candidates.len();
    let mut best = Solution::empty();
    let mut calls = 0u64;
    for mask in 0u32..(1 << m) {
        if mask.count_ones() as usize > k {
            continue;
        }
        let s: Vec<usize> =
            (0..m).filter(|&i| mask >> i & 1 == 1).map(|i| candidates[i]).collect();
        let v = f.eval(&s);
        calls += 1;
        if v > best.value {
            best = Solution { set: s, value: v, oracle_calls: 0, wall_s: 0.0 };
        }
    }
    best.oracle_calls = calls;
    best
}
