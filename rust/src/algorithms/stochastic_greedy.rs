//! Stochastic greedy ("lazier than lazy greedy", Mirzasoleiman et al. 2015):
//! each step evaluates gains only on a uniform sample of size
//! `⌈(|candidates|/k)·ln(1/ε)⌉`, giving a `1 − 1/e − ε` expected guarantee
//! with `O(n log 1/ε)` total evaluations. Related-work baseline + ablation
//! partner for SS (sampling *per step* vs SS's sampling *per prune round*).
//!
//! [`stochastic_greedy`] is engine-backed: each step's probe set is one
//! batched kernel dispatch. [`stochastic_greedy_reference`] is the frozen
//! scalar loop — same RNG draw sequence (`sample_indices_into` reproduces
//! `sample_indices` draw-for-draw), same probe scan, bit-identical output.

use super::engine::{GainRoute, MaximizerEngine};
use super::Solution;
use crate::submodular::SubmodularFn;
use crate::util::rng::Rng;
use crate::util::stats::Timer;

/// Batched stochastic greedy — bit-identical to
/// [`stochastic_greedy_reference`], one kernel dispatch per step.
pub fn stochastic_greedy(
    f: &dyn SubmodularFn,
    candidates: &[usize],
    k: usize,
    eps: f64,
    seed: u64,
) -> Solution {
    MaximizerEngine::new(f, GainRoute::Direct).stochastic_greedy(candidates, k, eps, seed)
}

/// The scalar loop, frozen as the engine's bit-identity oracle and bench
/// baseline.
pub fn stochastic_greedy_reference(
    f: &dyn SubmodularFn,
    candidates: &[usize],
    k: usize,
    eps: f64,
    seed: u64,
) -> Solution {
    assert!(eps > 0.0 && eps < 1.0);
    let timer = Timer::new();
    let mut rng = Rng::new(seed);
    let mut state = f.state();
    let mut remaining: Vec<usize> = candidates.to_vec();
    let mut calls = 0u64;
    let k = k.min(remaining.len());
    let sample_size =
        (((candidates.len() as f64 / k.max(1) as f64) * (1.0 / eps).ln()).ceil() as usize).max(1);

    for _ in 0..k {
        if remaining.is_empty() {
            break;
        }
        let m = sample_size.min(remaining.len());
        let probe_pos = rng.sample_indices(remaining.len(), m);
        let mut best_pos = usize::MAX;
        let mut best_gain = f64::NEG_INFINITY;
        for &p in &probe_pos {
            let g = state.gain(remaining[p]);
            calls += 1;
            if g > best_gain {
                best_gain = g;
                best_pos = p;
            }
        }
        if best_pos == usize::MAX || best_gain <= 0.0 {
            break;
        }
        let v = remaining.swap_remove(best_pos);
        state.add(v);
    }
    Solution { set: state.set().to_vec(), value: state.value(), oracle_calls: calls, wall_s: timer.elapsed_s() }
}

#[cfg(test)]
mod tests {
    use super::super::greedy::greedy;
    use super::*;
    use crate::submodular::FeatureBased;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feature_instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.5) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn near_greedy_quality() {
        let f = feature_instance(150, 8, 1);
        let all: Vec<usize> = (0..150).collect();
        let g = greedy(&f, &all, 10);
        let s = stochastic_greedy(&f, &all, 10, 0.1, 42);
        assert_eq!(s.set.len(), 10);
        assert!(
            s.value >= 0.85 * g.value,
            "stochastic {sv} too far below greedy {gv}",
            sv = s.value,
            gv = g.value
        );
    }

    #[test]
    fn engine_backed_identical_to_scalar_reference() {
        let f = feature_instance(80, 6, 6);
        let all: Vec<usize> = (0..80).collect();
        for seed in 0..6u64 {
            for (k, eps) in [(5usize, 0.1f64), (12, 0.3), (80, 0.5)] {
                let want = stochastic_greedy_reference(&f, &all, k, eps, seed);
                let got = stochastic_greedy(&f, &all, k, eps, seed);
                assert_eq!(got.set, want.set, "seed={seed} k={k} eps={eps}");
                assert_eq!(got.value.to_bits(), want.value.to_bits());
                assert_eq!(got.oracle_calls, want.oracle_calls);
            }
        }
    }

    #[test]
    fn far_fewer_oracle_calls() {
        let f = feature_instance(400, 6, 2);
        let all: Vec<usize> = (0..400).collect();
        let g = greedy(&f, &all, 20);
        let s = stochastic_greedy(&f, &all, 20, 0.1, 7);
        assert!(s.oracle_calls < g.oracle_calls / 3);
    }

    #[test]
    fn deterministic_given_seed() {
        let f = feature_instance(60, 5, 3);
        let all: Vec<usize> = (0..60).collect();
        let a = stochastic_greedy(&f, &all, 8, 0.2, 9);
        let b = stochastic_greedy(&f, &all, 8, 0.2, 9);
        assert_eq!(a.set, b.set);
    }

    #[test]
    fn eps_one_half_still_valid_solution() {
        let f = feature_instance(40, 4, 4);
        let all: Vec<usize> = (0..40).collect();
        let s = stochastic_greedy(&f, &all, 5, 0.5, 1);
        assert_eq!(s.set.len(), 5);
        assert!((s.value - f.eval(&s.set)).abs() < 1e-6);
    }
}
