//! Sieve-streaming (Badanidiyuru et al., KDD 2014): one-pass streaming
//! submodular maximization with a `1/2 − ε` guarantee.
//!
//! A grid of thresholds `τ = (1+ε)^i` brackets the unknown optimum; each
//! threshold keeps an independent candidate set, adding a streamed element
//! when its marginal gain is at least `(τ/2 − f(S_τ)) / (k − |S_τ|)`. The
//! best thresholded set at the end wins. Memory is `O(k · #thresholds)` —
//! the paper's news experiments run it with 50 thresholds ("trials"),
//! i.e. a 50k-element memory, which [`SieveParams::paper_default`] mirrors.

use super::Solution;
use crate::submodular::{SolState, SubmodularFn};
use crate::util::stats::Timer;

#[derive(Clone, Debug)]
pub struct SieveParams {
    /// grid resolution ε (τ ratio = 1+ε)
    pub eps: f64,
    /// hard cap on live thresholds (the paper's "number of trials")
    pub max_thresholds: usize,
}

impl SieveParams {
    /// Paper configuration: 50 trials → memory 50·k.
    pub fn paper_default() -> Self {
        Self { eps: 0.08, max_thresholds: 50 }
    }
}

struct Sieve<'a> {
    state: Box<dyn SolState + 'a>,
    tau: f64,
}

pub fn sieve_streaming(
    f: &dyn SubmodularFn,
    stream: &[usize],
    k: usize,
    params: &SieveParams,
) -> Solution {
    let timer = Timer::new();
    let mut calls = 0u64;
    let mut max_singleton = 0.0f64;
    let mut sieves: Vec<Sieve> = Vec::new();
    let ratio = 1.0 + params.eps;

    // Peak memory accounting (elements resident across all sieves + the
    // max-singleton tracker) — reported via oracle_calls? No: wall_s and a
    // dedicated field would bloat Solution; expose via return set len and
    // the bench harness's own instrumentation instead.
    for &v in stream {
        let sv = f.singleton(v);
        calls += 1;
        if sv > max_singleton {
            max_singleton = sv;
            // re-grid: thresholds must cover [m, 2km]
            let lo = max_singleton;
            let hi = 2.0 * k as f64 * max_singleton;
            // keep existing sieves whose tau is still in range; spawn new taus
            sieves.retain(|s| s.tau >= lo * 0.999 && s.tau <= hi * 1.001);
            let mut tau = {
                // smallest power of ratio >= lo
                let e = (lo.ln() / ratio.ln()).ceil();
                ratio.powf(e)
            };
            while tau <= hi && sieves.len() < params.max_thresholds {
                let exists = sieves.iter().any(|s| (s.tau / tau - 1.0).abs() < 1e-9);
                if !exists {
                    sieves.push(Sieve { state: f.state(), tau });
                }
                tau *= ratio;
            }
        }
        for s in &mut sieves {
            if s.state.set().len() >= k {
                continue;
            }
            let need =
                (s.tau / 2.0 - s.state.value()) / (k - s.state.set().len()) as f64;
            let g = s.state.gain(v);
            calls += 1;
            if g >= need && g > 0.0 {
                s.state.add(v);
            }
        }
    }

    let best = sieves
        .iter()
        .max_by(|a, b| a.state.value().partial_cmp(&b.state.value()).unwrap());
    match best {
        Some(s) => Solution {
            set: s.state.set().to_vec(),
            value: s.state.value(),
            oracle_calls: calls,
            wall_s: timer.elapsed_s(),
        },
        None => Solution { set: vec![], value: 0.0, oracle_calls: calls, wall_s: timer.elapsed_s() },
    }
}

/// Peak memory (in elements) a sieve configuration can hold — the number the
/// paper quotes as "memory of 50k".
pub fn sieve_memory_elements(k: usize, params: &SieveParams) -> usize {
    params.max_thresholds * k
}

#[cfg(test)]
mod tests {
    use super::super::{brute_force, greedy::greedy};
    use super::*;
    use crate::submodular::FeatureBased;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feature_instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.5) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn half_minus_eps_guarantee_vs_brute_force() {
        for seed in 0..4 {
            let f = feature_instance(14, 4, seed);
            let all: Vec<usize> = (0..14).collect();
            let k = 4;
            let opt = brute_force(&f, &all, k);
            let s = sieve_streaming(&f, &all, k, &SieveParams { eps: 0.05, max_thresholds: 200 });
            let bound = (0.5 - 0.05) * opt.value;
            assert!(
                s.value >= bound - 1e-9,
                "seed {seed}: sieve {sv} < bound {bound}",
                sv = s.value
            );
        }
    }

    #[test]
    fn worse_than_greedy_but_not_catastrophic() {
        let f = feature_instance(200, 8, 9);
        let all: Vec<usize> = (0..200).collect();
        let g = greedy(&f, &all, 12);
        let s = sieve_streaming(&f, &all, 12, &SieveParams::paper_default());
        assert!(s.value <= g.value + 1e-9, "sieve cannot beat greedy here");
        assert!(s.value >= 0.5 * g.value, "sieve {} vs greedy {}", s.value, g.value);
    }

    #[test]
    fn budget_respected() {
        let f = feature_instance(80, 5, 2);
        let all: Vec<usize> = (0..80).collect();
        let s = sieve_streaming(&f, &all, 7, &SieveParams::paper_default());
        assert!(s.set.len() <= 7);
        assert!((s.value - f.eval(&s.set)).abs() < 1e-6);
    }

    #[test]
    fn threshold_cap_respected() {
        // With a tiny cap the algorithm still runs and returns something sane.
        let f = feature_instance(60, 4, 3);
        let all: Vec<usize> = (0..60).collect();
        let s = sieve_streaming(&f, &all, 5, &SieveParams { eps: 0.01, max_thresholds: 3 });
        assert!(!s.set.is_empty());
    }

    #[test]
    fn memory_accounting() {
        assert_eq!(sieve_memory_elements(10, &SieveParams::paper_default()), 500);
    }

    #[test]
    fn single_pass_order_sensitivity() {
        // streaming is order-dependent; both orders must still satisfy bounds
        let f = feature_instance(30, 4, 4);
        let fwd: Vec<usize> = (0..30).collect();
        let rev: Vec<usize> = (0..30).rev().collect();
        let p = SieveParams::paper_default();
        let a = sieve_streaming(&f, &fwd, 5, &p);
        let b = sieve_streaming(&f, &rev, 5, &p);
        assert!(a.value > 0.0 && b.value > 0.0);
    }
}
