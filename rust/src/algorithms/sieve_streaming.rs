//! Sieve-streaming (Badanidiyuru et al., KDD 2014): one-pass streaming
//! submodular maximization with a `1/2 − ε` guarantee.
//!
//! A grid of thresholds `τ = (1+ε)^i` brackets the unknown optimum; each
//! threshold keeps an independent candidate set, adding a streamed element
//! when its marginal gain is at least `(τ/2 − f(S_τ)) / (k − |S_τ|)`. The
//! best thresholded set at the end wins. Memory is `O(k · #thresholds)` —
//! the paper's news experiments run it with 50 thresholds ("trials"),
//! i.e. a 50k-element memory, which [`SieveParams::paper_default`] mirrors;
//! [`SieveStats::peak_resident`] reports the *measured* high-water mark.
//!
//! The threshold-grid core is the reusable incremental
//! [`SieveFilter`](super::sieve_filter::SieveFilter) — the same grid
//! gates arrivals into [`crate::stream::StreamSession`]'s candidate
//! buffer.

use super::sieve_filter::{SieveFilter, SieveSet};
use super::Solution;
use crate::submodular::{SolState, SubmodularFn};
use crate::util::stats::Timer;

// The grid parameters moved to the reusable filter core with the
// refactor; re-exported here so every pre-refactor path keeps working.
pub use super::sieve_filter::SieveParams;

/// Measured memory behavior of one sieve run: the quantity the paper
/// quotes as "memory of 50k", observed rather than bounded.
#[derive(Clone, Copy, Debug, Default)]
pub struct SieveStats {
    /// High-water mark of elements resident across all threshold sets —
    /// always ≤ [`sieve_memory_elements`] (the 50·k bound), usually far
    /// below it because most thresholds never fill.
    pub peak_resident: usize,
    /// Threshold sets live at the end of the stream.
    pub thresholds_live: usize,
}

/// Per-threshold candidate set of the batch algorithm: an incremental
/// [`SolState`] (the filter core only needs size and value; gains flow
/// through the `offer` closures so oracle accounting stays caller-side).
struct SolSieve<'a>(Box<dyn SolState + 'a>);

impl SieveSet for SolSieve<'_> {
    fn len(&self) -> usize {
        self.0.set().len()
    }
    fn value(&self) -> f64 {
        self.0.value()
    }
}

pub fn sieve_streaming(
    f: &dyn SubmodularFn,
    stream: &[usize],
    k: usize,
    params: &SieveParams,
) -> Solution {
    sieve_streaming_with_stats(f, stream, k, params).0
}

/// [`sieve_streaming`] plus measured memory stats. The threshold-grid
/// logic lives in the reusable incremental [`SieveFilter`] (shared with
/// the streaming session's admission stage); this driver supplies the
/// per-threshold [`SolState`]s and the oracle-call metering the batch
/// algorithm reports.
pub fn sieve_streaming_with_stats(
    f: &dyn SubmodularFn,
    stream: &[usize],
    k: usize,
    params: &SieveParams,
) -> (Solution, SieveStats) {
    let timer = Timer::new();
    let mut calls = 0u64;
    let mut filter: SieveFilter<SolSieve> = SieveFilter::new(k, params);

    for &v in stream {
        let sv = f.singleton(v);
        calls += 1;
        filter.observe(sv, || SolSieve(f.state()));
        filter.offer(
            |s| {
                calls += 1;
                s.0.gain(v)
            },
            |s, _gain| s.0.add(v),
        );
    }

    let stats =
        SieveStats { peak_resident: filter.peak_resident(), thresholds_live: filter.thresholds() };
    let sol = match filter.best() {
        Some(s) => Solution {
            set: s.0.set().to_vec(),
            value: s.0.value(),
            oracle_calls: calls,
            wall_s: timer.elapsed_s(),
        },
        None => Solution { set: vec![], value: 0.0, oracle_calls: calls, wall_s: timer.elapsed_s() },
    };
    (sol, stats)
}

/// Peak memory (in elements) a sieve configuration can hold — the number the
/// paper quotes as "memory of 50k".
pub fn sieve_memory_elements(k: usize, params: &SieveParams) -> usize {
    params.max_thresholds * k
}

#[cfg(test)]
mod tests {
    use super::super::{brute_force, greedy::greedy};
    use super::*;
    use crate::submodular::FeatureBased;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feature_instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.5) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn half_minus_eps_guarantee_vs_brute_force() {
        for seed in 0..4 {
            let f = feature_instance(14, 4, seed);
            let all: Vec<usize> = (0..14).collect();
            let k = 4;
            let opt = brute_force(&f, &all, k);
            let s = sieve_streaming(&f, &all, k, &SieveParams { eps: 0.05, max_thresholds: 200 });
            let bound = (0.5 - 0.05) * opt.value;
            assert!(
                s.value >= bound - 1e-9,
                "seed {seed}: sieve {sv} < bound {bound}",
                sv = s.value
            );
        }
    }

    #[test]
    fn worse_than_greedy_but_not_catastrophic() {
        let f = feature_instance(200, 8, 9);
        let all: Vec<usize> = (0..200).collect();
        let g = greedy(&f, &all, 12);
        let s = sieve_streaming(&f, &all, 12, &SieveParams::paper_default());
        assert!(s.value <= g.value + 1e-9, "sieve cannot beat greedy here");
        assert!(s.value >= 0.5 * g.value, "sieve {} vs greedy {}", s.value, g.value);
    }

    #[test]
    fn budget_respected() {
        let f = feature_instance(80, 5, 2);
        let all: Vec<usize> = (0..80).collect();
        let s = sieve_streaming(&f, &all, 7, &SieveParams::paper_default());
        assert!(s.set.len() <= 7);
        assert!((s.value - f.eval(&s.set)).abs() < 1e-6);
    }

    #[test]
    fn threshold_cap_respected() {
        // With a tiny cap the algorithm still runs and returns something sane.
        let f = feature_instance(60, 4, 3);
        let all: Vec<usize> = (0..60).collect();
        let s = sieve_streaming(&f, &all, 5, &SieveParams { eps: 0.01, max_thresholds: 3 });
        assert!(!s.set.is_empty());
    }

    #[test]
    fn memory_accounting() {
        assert_eq!(sieve_memory_elements(10, &SieveParams::paper_default()), 500);
    }

    #[test]
    fn zero_budget_returns_empty_solution() {
        // pre-refactor behavior, preserved through the SieveFilter core:
        // k = 0 spawns no sieves and returns an empty solution after one
        // singleton evaluation per streamed element
        let f = feature_instance(30, 4, 6);
        let all: Vec<usize> = (0..30).collect();
        let s = sieve_streaming(&f, &all, 0, &SieveParams::paper_default());
        assert!(s.set.is_empty());
        assert_eq!(s.value, 0.0);
        assert_eq!(s.oracle_calls, 30);
    }

    #[test]
    fn peak_resident_measured_and_within_doc_bound() {
        // the doc claim: 50 trials ⇒ memory ≤ 50·k elements. peak_resident
        // is the *measured* high-water mark and must respect the bound —
        // and actually mean something (> 0, ≥ the winning set's size).
        let f = feature_instance(300, 8, 12);
        let all: Vec<usize> = (0..300).collect();
        let k = 9;
        let p = SieveParams::paper_default();
        let (sol, stats) = sieve_streaming_with_stats(&f, &all, k, &p);
        assert!(stats.peak_resident > 0);
        assert!(
            stats.peak_resident <= sieve_memory_elements(k, &p),
            "peak resident {} exceeds the documented 50·k = {} bound",
            stats.peak_resident,
            sieve_memory_elements(k, &p)
        );
        assert!(stats.peak_resident >= sol.set.len(), "the winner was resident");
        assert!(stats.thresholds_live <= p.max_thresholds);
        // the wrapper returns the identical solution
        let plain = sieve_streaming(&f, &all, k, &p);
        assert_eq!(plain.set, sol.set);
        assert_eq!(plain.value.to_bits(), sol.value.to_bits());
        assert_eq!(plain.oracle_calls, sol.oracle_calls);
    }

    #[test]
    fn single_pass_order_sensitivity() {
        // streaming is order-dependent; both orders must still satisfy bounds
        let f = feature_instance(30, 4, 4);
        let fwd: Vec<usize> = (0..30).collect();
        let rev: Vec<usize> = (0..30).rev().collect();
        let p = SieveParams::paper_default();
        let a = sieve_streaming(&f, &fwd, 5, &p);
        let b = sieve_streaming(&f, &rev, 5, &p);
        assert!(a.value > 0.0 && b.value > 0.0);
    }
}
