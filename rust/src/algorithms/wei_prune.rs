//! Safe pruning of Wei, Iyer & Bilmes (ICML 2014), cited as [27] in the
//! paper and applied as §3.4's first improvement (a pre-pass before SS).
//!
//! Rationale: `f(v|V∖v) ≤ f(v|S)` for any `S ⊆ V∖v` (submodularity), so if
//! the singleton value `f(u)` — an upper bound on u's gain at any point —
//! is below the k-th largest lower bound `f(v|V∖v)`, element u can never be
//! selected by greedy and is *safe* to remove (greedy output unchanged).

use crate::submodular::SubmodularFn;
use crate::util::select::top_k_desc;

/// Returns the surviving candidate indices (a subset of `candidates`),
/// preserving order. `sing` may be passed in when already computed (the SS
/// pipeline shares it); otherwise it is computed here.
pub fn wei_prune(
    f: &dyn SubmodularFn,
    candidates: &[usize],
    k: usize,
    sing: Option<&[f64]>,
) -> Vec<usize> {
    if candidates.len() <= k {
        return candidates.to_vec();
    }
    let owned;
    let sing = match sing {
        Some(s) => s,
        None => {
            owned = f.singleton_complements();
            &owned
        }
    };
    // k-th largest f(v|V\v) among candidates
    let keys: Vec<f32> = candidates.iter().map(|&v| sing[v] as f32).collect();
    let top = top_k_desc(&keys, k);
    let threshold = top.iter().map(|&i| keys[i]).fold(f32::INFINITY, f32::min) as f64;
    candidates
        .iter()
        .copied()
        .filter(|&u| f.singleton(u) >= threshold)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::greedy::greedy;
    use super::*;
    use crate::submodular::FeatureBased;
    use crate::util::prop::check_seeded;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feature_instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn pruning_is_safe_for_greedy() {
        // Wei et al.'s guarantee: greedy output is *unchanged* by the prune.
        check_seeded(700, 20, |g| {
            let n = g.usize_in(8, 40);
            let k = g.usize_in(1, 6);
            let f = feature_instance(n, 5, g.usize_in(0, 1 << 30) as u64);
            let all: Vec<usize> = (0..n).collect();
            let pruned = wei_prune(&f, &all, k, None);
            assert!(pruned.len() >= k.min(n));
            let a = greedy(&f, &all, k);
            let b = greedy(&f, &pruned, k);
            assert!(
                (a.value - b.value).abs() < 1e-9,
                "greedy value changed after safe prune: {} vs {} (n={n}, k={k})",
                a.value,
                b.value
            );
        });
    }

    #[test]
    fn keeps_everything_when_k_ge_n() {
        let f = feature_instance(6, 3, 1);
        let all: Vec<usize> = (0..6).collect();
        assert_eq!(wei_prune(&f, &all, 6, None), all);
        assert_eq!(wei_prune(&f, &all, 10, None), all);
    }

    #[test]
    fn prunes_dominated_duplicates() {
        // near-duplicate heavy items + weak items: weak ones get pruned
        let mut m = FeatureMatrix::zeros(6, 3);
        for i in 0..3 {
            m.row_mut(i).copy_from_slice(&[5.0, 5.0, 5.0]); // strong triplets
        }
        for i in 3..6 {
            m.row_mut(i).copy_from_slice(&[0.01, 0.0, 0.0]); // negligible
        }
        let f = FeatureBased::sqrt(m);
        let pruned = wei_prune(&f, &(0..6).collect::<Vec<_>>(), 2, None);
        assert!(pruned.iter().all(|&v| v < 3), "weak items must be pruned: {pruned:?}");
    }
}
