//! Reusable sieve-streaming threshold grid (Badanidiyuru et al., KDD 2014),
//! factored out of [`sieve_streaming`] so it can run **incrementally** —
//! one element at a time, over an unbounded stream — instead of over a
//! fully materialized `&[usize]` slice.
//!
//! The grid logic is unchanged from the batch algorithm: thresholds
//! `τ = (1+ε)^i` bracket the unknown optimum over `[m, 2km]` (m = best
//! singleton seen so far); on a new max singleton the ladder re-grids
//! (out-of-range sieves dropped, fresh ones spawned up to the trial cap);
//! an element is admitted by a sieve when its marginal gain clears
//! `(τ/2 − f(S_τ)) / (k − |S_τ|)`. What *is* new is the shape: the filter
//! is generic over the per-threshold candidate-set state [`SieveSet`], so
//!
//! * the batch [`sieve_streaming`] instantiates it with a boxed
//!   [`SolState`](crate::submodular::SolState) per threshold (exact
//!   pre-refactor behavior, including oracle accounting), and
//! * the streaming session instantiates it with a plain coverage vector
//!   per threshold and offers raw *feature rows* — elements are screened
//!   **before** their storage is admitted, which is what makes the filter
//!   usable as an ingestion gate.
//!
//! The filter also tracks what the batch code only mused about in a
//! comment: `resident` (elements currently held across all sieves) and
//! its high-water mark [`peak_resident`](SieveFilter::peak_resident),
//! the number the paper quotes as "memory of 50k".
//!
//! This module lives in the *algorithm* layer (it is a plain algorithm
//! with no stream-specific state) and depends on nothing above it; the
//! streaming subsystem re-exports it, keeping the `algorithms ← stream`
//! dependency one-directional.
//!
//! [`sieve_streaming`]: crate::algorithms::sieve_streaming

/// Sieve threshold-grid parameters — shared by the batch
/// [`sieve_streaming`] algorithm (which re-exports this type at its
/// pre-refactor path) and the streaming admission filter. Defined here so
/// the grid core depends on nothing above it.
///
/// [`sieve_streaming`]: crate::algorithms::sieve_streaming
#[derive(Clone, Debug)]
pub struct SieveParams {
    /// grid resolution ε (τ ratio = 1+ε)
    pub eps: f64,
    /// hard cap on live thresholds (the paper's "number of trials")
    pub max_thresholds: usize,
}

impl SieveParams {
    /// Paper configuration: 50 trials → memory 50·k.
    pub fn paper_default() -> Self {
        Self { eps: 0.08, max_thresholds: 50 }
    }
}

/// Per-threshold candidate-set state. Implementations carry whatever makes
/// `gain` cheap for their objective (an incremental [`SolState`] for the
/// batch path, a coverage vector for the streaming feature path); the
/// filter itself only needs the set size and current value to evaluate the
/// admission threshold.
///
/// [`SolState`]: crate::submodular::SolState
pub trait SieveSet {
    /// `|S_τ|` — elements admitted by this threshold so far.
    fn len(&self) -> usize;
    /// `f(S_τ)`.
    fn value(&self) -> f64;
}

/// Incremental sieve-streaming admission filter: the τ ladder plus one
/// [`SieveSet`] per live threshold.
pub struct SieveFilter<S> {
    k: usize,
    ratio: f64,
    max_thresholds: usize,
    max_singleton: f64,
    sieves: Vec<(f64, S)>,
    resident: usize,
    peak_resident: usize,
}

impl<S: SieveSet> SieveFilter<S> {
    /// `k = 0` yields an inert grid — `hi = 2km = 0` keeps the τ range
    /// empty, so no sieve ever spawns and nothing is admitted, matching
    /// the pre-refactor batch loop's degenerate behavior (an empty
    /// solution, one singleton evaluation per element).
    pub fn new(k: usize, params: &SieveParams) -> Self {
        assert!(params.eps > 0.0);
        Self {
            k,
            ratio: 1.0 + params.eps,
            max_thresholds: params.max_thresholds,
            max_singleton: 0.0,
            sieves: Vec::new(),
            resident: 0,
            peak_resident: 0,
        }
    }

    /// Threshold-grid maintenance — call once per arriving element, with
    /// its singleton value, *before* [`offer`](Self::offer). When `sv` is a
    /// new maximum the ladder re-grids to cover `[m, 2km]`: sieves whose τ
    /// left the range are dropped, missing rungs are spawned via `fresh`
    /// (an empty candidate set), up to the trial cap. Returns whether the
    /// grid changed — the only step that may allocate; between re-grids the
    /// filter is allocation-free.
    pub fn observe(&mut self, sv: f64, mut fresh: impl FnMut() -> S) -> bool {
        if !(sv > self.max_singleton) {
            return false;
        }
        self.max_singleton = sv;
        // re-grid: thresholds must cover [m, 2km]
        let lo = self.max_singleton;
        let hi = 2.0 * self.k as f64 * self.max_singleton;
        // keep existing sieves whose tau is still in range; spawn new taus
        self.sieves.retain(|(tau, _)| *tau >= lo * 0.999 && *tau <= hi * 1.001);
        let mut tau = {
            // smallest power of ratio >= lo
            let e = (lo.ln() / self.ratio.ln()).ceil();
            self.ratio.powf(e)
        };
        while tau <= hi && self.sieves.len() < self.max_thresholds {
            let exists = self.sieves.iter().any(|(t, _)| (t / tau - 1.0).abs() < 1e-9);
            if !exists {
                self.sieves.push((tau, fresh()));
            }
            tau *= self.ratio;
        }
        self.resident = self.sieves.iter().map(|(_, s)| s.len()).sum();
        true
    }

    /// Offer the current element to every under-budget sieve: `gain`
    /// evaluates its marginal gain against a sieve's candidate set (called
    /// exactly once per attempted sieve — the caller meters oracle calls
    /// there), `add` commits it where the gain clears the admission
    /// threshold and receives that accepted gain (so states that fold the
    /// value incrementally don't need a side channel). Returns whether
    /// **any** sieve admitted the element — the streaming session's signal
    /// that the element enters the candidate buffer at all.
    pub fn offer(
        &mut self,
        mut gain: impl FnMut(&S) -> f64,
        mut add: impl FnMut(&mut S, f64),
    ) -> bool {
        let mut admitted = false;
        for (tau, s) in &mut self.sieves {
            if s.len() >= self.k {
                continue;
            }
            let need = (*tau / 2.0 - s.value()) / (self.k - s.len()) as f64;
            let g = gain(s);
            if g >= need && g > 0.0 {
                add(s, g);
                self.resident += 1;
                admitted = true;
            }
        }
        if self.resident > self.peak_resident {
            self.peak_resident = self.resident;
        }
        admitted
    }

    /// The best thresholded candidate set so far (max `f(S_τ)`).
    pub fn best(&self) -> Option<&S> {
        self.sieves
            .iter()
            .max_by(|a, b| a.1.value().partial_cmp(&b.1.value()).unwrap())
            .map(|(_, s)| s)
    }

    /// Elements currently resident across all sieves.
    pub fn resident(&self) -> usize {
        self.resident
    }

    /// High-water mark of [`resident`](Self::resident) — bounded by
    /// `max_thresholds · k` ("memory of 50k" in the paper's configuration).
    pub fn peak_resident(&self) -> usize {
        self.peak_resident
    }

    /// Live thresholds.
    pub fn thresholds(&self) -> usize {
        self.sieves.len()
    }

    /// Largest singleton value observed.
    pub fn max_singleton(&self) -> f64 {
        self.max_singleton
    }

    /// Borrow the τ ladder and its per-threshold states — the durable
    /// state a checkpoint must carry (thresholds and candidate sets are
    /// stream history, not recomputable from retained storage alone).
    pub fn sieves(&self) -> &[(f64, S)] {
        &self.sieves
    }

    /// Rebuild a filter from checkpointed state. `resident` is a pure
    /// function of the sieve states and is recomputed; `peak_resident`
    /// is a high-water mark that must be restored verbatim (recovery
    /// would otherwise under-report the paper's "memory of 50k" figure).
    pub fn restore(
        k: usize,
        params: &SieveParams,
        max_singleton: f64,
        peak_resident: usize,
        sieves: Vec<(f64, S)>,
    ) -> Self {
        assert!(params.eps > 0.0);
        let resident = sieves.iter().map(|(_, s)| s.len()).sum();
        Self {
            k,
            ratio: 1.0 + params.eps,
            max_thresholds: params.max_thresholds,
            max_singleton,
            sieves,
            resident,
            peak_resident: peak_resident.max(resident),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal modular sieve state: value = sum of admitted weights.
    struct ModSet {
        total: f64,
        n: usize,
    }

    impl SieveSet for ModSet {
        fn len(&self) -> usize {
            self.n
        }
        fn value(&self) -> f64 {
            self.total
        }
    }

    #[test]
    fn grid_covers_range_and_respects_cap() {
        let p = SieveParams { eps: 0.05, max_thresholds: 500 };
        let mut f: SieveFilter<ModSet> = SieveFilter::new(4, &p);
        assert!(f.observe(1.0, || ModSet { total: 0.0, n: 0 }));
        // ladder must cover [1, 8] at ratio 1.05
        assert!(f.thresholds() > 0);
        let needed = ((8.0f64).ln() / (1.05f64).ln()).ceil() as usize;
        assert!(f.thresholds() >= needed, "{} < {needed}", f.thresholds());
        // no re-grid on a smaller singleton
        assert!(!f.observe(0.5, || unreachable!("no spawn without a new max")));
        // capped configuration stays capped
        let mut capped: SieveFilter<ModSet> = SieveFilter::new(4, &SieveParams {
            eps: 0.01,
            max_thresholds: 3,
        });
        capped.observe(1.0, || ModSet { total: 0.0, n: 0 });
        assert_eq!(capped.thresholds(), 3);
    }

    #[test]
    fn admission_thresholds_and_peak_resident() {
        let p = SieveParams { eps: 0.5, max_thresholds: 8 };
        let k = 2;
        let mut f: SieveFilter<ModSet> = SieveFilter::new(k, &p);
        let mut admitted_total = 0usize;
        for &w in &[1.0f64, 0.9, 0.8, 0.05, 1.0, 0.7] {
            f.observe(w, || ModSet { total: 0.0, n: 0 });
            let any = f.offer(
                |_s| w,
                |s, g| {
                    s.total += g;
                    s.n += 1;
                },
            );
            if any {
                admitted_total += 1;
            }
        }
        assert!(admitted_total >= 1);
        assert!(f.peak_resident() >= f.resident());
        assert!(f.peak_resident() <= p.max_thresholds * k);
        let best = f.best().unwrap();
        assert!(best.value() > 0.0);
        assert!(best.len() <= k);
    }

    #[test]
    fn zero_budget_grid_is_inert() {
        // pre-refactor batch behavior: k = 0 spawns no sieves, admits
        // nothing, panics nowhere
        let mut f: SieveFilter<ModSet> = SieveFilter::new(0, &SieveParams::paper_default());
        assert!(f.observe(1.0, || unreachable!("hi = 0 must spawn nothing")));
        assert_eq!(f.thresholds(), 0);
        assert!(!f.offer(|_| 1.0, |_, _| panic!("nothing to admit into")));
        assert!(f.best().is_none());
        assert_eq!(f.peak_resident(), 0);
    }

    #[test]
    fn regrid_drops_out_of_range_sieves() {
        let p = SieveParams { eps: 0.08, max_thresholds: 50 };
        let mut f: SieveFilter<ModSet> = SieveFilter::new(3, &p);
        f.observe(0.001, || ModSet { total: 0.0, n: 0 });
        let small_grid = f.thresholds();
        assert!(small_grid > 0);
        // a 1000× larger singleton moves [m, 2km] entirely: the old rungs
        // all fall out of range and the resident count resets with them
        f.observe(1.0, || ModSet { total: 0.0, n: 0 });
        assert!(f.max_singleton() == 1.0);
        assert!(f.thresholds() > 0);
        for (tau, _) in f.sieves.iter() {
            assert!(*tau >= 1.0 * 0.999 && *tau <= 6.0 * 1.001);
        }
    }
}
