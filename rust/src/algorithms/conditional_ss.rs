//! Conditional submodular sparsification: Algorithm 1 over the
//! *conditional* submodularity graph `G(V, E|S)` (paper Eq. 4 and §3:
//! "SS can be easily extended to G(V,E|S)").
//!
//! Given a partial solution `S` (e.g. a summary that must keep yesterday's
//! picks, or an interactive session where a user pinned items), the edge
//! weight becomes `w_{uv|S} = f(v|S+u) − f(u|V∖u)`. By Lemma 1 the
//! conditional weights only shrink (`w_{uv|S} ≤ w_{uv}`), so conditioning
//! prunes *more aggressively* while Lemma 2's loss bound still holds
//! relative to gains conditioned on S — exactly what an incremental
//! summarization pipeline wants.

use crate::submodular::SubmodularFn;
use crate::util::rng::Rng;
use crate::util::select::partition_smallest;
use crate::util::stats::Timer;

use super::ss::{SsParams, SsResult};

/// Conditional-divergence backend over any [`SubmodularFn`]: computes
/// `w_{U,v|S} = min_u [f(v|S+u) − f(u|V∖u)]` with an incremental context
/// state for `S`.
pub struct ConditionalCpuBackend<'a> {
    f: &'a dyn SubmodularFn,
    sing: Vec<f64>,
    /// the conditioning set S
    context: Vec<usize>,
    /// f(S) cached
    f_s: f64,
}

impl<'a> ConditionalCpuBackend<'a> {
    pub fn new(f: &'a dyn SubmodularFn, context: &[usize]) -> Self {
        let sing = f.singleton_complements();
        let f_s = f.eval(context);
        Self { f, sing, context: context.to_vec(), f_s }
    }

    /// `w_{uv|S} = f(v|S+u) − f(u|V∖u)`.
    pub fn weight(&self, u: usize, v: usize) -> f64 {
        let mut su = self.context.clone();
        su.push(u);
        let f_su = self.f.eval(&su);
        su.push(v);
        let f_suv = self.f.eval(&su);
        (f_suv - f_su) - self.sing[u]
    }

    fn divergences(&self, probes: &[usize], items: &[usize]) -> Vec<f32> {
        // One pass per probe, reusing f(S+u) across all items.
        let mut best = vec![f32::INFINITY; items.len()];
        let mut su = self.context.clone();
        for &u in probes {
            su.push(u);
            let f_su = self.f.eval(&su);
            for (i, &v) in items.iter().enumerate() {
                su.push(v);
                let w = ((self.f.eval(&su) - f_su) - self.sing[u]) as f32;
                su.pop();
                if w < best[i] {
                    best[i] = w;
                }
            }
            su.pop();
        }
        let _ = self.f_s;
        best
    }
}

/// Algorithm 1 on `G(V, E|S)`: prune `candidates ∖ S`, keeping `S` pinned
/// in the output.
pub fn sparsify_conditional(
    backend: &ConditionalCpuBackend,
    candidates: &[usize],
    params: &SsParams,
) -> SsResult {
    let timer = Timer::new();
    let mut rng = Rng::new(params.seed);
    let context: std::collections::HashSet<usize> =
        backend.context.iter().copied().collect();
    let mut live: Vec<usize> =
        candidates.iter().copied().filter(|v| !context.contains(v)).collect();
    let n0 = live.len();
    let mut kept: Vec<usize> = backend.context.clone();

    let probes_per_round =
        ((params.r as f64) * (n0.max(2) as f64).log2()).ceil().max(1.0) as usize;
    let keep_frac = 1.0 / params.c.sqrt();
    let mut rounds = 0usize;
    let mut divergence_evals = 0u64;
    let mut pruned_max = f64::NEG_INFINITY;

    while live.len() > probes_per_round {
        rounds += 1;
        let pos = rng.sample_indices(live.len(), probes_per_round);
        let mut probes = Vec::with_capacity(pos.len());
        for &p in pos.iter().rev() {
            probes.push(live.swap_remove(p));
        }
        kept.extend_from_slice(&probes);
        if live.is_empty() {
            break;
        }
        let w = backend.divergences(&probes, &live);
        divergence_evals += (probes.len() * live.len()) as u64;
        let keep_count = ((live.len() as f64) * keep_frac).floor() as usize;
        let drop_count = live.len() - keep_count;
        if drop_count == 0 {
            break;
        }
        let drop_pos = partition_smallest(&w, drop_count);
        let mut dropped = vec![false; live.len()];
        for &p in &drop_pos {
            dropped[p] = true;
            pruned_max = pruned_max.max(w[p] as f64);
        }
        live = live
            .iter()
            .enumerate()
            .filter(|(i, _)| !dropped[*i])
            .map(|(_, &v)| v)
            .collect();
    }
    kept.extend_from_slice(&live);
    kept.sort_unstable();
    kept.dedup();
    SsResult {
        kept,
        rounds,
        probes_per_round,
        divergence_evals,
        pruned_max_divergence: if pruned_max.is_finite() { pruned_max } else { 0.0 },
        wall_s: timer.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::{lazy_greedy, sparsify, CpuBackend};
    use super::*;
    use crate::submodular::FeatureBased;
    use crate::util::rng::Rng as URng;
    use crate::util::vecmath::FeatureMatrix;

    fn instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = URng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn context_is_pinned_in_output() {
        let f = instance(300, 8, 1);
        let context = vec![5usize, 17, 200];
        let backend = ConditionalCpuBackend::new(&f, &context);
        let all: Vec<usize> = (0..300).collect();
        let res = sparsify_conditional(&backend, &all, &SsParams::default().with_seed(2));
        for c in &context {
            assert!(res.kept.contains(c), "context element {c} must survive");
        }
        assert!(res.kept.len() < 300);
    }

    #[test]
    fn conditional_weights_below_unconditional() {
        // Lemma 1: conditioning only shrinks weights
        let f = instance(40, 6, 2);
        let uncond = ConditionalCpuBackend::new(&f, &[]);
        let cond = ConditionalCpuBackend::new(&f, &[0, 1, 2, 3, 4]);
        for u in 10..14 {
            for v in 20..24 {
                assert!(
                    cond.weight(u, v) <= uncond.weight(u, v) + 1e-6,
                    "w({u},{v}|S) > w({u},{v})"
                );
            }
        }
    }

    #[test]
    fn empty_context_matches_plain_ss() {
        let f = instance(250, 8, 3);
        let cond_backend = ConditionalCpuBackend::new(&f, &[]);
        let plain_backend = CpuBackend::new(&f);
        let p = SsParams::default().with_seed(7);
        let all: Vec<usize> = (0..250).collect();
        let a = sparsify_conditional(&cond_backend, &all, &p);
        let b = sparsify(&plain_backend, &p);
        assert_eq!(a.kept, b.kept, "S=∅ must reduce to Algorithm 1");
    }

    #[test]
    fn incremental_summarization_quality() {
        // pin a partial summary, sparsify conditionally, extend greedily —
        // quality vs unconstrained-greedy-from-scratch should stay high
        let f = instance(400, 10, 4);
        let all: Vec<usize> = (0..400).collect();
        let base = lazy_greedy(&f, &all, 4);
        let backend = ConditionalCpuBackend::new(&f, &base.set);
        let res = sparsify_conditional(&backend, &all, &SsParams::default().with_seed(5));
        let extended = lazy_greedy(&f, &res.kept, 12);
        let fresh = lazy_greedy(&f, &all, 12);
        assert!(
            extended.value / fresh.value > 0.9,
            "conditional pipeline rel-utility {}",
            extended.value / fresh.value
        );
    }
}
