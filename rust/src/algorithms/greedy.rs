//! Naive greedy (Nemhauser–Wolsey–Fisher): at each of `k` steps, add the
//! candidate with the largest marginal gain. `O(k·|candidates|)` gain
//! evaluations; the 1−1/e guarantee holds for monotone f.
//!
//! [`greedy`] is engine-backed: the per-step gain sweep is one batched
//! kernel dispatch instead of `|remaining|` scalar oracle calls.
//! [`greedy_reference`] is the frozen scalar loop — the bit-identity
//! oracle (same strict-`>` first-maximal selection over the same
//! `swap_remove` candidate order).

use super::engine::{GainRoute, MaximizerEngine};
use super::Solution;
use crate::submodular::SubmodularFn;
use crate::util::stats::Timer;

/// Batched naive greedy — bit-identical to [`greedy_reference`], one
/// kernel dispatch per commit.
pub fn greedy(f: &dyn SubmodularFn, candidates: &[usize], k: usize) -> Solution {
    MaximizerEngine::new(f, GainRoute::Direct).greedy(candidates, k)
}

/// The scalar loop, frozen as the engine's bit-identity oracle and bench
/// baseline.
pub fn greedy_reference(f: &dyn SubmodularFn, candidates: &[usize], k: usize) -> Solution {
    let timer = Timer::new();
    let mut state = f.state();
    let mut remaining: Vec<usize> = candidates.to_vec();
    let mut calls = 0u64;
    let k = k.min(remaining.len());
    for _ in 0..k {
        let mut best_i = usize::MAX;
        let mut best_gain = f64::NEG_INFINITY;
        for (i, &v) in remaining.iter().enumerate() {
            let g = state.gain(v);
            calls += 1;
            // deterministic tie-break on index keeps greedy == lazy_greedy
            if g > best_gain {
                best_gain = g;
                best_i = i;
            }
        }
        if best_i == usize::MAX || best_gain <= 0.0 {
            // monotone f never hits this; non-monotone stops early
            break;
        }
        let v = remaining.swap_remove(best_i);
        state.add(v);
    }
    Solution { set: state.set().to_vec(), value: state.value(), oracle_calls: calls, wall_s: timer.elapsed_s() }
}

#[cfg(test)]
mod tests {
    use super::super::brute_force;
    use super::*;
    use crate::submodular::{FeatureBased, Modular, SetCover};
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    pub(crate) fn feature_instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.5) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn modular_greedy_is_exact_topk() {
        let w = vec![5.0, 1.0, 4.0, 2.0, 3.0];
        let f = Modular::new(w);
        let all: Vec<usize> = (0..5).collect();
        let s = greedy(&f, &all, 3);
        let mut set = s.set.clone();
        set.sort_unstable();
        assert_eq!(set, vec![0, 2, 4]);
        assert!((s.value - 12.0).abs() < 1e-9);
    }

    #[test]
    fn engine_backed_identical_to_scalar_reference() {
        // incl. exact ties (modular duplicates): the strict-> scan and
        // swap_remove order must resolve them identically
        let f = Modular::new(vec![2.0, 5.0, 5.0, 1.0, 5.0, 2.0]);
        let all: Vec<usize> = (0..6).collect();
        for k in 1..=6 {
            let want = greedy_reference(&f, &all, k);
            let got = greedy(&f, &all, k);
            assert_eq!(got.set, want.set, "k={k}: tie resolution diverged");
            assert_eq!(got.oracle_calls, want.oracle_calls);
        }
        let f = feature_instance(40, 6, 8);
        let all: Vec<usize> = (0..40).collect();
        let want = greedy_reference(&f, &all, 9);
        let got = greedy(&f, &all, 9);
        assert_eq!(got.set, want.set);
        assert_eq!(got.value.to_bits(), want.value.to_bits());
    }

    #[test]
    fn respects_candidate_restriction() {
        let f = feature_instance(20, 5, 1);
        let cands = vec![3, 7, 11, 15];
        let s = greedy(&f, &cands, 2);
        assert!(s.set.iter().all(|v| cands.contains(v)));
        assert_eq!(s.set.len(), 2);
    }

    #[test]
    fn achieves_1_minus_1_over_e_vs_brute_force() {
        for seed in 0..5 {
            let f = feature_instance(12, 4, seed);
            let all: Vec<usize> = (0..12).collect();
            let k = 4;
            let opt = brute_force(&f, &all, k);
            let g = greedy(&f, &all, k);
            let bound = (1.0 - (-1.0f64).exp()) * opt.value;
            assert!(
                g.value >= bound - 1e-9,
                "seed {seed}: greedy {g} < bound {bound} (opt {o})",
                g = g.value,
                o = opt.value
            );
        }
    }

    #[test]
    fn value_matches_eval_of_set() {
        let f = feature_instance(15, 6, 2);
        let all: Vec<usize> = (0..15).collect();
        let s = greedy(&f, &all, 6);
        assert!((s.value - f.eval(&s.set)).abs() < 1e-6);
    }

    #[test]
    fn budget_larger_than_ground_set() {
        let f = SetCover::unit(vec![vec![0], vec![1], vec![0, 1]], 2);
        let s = greedy(&f, &[0, 1, 2], 10);
        assert!(s.set.len() <= 3);
        assert!((s.value - 2.0).abs() < 1e-9);
    }

    #[test]
    fn oracle_call_count_is_nk_shaped() {
        let f = feature_instance(30, 4, 3);
        let all: Vec<usize> = (0..30).collect();
        let s = greedy(&f, &all, 5);
        // sum_{i=0..4} (30 - i) = 140 — the engine counts per-element
        // evaluations in the same unit as the scalar reference
        assert_eq!(s.oracle_calls, 30 + 29 + 28 + 27 + 26);
    }
}
