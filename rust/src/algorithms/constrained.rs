//! Constrained maximization beyond cardinality: knapsack and partition
//! matroid. The paper (§3.3 Remarks) notes SS applies *before any*
//! constrained algorithm since Lemmas 1–3 only need submodularity +
//! non-negativity; these maximizers let the ablation bench demonstrate
//! that composition.

use super::Solution;
use crate::submodular::SubmodularFn;
use crate::util::stats::Timer;

/// Cost-benefit greedy for a knapsack constraint `Σ cost(v) ≤ budget`
/// (Leskovec et al.'s CELF-style ratio rule + best-singleton fallback,
/// giving the standard (1 − 1/√e)-ish practical guarantee).
pub fn knapsack_greedy(
    f: &dyn SubmodularFn,
    candidates: &[usize],
    costs: &[f64],
    budget: f64,
) -> Solution {
    assert_eq!(costs.len(), f.n(), "costs are indexed by global element id");
    let timer = Timer::new();
    let mut calls = 0u64;

    // ratio-greedy pass
    let mut state = f.state();
    let mut spent = 0.0;
    let mut remaining: Vec<usize> =
        candidates.iter().copied().filter(|&v| costs[v] <= budget).collect();
    loop {
        let mut best: Option<(usize, f64)> = None; // (position, ratio)
        for (i, &v) in remaining.iter().enumerate() {
            if spent + costs[v] > budget {
                continue;
            }
            let g = state.gain(v);
            calls += 1;
            let ratio = g / costs[v].max(1e-12);
            if g > 0.0 && best.map_or(true, |(_, r)| ratio > r) {
                best = Some((i, ratio));
            }
        }
        match best {
            Some((i, _)) => {
                let v = remaining.swap_remove(i);
                spent += costs[v];
                state.add(v);
            }
            None => break,
        }
    }

    // best-feasible-singleton fallback (guards the ratio rule's worst case)
    let mut best_single: Option<(usize, f64)> = None;
    for &v in candidates {
        if costs[v] <= budget {
            let g = f.singleton(v);
            calls += 1;
            if best_single.map_or(true, |(_, bg)| g > bg) {
                best_single = Some((v, g));
            }
        }
    }
    let ratio_sol =
        Solution { set: state.set().to_vec(), value: state.value(), oracle_calls: 0, wall_s: 0.0 };
    let result = match best_single {
        Some((v, g)) if g > ratio_sol.value => {
            Solution { set: vec![v], value: g, oracle_calls: calls, wall_s: timer.elapsed_s() }
        }
        _ => Solution { oracle_calls: calls, wall_s: timer.elapsed_s(), ..ratio_sol },
    };
    result
}

/// A partition matroid: elements are colored; at most `cap[color]` of each
/// color may be selected.
pub struct PartitionMatroid {
    color: Vec<usize>,
    cap: Vec<usize>,
}

impl PartitionMatroid {
    pub fn new(color: Vec<usize>, cap: Vec<usize>) -> Self {
        if let Some(&m) = color.iter().max() {
            assert!(m < cap.len(), "color out of range");
        }
        Self { color, cap }
    }

    pub fn rank(&self) -> usize {
        self.cap.iter().sum()
    }

    fn feasible_add(&self, used: &[usize], v: usize) -> bool {
        used[self.color[v]] < self.cap[self.color[v]]
    }
}

/// Greedy under a partition matroid (1/2-approximation for monotone f).
pub fn matroid_greedy(
    f: &dyn SubmodularFn,
    candidates: &[usize],
    matroid: &PartitionMatroid,
) -> Solution {
    let timer = Timer::new();
    let mut state = f.state();
    let mut used = vec![0usize; matroid.cap.len()];
    let mut remaining: Vec<usize> = candidates.to_vec();
    let mut calls = 0u64;
    loop {
        let mut best: Option<(usize, f64)> = None;
        for (i, &v) in remaining.iter().enumerate() {
            if !matroid.feasible_add(&used, v) {
                continue;
            }
            let g = state.gain(v);
            calls += 1;
            if g > 0.0 && best.map_or(true, |(_, bg)| g > bg) {
                best = Some((i, g));
            }
        }
        match best {
            Some((i, _)) => {
                let v = remaining.swap_remove(i);
                used[matroid.color[v]] += 1;
                state.add(v);
            }
            None => break,
        }
    }
    Solution { set: state.set().to_vec(), value: state.value(), oracle_calls: calls, wall_s: timer.elapsed_s() }
}

#[cfg(test)]
mod tests {
    use super::super::{sparsify, CpuBackend, SsParams};
    use super::*;
    use crate::submodular::{FeatureBased, Modular};
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn knapsack_respects_budget() {
        let f = instance(60, 6, 1);
        let costs: Vec<f64> = (0..60).map(|i| 1.0 + (i % 5) as f64).collect();
        let s = knapsack_greedy(&f, &(0..60).collect::<Vec<_>>(), &costs, 12.0);
        let spent: f64 = s.set.iter().map(|&v| costs[v]).sum();
        assert!(spent <= 12.0 + 1e-9, "spent {spent}");
        assert!(s.value > 0.0);
    }

    #[test]
    fn knapsack_unit_costs_equals_cardinality_greedy() {
        // unit costs + budget k ≈ plain greedy (ratio rule = gain rule)
        let f = instance(40, 5, 2);
        let costs = vec![1.0; 40];
        let all: Vec<usize> = (0..40).collect();
        let ks = knapsack_greedy(&f, &all, &costs, 6.0);
        let g = super::super::greedy::greedy(&f, &all, 6);
        assert!((ks.value - g.value).abs() < 1e-9);
    }

    #[test]
    fn knapsack_singleton_fallback_fires() {
        // one huge expensive item vs many cheap tiny ones: ratio rule picks
        // the cheap ones, fallback must consider the big one
        let f = Modular::new(vec![100.0, 1.0, 1.0, 1.0]);
        let costs = vec![10.0, 1.0, 1.0, 1.0];
        let s = knapsack_greedy(&f, &[0, 1, 2, 3], &costs, 10.0);
        assert_eq!(s.set, vec![0], "must take the single high-value item");
        assert_eq!(s.value, 100.0);
    }

    #[test]
    fn matroid_caps_respected() {
        let f = instance(30, 5, 3);
        let color: Vec<usize> = (0..30).map(|i| i % 3).collect();
        let m = PartitionMatroid::new(color.clone(), vec![2, 1, 3]);
        let s = matroid_greedy(&f, &(0..30).collect::<Vec<_>>(), &m);
        let mut used = [0usize; 3];
        for &v in &s.set {
            used[color[v]] += 1;
        }
        assert!(used[0] <= 2 && used[1] <= 1 && used[2] <= 3, "{used:?}");
        assert_eq!(s.set.len(), s.set.iter().collect::<std::collections::HashSet<_>>().len());
    }

    #[test]
    fn ss_composes_with_constrained_maximizers() {
        // §3.3: run SS first, then the constrained algorithm on V'
        let f = instance(500, 8, 4);
        let backend = CpuBackend::new(&f);
        let vp = sparsify(&backend, &SsParams::default().with_seed(5)).kept;
        let costs: Vec<f64> = (0..500).map(|i| 1.0 + (i % 4) as f64).collect();
        let all: Vec<usize> = (0..500).collect();
        let full = knapsack_greedy(&f, &all, &costs, 20.0);
        let pruned = knapsack_greedy(&f, &vp, &costs, 20.0);
        assert!(
            pruned.value / full.value > 0.85,
            "SS+knapsack rel-utility {}",
            pruned.value / full.value
        );
        // matroid composition too
        let color: Vec<usize> = (0..500).map(|i| i % 4).collect();
        let m = PartitionMatroid::new(color, vec![3, 3, 3, 3]);
        let full_m = matroid_greedy(&f, &all, &m);
        let pruned_m = matroid_greedy(&f, &vp, &m);
        assert!(pruned_m.value / full_m.value > 0.85);
    }
}
