//! **Submodular Sparsification (SS)** — Algorithm 1 of the paper, the core
//! contribution: randomized pruning of the submodularity graph that shrinks
//! a ground set of size `n` to `O(K log² n)` while preserving, w.h.p., a
//! `(1 − 1/e)(f(S*) − 2kε)` greedy guarantee (Theorem 2).
//!
//! Per round (on the live set `V`):
//! 1. sample `r·log₂ n` probes `U` (uniformly, or by importance
//!    `f(u) + f(u|V∖u)` per §3.4's second improvement),
//! 2. move `U` from `V` into the output `V'`,
//! 3. compute divergences `w_{U,v} = min_{u∈U} [f(v|u) − f(u|V∖u)]` for all
//!    remaining `v ∈ V` — the hot loop, delegated to a
//!    [`DivergenceBackend`] (CPU reference here; PJRT/coordinator backends
//!    in [`crate::runtime`] / [`crate::coordinator`]),
//! 4. drop the `(1 − 1/√c)` fraction of `V` with smallest divergence
//!    (quickselect, not sort),
//! until `|V| ≤ r·log₂ n`; the leftovers join `V'`.
//!
//! `c` trades success probability and |V'| against shrink rate; the paper
//! fixes `c = 8` (shrink `1/√c = √2/4 ≈ 0.354`, i.e. ~64.6% pruned per
//! round) and finds `r = 8` works in practice.

use std::sync::Mutex;

use super::engine::{GainRoute, MaximizerEngine};
use super::Solution;
use crate::submodular::{BatchedDivergence, SolState, SubmodularFn};
use crate::trace::{EventKind, Tracer};
use crate::util::rng::Rng;
use crate::util::select::{partition_smallest, prune_smallest_paired};
use crate::util::stats::Timer;

/// Why an interruptible SS run stopped early (cooperative, checked at
/// round boundaries — see [`sparsify_candidates_with`]). The service layer
/// maps these onto its typed error variants
/// ([`Cancelled`](crate::coordinator::ServiceError::Cancelled) /
/// [`DeadlineExceeded`](crate::coordinator::ServiceError::DeadlineExceeded)),
/// which is why the distinction is drawn here rather than collapsed into a
/// bare `bool`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Interrupt {
    /// The caller revoked the work (ticket cancelled).
    Cancelled,
    /// The work's deadline passed while it was running.
    DeadlineExceeded,
}

/// Probe-sampling strategy (paper §3.4, improvement 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sampling {
    Uniform,
    /// weight ∝ f(u) + f(u|V∖u): favors globally important probes, raising
    /// the success probability q of Proposition 5.
    Importance,
}

#[derive(Clone, Debug, PartialEq)]
pub struct SsParams {
    /// probe multiplier r (paper: r = O(cK); r = 8 empirically)
    pub r: usize,
    /// accuracy/speed tradeoff c > 1 (paper: c = 8)
    pub c: f64,
    pub seed: u64,
    pub sampling: Sampling,
    /// Floor on |V'|: pruning stops short of dropping below this many
    /// survivors. The analysis requires |V*| ≥ k (Theorem 1), so callers
    /// with large budgets (video: k = 0.15·n) set this to a small multiple
    /// of k — the paper's video runs keep |V'| ≈ 1.5·k. 0 = no floor.
    pub min_keep: usize,
}

impl Default for SsParams {
    fn default() -> Self {
        Self { r: 8, c: 8.0, seed: 0, sampling: Sampling::Uniform, min_keep: 0 }
    }
}

impl SsParams {
    pub fn with_r(mut self, r: usize) -> Self {
        self.r = r;
        self
    }
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn with_sampling(mut self, s: Sampling) -> Self {
        self.sampling = s;
        self
    }
    pub fn with_min_keep(mut self, m: usize) -> Self {
        self.min_keep = m;
        self
    }
}

/// Result of one sparsification run.
#[derive(Clone, Debug)]
pub struct SsResult {
    /// The reduced ground set V' (global indices, ascending).
    pub kept: Vec<usize>,
    pub rounds: usize,
    /// Probes drawn per round (`r · log₂ n`).
    pub probes_per_round: usize,
    /// Total pairwise divergence evaluations (the O(n log n) per-round cost).
    pub divergence_evals: u64,
    /// max over pruned v of w_{V',v} *at prune time* — the measured ε̂ that
    /// Theorem 1/2 plug in as the objective-loss certificate.
    pub pruned_max_divergence: f64,
    pub wall_s: f64,
}

/// Backend computing divergences `w_{U,v}`. Implementations: CPU reference
/// (here), PJRT tiled executor ([`crate::runtime::PjrtBackend`]), and the
/// full parallel coordinator ([`crate::coordinator`]).
pub trait DivergenceBackend: Send + Sync {
    /// Ground-set size (global index space).
    fn n(&self) -> usize;

    /// `w_{U,v} = min_{u∈probes} [f(v|u) − f(u|V∖u)]` for each v in `items`.
    fn divergences(&self, probes: &[usize], items: &[usize]) -> Vec<f32>;

    /// Write-into form of [`divergences`]: `out[i]` receives item `i`'s
    /// divergence, bit-identical to the allocating path. The round loop
    /// calls this with its reused arena buffer; production backends
    /// override it to write in place (CPU kernels directly, the sharded
    /// coordinator via disjoint slices of `out`). The default delegates to
    /// [`divergences`] so existing backends stay correct unmodified.
    ///
    /// [`divergences`]: DivergenceBackend::divergences
    fn divergences_into(&self, probes: &[usize], items: &[usize], out: &mut [f32]) {
        debug_assert_eq!(out.len(), items.len());
        out.copy_from_slice(&self.divergences(probes, items));
    }

    /// Importance weights `f(u) + f(u|V∖u)` (only called under
    /// [`Sampling::Importance`]).
    fn importance_weights(&self, items: &[usize]) -> Vec<f64>;

    /// Write-into form of [`importance_weights`], reusing `out`'s capacity
    /// across rounds. Default delegates to the allocating path.
    ///
    /// [`importance_weights`]: DivergenceBackend::importance_weights
    fn importance_weights_into(&self, items: &[usize], out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.importance_weights(items));
    }

    /// Batched marginal gains under `state` — the post-reduction
    /// maximizer's route: `out[i] = f(candidates[i] | S)`, bit-identical
    /// to the scalar `state.gain` loop. The default runs the state's own
    /// batched kernel inline; the sharded coordinator overrides it to fan
    /// large cohorts over its pool and meter them (`gain_evals`).
    fn gains_into(&self, state: &dyn SolState, candidates: &[usize], out: &mut [f64]) {
        state.gains_into(candidates, out);
    }

    /// Commit `state ← state + v` — the maximizer's per-epoch add,
    /// **bit-identical** to `state.add(v)`. The default *is* that serial
    /// add; the sharded coordinator overrides it to fan the state's O(n)
    /// bookkeeping walk over its pool via [`SolState::add_pooled`] once
    /// the ground set is large enough to pay for the dispatch.
    fn commit(&self, state: &mut dyn SolState, v: usize) {
        state.add(v);
    }
}

/// Reference CPU backend over any [`BatchedDivergence`] objective. The
/// divergence batch dispatches through the trait, so objectives with
/// blocked kernels (feature-based, facility location, mixtures) get them
/// here and under the sharded coordinator identically; everything else
/// rides the scalar `pair_gain` default.
pub struct CpuBackend<'a> {
    f: &'a dyn BatchedDivergence,
    sing: Vec<f64>,
    /// reused probe-singleton gather. Taken out of the mutex for the
    /// duration of a batch (lock held only for the swap) so concurrent
    /// callers on a shared backend never serialize on it; capacity is warm
    /// after round 1 since P is constant within a run.
    probe_sing: Mutex<Vec<f64>>,
}

impl<'a> CpuBackend<'a> {
    pub fn new(f: &'a dyn BatchedDivergence) -> Self {
        Self { sing: f.singleton_complements(), f, probe_sing: Mutex::new(Vec::new()) }
    }

    /// Share a precomputed singleton-complement vector.
    pub fn with_singletons(f: &'a dyn BatchedDivergence, sing: Vec<f64>) -> Self {
        assert_eq!(sing.len(), f.n());
        Self { f, sing, probe_sing: Mutex::new(Vec::new()) }
    }

    pub fn singletons(&self) -> &[f64] {
        &self.sing
    }
}

impl DivergenceBackend for CpuBackend<'_> {
    fn n(&self) -> usize {
        self.f.n()
    }

    fn divergences(&self, probes: &[usize], items: &[usize]) -> Vec<f32> {
        let mut out = vec![0.0f32; items.len()];
        self.divergences_into(probes, items, &mut out);
        out
    }

    fn divergences_into(&self, probes: &[usize], items: &[usize], out: &mut [f32]) {
        debug_assert_eq!(out.len(), items.len());
        // lock held only for the swap; see ShardedBackend::probe_sing
        let mut ps = std::mem::take(&mut *self.probe_sing.lock().unwrap());
        ps.clear();
        ps.extend(probes.iter().map(|&u| self.sing[u]));
        self.f.divergences_into(probes, &ps, items, out);
        *self.probe_sing.lock().unwrap() = ps;
    }

    fn importance_weights(&self, items: &[usize]) -> Vec<f64> {
        let mut out = Vec::with_capacity(items.len());
        self.importance_weights_into(items, &mut out);
        out
    }

    fn importance_weights_into(&self, items: &[usize], out: &mut Vec<f64>) {
        out.clear();
        out.extend(items.iter().map(|&u| self.f.singleton(u) + self.sing[u]));
    }
}

/// Algorithm 1 over the full ground set.
pub fn sparsify(backend: &dyn DivergenceBackend, params: &SsParams) -> SsResult {
    let all: Vec<usize> = (0..backend.n()).collect();
    sparsify_candidates(backend, &all, params)
}

/// Interruptible form of [`sparsify`] — see [`sparsify_candidates_with`].
pub fn sparsify_with(
    backend: &dyn DivergenceBackend,
    params: &SsParams,
    check: &mut dyn FnMut() -> Option<Interrupt>,
) -> Result<SsResult, Interrupt> {
    let all: Vec<usize> = (0..backend.n()).collect();
    sparsify_candidates_with(backend, &all, params, check)
}

/// Per-invocation arena for the round loop: every buffer the loop touches
/// each round, allocated once up front and reused until the run ends. With
/// a backend whose `divergences_into` writes in place (all production
/// backends) and kernels that keep their tiles in thread-local scratch,
/// steady-state rounds perform **zero heap allocations** — asserted by the
/// counting-allocator test in `rust/tests/alloc_steady_state.rs`.
struct RoundScratch {
    /// divergence buffer, capacity = n₀ (the round-1 live set is largest)
    w: Vec<f32>,
    /// selection workspace for the fused prune's threshold quickselect
    sel: Vec<f32>,
    /// this round's probe set U
    probes: Vec<usize>,
    /// sampled positions into the live vector (sorted ascending)
    probe_pos: Vec<usize>,
    /// importance weights (only grown under [`Sampling::Importance`])
    iw: Vec<f64>,
    /// keyed race array for weighted sampling (idem)
    keyed: Vec<(f64, usize)>,
}

impl RoundScratch {
    fn new(n0: usize, probes_per_round: usize) -> Self {
        Self {
            w: Vec::with_capacity(n0),
            sel: Vec::with_capacity(n0),
            probes: Vec::with_capacity(probes_per_round),
            probe_pos: Vec::with_capacity(probes_per_round),
            iw: Vec::new(),
            keyed: Vec::new(),
        }
    }
}

/// Algorithm 1 restricted to a candidate subset (used by the distributed
/// composable-coreset example, which runs SS per partition).
///
/// This is the arena implementation: one [`RoundScratch`] carries the
/// divergence buffer, probe scratch and selection workspace across rounds;
/// divergences are written in place through
/// [`DivergenceBackend::divergences_into`]; and the prune step is fused —
/// `(live, w)` pairs are partitioned in place by
/// [`prune_smallest_paired`] instead of quickselect → bitmap → rebuild.
/// Pruning decisions are **bit-identical** to
/// [`sparsify_candidates_reference`] (same RNG draw sequence, same
/// canonical selection order — see `util::select` for the NaN/tie policy),
/// which the determinism suites assert across objectives, backends, shard
/// counts and sampling modes.
pub fn sparsify_candidates(
    backend: &dyn DivergenceBackend,
    candidates: &[usize],
    params: &SsParams,
) -> SsResult {
    match sparsify_candidates_with(backend, candidates, params, &mut || None) {
        Ok(res) => res,
        Err(_) => unreachable!("a None-returning check can never interrupt"),
    }
}

/// [`sparsify_candidates`] with a cooperative interruption probe, polled
/// once per round **before** any RNG draw: the shed path of the service's
/// cancellable deadline-aware jobs. A `Some(Interrupt)` abandons the run —
/// partial state is dropped (SS keeps no external state, so there is
/// nothing to unwind) and the interrupt is handed back for the caller to
/// map onto its error type.
///
/// The probe sits at the round boundary and never touches the RNG or any
/// buffer, so a run whose probe always returns `None` is **bit-identical**
/// to [`sparsify_candidates`] (which delegates here) — draw sequence,
/// pruning decisions, accounting, everything.
pub fn sparsify_candidates_with(
    backend: &dyn DivergenceBackend,
    candidates: &[usize],
    params: &SsParams,
    check: &mut dyn FnMut() -> Option<Interrupt>,
) -> Result<SsResult, Interrupt> {
    ss_round_loop::<false>(backend, candidates, params, check, Tracer::noop())
}

/// [`sparsify_candidates_with`] recording one [`EventKind::SsRound`] span
/// per round on `tracer`: payload `[live_before, survivors,
/// divergence_evals, probes]` (the round's live set before sampling, the
/// post-prune live count, the divergence evaluations it charged, and the
/// probe count moved into `V'`). Exporters derive the observed shrink rate
/// `survivors / live_before` from the first two fields for comparison
/// against the paper's theoretical `1/√c` (≈ 0.354 at c = 8).
///
/// Tracing is **provably inert**: the traced and untraced loops are the
/// same `ss_round_loop` monomorphized over a `const TRACED: bool`, and the
/// `TRACED = false` instantiation contains no tracing code at all — not
/// even a branch. Span recording happens strictly between rounds (after
/// the prune, before the next `check()` poll), touches neither the RNG nor
/// any loop buffer, and allocates nothing (the tracer's ring is
/// pre-reserved), so kept sets, accounting and interrupt polling are
/// bit-identical across all three of {untraced, traced-disabled,
/// traced-enabled} — asserted by the `perf_trace` bench and the
/// counting-allocator suite.
pub fn sparsify_candidates_traced(
    backend: &dyn DivergenceBackend,
    candidates: &[usize],
    params: &SsParams,
    check: &mut dyn FnMut() -> Option<Interrupt>,
    tracer: &Tracer,
) -> Result<SsResult, Interrupt> {
    ss_round_loop::<true>(backend, candidates, params, check, tracer)
}

/// Whole-ground-set form of [`sparsify_candidates_traced`] — the traced
/// sibling of [`sparsify_with`].
pub fn sparsify_traced(
    backend: &dyn DivergenceBackend,
    params: &SsParams,
    check: &mut dyn FnMut() -> Option<Interrupt>,
    tracer: &Tracer,
) -> Result<SsResult, Interrupt> {
    let all: Vec<usize> = (0..backend.n()).collect();
    sparsify_candidates_traced(backend, &all, params, check, tracer)
}

/// The one true round loop, monomorphized over `TRACED`. Every public
/// sparsify entry point lands here; `TRACED = false` (the default path)
/// compiles the span recording out entirely, `TRACED = true` adds one
/// clock pair and one ring write per round. Both instantiations are
/// otherwise the same instruction stream operating on the same state, so
/// bit-identity between them is structural, not tested-into-existence
/// (though the suites assert it anyway).
fn ss_round_loop<const TRACED: bool>(
    backend: &dyn DivergenceBackend,
    candidates: &[usize],
    params: &SsParams,
    check: &mut dyn FnMut() -> Option<Interrupt>,
    tracer: &Tracer,
) -> Result<SsResult, Interrupt> {
    assert!(params.c > 1.0, "c must be > 1");
    assert!(params.r >= 1);
    let timer = Timer::new();
    let mut rng = Rng::new(params.seed);
    let n0 = candidates.len();
    let mut live: Vec<usize> = candidates.to_vec();

    // r·log₂ n probes per round; the loop stops when |V| falls below it.
    let probes_per_round =
        ((params.r as f64) * (n0.max(2) as f64).log2()).ceil().max(1.0) as usize;
    let keep_frac = 1.0 / params.c.sqrt();

    // |V'| grows by exactly `probes_per_round` per round plus the final
    // tail; reserve for the expected log_{√c}(n₀/P) rounds (plus slack) so
    // steady-state rounds never reallocate `kept`. The min() caps the
    // reservation at n₀ for degenerate parameter choices.
    let est_rounds = ((n0.max(2) as f64) / (probes_per_round as f64))
        .max(1.0)
        .log2()
        / params.c.sqrt().log2().max(1e-9);
    let kept_cap = (probes_per_round * (est_rounds.ceil() as usize + 3)).min(n0);
    let mut kept: Vec<usize> = Vec::with_capacity(kept_cap);

    let mut scratch = RoundScratch::new(n0, probes_per_round);
    let mut rounds = 0usize;
    let mut divergence_evals = 0u64;
    let mut pruned_max_divergence = f64::NEG_INFINITY;

    while live.len() > probes_per_round {
        if let Some(why) = check() {
            return Err(why);
        }
        rounds += 1;
        let span = if TRACED { tracer.start() } else { 0 };
        let live_before = live.len();
        let evals_before = divergence_evals;
        // --- line 5: sample U from V ---
        match params.sampling {
            Sampling::Uniform => {
                rng.sample_indices_into(live.len(), probes_per_round, &mut scratch.probe_pos)
            }
            Sampling::Importance => {
                backend.importance_weights_into(&live, &mut scratch.iw);
                rng.weighted_indices_into(
                    &scratch.iw,
                    probes_per_round,
                    &mut scratch.probe_pos,
                    &mut scratch.keyed,
                );
            }
        }
        // --- lines 6-7: V ← V∖U, V' ← V' ∪ U --- (probe_pos is sorted asc)
        scratch.probes.clear();
        for &p in scratch.probe_pos.iter().rev() {
            scratch.probes.push(live.swap_remove(p));
        }
        kept.extend_from_slice(&scratch.probes);
        if live.is_empty() {
            if TRACED {
                tracer.record_since(
                    EventKind::SsRound,
                    span,
                    live_before as u64,
                    0,
                    0,
                    scratch.probes.len() as u64,
                );
            }
            break;
        }
        // --- lines 8-10: divergences w_{U,v} for v ∈ V, written in place ---
        scratch.w.resize(live.len(), 0.0); // shrinks only (round 1 is largest)
        backend.divergences_into(&scratch.probes, &live, &mut scratch.w);
        divergence_evals += (scratch.probes.len() * live.len()) as u64;
        // --- line 11: drop the (1 − 1/√c)|V| smallest, fused in place ---
        let keep_count = ((live.len() as f64) * keep_frac).floor() as usize;
        let mut drop_count = live.len() - keep_count;
        // respect the |V'| floor (Theorem 1 needs |V*| ≥ k)
        let total_after = kept.len() + live.len();
        if total_after.saturating_sub(drop_count) < params.min_keep {
            drop_count = total_after.saturating_sub(params.min_keep);
        }
        if drop_count == 0 {
            if TRACED {
                tracer.record_since(
                    EventKind::SsRound,
                    span,
                    live_before as u64,
                    live.len() as u64,
                    divergence_evals - evals_before,
                    scratch.probes.len() as u64,
                );
            }
            break; // no further progress possible (floor hit or c ≈ 1)
        }
        // the returned value is the reference loop's exact ε̂ fold over the
        // dropped keys (NaN-skipping f64::max; NEG_INFINITY when all NaN)
        let round_max =
            prune_smallest_paired(&mut scratch.w, &mut live, drop_count, &mut scratch.sel);
        pruned_max_divergence = pruned_max_divergence.max(round_max);
        if TRACED {
            tracer.record_since(
                EventKind::SsRound,
                span,
                live_before as u64,
                live.len() as u64,
                divergence_evals - evals_before,
                scratch.probes.len() as u64,
            );
        }
    }
    // --- line 13: V' ← V ∪ V' ---
    kept.extend_from_slice(&live);
    kept.sort_unstable();
    Ok(SsResult {
        kept,
        rounds,
        probes_per_round,
        divergence_evals,
        pruned_max_divergence: if pruned_max_divergence.is_finite() {
            pruned_max_divergence
        } else {
            0.0
        },
        wall_s: timer.elapsed_s(),
    })
}

/// Fresh-allocation reference for the arena round loop, kept compiled-in
/// as (a) the baseline leg of the `perf_ss_round` bench and (b) the
/// bit-identity oracle for the property/e2e determinism suites: identical
/// RNG draw sequence, identical canonical prune policy
/// (`partition_smallest`'s `(total_cmp, index)` order), implemented with
/// the allocating primitives — fresh `Vec`s for probes/divergences, index
/// quickselect, bool bitmap, survivor rebuild. `sparsify_candidates` must
/// match it exactly, forever.
pub fn sparsify_candidates_reference(
    backend: &dyn DivergenceBackend,
    candidates: &[usize],
    params: &SsParams,
) -> SsResult {
    assert!(params.c > 1.0, "c must be > 1");
    assert!(params.r >= 1);
    let timer = Timer::new();
    let mut rng = Rng::new(params.seed);
    let n0 = candidates.len();
    let mut live: Vec<usize> = candidates.to_vec();
    let mut kept: Vec<usize> = Vec::new();

    let probes_per_round =
        ((params.r as f64) * (n0.max(2) as f64).log2()).ceil().max(1.0) as usize;
    let keep_frac = 1.0 / params.c.sqrt();

    let mut rounds = 0usize;
    let mut divergence_evals = 0u64;
    let mut pruned_max_divergence = f64::NEG_INFINITY;

    while live.len() > probes_per_round {
        rounds += 1;
        let probe_pos: Vec<usize> = match params.sampling {
            Sampling::Uniform => rng.sample_indices(live.len(), probes_per_round),
            Sampling::Importance => {
                let w = backend.importance_weights(&live);
                rng.weighted_indices(&w, probes_per_round)
            }
        };
        let mut probes = Vec::with_capacity(probe_pos.len());
        for &p in probe_pos.iter().rev() {
            probes.push(live.swap_remove(p));
        }
        kept.extend_from_slice(&probes);
        if live.is_empty() {
            break;
        }
        let w = backend.divergences(&probes, &live);
        divergence_evals += (probes.len() * live.len()) as u64;
        let keep_count = ((live.len() as f64) * keep_frac).floor() as usize;
        let mut drop_count = live.len() - keep_count;
        let total_after = kept.len() + live.len();
        if total_after.saturating_sub(drop_count) < params.min_keep {
            drop_count = total_after.saturating_sub(params.min_keep);
        }
        if drop_count == 0 {
            break;
        }
        let drop_pos = partition_smallest(&w, drop_count);
        let mut dropped = vec![false; live.len()];
        for &p in &drop_pos {
            dropped[p] = true;
            pruned_max_divergence = pruned_max_divergence.max(w[p] as f64);
        }
        // sized with the post-floor survivor count (the pre-fix code used
        // the pre-`min_keep` keep_count and could under-reserve)
        let mut next = Vec::with_capacity(live.len() - drop_count);
        for (i, &v) in live.iter().enumerate() {
            if !dropped[i] {
                next.push(v);
            }
        }
        live = next;
    }
    kept.extend_from_slice(&live);
    kept.sort_unstable();
    SsResult {
        kept,
        rounds,
        probes_per_round,
        divergence_evals,
        pruned_max_divergence: if pruned_max_divergence.is_finite() {
            pruned_max_divergence
        } else {
            0.0
        },
        wall_s: timer.elapsed_s(),
    }
}

/// Convenience pipeline: SS-reduce then lazy-greedy maximize — the paper's
/// headline configuration ("greedy on the pruned set"). The maximizer runs
/// through the batched engine with the *same backend* as the gain route,
/// so a sharded backend batches (and meters) the post-reduction cohorts
/// exactly like its divergence rounds.
pub fn ss_then_greedy(
    f: &dyn SubmodularFn,
    backend: &dyn DivergenceBackend,
    k: usize,
    params: &SsParams,
) -> (SsResult, Solution) {
    let ss = sparsify(backend, params);
    let sol = MaximizerEngine::new(f, GainRoute::Backend(backend)).lazy_greedy(&ss.kept, k);
    (ss, sol)
}

#[cfg(test)]
mod tests {
    use super::super::{greedy::greedy, lazy_greedy::lazy_greedy};
    use super::*;
    use crate::submodular::FeatureBased;
    use crate::util::rng::Rng as URng;
    use crate::util::vecmath::FeatureMatrix;

    fn feature_instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = URng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.3) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    /// Redundant instance: many near-duplicates — SS's natural habitat.
    fn redundant_instance(n: usize, clusters: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = URng::new(seed);
        let centers: Vec<Vec<f32>> = (0..clusters)
            .map(|_| (0..d).map(|_| if rng.bool(0.4) { rng.f32() * 3.0 } else { 0.0 }).collect())
            .collect();
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            let c = &centers[rng.below(clusters)];
            for j in 0..d {
                m.row_mut(i)[j] = (c[j] + 0.05 * rng.f32()).max(0.0);
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn output_is_subset_and_deterministic() {
        let f = feature_instance(300, 8, 1);
        let b = CpuBackend::new(&f);
        let p = SsParams::default().with_seed(42);
        let a = sparsify(&b, &p);
        let c = sparsify(&b, &p);
        assert_eq!(a.kept, c.kept, "same seed ⇒ same V'");
        assert!(a.kept.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
        assert!(a.kept.iter().all(|&v| v < 300));
        assert!(a.kept.len() < 300, "must actually prune");
    }

    #[test]
    fn different_seeds_differ() {
        let f = feature_instance(300, 8, 2);
        let b = CpuBackend::new(&f);
        let a = sparsify(&b, &SsParams::default().with_seed(1));
        let c = sparsify(&b, &SsParams::default().with_seed(2));
        assert_ne!(a.kept, c.kept);
    }

    #[test]
    fn round_count_is_logarithmic() {
        // iterations ≈ log_{√c}(n / (r log n)); must stay ≪ n.
        let f = feature_instance(2000, 6, 3);
        let b = CpuBackend::new(&f);
        let r = sparsify(&b, &SsParams::default());
        let bound = ((2000f64).log2() / (8f64).sqrt().log2()).ceil() as usize + 2;
        assert!(r.rounds <= bound, "rounds {} > bound {bound}", r.rounds);
        assert!(r.rounds >= 1);
    }

    #[test]
    fn vprime_size_order_log_squared() {
        // |V'| ≈ r·log n · #rounds + tail ≤ (r log² n)/log √c + slack
        let n = 4000usize;
        let f = redundant_instance(n, 20, 8, 4);
        let b = CpuBackend::new(&f);
        let p = SsParams::default();
        let res = sparsify(&b, &p);
        let log_n = (n as f64).log2();
        let bound = (p.r as f64) * log_n * log_n / (p.c.sqrt()).log2() + (p.r as f64) * 2.0 * log_n;
        assert!(
            (res.kept.len() as f64) <= bound * 1.2,
            "|V'| = {} exceeds O(r log² n) ≈ {bound}",
            res.kept.len()
        );
        assert!(res.kept.len() >= res.probes_per_round, "keeps at least one round of probes");
    }

    #[test]
    fn quality_near_greedy_on_redundant_data() {
        // the paper's headline: rel-utility ≥ ~0.95 on redundant ground sets
        let f = redundant_instance(600, 12, 10, 5);
        let all: Vec<usize> = (0..600).collect();
        let k = 12;
        let g = greedy(&f, &all, k);
        let b = CpuBackend::new(&f);
        let (_ss, sol) = ss_then_greedy(&f, &b, k, &SsParams::default().with_seed(7));
        let rel = sol.value / g.value;
        assert!(rel >= 0.93, "relative utility {rel} too low");
    }

    #[test]
    fn theorem1_style_bound_holds_empirically() {
        // f(S') ≥ (1 − 1/e)(f(S_greedy) − 2k·ε̂) with ε̂ = measured max pruned
        // divergence (we use greedy value as a stand-in for f(S*) since
        // n is too large to brute force; f(S*) ≥ f(greedy) makes this weaker
        // only through the (1-1/e) factor direction — still a useful check
        // plus the rel-utility assertion above covers quality).
        let f = redundant_instance(500, 10, 8, 6);
        let k = 10;
        let b = CpuBackend::new(&f);
        let (ss, sol) = ss_then_greedy(&f, &b, k, &SsParams::default().with_seed(11));
        let g = greedy(&f, &(0..500).collect::<Vec<_>>(), k);
        let eps_hat = ss.pruned_max_divergence.max(0.0);
        let bound = (1.0 - (-1.0f64).exp()) * (g.value - 2.0 * k as f64 * eps_hat);
        assert!(
            sol.value >= bound - 1e-9,
            "Theorem-2-style bound violated: f(S')={} < {bound} (ε̂={eps_hat})",
            sol.value
        );
    }

    #[test]
    fn importance_sampling_runs_and_prunes() {
        let f = redundant_instance(400, 8, 8, 7);
        let b = CpuBackend::new(&f);
        let p = SsParams::default().with_sampling(Sampling::Importance).with_seed(3);
        let res = sparsify(&b, &p);
        assert!(res.kept.len() < 400);
        // quality preserved
        let sol = lazy_greedy(&f, &res.kept, 8);
        let g = greedy(&f, &(0..400).collect::<Vec<_>>(), 8);
        assert!(sol.value / g.value > 0.9);
    }

    #[test]
    fn small_ground_set_passthrough() {
        // when n ≤ r log n nothing is pruned
        let f = feature_instance(20, 4, 8);
        let b = CpuBackend::new(&f);
        let res = sparsify(&b, &SsParams::default());
        assert_eq!(res.kept, (0..20).collect::<Vec<_>>());
        assert_eq!(res.rounds, 0);
    }

    #[test]
    fn min_keep_floor_respected() {
        let f = redundant_instance(2000, 10, 8, 12);
        let b = CpuBackend::new(&f);
        let k = 300usize; // video-style big budget
        let with_floor =
            sparsify(&b, &SsParams::default().with_seed(3).with_min_keep(k + k / 2));
        assert!(
            with_floor.kept.len() >= k + k / 2,
            "|V'| = {} below floor {}",
            with_floor.kept.len(),
            k + k / 2
        );
        let without = sparsify(&b, &SsParams::default().with_seed(3));
        assert!(without.kept.len() < with_floor.kept.len());
    }

    #[test]
    fn candidates_subset_respected() {
        let f = feature_instance(200, 6, 9);
        let b = CpuBackend::new(&f);
        let cands: Vec<usize> = (0..200).step_by(2).collect();
        let res = sparsify_candidates(&b, &cands, &SsParams::default());
        assert!(res.kept.iter().all(|v| cands.contains(v)));
    }

    #[test]
    fn arena_loop_bit_identical_to_reference() {
        // the tentpole invariant: the zero-allocation arena path and the
        // fresh-allocation reference agree exactly — kept set, round
        // count, eval accounting, and the measured ε̂ — across sampling
        // modes and min_keep floors
        let f = redundant_instance(900, 14, 10, 21);
        let b = CpuBackend::new(&f);
        for sampling in [Sampling::Uniform, Sampling::Importance] {
            for min_keep in [0usize, 120, 400] {
                for seed in [0u64, 5, 99] {
                    let p = SsParams {
                        sampling,
                        min_keep,
                        ..SsParams::default().with_seed(seed)
                    };
                    let want = sparsify_candidates_reference(&b, &(0..900).collect::<Vec<_>>(), &p);
                    let got = sparsify(&b, &p);
                    assert_eq!(
                        got.kept, want.kept,
                        "{sampling:?}/min_keep={min_keep}/seed={seed}: kept sets diverged"
                    );
                    assert_eq!(got.rounds, want.rounds);
                    assert_eq!(got.divergence_evals, want.divergence_evals);
                    assert_eq!(
                        got.pruned_max_divergence, want.pruned_max_divergence,
                        "{sampling:?}/min_keep={min_keep}/seed={seed}: ε̂ diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn arena_loop_handles_tied_divergences() {
        // exact duplicate rows ⇒ exact divergence ties: the canonical
        // (key, position) policy must keep arena == reference anyway
        let mut m = FeatureMatrix::zeros(240, 6);
        let mut rng = URng::new(31);
        for i in 0..40 {
            for j in 0..6 {
                m.row_mut(i)[j] = rng.f32();
            }
        }
        for i in 40..240 {
            for j in 0..6 {
                let v = m.row(i % 40)[j]; // 6 exact copies of each base row
                m.row_mut(i)[j] = v;
            }
        }
        let f = FeatureBased::sqrt(m);
        let b = CpuBackend::new(&f);
        for seed in 0..6u64 {
            let p = SsParams::default().with_seed(seed);
            let want = sparsify_candidates_reference(&b, &(0..240).collect::<Vec<_>>(), &p);
            let got = sparsify(&b, &p);
            assert_eq!(got.kept, want.kept, "seed={seed}: tie-breaking diverged");
        }
    }

    #[test]
    fn interrupt_probe_aborts_between_rounds() {
        let f = redundant_instance(1200, 12, 8, 17);
        let b = CpuBackend::new(&f);
        let p = SsParams::default().with_seed(4);
        // a None probe is bit-identical to the plain entry point
        let want = sparsify(&b, &p);
        assert!(want.rounds >= 3, "instance must run several rounds");
        let got = sparsify_with(&b, &p, &mut || None).unwrap();
        assert_eq!(got.kept, want.kept);
        assert_eq!(got.rounds, want.rounds);
        assert_eq!(got.divergence_evals, want.divergence_evals);
        // a probe firing after 2 rounds abandons the run with its reason
        for why in [Interrupt::Cancelled, Interrupt::DeadlineExceeded] {
            let mut polls = 0usize;
            let err = sparsify_with(&b, &p, &mut || {
                polls += 1;
                (polls > 2).then_some(why)
            })
            .unwrap_err();
            assert_eq!(err, why);
            assert_eq!(polls, 3, "probe must be polled once per round boundary");
        }
        // a probe firing immediately sheds before any divergence work
        let err = sparsify_with(&b, &p, &mut || Some(Interrupt::Cancelled)).unwrap_err();
        assert_eq!(err, Interrupt::Cancelled);
    }

    #[test]
    fn shrink_rate_tracks_c() {
        // At fixed r, larger c removes a bigger fraction (1 − 1/√c) per
        // round ⇒ fewer rounds and a smaller V'. (In the paper's analysis r
        // scales as O(cK), which is how larger c buys success probability
        // at the cost of memory — that coupling is the *caller's* choice.)
        let f = redundant_instance(1500, 15, 8, 10);
        let b = CpuBackend::new(&f);
        let small_c = sparsify(&b, &SsParams { c: 2.0, ..Default::default() });
        let big_c = sparsify(&b, &SsParams { c: 32.0, ..Default::default() });
        assert!(
            big_c.rounds < small_c.rounds,
            "c=32 rounds {} ≥ c=2 rounds {}",
            big_c.rounds,
            small_c.rounds
        );
        assert!(
            big_c.kept.len() < small_c.kept.len(),
            "c=32 kept {} ≥ c=2 kept {}",
            big_c.kept.len(),
            small_c.kept.len()
        );
        // paper-style coupling: r = O(cK) ⇒ bigger c with proportional r
        // grows |V'|
        let coupled = sparsify(&b, &SsParams { c: 32.0, r: 32, ..Default::default() });
        assert!(coupled.kept.len() > big_c.kept.len());
    }

    #[test]
    fn divergence_eval_budget_n_log_n_per_round() {
        let n = 1000usize;
        let f = feature_instance(n, 6, 11);
        let b = CpuBackend::new(&f);
        let res = sparsify(&b, &SsParams::default());
        // per round ≤ (r log n) · |V|, and |V| shrinks by 1/√c each round ⇒
        // total ≤ r log n · n · √c/(√c−1)
        let cap = (res.probes_per_round as f64) * (n as f64) * (8f64.sqrt() / (8f64.sqrt() - 1.0));
        assert!(
            (res.divergence_evals as f64) <= cap * 1.05,
            "evals {} > cap {cap}",
            res.divergence_evals
        );
    }
}
