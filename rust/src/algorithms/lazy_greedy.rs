//! Lazy (accelerated) greedy [Minoux '78]: keep stale upper bounds on the
//! marginal gains in a max-heap; re-evaluate only the top until it survives
//! at the top, then commit it. Submodularity (gains only shrink) makes the
//! output *identical* to naive greedy — verified property-style in tests —
//! while skipping most re-evaluations in practice.
//!
//! This is the paper's main quality baseline ("lazy greedy"), and also the
//! maximizer SS runs on the reduced set V'.
//!
//! [`lazy_greedy`] is the engine-backed default: stale heap entries are
//! re-evaluated in cohorts through the batched gain kernels
//! ([`MaximizerEngine`]), bit-identical to the scalar schedule.
//! [`lazy_greedy_reference`] is that scalar schedule, frozen — the
//! bit-identity oracle for the property suites and the baseline leg of
//! `rust/benches/perf_greedy.rs`. It must never change.

use super::engine::{GainRoute, MaximizerEngine};
use super::Solution;
use crate::submodular::SubmodularFn;
use crate::util::select::LazyMaxHeap;
use crate::util::stats::Timer;

/// Cohort-batched lazy greedy — bit-identical solution to
/// [`lazy_greedy_reference`], strictly fewer oracle dispatches.
pub fn lazy_greedy(f: &dyn SubmodularFn, candidates: &[usize], k: usize) -> Solution {
    MaximizerEngine::new(f, GainRoute::Direct).lazy_greedy(candidates, k)
}

/// The scalar Minoux loop, frozen as the engine's bit-identity oracle and
/// bench baseline: one `state.gain` call per evaluation, one heap pop per
/// re-evaluation decision.
pub fn lazy_greedy_reference(f: &dyn SubmodularFn, candidates: &[usize], k: usize) -> Solution {
    let timer = Timer::new();
    let mut state = f.state();
    let mut calls = 0u64;
    let k = k.min(candidates.len());

    // id-space: positions in `candidates`; versions bump on re-evaluation.
    let mut versions = vec![0u64; candidates.len()];
    let mut heap = LazyMaxHeap::new();
    for (i, &v) in candidates.iter().enumerate() {
        heap.push(i, state.gain(v) as f32, 0);
        calls += 1;
    }

    let mut chosen = 0usize;
    // epoch = number of commits; a gain computed in the current epoch is exact
    let mut evaluated_epoch = vec![0u64; candidates.len()];
    let mut epoch = 1u64;
    while chosen < k {
        let Some((i, cached)) = heap.pop_fresh(&versions) else { break };
        if evaluated_epoch[i] == epoch {
            // exact under current solution: commit
            if cached <= 0.0 {
                break; // non-monotone early stop
            }
            state.add(candidates[i]);
            versions[i] = u64::MAX; // never re-enters
            chosen += 1;
            epoch += 1;
        } else {
            // stale: re-evaluate and re-insert
            let g = state.gain(candidates[i]) as f32;
            calls += 1;
            versions[i] += 1;
            evaluated_epoch[i] = epoch;
            heap.push(i, g, versions[i]);
        }
    }

    Solution { set: state.set().to_vec(), value: state.value(), oracle_calls: calls, wall_s: timer.elapsed_s() }
}

#[cfg(test)]
mod tests {
    use super::super::greedy::greedy;
    use super::*;
    use crate::submodular::FeatureBased;
    use crate::util::prop::check_seeded;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feature_instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.5) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn identical_to_naive_greedy() {
        // Minoux's key property: same output, fewer evaluations.
        check_seeded(500, 25, |g| {
            let n = g.usize_in(5, 40);
            let d = g.usize_in(2, 8);
            let k = g.usize_in(1, n);
            let f = feature_instance(n, d, g.usize_in(0, 1 << 30) as u64);
            let all: Vec<usize> = (0..n).collect();
            let a = greedy(&f, &all, k);
            let b = lazy_greedy(&f, &all, k);
            assert_eq!(a.set, b.set, "lazy must equal naive greedy (n={n}, k={k})");
            assert!((a.value - b.value).abs() < 1e-9);
        });
    }

    #[test]
    fn engine_backed_identical_to_scalar_reference() {
        check_seeded(501, 25, |g| {
            let n = g.usize_in(5, 50);
            let d = g.usize_in(2, 8);
            let k = g.usize_in(1, n + 3);
            let f = feature_instance(n, d, g.usize_in(0, 1 << 30) as u64);
            let all: Vec<usize> = (0..n).collect();
            let want = lazy_greedy_reference(&f, &all, k);
            let got = lazy_greedy(&f, &all, k);
            assert_eq!(got.set, want.set, "engine must match the scalar oracle (n={n}, k={k})");
            assert_eq!(got.value.to_bits(), want.value.to_bits());
        });
    }

    #[test]
    fn fewer_oracle_calls_than_naive() {
        let f = feature_instance(200, 8, 7);
        let all: Vec<usize> = (0..200).collect();
        let a = greedy(&f, &all, 20);
        let b = lazy_greedy(&f, &all, 20);
        assert_eq!(a.set, b.set);
        assert!(
            b.oracle_calls < a.oracle_calls,
            "lazy {} vs naive {}",
            b.oracle_calls,
            a.oracle_calls
        );
    }

    #[test]
    fn candidate_restriction() {
        let f = feature_instance(30, 5, 9);
        let cands: Vec<usize> = (0..30).step_by(3).collect();
        let s = lazy_greedy(&f, &cands, 4);
        assert!(s.set.iter().all(|v| cands.contains(v)));
    }

    #[test]
    fn empty_candidates() {
        let f = feature_instance(5, 3, 1);
        let s = lazy_greedy(&f, &[], 3);
        assert!(s.set.is_empty());
        assert_eq!(s.value, 0.0);
    }
}
