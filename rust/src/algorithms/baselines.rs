//! Control baselines: uniform random selection and top-k by singleton value.
//! Neither uses higher-order structure; the evaluation figures use them to
//! show the submodular machinery is doing real work.

use super::Solution;
use crate::submodular::SubmodularFn;
use crate::util::rng::Rng;
use crate::util::select::top_k_desc;
use crate::util::stats::Timer;

pub fn random_subset(f: &dyn SubmodularFn, candidates: &[usize], k: usize, seed: u64) -> Solution {
    let timer = Timer::new();
    let mut rng = Rng::new(seed);
    let k = k.min(candidates.len());
    let set: Vec<usize> =
        rng.sample_indices(candidates.len(), k).into_iter().map(|i| candidates[i]).collect();
    let value = f.eval(&set);
    Solution { set, value, oracle_calls: 1, wall_s: timer.elapsed_s() }
}

pub fn top_k_singleton(f: &dyn SubmodularFn, candidates: &[usize], k: usize) -> Solution {
    let timer = Timer::new();
    let keys: Vec<f32> = candidates.iter().map(|&v| f.singleton(v) as f32).collect();
    let set: Vec<usize> =
        top_k_desc(&keys, k.min(candidates.len())).into_iter().map(|i| candidates[i]).collect();
    let value = f.eval(&set);
    Solution {
        set,
        value,
        oracle_calls: candidates.len() as u64 + 1,
        wall_s: timer.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::greedy::greedy;
    use super::*;
    use crate::submodular::FeatureBased;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feature_instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.5) { rng.f32() } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn baselines_bounded_by_greedy() {
        let f = feature_instance(100, 6, 1);
        let all: Vec<usize> = (0..100).collect();
        let g = greedy(&f, &all, 10);
        let r = random_subset(&f, &all, 10, 3);
        let t = top_k_singleton(&f, &all, 10);
        assert!(r.value <= g.value + 1e-9);
        assert!(t.value <= g.value + 1e-9);
        assert_eq!(r.set.len(), 10);
        assert_eq!(t.set.len(), 10);
    }

    #[test]
    fn random_deterministic_per_seed() {
        let f = feature_instance(50, 4, 2);
        let all: Vec<usize> = (0..50).collect();
        assert_eq!(random_subset(&f, &all, 5, 7).set, random_subset(&f, &all, 5, 7).set);
    }
}
