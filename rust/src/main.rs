//! `ssctl` — the launcher for the submodular-sparsification stack.
//!
//! Subcommands cover the operational surface: one-shot summarization,
//! standalone sparsification, the summarization service demo, synthetic
//! data generation, the paper-experiment drivers, and artifact inspection.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use submodular_ss::algorithms::{lazy_greedy, sparsify, CpuBackend, Sampling, SsParams};
use submodular_ss::bench::full_scale;
use submodular_ss::cluster::{WorkerConfig, WorkerRuntime};
use submodular_ss::coordinator::{
    Compute, Metrics, ServiceConfig, ShardedBackend, SummarizationService, SummarizeRequest,
};
use submodular_ss::data::{CorpusParams, NewsGenerator, VideoParams};
use submodular_ss::eval;
use submodular_ss::runtime;
use submodular_ss::submodular::{FeatureBased, SubmodularFn};
use submodular_ss::util::cli::{App, Args, Command, Parsed};
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::stats::Timer;

fn app() -> App {
    App::new("ssctl", "submodular sparsification (Zhou et al. 2016) — coordinator CLI")
        .command(
            Command::new("summarize", "generate a news day and summarize it (SS + lazy greedy)")
                .opt("n", "2000", "ground-set sentences")
                .opt("k", "0", "budget (0 = reference size)")
                .opt("r", "8", "SS probe multiplier")
                .opt("c", "8.0", "SS tradeoff parameter")
                .opt("seed", "0", "rng seed")
                .opt("method", "ss", "ss | lazy | sieve")
                .flag("pjrt", "route SS divergences through PJRT artifacts")
                .flag("importance", "importance probe sampling (§3.4)"),
        )
        .command(
            Command::new("sparsify", "run Algorithm 1 only; print V' statistics")
                .opt("n", "4000", "ground-set size")
                .opt("r", "8", "probe multiplier")
                .opt("c", "8.0", "tradeoff parameter")
                .opt("seed", "0", "rng seed")
                .opt("threads", "2", "coordinator worker threads")
                .flag("pjrt", "use PJRT backend"),
        )
        .command(
            Command::new("serve", "run the summarization service on a synthetic request stream")
                .opt("requests", "12", "number of requests")
                .opt("workers", "2", "service workers")
                .opt("n", "800", "sentences per request")
                .opt("seed", "0", "rng seed")
                .flag("pjrt", "serve through PJRT artifacts"),
        )
        .command(
            Command::new("experiment", "reproduce a paper figure/table (fig1..fig11, table1, table2, ablation)")
                .opt("seed", "0", "rng seed"),
        )
        .command(
            Command::new("gen-data", "generate a synthetic day/video and print statistics")
                .opt("kind", "news", "news | video")
                .opt("n", "1000", "sentences / frames")
                .opt("seed", "0", "rng seed"),
        )
        .command(Command::new("inspect", "validate the artifacts directory and PJRT runtime"))
        .command(
            Command::new("worker", "serve the summarization service to a cluster coordinator")
                .opt("tcp", "", "bind address (e.g. 127.0.0.1:7077); empty = stdio")
                .opt("id", "0", "worker identity (handshake + metrics scope)")
                .opt("workers", "2", "service request workers")
                .opt("threads", "2", "compute threads"),
        )
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match app().parse(&argv) {
        Parsed::Help(h) => print!("{h}"),
        Parsed::Error(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
        Parsed::Run(name, args) => {
            let r = match name.as_str() {
                "summarize" => cmd_summarize(&args),
                "sparsify" => cmd_sparsify(&args),
                "serve" => cmd_serve(&args),
                "experiment" => cmd_experiment(&args),
                "gen-data" => cmd_gen_data(&args),
                "inspect" => cmd_inspect(),
                "worker" => cmd_worker(&args),
                _ => unreachable!(),
            };
            if let Err(e) = r {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

fn ss_params(args: &Args) -> SsParams {
    let mut p = SsParams {
        r: args.usize("r"),
        c: args.f64("c"),
        seed: args.u64("seed"),
        sampling: Sampling::Uniform,
        ..Default::default()
    };
    if args.has("importance") {
        p.sampling = Sampling::Importance;
    }
    p
}

fn cmd_summarize(args: &Args) -> Result<()> {
    let n = args.usize("n");
    let seed = args.u64("seed");
    let g = NewsGenerator::new(CorpusParams::default(), seed);
    let day = g.day(n, 0, seed);
    let k = if args.usize("k") == 0 { day.k } else { args.usize("k") };
    let f = FeatureBased::sqrt(day.feats.clone());
    let all: Vec<usize> = (0..f.n()).collect();
    let timer = Timer::new();
    let (set, value, reduced) = match args.str("method").as_str() {
        "lazy" => {
            let s = lazy_greedy(&f, &all, k);
            (s.set, s.value, n)
        }
        "sieve" => {
            let s = submodular_ss::algorithms::sieve_streaming(
                &f,
                &all,
                k,
                &submodular_ss::algorithms::SieveParams::paper_default(),
            );
            (s.set, s.value, n)
        }
        "ss" => {
            let params = ss_params(args);
            let ss = if args.has("pjrt") {
                let (_svc, rt) = runtime::start_default(1)?;
                let backend = runtime::PjrtBackend::new(&f, rt)?;
                sparsify(&backend, &params)
            } else {
                let backend = CpuBackend::new(&f);
                sparsify(&backend, &params)
            };
            let s = lazy_greedy(&f, &ss.kept, k);
            (s.set, s.value, ss.kept.len())
        }
        m => return Err(anyhow!("unknown method '{m}'")),
    };
    let elapsed = timer.elapsed_s();
    let rouge = eval::runners::rouge_of(&set, &day.sentences, &day.reference);
    println!("method={} n={n} k={k} |V'|={reduced}", args.str("method"));
    println!("f(S)={value:.3}  ROUGE-2={:.3}  F1={:.3}  time={elapsed:.3}s", rouge.recall, rouge.f1);
    println!("summary sentence indices: {set:?}");
    Ok(())
}

fn cmd_sparsify(args: &Args) -> Result<()> {
    let n = args.usize("n");
    let seed = args.u64("seed");
    let g = NewsGenerator::new(CorpusParams::default(), seed);
    let day = g.day(n, 0, seed);
    let f = Arc::new(FeatureBased::sqrt(day.feats.clone()));
    let params = ss_params(args);
    let pool = Arc::new(ThreadPool::new(args.usize("threads"), 64));
    let metrics = Arc::new(Metrics::new());
    let compute = if args.has("pjrt") {
        let (svc, rt) = runtime::start_default(1)?;
        std::mem::forget(svc); // keep executor threads alive for process life
        Compute::Pjrt(rt)
    } else {
        Compute::Cpu
    };
    let backend = ShardedBackend::new(Arc::clone(&f), pool, compute, Arc::clone(&metrics))?;
    let res = sparsify(&backend, &params);
    println!(
        "n={n} -> |V'|={} ({:.1}% kept) in {} rounds, {} divergence evals, {:.3}s",
        res.kept.len(),
        100.0 * res.kept.len() as f64 / n as f64,
        res.rounds,
        res.divergence_evals,
        res.wall_s
    );
    println!("probes/round={} measured eps-hat={:.4}", res.probes_per_round, res.pruned_max_divergence);
    println!("metrics: {}", metrics.snapshot().to_string());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let use_pjrt = args.has("pjrt");
    let rt = if use_pjrt {
        let (svc, rt) = runtime::start_default(1)?;
        std::mem::forget(svc);
        Some(rt)
    } else {
        None
    };
    let svc = SummarizationService::start(
        ServiceConfig { workers: args.usize("workers"), ..Default::default() },
        rt,
    );
    let seed = args.u64("seed");
    let n = args.usize("n");
    let g = NewsGenerator::new(CorpusParams::default(), seed);
    let count = args.usize("requests");
    let timer = Timer::new();
    let tickets: Vec<_> = (0..count)
        .map(|i| {
            let day = g.day(n, 0, seed + i as u64);
            svc.submit(
                SummarizeRequest::features(
                    day.feats,
                    day.k,
                    SsParams::default().with_seed(seed + i as u64),
                )
                .with_pjrt(use_pjrt),
            )
        })
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait()?;
        println!(
            "req {i}: n={} |V'|={} f(S)={:.2} latency={:.3}s (queued {:.3}s)",
            r.n, r.reduced, r.value, r.latency_s, r.queue_s
        );
    }
    let total = timer.elapsed_s();
    println!("\nthroughput: {:.2} req/s over {count} requests", count as f64 / total);
    println!("{}", svc.metrics_json());
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let seed = args.u64("seed");
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("fig1");
    let scale = if full_scale() { 4 } else { 1 };
    match which {
        "fig1" => {
            let sizes: Vec<usize> = [500, 1000, 2000, 4000].iter().map(|&n| n * scale).collect();
            eval::news::fig1(&sizes, seed).print();
        }
        "fig2" => eval::news::fig2(1500 * scale, seed).print(),
        "fig3" | "fig4" | "fig5" => {
            let records = eval::news::run_days(20 * scale, 300, 2000 * scale, seed);
            match which {
                "fig3" => eval::news::fig3(&records).print(),
                "fig4" => eval::news::fig4(&records).print(),
                _ => eval::news::fig5(&records).print(),
            }
        }
        "fig6" => eval::duc::fig67(10 * scale, 300, 400, seed).print(),
        "fig7" => eval::duc::fig67(10 * scale, 300, 200, seed).print(),
        "table1" => eval::duc::table1(250 * scale, seed).print(),
        "table2" | "fig8" | "fig9" | "fig10" | "fig11" => {
            let params = VideoParams::default();
            let suite: Vec<(String, usize)> = submodular_ss::data::video::summe_suite(&params, seed)
                .into_iter()
                .take(if full_scale() { 25 } else { 5 })
                .map(|(name, frames)| (name, if full_scale() { frames } else { frames / 4 }))
                .collect();
            let (t2, records) = eval::video_eval::table2(&suite, &params, seed);
            match which {
                "table2" => t2.print(),
                "fig8" | "fig9" => eval::video_eval::fig89(&records).print(),
                _ => eval::video_eval::fig1011(&records).print(),
            }
        }
        "ablation" => {
            eval::ablation::ablation_variants(1000 * scale, seed).print();
            eval::ablation::ablation_c_sweep(1000 * scale, seed).print();
        }
        other => return Err(anyhow!("unknown experiment '{other}'")),
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let n = args.usize("n");
    let seed = args.u64("seed");
    match args.str("kind").as_str() {
        "news" => {
            let g = NewsGenerator::new(CorpusParams::default(), seed);
            let day = g.day(n, 0, seed);
            println!(
                "news day: {} sentences, {} topics, {} reference sentences (k), d={}",
                day.sentences.len(),
                day.n_topics,
                day.k,
                day.feats.d
            );
        }
        "video" => {
            let v = submodular_ss::data::generate_video("synthetic", n, &VideoParams::default(), seed);
            println!(
                "video: {} frames, {} shots, {} users, total votes {}",
                v.feats.n(),
                v.boundaries.len(),
                v.user_selections.len(),
                v.gt_scores.iter().sum::<u32>()
            );
        }
        k => return Err(anyhow!("unknown kind '{k}'")),
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    // stdout may be the protocol channel (stdio transport), so all
    // operator-facing output goes to stderr.
    let config = WorkerConfig {
        worker_id: args.u64("id"),
        service: ServiceConfig {
            workers: args.usize("workers"),
            compute_threads: args.usize("threads"),
            ..Default::default()
        },
    };
    let runtime = WorkerRuntime::new(config);
    let addr = args.str("tcp");
    let report = if addr.is_empty() {
        eprintln!("ssctl worker {}: serving stdio", args.u64("id"));
        runtime.serve_stdio()
    } else {
        eprintln!("ssctl worker {}: listening on {addr}", args.u64("id"));
        runtime.serve_tcp(addr.as_str())
    }
    .map_err(|e| anyhow!("worker connection failed: {e}"))?;
    eprintln!(
        "ssctl worker {}: connection ended (jobs={} errors={} shutdown={})",
        args.u64("id"),
        report.jobs_done,
        report.job_errors,
        report.saw_shutdown
    );
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let manifest = runtime::Manifest::load_default()?;
    println!("artifacts dir: {:?}", manifest.dir);
    println!("tile geometry: P={} B={} D={}", manifest.p, manifest.b, manifest.d);
    for (name, meta) in &manifest.artifacts {
        println!("  {name:<16} {:?} inputs={:?}", meta.file.file_name().unwrap(), meta.inputs);
    }
    let (svc, rt) = runtime::start_default(1)?;
    let mut feats = submodular_ss::util::vecmath::FeatureMatrix::zeros(4, 8);
    for i in 0..4 {
        for j in 0..8 {
            feats.row_mut(i)[j] = (i + j) as f32 * 0.1;
        }
    }
    let total = feats.col_sums();
    let s = rt.singleton_complements(&feats, &total, &[0, 1, 2, 3])?;
    println!("runtime smoke: singleton complements = {s:?}");
    drop(svc);
    println!("PJRT runtime OK");
    Ok(())
}
