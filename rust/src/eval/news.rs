//! News experiments: Figures 1–5 of the paper on the NYT-like substitute.

use crate::algorithms::{lazy_greedy, sparsify, CpuBackend, SsParams};
use crate::bench::Table;
use crate::data::{CorpusParams, NewsGenerator};
use crate::submodular::{FeatureBased, SubmodularFn};
use crate::util::stats::Samples;

use super::runners::{rouge_of, run_trio, MethodResult, TrioParams};

fn generator(seed: u64) -> NewsGenerator {
    NewsGenerator::new(CorpusParams::default(), seed)
}

/// **Figure 1**: utility f(S) and time vs data size n, for the three
/// methods. Returns (table, raw rows).
pub fn fig1(sizes: &[usize], seed: u64) -> Table {
    let g = generator(seed);
    let mut t = Table::new(
        "Figure 1 — utility f(S) and time (s) vs n  [paper: SS utility overlaps lazy greedy; SS time ≪ greedy; sieve fastest but lowest utility]",
        &["n", "k", "f_lazy", "f_sieve", "f_ss", "rel_sieve", "rel_ss", "t_lazy_s", "t_sieve_s", "t_ss_s", "|V'|"],
    );
    for (i, &n) in sizes.iter().enumerate() {
        let day = g.day(n, 0, seed.wrapping_add(i as u64));
        let f = FeatureBased::sqrt(day.feats.clone());
        let rs = run_trio(&f, &TrioParams::paper(day.k, seed));
        let (lg, sv, ss) = (&rs[0], &rs[1], &rs[2]);
        t.row(vec![
            n.to_string(),
            day.k.to_string(),
            format!("{:.2}", lg.value),
            format!("{:.2}", sv.value),
            format!("{:.2}", ss.value),
            format!("{:.4}", sv.rel_utility),
            format!("{:.4}", ss.rel_utility),
            format!("{:.3}", lg.time_s),
            format!("{:.3}", sv.time_s),
            format!("{:.3}", ss.time_s),
            ss.working_set.to_string(),
        ]);
    }
    t
}

/// **Figure 2**: relative utility and SS time vs |V'|, swept via
/// r ∈ {2, 4, …, 20} at c = 8 (the paper's exact sweep).
pub fn fig2(n: usize, seed: u64) -> Table {
    let g = generator(seed);
    let day = g.day(n, 0, seed);
    let f = FeatureBased::sqrt(day.feats.clone());
    let all: Vec<usize> = (0..f.n()).collect();
    let lg = lazy_greedy(&f, &all, day.k);
    let backend = CpuBackend::new(&f);
    let mut t = Table::new(
        "Figure 2 — rel. utility & time vs |V'| via r ∈ [2,20]  [paper: rel ≥ 0.97 once |V'| ≳ 300; time grows slowly]",
        &["r", "|V'|", "rel_utility", "t_ss_s"],
    );
    for r in (2..=20).step_by(2) {
        let params = SsParams { r, ..SsParams::default().with_seed(seed) };
        let ss = sparsify(&backend, &params);
        let sol = lazy_greedy(&f, &ss.kept, day.k);
        t.row(vec![
            r.to_string(),
            ss.kept.len().to_string(),
            format!("{:.4}", sol.value / lg.value),
            format!("{:.3}", ss.wall_s),
        ]);
    }
    t
}

/// Per-day record backing Figures 3, 4 and 5.
pub struct DayRecord {
    pub n: usize,
    pub vprime: usize,
    pub results: Vec<MethodResult>,
    pub rouge: Vec<(String, f64, f64)>, // (method, rouge2 recall, f1)
}

/// Run the daily-news stream experiment once, reused by fig3/4/5.
pub fn run_days(days: usize, n_lo: usize, n_hi: usize, seed: u64) -> Vec<DayRecord> {
    let g = generator(seed);
    let stream = g.days(days, n_lo, n_hi, seed);
    stream
        .iter()
        .map(|day| {
            let f = FeatureBased::sqrt(day.feats.clone());
            let rs = run_trio(&f, &TrioParams::paper(day.k, seed));
            let rouge = rs
                .iter()
                .map(|m| {
                    let s = rouge_of(&m.set, &day.sentences, &day.reference);
                    (m.method.to_string(), s.recall, s.f1)
                })
                .collect();
            DayRecord {
                n: day.sentences.len(),
                vprime: rs[2].working_set,
                results: rs,
                rouge,
            }
        })
        .collect()
}

/// **Figure 3**: five-number summaries of relative utility / ROUGE-2 / F1
/// across the day stream. [paper: SS rel ≥ 0.99 most days; sieve ~0.92–0.93;
/// SS ROUGE ≥ sieve, ≈ greedy or slightly above].
pub fn fig3(records: &[DayRecord]) -> Table {
    let mut t = Table::new(
        "Figure 3 — per-day stats over the news stream (min/q1/median/q3/max)",
        &["metric", "method", "min", "q1", "median", "q3", "max"],
    );
    let methods = ["lazy_greedy", "sieve", "ss"];
    for (mi, m) in methods.iter().enumerate() {
        let mut rel = Samples::new();
        let mut rouge = Samples::new();
        let mut f1 = Samples::new();
        for r in records {
            rel.push(r.results[mi].rel_utility);
            rouge.push(r.rouge[mi].1);
            f1.push(r.rouge[mi].2);
        }
        for (name, s) in [("rel_utility", rel), ("rouge2", rouge), ("f1", f1)] {
            let f = s.five_number();
            t.row(vec![
                name.to_string(),
                m.to_string(),
                format!("{:.4}", f[0]),
                format!("{:.4}", f[1]),
                format!("{:.4}", f[2]),
                format!("{:.4}", f[3]),
                format!("{:.4}", f[4]),
            ]);
        }
    }
    t
}

/// **Figure 4**: n vs time scatter rows (circle area ∝ rel utility in the
/// paper's plot; we emit the triplets).
pub fn fig4(records: &[DayRecord]) -> Table {
    let mut t = Table::new(
        "Figure 4 — per-day (n, time, rel-utility) scatter  [paper: SS ≪ lazy-greedy time at large n; sieve flat-ish but low utility]",
        &["n", "t_lazy_s", "t_sieve_s", "t_ss_s", "rel_sieve", "rel_ss"],
    );
    let mut sorted: Vec<&DayRecord> = records.iter().collect();
    sorted.sort_by_key(|r| r.n);
    for r in sorted {
        t.row(vec![
            r.n.to_string(),
            format!("{:.3}", r.results[0].time_s),
            format!("{:.3}", r.results[1].time_s),
            format!("{:.3}", r.results[2].time_s),
            format!("{:.4}", r.results[1].rel_utility),
            format!("{:.4}", r.results[2].rel_utility),
        ]);
    }
    t
}

/// **Figure 5**: (n, |V'|, rel-utility) scatter for SS across days.
pub fn fig5(records: &[DayRecord]) -> Table {
    let mut t = Table::new(
        "Figure 5 — SS rel-utility vs (n, |V'|) per day  [paper: rel ≥ 0.99 most days, can exceed 1 for small n]",
        &["n", "|V'|", "rel_ss"],
    );
    let mut sorted: Vec<&DayRecord> = records.iter().collect();
    sorted.sort_by_key(|r| r.n);
    for r in sorted {
        t.row(vec![
            r.n.to_string(),
            r.vprime.to_string(),
            format!("{:.4}", r.results[2].rel_utility),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_rows_and_shape_claims() {
        let t = fig1(&[150, 400], 3);
        assert_eq!(t.to_json().get("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn day_stream_metrics_populated() {
        let records = run_days(4, 120, 400, 5);
        assert_eq!(records.len(), 4);
        for r in &records {
            assert_eq!(r.results.len(), 3);
            assert_eq!(r.rouge.len(), 3);
            assert!(r.vprime <= r.n);
            assert!(r.results[2].rel_utility > 0.7);
        }
        // aggregation tables build
        fig3(&records);
        fig4(&records);
        fig5(&records);
    }
}
