//! Ablations over the design choices DESIGN.md calls out: §3.4's
//! improvements (importance sampling, Wei-prune pre-pass, bi-directional
//! greedy post-reduction), the c/r knobs, and the non-monotone extension.

use crate::algorithms::{
    bidirectional_greedy, lazy_greedy, sparsify, sparsify_candidates, wei_prune, CpuBackend,
    Sampling, SsParams,
};
use crate::bench::Table;
use crate::data::{CorpusParams, NewsGenerator};
use crate::submodular::{FeatureBased, SparsificationObjective, SubmodularFn};
use crate::util::stats::Timer;

/// Run SS variants on one news day and report |V'|, rel-utility and time.
pub fn ablation_variants(n: usize, seed: u64) -> Table {
    let g = NewsGenerator::new(CorpusParams::default(), seed);
    let day = g.day(n, 0, seed);
    let f = FeatureBased::sqrt(day.feats.clone());
    let all: Vec<usize> = (0..f.n()).collect();
    let k = day.k;
    let full = lazy_greedy(&f, &all, k);
    let backend = CpuBackend::new(&f);
    let sing: Vec<f64> = backend.singletons().to_vec();

    let mut t = Table::new(
        "Ablation — SS variants (§3.4 improvements)",
        &["variant", "|V'|", "rel_utility", "time_s"],
    );
    let mut push = |name: &str, kept: &[usize], secs: f64| {
        let sol = lazy_greedy(&f, kept, k);
        t.row(vec![
            name.to_string(),
            kept.len().to_string(),
            format!("{:.4}", sol.value / full.value),
            format!("{:.3}", secs),
        ]);
    };

    // vanilla
    let timer = Timer::new();
    let base = sparsify(&backend, &SsParams::default().with_seed(seed));
    push("ss_uniform", &base.kept, timer.elapsed_s());

    // importance sampling (§3.4 #2)
    let timer = Timer::new();
    let imp = sparsify(
        &backend,
        &SsParams::default().with_seed(seed).with_sampling(Sampling::Importance),
    );
    push("ss_importance", &imp.kept, timer.elapsed_s());

    // Wei-prune pre-pass (§3.4 #1)
    let timer = Timer::new();
    let surviving = wei_prune(&f, &all, k, Some(&sing));
    let pre = sparsify_candidates(&backend, &surviving, &SsParams::default().with_seed(seed));
    push("wei_prune+ss", &pre.kept, timer.elapsed_s());

    // bidirectional-greedy post-reduction on h over V' (§3.4 #3)
    let timer = Timer::new();
    let eps = base.pruned_max_divergence.max(0.0);
    // h is defined on the reduced set: remap indices V' -> [0, |V'|)
    let kept = &base.kept;
    let graph = crate::graph::SubmodularityGraph::with_singletons(&f, sing.clone());
    let h = SparsificationObjective::from_weights(kept.len(), eps, |u, v| {
        graph.weight(kept[u], kept[v])
    });
    let local: Vec<usize> = (0..kept.len()).collect();
    let reduced_local = bidirectional_greedy(&h, &local, seed, true);
    let mut post: Vec<usize> = reduced_local.set.iter().map(|&i| kept[i]).collect();
    // h maximization may shrink below k: keep at least the probes
    if post.len() < k {
        post = kept.clone();
    }
    post.sort_unstable();
    push("ss+bidir_reduce", &post, timer.elapsed_s());

    t
}

/// c-sweep: shrink-rate / quality / work tradeoff.
pub fn ablation_c_sweep(n: usize, seed: u64) -> Table {
    let g = NewsGenerator::new(CorpusParams::default(), seed);
    let day = g.day(n, 0, seed);
    let f = FeatureBased::sqrt(day.feats.clone());
    let all: Vec<usize> = (0..f.n()).collect();
    let k = day.k;
    let full = lazy_greedy(&f, &all, k);
    let backend = CpuBackend::new(&f);
    let mut t = Table::new(
        "Ablation — c sweep (paper fixes c = 8: shrink √2/4 per round)",
        &["c", "rounds", "|V'|", "divergence_evals", "rel_utility"],
    );
    for &c in &[2.0f64, 4.0, 8.0, 16.0, 32.0] {
        let ss = sparsify(&backend, &SsParams { c, ..SsParams::default().with_seed(seed) });
        let sol = lazy_greedy(&f, &ss.kept, k);
        t.row(vec![
            format!("{c}"),
            ss.rounds.to_string(),
            ss.kept.len().to_string(),
            ss.divergence_evals.to_string(),
            format!("{:.4}", sol.value / full.value),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variants_table_builds() {
        let t = ablation_variants(250, 3);
        let rows = t.to_json();
        assert_eq!(rows.get("rows").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn c_sweep_builds() {
        let t = ablation_c_sweep(200, 5);
        assert_eq!(t.to_json().get("rows").unwrap().as_arr().unwrap().len(), 5);
    }
}
