//! Shared experiment running machinery: execute the paper's three methods
//! (lazy greedy / sieve-streaming / SS+lazy-greedy) on a ground set and
//! collect utility, timing and quality metrics.

use crate::algorithms::{
    lazy_greedy, sieve_streaming, sparsify, CpuBackend, DivergenceBackend, SieveParams, Solution,
    SsParams,
};
use crate::data::rouge::{rouge_2, RougeScore};
use crate::data::text::Sentence;
use crate::submodular::{FeatureBased, SubmodularFn};
use crate::util::stats::Timer;

/// One method's outcome on one ground set.
#[derive(Clone, Debug)]
pub struct MethodResult {
    pub method: &'static str,
    pub value: f64,
    /// f(S) / f(S_lazy_greedy) — the paper's relative utility
    pub rel_utility: f64,
    pub time_s: f64,
    pub set: Vec<usize>,
    /// |V'| for SS, n for offline methods, memory bound for sieve
    pub working_set: usize,
}

/// The paper's standard trio, run on a feature-based objective.
pub struct TrioParams {
    pub k: usize,
    pub ss: SsParams,
    pub sieve: SieveParams,
}

impl TrioParams {
    pub fn paper(k: usize, seed: u64) -> Self {
        Self { k, ss: SsParams::default().with_seed(seed), sieve: SieveParams::paper_default() }
    }
}

pub fn run_trio(f: &FeatureBased, params: &TrioParams) -> Vec<MethodResult> {
    run_trio_with_backend(f, params, None)
}

/// `backend`: override the SS divergence backend (PJRT / sharded
/// coordinator); `None` = single-threaded CPU reference.
pub fn run_trio_with_backend(
    f: &FeatureBased,
    params: &TrioParams,
    backend: Option<&dyn DivergenceBackend>,
) -> Vec<MethodResult> {
    let n = f.n();
    let all: Vec<usize> = (0..n).collect();
    let k = params.k.min(n);

    // --- lazy greedy (the quality reference) ---
    let lg = lazy_greedy(f, &all, k);
    let lg_value = lg.value.max(1e-12);

    // --- sieve-streaming ---
    let sv = sieve_streaming(f, &all, k, &params.sieve);

    // --- SS + lazy greedy ---
    let t = Timer::new();
    let owned_backend;
    let be: &dyn DivergenceBackend = match backend {
        Some(b) => b,
        None => {
            owned_backend = CpuBackend::new(f);
            &owned_backend
        }
    };
    let ss = sparsify(be, &params.ss);
    let ss_sol = lazy_greedy(f, &ss.kept, k);
    let ss_time = t.elapsed_s();

    let mk = |method: &'static str, sol: &Solution, time_s: f64, ws: usize| MethodResult {
        method,
        value: sol.value,
        rel_utility: sol.value / lg_value,
        time_s,
        set: sol.set.clone(),
        working_set: ws,
    };
    vec![
        mk("lazy_greedy", &lg, lg.wall_s, n),
        mk("sieve", &sv, sv.wall_s, crate::algorithms::sieve_streaming::sieve_memory_elements(k, &params.sieve).min(n)),
        mk("ss", &ss_sol, ss_time, ss.kept.len()),
    ]
}

/// ROUGE-2 of a sentence-selection solution against a reference.
pub fn rouge_of(set: &[usize], sentences: &[Sentence], reference: &[Sentence]) -> RougeScore {
    let chosen: Vec<Sentence> = set.iter().map(|&i| sentences[i].clone()).collect();
    rouge_2(&chosen, reference)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CorpusParams, NewsGenerator};

    #[test]
    fn trio_ordering_and_shapes() {
        let g = NewsGenerator::new(
            CorpusParams { vocab_size: 600, d: 64, ..Default::default() },
            1,
        );
        let day = g.day(300, 4, 3);
        let f = FeatureBased::sqrt(day.feats.clone());
        let rs = run_trio(&f, &TrioParams::paper(day.k, 7));
        assert_eq!(rs.len(), 3);
        let lg = &rs[0];
        let sieve = &rs[1];
        let ss = &rs[2];
        assert_eq!(lg.rel_utility, 1.0);
        assert!(sieve.value <= lg.value + 1e-9, "sieve cannot beat lazy greedy");
        assert!(ss.rel_utility > 0.85, "ss rel utility {r}", r = ss.rel_utility);
        assert!(ss.working_set < 300, "ss must reduce the ground set");
        // ROUGE is computable for each
        for r in &rs {
            let score = rouge_of(&r.set, &day.sentences, &day.reference);
            assert!(score.recall >= 0.0 && score.recall <= 1.0);
        }
    }
}
