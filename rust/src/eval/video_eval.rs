//! Video experiments: Table 2 (per-video |V'| and timing) and Figures 8–11
//! (F1/recall against score-based references and per-user summaries).

use crate::bench::Table;
use crate::data::video::{self, frame_f1_tol, reference_by_score, Video, VideoParams};
use crate::submodular::FeatureBased;

use super::runners::{run_trio, MethodResult, TrioParams};

/// Frame-match tolerance (±frames) for F1/recall: SumMe matches at the
/// segment level, and adjacent frames are visually identical (DESIGN.md §3).
pub const MATCH_TOL: usize = 8;

pub struct VideoRecord {
    pub video: Video,
    pub results: Vec<MethodResult>,
}

/// Run the paper's protocol on one synthetic video: each method selects
/// k = 15% of frames; sieve memory = 10k frames worth.
pub fn run_video(name: &str, n_frames: usize, params: &VideoParams, seed: u64) -> VideoRecord {
    let v = video::generate(name, n_frames, params, seed);
    let f = FeatureBased::sqrt(v.feats.clone());
    let k = ((n_frames as f64) * 0.15) as usize;
    let mut trio = TrioParams::paper(k, seed);
    trio.sieve.max_thresholds = 10; // sieve memory 10·k (paper's video setup)
    // video budgets are huge (k = 0.15·n); keep |V'| ≈ 1.5·k like the
    // paper's Table 2 (e.g. 1031 kept for k = 674)
    trio.ss.min_keep = k + k / 2;
    let results = run_trio(&f, &trio);
    VideoRecord { video: v, results }
}

/// **Table 2**: per-video #frames, |V'|, and per-method time. [paper: SS
/// time ~5–15% of greedy; |V'| a fraction of #frames; sieve fastest].
///
/// The paper's "Lazy Greedy" column behaves like an `O(n·k)`-evaluation
/// greedy (its oracle re-evaluates solutions non-incrementally); our lazy
/// greedy over an *incremental* coverage state is a substantially stronger
/// baseline. We therefore report both: `t_naive_s` reproduces the paper's
/// timing shape (SS ≪ greedy at video budgets k = 0.15·n), `t_lazy_s` shows
/// the honest gap against the stronger baseline (EXPERIMENTS.md §Deviations).
pub fn table2(suite: &[(String, usize)], params: &VideoParams, seed: u64) -> (Table, Vec<VideoRecord>) {
    let mut t = Table::new(
        "Table 2 — videos: frames, |V'|, time (s) per method",
        &["video", "#frames", "|V'|", "t_naive_s", "t_lazy_s", "t_sieve_s", "t_ss_s", "rel_ss"],
    );
    let mut records = Vec::new();
    for (i, (name, frames)) in suite.iter().enumerate() {
        let rec = run_video(name, *frames, params, seed.wrapping_add(i as u64 * 31));
        // the paper-equivalent baseline: non-lazy greedy, O(n·k) evaluations
        let f = FeatureBased::sqrt(rec.video.feats.clone());
        let all: Vec<usize> = (0..rec.video.feats.n()).collect();
        let k = ((*frames as f64) * 0.15) as usize;
        let naive = crate::algorithms::greedy(&f, &all, k);
        t.row(vec![
            name.clone(),
            frames.to_string(),
            rec.results[2].working_set.to_string(),
            format!("{:.3}", naive.wall_s),
            format!("{:.3}", rec.results[0].time_s),
            format!("{:.3}", rec.results[1].time_s),
            format!("{:.3}", rec.results[2].time_s),
            format!("{:.4}", rec.results[2].rel_utility),
        ]);
        records.push(rec);
    }
    (t, records)
}

/// **Figures 8/9**: F1 and recall vs score-based reference summaries of
/// sizes p ∈ [0.02, 0.32]·|V| (plus the "first 15% frames" control).
pub fn fig89(records: &[VideoRecord]) -> Table {
    let fracs = [0.02, 0.08, 0.15, 0.32];
    let mut t = Table::new(
        "Figures 8/9 — F1 / recall vs ground-truth-score references  [paper: SS ≈ or > lazy greedy; first-15% control trails]",
        &["video", "p", "lazy_F1", "sieve_F1", "ss_F1", "first15_F1", "lazy_rec", "sieve_rec", "ss_rec", "first15_rec"],
    );
    for rec in records {
        let n = rec.video.feats.n();
        let first15: Vec<usize> = (0..((n as f64 * 0.15) as usize)).collect();
        for &p in &fracs {
            let reference = reference_by_score(&rec.video, p);
            let scores: Vec<(f64, f64)> = rec
                .results
                .iter()
                .map(|m| frame_f1_tol(&m.set, &reference, MATCH_TOL))
                .chain(std::iter::once(frame_f1_tol(&first15, &reference, MATCH_TOL)))
                .collect();
            t.row(vec![
                rec.video.name.clone(),
                format!("{p:.2}"),
                format!("{:.3}", scores[0].0),
                format!("{:.3}", scores[1].0),
                format!("{:.3}", scores[2].0),
                format!("{:.3}", scores[3].0),
                format!("{:.3}", scores[0].1),
                format!("{:.3}", scores[1].1),
                format!("{:.3}", scores[2].1),
                format!("{:.3}", scores[3].1),
            ]);
        }
    }
    t
}

/// **Figures 10/11**: F1 and recall vs each of the 15 user summaries,
/// averaged per video.
pub fn fig1011(records: &[VideoRecord]) -> Table {
    let mut t = Table::new(
        "Figures 10/11 — avg F1 / recall vs 15 user summaries",
        &["video", "lazy_F1", "sieve_F1", "ss_F1", "first15_F1", "lazy_rec", "sieve_rec", "ss_rec", "first15_rec"],
    );
    for rec in records {
        let n = rec.video.feats.n();
        let first15: Vec<usize> = (0..((n as f64 * 0.15) as usize)).collect();
        let sets: Vec<&[usize]> = rec
            .results
            .iter()
            .map(|m| m.set.as_slice())
            .chain(std::iter::once(first15.as_slice()))
            .collect();
        let mut avg = vec![(0.0f64, 0.0f64); sets.len()];
        for user in &rec.video.user_selections {
            for (i, s) in sets.iter().enumerate() {
                let (f1, rec_) = frame_f1_tol(s, user, MATCH_TOL);
                avg[i].0 += f1;
                avg[i].1 += rec_;
            }
        }
        let u = rec.video.user_selections.len() as f64;
        for a in &mut avg {
            a.0 /= u;
            a.1 /= u;
        }
        t.row(vec![
            rec.video.name.clone(),
            format!("{:.3}", avg[0].0),
            format!("{:.3}", avg[1].0),
            format!("{:.3}", avg[2].0),
            format!("{:.3}", avg[3].0),
            format!("{:.3}", avg[0].1),
            format!("{:.3}", avg[1].1),
            format!("{:.3}", avg[2].1),
            format!("{:.3}", avg[3].1),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn video_pipeline_end_to_end() {
        let params = VideoParams { d: 64, seg_len: 60, ..Default::default() };
        let suite = vec![("Tiny clip".to_string(), 500), ("Second clip".to_string(), 700)];
        let (t2, records) = table2(&suite, &params, 3);
        assert_eq!(t2.to_json().get("rows").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(records.len(), 2);
        // SS must substantially reduce frames on smooth video
        assert!(records[0].results[2].working_set < 500);
        let f89 = fig89(&records);
        assert_eq!(f89.to_json().get("rows").unwrap().as_arr().unwrap().len(), 8);
        let f1011 = fig1011(&records);
        assert_eq!(f1011.to_json().get("rows").unwrap().as_arr().unwrap().len(), 2);
    }
}
