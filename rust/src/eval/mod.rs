//! Experiment drivers: one function per paper figure/table (DESIGN.md §5
//! maps each to its bench target). Every driver prints + returns a
//! [`Table`](crate::bench::Table) whose caption records the paper's
//! expected *shape* so the reproduction claim is checkable from the output.

pub mod ablation;
pub mod duc;
pub mod news;
pub mod runners;
pub mod video_eval;

pub use runners::{run_trio, MethodResult, TrioParams};
