//! DUC-2001-like experiments: Figures 6/7 (60 topic sets, 400/200-word
//! references) and Table 1 (four named topics × four word budgets).

use crate::bench::Table;
use crate::data::rouge::{rouge_2, truncate_to_words};
use crate::data::text::Sentence;
use crate::data::{CorpusParams, NewsGenerator};
use crate::submodular::FeatureBased;
use crate::util::stats::Samples;

use super::runners::{run_trio, TrioParams};

fn duc_generator(seed: u64) -> NewsGenerator {
    NewsGenerator::new(CorpusParams::duc_like(), seed)
}

/// Evaluate one topic set at a reference word budget: select a summary with
/// each method, truncate both sides DUC-style, score ROUGE-2 + F1.
fn eval_topic(
    sentences: &[Sentence],
    reference: &[Sentence],
    feats: crate::util::vecmath::FeatureMatrix,
    k: usize,
    words: usize,
    seed: u64,
) -> Vec<(String, f64, f64, f64)> {
    let f = FeatureBased::sqrt(feats);
    let rs = run_trio(&f, &TrioParams::paper(k, seed));
    let ref_trunc = truncate_to_words(reference, words);
    rs.iter()
        .map(|m| {
            let chosen: Vec<Sentence> = m.set.iter().map(|&i| sentences[i].clone()).collect();
            let cand = truncate_to_words(&chosen, words);
            let score = rouge_2(&cand, &ref_trunc);
            (m.method.to_string(), score.recall, score.f1, m.rel_utility)
        })
        .collect()
}

/// **Figures 6 & 7**: stats over `sets` topic sets at a given reference word
/// count (400 for Fig 6, 200 for Fig 7). [paper: SS ≈ lazy greedy on all
/// three metrics, both above sieve-streaming].
pub fn fig67(sets: usize, n_per_set: usize, words: usize, seed: u64) -> Table {
    let g = duc_generator(seed);
    let mut per_method: Vec<(&str, Samples, Samples, Samples)> = vec![
        ("lazy_greedy", Samples::new(), Samples::new(), Samples::new()),
        ("sieve", Samples::new(), Samples::new(), Samples::new()),
        ("ss", Samples::new(), Samples::new(), Samples::new()),
    ];
    for i in 0..sets {
        let topic = g.duc_topic(n_per_set, seed.wrapping_add(i as u64 * 13));
        let rows = eval_topic(
            &topic.sentences,
            &topic.reference,
            topic.feats.clone(),
            topic.k.min(n_per_set / 4),
            words,
            seed,
        );
        for (mi, (_m, rouge, f1, rel)) in rows.iter().enumerate() {
            per_method[mi].1.push(*rouge);
            per_method[mi].2.push(*f1);
            per_method[mi].3.push(*rel);
        }
    }
    let mut t = Table::new(
        &format!("Figures 6/7 — DUC-like {sets} topic sets, {words}-word references (median [q1, q3])"),
        &["method", "rel_utility", "ROUGE-2", "F1"],
    );
    for (m, rouge, f1, rel) in &per_method {
        let f = |s: &Samples| {
            format!("{:.3} [{:.3}, {:.3}]", s.percentile(50.0), s.percentile(25.0), s.percentile(75.0))
        };
        t.row(vec![m.to_string(), f(rel), f(rouge), f(f1)]);
    }
    t
}

/// **Table 1**: four named topics × word budgets {400, 200, 100, 50} ×
/// methods {lazy greedy, sieve, SS}: ROUGE-2 and F1. [paper: SS matches
/// lazy greedy to ~3 decimals on every cell; sieve lower].
pub fn table1(n_per_topic: usize, seed: u64) -> Table {
    let topics = ["Daycare", "Healthcare", "Pres92", "Robert Gates"];
    let g = duc_generator(seed);
    let mut t = Table::new(
        "Table 1 — DUC-like four-topic summarization (ROUGE-2 / F1)",
        &["topic", "words", "lazy_R2", "lazy_F1", "sieve_R2", "sieve_F1", "ss_R2", "ss_F1"],
    );
    for (ti, topic_name) in topics.iter().enumerate() {
        let topic = g.duc_topic(n_per_topic, seed.wrapping_add(ti as u64 * 101));
        for &words in &[400usize, 200, 100, 50] {
            let rows = eval_topic(
                &topic.sentences,
                &topic.reference,
                topic.feats.clone(),
                topic.k.min(n_per_topic / 4),
                words,
                seed,
            );
            t.row(vec![
                topic_name.to_string(),
                words.to_string(),
                format!("{:.3}", rows[0].1),
                format!("{:.3}", rows[0].2),
                format!("{:.3}", rows[1].1),
                format!("{:.3}", rows[1].2),
                format!("{:.3}", rows[2].1),
                format!("{:.3}", rows[2].2),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig67_builds_with_three_methods() {
        let t = fig67(3, 120, 200, 11);
        assert_eq!(t.to_json().get("rows").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn table1_has_16_rows() {
        let t = table1(100, 13);
        assert_eq!(t.to_json().get("rows").unwrap().as_arr().unwrap().len(), 16);
    }
}
