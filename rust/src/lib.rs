//! # submodular-ss
//!
//! A production-scale reproduction of **"Scaling Submodular Maximization via
//! Pruned Submodularity Graphs"** (Zhou, Ouyang, Chang, Bilmes, Guestrin;
//! NIPS 2016 submission / arXiv 2016).
//!
//! The paper's contribution — *submodular sparsification (SS)* — is a
//! randomized pruning algorithm that reduces a ground set `V` of size `n`
//! down to `O(log^2 n)` elements by pruning a directed "submodularity graph"
//! whose edge weights `w_{uv} = f(v|u) - f(u|V\u)` bound the utility loss of
//! dropping `v` while keeping `u`. Greedy maximization on the reduced set
//! achieves `(1 - 1/e)(f(S*) - 2k eps)` with high probability.
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the coordinator: SS leader/worker round
//!   orchestration, dynamic batching of edge-weight jobs, a summarization
//!   service, dataset substrates, baseline algorithms and the full
//!   benchmark/eval harness.
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs for the
//!   feature-based submodular objective (batched edge weights, marginal
//!   gains, singleton-complement gains), lowered once to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels implementing the
//!   hot loops, called from the L2 graphs so they lower into the same HLO.
//!
//! Python never runs on the request path: `make artifacts` AOT-compiles the
//! kernels to `artifacts/*.hlo.txt`, and [`runtime`] loads and executes them
//! via the PJRT C API (`xla` crate).

pub mod util;
pub mod submodular;
pub mod graph;
pub mod algorithms;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod net;
pub mod cluster;
pub mod stream;
pub mod trace;
pub mod config;
pub mod eval;
pub mod bench;

pub use coordinator::{JobOptions, ServiceError, SummarizationService, Ticket};
pub use submodular::{BatchedDivergence, FeatureBased, ObjectiveSpec, SubmodularFn};

