//! [`PjrtBackend`]: the [`DivergenceBackend`] implementation that routes
//! SS's hot loop through the AOT-compiled Pallas kernels, making the
//! `ssctl`/bench SS runs exercise the full three-layer stack.

use std::sync::Arc;

use crate::algorithms::DivergenceBackend;
use crate::submodular::{FeatureBased, SubmodularFn};

use super::tiled::TiledRuntime;

pub struct PjrtBackend<'a> {
    f: &'a FeatureBased,
    rt: Arc<TiledRuntime>,
    /// f(u|V∖u) — computed through the PJRT singleton kernel at construction
    sing: Vec<f64>,
}

impl<'a> PjrtBackend<'a> {
    pub fn new(f: &'a FeatureBased, rt: Arc<TiledRuntime>) -> anyhow::Result<Self> {
        let items: Vec<usize> = (0..f.n()).collect();
        let sing = rt.singleton_complements(f.feats(), f.total_mass(), &items)?;
        Ok(Self { f, rt, sing })
    }

    pub fn singletons(&self) -> &[f64] {
        &self.sing
    }

    pub fn runtime(&self) -> &TiledRuntime {
        &self.rt
    }
}

impl DivergenceBackend for PjrtBackend<'_> {
    fn n(&self) -> usize {
        self.f.n()
    }

    fn divergences(&self, probes: &[usize], items: &[usize]) -> Vec<f32> {
        let sing: Vec<f64> = probes.iter().map(|&u| self.sing[u]).collect();
        self.rt
            .divergences(self.f.feats(), probes, &sing, items)
            .expect("pjrt divergence execution failed")
    }

    fn importance_weights(&self, items: &[usize]) -> Vec<f64> {
        items.iter().map(|&u| self.f.singleton(u) + self.sing[u]).collect()
    }
}
