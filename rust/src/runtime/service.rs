//! The PJRT executor service: a dedicated OS thread that owns the PJRT CPU
//! client and the compiled artifact executables, fed through a bounded job
//! channel.
//!
//! Why a thread-per-client design: the `xla` crate's handles wrap raw
//! C-API pointers and are `!Send`/`!Sync`, so the only sound way to share
//! them with the coordinator's worker pool is message passing. This also
//! gives the batcher its backpressure point for free (the bounded channel).
//! `pool_size > 1` spins up several executor threads, each with its own
//! client + compiled executables (PJRT CPU executables are cheap to
//! duplicate and this sidesteps any cross-thread aliasing questions).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;

/// One padded tile job. All buffers are already padded to the artifact
/// geometry by the [`super::TiledRuntime`] layer; the service is dumb.
pub enum Job {
    /// edge_weights(u_feat[p,d], u_sing[p], v_feat[b,d]) -> w[b]
    EdgeWeights { u_feat: Vec<f32>, u_sing: Vec<f32>, v_feat: Vec<f32>, reply: SyncSender<Result<Vec<f32>>> },
    /// marginal_gains(cov[d], v_feat[b,d]) -> g[b]
    MarginalGains { cov: Vec<f32>, v_feat: Vec<f32>, reply: SyncSender<Result<Vec<f32>>> },
    /// singleton(total[d], v_feat[b,d]) -> s[b]
    Singleton { total: Vec<f32>, v_feat: Vec<f32>, reply: SyncSender<Result<Vec<f32>>> },
    /// utility(v_feat[b,d], mask[b]) -> f[1]
    Utility { v_feat: Vec<f32>, mask: Vec<f32>, reply: SyncSender<Result<Vec<f32>>> },
    Shutdown,
}

/// Handle to the executor service. Cloneable; submitting blocks when the
/// queue is full (backpressure).
#[derive(Clone)]
pub struct PjrtHandle {
    tx: SyncSender<Job>,
    manifest: Arc<Manifest>,
}

pub struct PjrtService {
    handle: PjrtHandle,
    threads: Vec<JoinHandle<()>>,
}

impl PjrtService {
    /// Start `pool_size` executor threads compiling all five artifacts each.
    /// Fails fast (synchronously) if any thread cannot compile.
    pub fn start(manifest: Manifest, pool_size: usize, queue_cap: usize) -> Result<Self> {
        assert!(pool_size >= 1);
        let manifest = Arc::new(manifest);
        let (tx, rx) = sync_channel::<Job>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let mut threads = Vec::new();
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(pool_size);
        for i in 0..pool_size {
            let rx = Arc::clone(&rx);
            let m = Arc::clone(&manifest);
            let ready = ready_tx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-exec-{i}"))
                    .spawn(move || executor_main(&m, &rx, &ready))
                    .context("spawning executor thread")?,
            );
        }
        drop(ready_tx);
        for _ in 0..pool_size {
            ready_rx.recv().context("executor thread died during startup")??;
        }
        Ok(Self { handle: PjrtHandle { tx, manifest }, threads })
    }

    pub fn handle(&self) -> PjrtHandle {
        self.handle.clone()
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        for _ in &self.threads {
            let _ = self.handle.tx.send(Job::Shutdown);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl PjrtHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn roundtrip(&self, make: impl FnOnce(SyncSender<Result<Vec<f32>>>) -> Job) -> Result<Vec<f32>> {
        let (rtx, rrx) = sync_channel(1);
        self.tx.send(make(rtx)).map_err(|_| anyhow!("pjrt service is down"))?;
        rrx.recv().map_err(|_| anyhow!("pjrt executor dropped the reply"))?
    }

    /// Padded-tile edge weights; buffers must match the artifact geometry.
    pub fn edge_weights(&self, u_feat: Vec<f32>, u_sing: Vec<f32>, v_feat: Vec<f32>) -> Result<Vec<f32>> {
        let (p, b, d) = (self.manifest.p, self.manifest.b, self.manifest.d);
        debug_assert_eq!(u_feat.len(), p * d);
        debug_assert_eq!(u_sing.len(), p);
        debug_assert_eq!(v_feat.len(), b * d);
        self.roundtrip(|reply| Job::EdgeWeights { u_feat, u_sing, v_feat, reply })
    }

    pub fn marginal_gains(&self, cov: Vec<f32>, v_feat: Vec<f32>) -> Result<Vec<f32>> {
        self.roundtrip(|reply| Job::MarginalGains { cov, v_feat, reply })
    }

    pub fn singleton(&self, total: Vec<f32>, v_feat: Vec<f32>) -> Result<Vec<f32>> {
        self.roundtrip(|reply| Job::Singleton { total, v_feat, reply })
    }

    pub fn utility(&self, v_feat: Vec<f32>, mask: Vec<f32>) -> Result<f64> {
        let out = self.roundtrip(|reply| Job::Utility { v_feat, mask, reply })?;
        Ok(out[0] as f64)
    }
}

/// Executor thread body: compile everything, then serve jobs forever.
fn executor_main(
    manifest: &Manifest,
    rx: &Mutex<Receiver<Job>>,
    ready: &SyncSender<Result<()>>,
) {
    let compiled = (|| -> Result<Compiled> { Compiled::new(manifest) })();
    let compiled = match compiled {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    loop {
        let job = {
            let rx = rx.lock().unwrap();
            rx.recv()
        };
        let Ok(job) = job else { return };
        match job {
            Job::Shutdown => return,
            Job::EdgeWeights { u_feat, u_sing, v_feat, reply } => {
                let (p, b, d) = geometry(manifest);
                let r = compiled.run1(
                    &compiled.edge_weights,
                    &[(&u_feat, &[p, d][..]), (&u_sing, &[p]), (&v_feat, &[b, d])],
                );
                let _ = reply.send(r);
            }
            Job::MarginalGains { cov, v_feat, reply } => {
                let (_, b, d) = geometry(manifest);
                let r = compiled
                    .run1(&compiled.marginal_gains, &[(&cov, &[d][..]), (&v_feat, &[b, d])]);
                let _ = reply.send(r);
            }
            Job::Singleton { total, v_feat, reply } => {
                let (_, b, d) = geometry(manifest);
                let r =
                    compiled.run1(&compiled.singleton, &[(&total, &[d][..]), (&v_feat, &[b, d])]);
                let _ = reply.send(r);
            }
            Job::Utility { v_feat, mask, reply } => {
                let (_, b, d) = geometry(manifest);
                let r = compiled.run1(&compiled.utility, &[(&v_feat, &[b, d][..]), (&mask, &[b])]);
                let _ = reply.send(r);
            }
        }
    }
}

fn geometry(m: &Manifest) -> (i64, i64, i64) {
    (m.p as i64, m.b as i64, m.d as i64)
}

/// Per-thread compiled state (must stay on its thread: !Send innards).
struct Compiled {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    edge_weights: xla::PjRtLoadedExecutable,
    marginal_gains: xla::PjRtLoadedExecutable,
    singleton: xla::PjRtLoadedExecutable,
    #[allow(dead_code)]
    ss_round: xla::PjRtLoadedExecutable,
    utility: xla::PjRtLoadedExecutable,
}

impl Compiled {
    fn new(manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let meta = &manifest.artifacts[name];
            let proto = xla::HloModuleProto::from_text_file(&meta.file)
                .map_err(|e| anyhow!("parsing HLO text {:?}: {e:?}", meta.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))
        };
        Ok(Self {
            edge_weights: compile("edge_weights")?,
            marginal_gains: compile("marginal_gains")?,
            singleton: compile("singleton")?,
            ss_round: compile("ss_round")?,
            utility: compile("utility")?,
            client,
        })
    }

    /// Execute a 1-output artifact on f32 inputs with the given dims.
    fn run1(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[(&Vec<f32>, &[i64])],
    ) -> Result<Vec<f32>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let out = exe.execute::<xla::Literal>(&lits).map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // artifacts lower with return_tuple=True → unwrap the 1-tuple
        let inner = lit.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
        inner.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}
