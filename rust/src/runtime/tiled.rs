//! Tiling + padding layer between arbitrary problem sizes and the fixed
//! AOT artifact geometry `(P, B, D)`.
//!
//! Padding contract (validated on the Python side by
//! `python/tests/test_kernel.py::test_probe_padding_is_inert` etc.):
//! * probe rows: zero features, singleton = −1e30 (never wins the min);
//! * item rows: zero-padded, outputs discarded;
//! * feature dims: zero-padded on both sides (contribute nothing).

use anyhow::{ensure, Result};

use super::service::PjrtHandle;
use crate::util::vecmath::FeatureMatrix;

/// Sentinel singleton for padded probe lanes: weight ≈ +1e30 ⇒ inert in min.
const PAD_SING: f32 = -1e30;

/// Statistics counters for the perf harness.
#[derive(Clone, Copy, Debug, Default)]
pub struct TileStats {
    pub edge_weight_calls: u64,
    pub marginal_calls: u64,
    pub singleton_calls: u64,
    pub items_processed: u64,
}

/// High-level tiled operations over a [`PjrtHandle`].
pub struct TiledRuntime {
    handle: PjrtHandle,
    stats: std::sync::Mutex<TileStats>,
    /// reusable padded-buffer scratch (perf: avoids re-zeroing every call)
    scratch: std::sync::Mutex<Scratch>,
}

#[derive(Default)]
struct Scratch {
    v_feat: Vec<f32>,
    u_feat: Vec<f32>,
    u_sing: Vec<f32>,
    /// padded coverage row for the marginal-gain route
    cov: Vec<f32>,
}

impl TiledRuntime {
    pub fn new(handle: PjrtHandle) -> Self {
        Self { handle, stats: Default::default(), scratch: Default::default() }
    }

    pub fn geometry(&self) -> (usize, usize, usize) {
        let m = self.handle.manifest();
        (m.p, m.b, m.d)
    }

    pub fn stats(&self) -> TileStats {
        *self.stats.lock().unwrap()
    }

    fn pad_dim(&self, src: &[f32], d: usize, dst: &mut [f32]) {
        // copy a d-dim row into a D-dim slot (D >= d), zero the tail
        dst[..d].copy_from_slice(src);
        for x in &mut dst[d..] {
            *x = 0.0;
        }
    }

    /// Divergences `w_{probes, v}` for each item row. `probes`/`items` index
    /// into `feats`; `sing[p]` is `f(u_p|V∖u_p)` aligned with `probes`.
    pub fn divergences(
        &self,
        feats: &FeatureMatrix,
        probes: &[usize],
        sing: &[f64],
        items: &[usize],
    ) -> Result<Vec<f32>> {
        let mut result = vec![0.0f32; items.len()];
        self.divergences_into(feats, probes, sing, items, &mut result)?;
        Ok(result)
    }

    /// Write-into form of [`Self::divergences`]: `out[i]` receives item
    /// `i`'s divergence (min-folded across probe tiles), so sharded
    /// callers hand disjoint slices of one round buffer straight to the
    /// PJRT route. The probe-singleton tile joins the padded-feature
    /// buffers in the reusable scratch; the remaining per-call clones are
    /// forced by [`PjrtHandle`]'s owned-`Vec` ABI (see ROADMAP open
    /// items).
    pub fn divergences_into(
        &self,
        feats: &FeatureMatrix,
        probes: &[usize],
        sing: &[f64],
        items: &[usize],
        out: &mut [f32],
    ) -> Result<()> {
        let (p_tile, b_tile, d_max) = self.geometry();
        ensure!(feats.d <= d_max, "feature dim {} exceeds artifact D={d_max}", feats.d);
        ensure!(probes.len() == sing.len(), "probes/sing length mismatch");
        ensure!(out.len() == items.len(), "out/items length mismatch");
        out.fill(f32::INFINITY);

        for (pchunk, schunk) in probes.chunks(p_tile).zip(sing.chunks(p_tile)) {
            // build padded probe tile
            let (mut u_feat, mut u_sing) = {
                let mut s = self.scratch.lock().unwrap();
                (std::mem::take(&mut s.u_feat), std::mem::take(&mut s.u_sing))
            };
            u_feat.resize(p_tile * d_max, 0.0);
            u_sing.clear();
            u_sing.resize(p_tile, PAD_SING);
            for (slot, (&u, &su)) in pchunk.iter().zip(schunk).enumerate() {
                self.pad_dim(feats.row(u), feats.d, &mut u_feat[slot * d_max..(slot + 1) * d_max]);
                u_sing[slot] = su as f32;
            }
            for pad_slot in pchunk.len()..p_tile {
                u_feat[pad_slot * d_max..(pad_slot + 1) * d_max].fill(0.0);
            }

            for (block_i, iblock) in items.chunks(b_tile).enumerate() {
                let mut v_feat = {
                    let mut s = self.scratch.lock().unwrap();
                    std::mem::take(&mut s.v_feat)
                };
                v_feat.resize(b_tile * d_max, 0.0);
                for (slot, &v) in iblock.iter().enumerate() {
                    self.pad_dim(
                        feats.row(v),
                        feats.d,
                        &mut v_feat[slot * d_max..(slot + 1) * d_max],
                    );
                }
                for pad_slot in iblock.len()..b_tile {
                    v_feat[pad_slot * d_max..(pad_slot + 1) * d_max].fill(0.0);
                }
                let w = self.handle.edge_weights(u_feat.clone(), u_sing.clone(), v_feat.clone())?;
                {
                    let mut s = self.scratch.lock().unwrap();
                    s.v_feat = v_feat;
                }
                let base = block_i * b_tile;
                for (slot, _) in iblock.iter().enumerate() {
                    let w_val = w[slot];
                    let r = &mut out[base + slot];
                    if w_val < *r {
                        *r = w_val;
                    }
                }
                let mut st = self.stats.lock().unwrap();
                st.edge_weight_calls += 1;
                st.items_processed += iblock.len() as u64;
            }
            let mut s = self.scratch.lock().unwrap();
            s.u_feat = u_feat;
            s.u_sing = u_sing;
        }
        Ok(())
    }

    /// Batched marginal gains `f(v|S)` given coverage `cov` (length d).
    pub fn marginal_gains(
        &self,
        feats: &FeatureMatrix,
        cov: &[f32],
        items: &[usize],
    ) -> Result<Vec<f32>> {
        let mut result = vec![0.0f32; items.len()];
        self.marginal_gains_into(feats, cov, items, &mut result)?;
        Ok(result)
    }

    /// Write-into form of [`Self::marginal_gains`] — the maximizer
    /// engine's PJRT gain route: `out[i]` receives `f(items[i] | S)` for
    /// the coverage vector `cov`, so gain cohorts land straight in the
    /// engine's staging buffer. The padded coverage row and item tiles
    /// live in the reusable scratch (warm after the first cohort, D and B
    /// are artifact constants); the remaining per-call clones are forced
    /// by [`PjrtHandle`]'s owned-`Vec` ABI (see ROADMAP open items).
    pub fn marginal_gains_into(
        &self,
        feats: &FeatureMatrix,
        cov: &[f32],
        items: &[usize],
        out: &mut [f32],
    ) -> Result<()> {
        let (_, b_tile, d_max) = self.geometry();
        ensure!(feats.d <= d_max, "feature dim {} exceeds artifact D={d_max}", feats.d);
        ensure!(cov.len() == feats.d, "coverage/feature dim mismatch");
        ensure!(out.len() == items.len(), "out/items length mismatch");
        let mut padded_cov = {
            let mut s = self.scratch.lock().unwrap();
            std::mem::take(&mut s.cov)
        };
        padded_cov.resize(d_max, 0.0);
        self.pad_dim(cov, feats.d, &mut padded_cov);
        for (iblock, out_block) in items.chunks(b_tile).zip(out.chunks_mut(b_tile)) {
            let mut v_feat = {
                let mut s = self.scratch.lock().unwrap();
                std::mem::take(&mut s.v_feat)
            };
            v_feat.resize(b_tile * d_max, 0.0);
            for (slot, &v) in iblock.iter().enumerate() {
                self.pad_dim(feats.row(v), feats.d, &mut v_feat[slot * d_max..(slot + 1) * d_max]);
            }
            for pad_slot in iblock.len()..b_tile {
                v_feat[pad_slot * d_max..(pad_slot + 1) * d_max].fill(0.0);
            }
            // restore the scratch buffers on the error path too — the
            // engine's PJRT route falls back to CPU per-dispatch and will
            // retry here on the next cohort
            let g = match self.handle.marginal_gains(padded_cov.clone(), v_feat.clone()) {
                Ok(g) => g,
                Err(e) => {
                    let mut s = self.scratch.lock().unwrap();
                    s.v_feat = v_feat;
                    s.cov = padded_cov;
                    return Err(e);
                }
            };
            {
                let mut s = self.scratch.lock().unwrap();
                s.v_feat = v_feat;
            }
            out_block.copy_from_slice(&g[..iblock.len()]);
            let mut st = self.stats.lock().unwrap();
            st.marginal_calls += 1;
            st.items_processed += iblock.len() as u64;
        }
        let mut s = self.scratch.lock().unwrap();
        s.cov = padded_cov;
        Ok(())
    }

    /// Batched `f(v|V∖v)` given the total mass vector.
    pub fn singleton_complements(
        &self,
        feats: &FeatureMatrix,
        total: &[f32],
        items: &[usize],
    ) -> Result<Vec<f64>> {
        let (_, b_tile, d_max) = self.geometry();
        ensure!(feats.d <= d_max);
        let mut padded_total = vec![0.0f32; d_max];
        self.pad_dim(total, feats.d, &mut padded_total);
        let mut result = Vec::with_capacity(items.len());
        for iblock in items.chunks(b_tile) {
            let mut v_feat = vec![0.0f32; b_tile * d_max];
            for (slot, &v) in iblock.iter().enumerate() {
                self.pad_dim(feats.row(v), feats.d, &mut v_feat[slot * d_max..(slot + 1) * d_max]);
            }
            let s = self.handle.singleton(padded_total.clone(), v_feat)?;
            result.extend(s[..iblock.len()].iter().map(|&x| x as f64));
            let mut st = self.stats.lock().unwrap();
            st.singleton_calls += 1;
            st.items_processed += iblock.len() as u64;
        }
        Ok(result)
    }

    /// On-device utility f(set) for a set of ≤ B items.
    pub fn utility(&self, feats: &FeatureMatrix, set: &[usize]) -> Result<f64> {
        let (_, b_tile, d_max) = self.geometry();
        ensure!(set.len() <= b_tile, "utility artifact handles ≤ {b_tile} items");
        let mut v_feat = vec![0.0f32; b_tile * d_max];
        let mut mask = vec![0.0f32; b_tile];
        for (slot, &v) in set.iter().enumerate() {
            self.pad_dim(feats.row(v), feats.d, &mut v_feat[slot * d_max..(slot + 1) * d_max]);
            mask[slot] = 1.0;
        }
        self.handle.utility(v_feat, mask)
    }
}
