//! PJRT runtime: load `artifacts/*.hlo.txt` (AOT-lowered JAX/Pallas
//! graphs), compile them once on the PJRT CPU client, and execute them from
//! the Rust hot path. Python is never involved at runtime.
//!
//! Layering:
//! * [`manifest`] — validates the artifact directory against the expected
//!   tile geometry;
//! * [`service`]  — executor threads owning the (!Send) PJRT handles, fed
//!   by a bounded job channel (the backpressure point);
//! * [`tiled`]    — pads/tiles arbitrary problem sizes to the fixed AOT
//!   shapes and folds partial results (min across probe tiles);
//! * [`backend`]  — plugs the above into the SS algorithm as a
//!   [`crate::algorithms::DivergenceBackend`].

pub mod backend;
pub mod manifest;
pub mod service;
pub mod tiled;

pub use backend::PjrtBackend;
pub use manifest::Manifest;
pub use service::{PjrtHandle, PjrtService};
pub use tiled::TiledRuntime;

use anyhow::Result;
use std::sync::Arc;

/// One-call setup: load the default artifacts and start a service.
pub fn start_default(pool_size: usize) -> Result<(PjrtService, Arc<TiledRuntime>)> {
    let manifest = Manifest::load_default()?;
    let service = PjrtService::start(manifest, pool_size, 64)?;
    let rt = Arc::new(TiledRuntime::new(service.handle()));
    Ok((service, rt))
}
