//! `artifacts/manifest.json` — the contract between the Python AOT step and
//! the Rust runtime. Shapes recorded at lowering time are validated here at
//! load time, so a stale artifacts directory fails fast instead of feeding
//! garbage through PJRT.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Names of the five AOT artifacts (must match `model.artifact_specs`).
pub const ARTIFACT_NAMES: [&str; 5] =
    ["edge_weights", "marginal_gains", "singleton", "ss_round", "utility"];

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: PathBuf,
    /// input shapes as recorded at lowering time
    pub inputs: Vec<Vec<usize>>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    /// probes per tile
    pub p: usize,
    /// items per tile
    pub b: usize,
    /// feature dims
    pub d: usize,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let geta = |k: &str| -> Result<usize> {
            v.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("manifest missing '{k}'"))
        };
        let (p, b, d) = (geta("p")?, geta("b")?, geta("d")?);
        let mut artifacts = BTreeMap::new();
        let arts = v.get("artifacts").ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        for name in ARTIFACT_NAMES {
            let meta = arts.get(name).ok_or_else(|| anyhow!("manifest missing artifact '{name}'"))?;
            let file = dir.join(
                meta.get("file").and_then(Json::as_str).ok_or_else(|| anyhow!("no file for {name}"))?,
            );
            if !file.exists() {
                bail!("artifact file {file:?} missing — re-run `make artifacts`");
            }
            let inputs = meta
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("no inputs for {name}"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .map(|dims| dims.iter().filter_map(Json::as_usize).collect::<Vec<_>>())
                        .ok_or_else(|| anyhow!("bad shape for {name}"))
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(name.to_string(), ArtifactMeta { file, inputs });
        }
        let m = Self { p, b, d, artifacts, dir: dir.to_path_buf() };
        m.validate()?;
        Ok(m)
    }

    /// Default location: `$SS_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("SS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&dir))
    }

    fn validate(&self) -> Result<()> {
        let (p, b, d) = (self.p, self.b, self.d);
        let expect: BTreeMap<&str, Vec<Vec<usize>>> = BTreeMap::from([
            ("edge_weights", vec![vec![p, d], vec![p], vec![b, d]]),
            ("marginal_gains", vec![vec![d], vec![b, d]]),
            ("singleton", vec![vec![d], vec![b, d]]),
            ("ss_round", vec![vec![p, d], vec![p], vec![b, d]]),
            ("utility", vec![vec![b, d], vec![b]]),
        ]);
        for (name, shapes) in expect {
            let got = &self.artifacts[name].inputs;
            if got != &shapes {
                bail!("artifact '{name}' shape mismatch: manifest says {got:?}, geometry (p={p},b={b},d={d}) implies {shapes:?}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, p: usize, b: usize, d: usize, shapes_ok: bool) {
        std::fs::create_dir_all(dir).unwrap();
        let shape = |dims: &[usize]| {
            format!("[{}]", dims.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(","))
        };
        let ew = if shapes_ok {
            format!("[{},{},{}]", shape(&[p, d]), shape(&[p]), shape(&[b, d]))
        } else {
            format!("[{},{},{}]", shape(&[p, d + 1]), shape(&[p]), shape(&[b, d]))
        };
        let art = |name: &str, inputs: String| {
            std::fs::write(dir.join(format!("{name}.hlo.txt")), "HloModule stub").unwrap();
            format!(r#""{name}": {{"file": "{name}.hlo.txt", "inputs": {inputs}}}"#)
        };
        let text = format!(
            r#"{{"p": {p}, "b": {b}, "d": {d}, "dtype": "f32", "artifacts": {{
                {},
                {},
                {},
                {},
                {}
            }}}}"#,
            art("edge_weights", ew),
            art("marginal_gains", format!("[{},{}]", shape(&[d]), shape(&[b, d]))),
            art("singleton", format!("[{},{}]", shape(&[d]), shape(&[b, d]))),
            art("ss_round", format!("[{},{},{}]", shape(&[p, d]), shape(&[p]), shape(&[b, d]))),
            art("utility", format!("[{},{}]", shape(&[b, d]), shape(&[b]))),
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join(format!("ss-manifest-ok-{}", std::process::id()));
        write_manifest(&dir, 4, 8, 16, true);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!((m.p, m.b, m.d), (4, 8, 16));
        assert_eq!(m.artifacts.len(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_shape_mismatch() {
        let dir = std::env::temp_dir().join(format!("ss-manifest-bad-{}", std::process::id()));
        write_manifest(&dir, 4, 8, 16, false);
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("shape mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_actionable() {
        let err = Manifest::load(Path::new("/nonexistent-ss-artifacts")).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn real_artifacts_load_when_present() {
        // exercises the real `make artifacts` output when built
        if Path::new("artifacts/manifest.json").exists() {
            let m = Manifest::load(Path::new("artifacts")).unwrap();
            assert_eq!((m.p, m.b, m.d), (32, 256, 256));
        }
    }
}
