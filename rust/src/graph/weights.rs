//! Edge-weight evaluation on the submodularity graph.

use crate::submodular::SubmodularFn;

/// On-demand edge-weight oracle over a submodular function.
pub struct SubmodularityGraph<'a> {
    f: &'a dyn SubmodularFn,
    /// precomputed `f(u|V∖u)` for all u (paper: "precomputed once in linear time")
    sing: Vec<f64>,
}

impl<'a> SubmodularityGraph<'a> {
    pub fn new(f: &'a dyn SubmodularFn) -> Self {
        let sing = f.singleton_complements();
        Self { f, sing }
    }

    /// Reuse an existing singleton-complement vector (the coordinator
    /// computes it through PJRT and shares it).
    pub fn with_singletons(f: &'a dyn SubmodularFn, sing: Vec<f64>) -> Self {
        assert_eq!(sing.len(), f.n());
        Self { f, sing }
    }

    pub fn n(&self) -> usize {
        self.f.n()
    }

    pub fn singletons(&self) -> &[f64] {
        &self.sing
    }

    /// `w_{uv} = f(v|u) − f(u|V∖u)` (Eq. 3). `w_{uu} = −f(u|V∖u) ≤ 0`.
    pub fn weight(&self, u: usize, v: usize) -> f64 {
        let pair = if u == v { 0.0 } else { self.f.pair_gain(u, v) };
        pair - self.sing[u]
    }

    /// Conditional weight `w_{uv|S} = f(v|S+u) − f(u|V∖u)` (Eq. 4),
    /// evaluated from scratch (used in tests for Lemma 1; the incremental
    /// path lives in the SS algorithm itself).
    pub fn weight_given(&self, s: &[usize], u: usize, v: usize) -> f64 {
        debug_assert!(!s.contains(&u) && !s.contains(&v) && u != v);
        let mut su = s.to_vec();
        su.push(u);
        let f_su = self.f.eval(&su);
        su.push(v);
        let f_suv = self.f.eval(&su);
        (f_suv - f_su) - self.sing[u]
    }

    /// Divergence `w_{U,v} = min_{u∈U} w_{uv}` (Definition 2).
    pub fn divergence(&self, us: &[usize], v: usize) -> f64 {
        us.iter().map(|&u| self.weight(u, v)).fold(f64::INFINITY, f64::min)
    }

    /// Full dense weight matrix (row = tail u, col = head v). Tests only.
    pub fn dense(&self) -> Vec<Vec<f64>> {
        let n = self.n();
        (0..n).map(|u| (0..n).map(|v| self.weight(u, v)).collect()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::{Concave, FeatureBased, SubmodularFn};
    use crate::util::prop::check_seeded;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.5) { rng.f32() * 2.0 } else { 0.0 };
            }
        }
        FeatureBased::new(m, Concave::Sqrt)
    }

    #[test]
    fn lemma3_directed_triangle_inequality() {
        // w_vx <= w_vu + w_ux for all triples (paper Lemma 3)
        let f = instance(12, 6, 1);
        let g = SubmodularityGraph::new(&f);
        for v in 0..12 {
            for u in 0..12 {
                for x in 0..12 {
                    if v == u || u == x || v == x {
                        continue;
                    }
                    let lhs = g.weight(v, x);
                    let rhs = g.weight(v, u) + g.weight(u, x);
                    assert!(
                        lhs <= rhs + 1e-6,
                        "triangle violated: w[{v}->{x}]={lhs} > {rhs}"
                    );
                }
            }
        }
    }

    #[test]
    fn lemma2_gain_bound() {
        // f(v|S) <= f(u|S) + w_{uv|S} (paper Lemma 2)
        let f = instance(14, 5, 2);
        let g = SubmodularityGraph::new(&f);
        check_seeded(200, 150, |gen| {
            let s = gen.subset(14, 0..6);
            let rest: Vec<usize> = (0..14).filter(|x| !s.contains(x)).collect();
            if rest.len() < 2 {
                return;
            }
            let u = rest[gen.usize_in(0, rest.len())];
            let v = rest[gen.usize_in(0, rest.len())];
            if u == v {
                return;
            }
            let f_s = f.eval(&s);
            let gain = |x: usize| {
                let mut sx = s.clone();
                sx.push(x);
                f.eval(&sx) - f_s
            };
            assert!(
                gain(v) <= gain(u) + g.weight_given(&s, u, v) + 1e-6,
                "Lemma 2 violated at S={s:?}, u={u}, v={v}"
            );
        });
    }

    #[test]
    fn lemma1_conditional_monotone() {
        // P ⊆ S  ⇒  w_{uv|S} <= w_{uv|P} (paper Lemma 1)
        let f = instance(12, 5, 3);
        let g = SubmodularityGraph::new(&f);
        check_seeded(300, 100, |gen| {
            let s = gen.subset(12, 0..6);
            let p: Vec<usize> = s.iter().copied().filter(|_| gen.bool()).collect();
            let rest: Vec<usize> = (0..12).filter(|x| !s.contains(x)).collect();
            if rest.len() < 2 {
                return;
            }
            let (u, v) = (rest[0], rest[rest.len() - 1]);
            if u == v {
                return;
            }
            assert!(
                g.weight_given(&s, u, v) <= g.weight_given(&p, u, v) + 1e-6,
                "Lemma 1 violated"
            );
        });
    }

    #[test]
    fn self_edge_nonpositive() {
        let f = instance(10, 4, 4);
        let g = SubmodularityGraph::new(&f);
        for u in 0..10 {
            assert!(g.weight(u, u) <= 1e-9, "w_uu = {}", g.weight(u, u));
        }
    }

    #[test]
    fn divergence_is_min_over_tails() {
        let f = instance(10, 4, 5);
        let g = SubmodularityGraph::new(&f);
        let us = vec![0, 3, 7];
        for v in [1usize, 4, 9] {
            let want = us.iter().map(|&u| g.weight(u, v)).fold(f64::INFINITY, f64::min);
            assert_eq!(g.divergence(&us, v), want);
        }
    }

    #[test]
    fn conditional_reduces_to_unconditional() {
        let f = instance(9, 4, 6);
        let g = SubmodularityGraph::new(&f);
        for u in 0..4 {
            for v in 5..9 {
                assert!((g.weight_given(&[], u, v) - g.weight(u, v)).abs() < 1e-9);
            }
        }
    }
}
