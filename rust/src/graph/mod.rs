//! The submodularity graph `G(V, E, w)` of paper §2.
//!
//! Nodes are ground elements; the directed edge `u → v` carries
//! `w_{uv} = f(v|u) − f(u|V∖u)` (Eq. 3): the worst-case net loss of pruning
//! head `v` while retaining tail `u`. [`SubmodularityGraph`] evaluates
//! weights on demand from any [`SubmodularFn`]; the conditional variant
//! `w_{uv|S} = f(v|S+u) − f(u|V∖u)` (Eq. 4) threads a context set `S`.
//!
//! Dense materialization is `O(n²)` and reserved for tests/diagnostics —
//! SS's entire point is that pruning needs only `O(n log n)` of these.

pub mod weights;

pub use weights::SubmodularityGraph;
