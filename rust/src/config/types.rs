//! Typed configurations bound from TOML documents.

use anyhow::{anyhow, Result};

use crate::algorithms::{Sampling, SsParams};

use super::toml_lite::{parse, Doc, TomlValue};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjectiveKind {
    FeatureSqrt,
    FeatureLog1p,
    FacilityLocation,
}

impl ObjectiveKind {
    pub fn from_str(s: &str) -> Result<Self> {
        match s {
            "feature_sqrt" | "sqrt" => Ok(Self::FeatureSqrt),
            "feature_log1p" | "log1p" => Ok(Self::FeatureLog1p),
            "facility_location" | "fl" => Ok(Self::FacilityLocation),
            other => Err(anyhow!("unknown objective '{other}'")),
        }
    }
}

/// How a run executes (threads, compute path).
#[derive(Clone, Debug)]
pub struct RunnerConfig {
    pub threads: usize,
    pub use_pjrt: bool,
    pub pjrt_pool: usize,
}

impl Default for RunnerConfig {
    fn default() -> Self {
        Self { threads: 2, use_pjrt: false, pjrt_pool: 1 }
    }
}

/// One experiment invocation (used by `ssctl experiment` and the benches).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    pub ss: SsParams,
    pub objective: ObjectiveKind,
    pub runner: RunnerConfig,
    /// experiment-specific sizes (e.g. Fig-1 n sweep)
    pub sizes: Vec<usize>,
    /// scale factor: 1 = CI-fast defaults, larger = closer to the paper
    pub scale: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "unnamed".into(),
            seed: 0,
            ss: SsParams::default(),
            objective: ObjectiveKind::FeatureSqrt,
            runner: RunnerConfig::default(),
            sizes: vec![],
            scale: 1.0,
        }
    }
}

fn get<'d>(doc: &'d Doc, section: &str, key: &str) -> Option<&'d TomlValue> {
    doc.get(section).and_then(|s| s.get(key))
}

impl ExperimentConfig {
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = parse(text).map_err(|e| anyhow!("config parse error: {e}"))?;
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = get(&doc, "", "name").and_then(TomlValue::as_str) {
            cfg.name = v.to_string();
        }
        if let Some(v) = get(&doc, "", "seed").and_then(TomlValue::as_i64) {
            cfg.seed = v as u64;
            cfg.ss.seed = v as u64;
        }
        if let Some(v) = get(&doc, "", "scale").and_then(TomlValue::as_f64) {
            cfg.scale = v;
        }
        if let Some(v) = get(&doc, "", "objective").and_then(TomlValue::as_str) {
            cfg.objective = ObjectiveKind::from_str(v)?;
        }
        if let Some(v) = get(&doc, "ss", "r").and_then(TomlValue::as_usize) {
            cfg.ss.r = v;
        }
        if let Some(v) = get(&doc, "ss", "c").and_then(TomlValue::as_f64) {
            cfg.ss.c = v;
        }
        if let Some(v) = get(&doc, "ss", "importance").and_then(TomlValue::as_bool) {
            cfg.ss.sampling = if v { Sampling::Importance } else { Sampling::Uniform };
        }
        if let Some(v) = get(&doc, "runner", "threads").and_then(TomlValue::as_usize) {
            cfg.runner.threads = v.max(1);
        }
        if let Some(v) = get(&doc, "runner", "use_pjrt").and_then(TomlValue::as_bool) {
            cfg.runner.use_pjrt = v;
        }
        if let Some(v) = get(&doc, "runner", "pjrt_pool").and_then(TomlValue::as_usize) {
            cfg.runner.pjrt_pool = v.max(1);
        }
        if let Some(v) = get(&doc, "data", "sizes").and_then(TomlValue::as_array) {
            cfg.sizes = v.iter().filter_map(TomlValue::as_usize).collect();
        }
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {path:?}: {e}"))?;
        Self::from_toml(&text)
    }

    /// Apply the CI-vs-full scale knob (`SS_FULL=1` doubles everything the
    /// paper-scale direction; benches read this).
    pub fn effective_sizes(&self, default: &[usize]) -> Vec<usize> {
        let base = if self.sizes.is_empty() { default.to_vec() } else { self.sizes.clone() };
        base.iter().map(|&n| ((n as f64) * self.scale) as usize).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binds_full_config() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            name = "fig1"
            seed = 7
            objective = "feature_sqrt"
            scale = 0.5

            [ss]
            r = 10
            c = 4.0
            importance = true

            [runner]
            threads = 3
            use_pjrt = true

            [data]
            sizes = [100, 200]
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "fig1");
        assert_eq!(cfg.ss.r, 10);
        assert_eq!(cfg.ss.c, 4.0);
        assert_eq!(cfg.ss.sampling, Sampling::Importance);
        assert_eq!(cfg.ss.seed, 7);
        assert!(cfg.runner.use_pjrt);
        assert_eq!(cfg.effective_sizes(&[1000]), vec![50, 100]);
    }

    #[test]
    fn defaults_without_sections() {
        let cfg = ExperimentConfig::from_toml("name = \"x\"").unwrap();
        assert_eq!(cfg.ss.r, 8);
        assert_eq!(cfg.ss.c, 8.0);
        assert_eq!(cfg.effective_sizes(&[10, 20]), vec![10, 20]);
    }

    #[test]
    fn rejects_unknown_objective() {
        assert!(ExperimentConfig::from_toml("objective = \"nope\"").is_err());
    }
}
