//! TOML-subset parser: sections, scalar + flat-array values, comments.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// `section.key -> value`; top-level keys live under the empty section `""`.
pub type Doc = BTreeMap<String, BTreeMap<String, TomlValue>>;

pub fn parse(text: &str) -> Result<Doc, String> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(format!("line {}: expected key = value, got '{raw}'", lineno + 1));
        };
        let v = parse_value(value.trim()).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        doc.entry(section.clone()).or_default().insert(key.trim().to_string(), v);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: no '#' inside our config strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse(
            r#"
            # experiment config
            name = "fig1"          # inline comment
            seed = 42

            [ss]
            r = 8
            c = 8.0
            importance = false
            sweep = [2, 4, 6]

            [data]
            sizes = [2000, 20000]
            label = "nyt-like"
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"], TomlValue::Str("fig1".into()));
        assert_eq!(doc[""]["seed"], TomlValue::Int(42));
        assert_eq!(doc["ss"]["c"].as_f64(), Some(8.0));
        assert_eq!(doc["ss"]["importance"].as_bool(), Some(false));
        assert_eq!(doc["ss"]["sweep"].as_array().unwrap().len(), 3);
        assert_eq!(doc["data"]["label"].as_str(), Some("nyt-like"));
    }

    #[test]
    fn int_coerces_to_f64_not_vice_versa() {
        let doc = parse("x = 3\ny = 3.5").unwrap();
        assert_eq!(doc[""]["x"].as_f64(), Some(3.0));
        assert_eq!(doc[""]["y"].as_i64(), None);
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = parse("ok = 1\nbroken line").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn empty_and_comment_only() {
        assert!(parse("").unwrap().is_empty());
        assert!(parse("# nothing\n\n# more").unwrap().is_empty());
    }
}
