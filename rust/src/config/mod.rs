//! Config system: a TOML-subset parser plus typed experiment/service
//! configurations (the offline substitute for `toml` + `serde`).
//!
//! Grammar supported: `[section]` headers, `key = value` with string,
//! integer, float, boolean and flat-array values, `#` comments. This covers
//! everything in `configs/*.toml`.

pub mod toml_lite;
pub mod types;

pub use toml_lite::{parse as parse_toml, TomlValue};
pub use types::{ExperimentConfig, ObjectiveKind, RunnerConfig};
