//! Trace exporters: JSON Lines (one self-describing object per event) and
//! the Chrome trace-event format (a `{"traceEvents": [...]}` document
//! loadable in Perfetto / `chrome://tracing`), both on the crate's own
//! [`Json`] writer — no new dependencies.

use crate::util::json::Json;

use super::{EventKind, TraceEvent, Tracer};

/// Theoretical per-round keep fraction `1/√c` at the paper's default
/// c = 8 — √2/4. JSON-lines SS-round records carry it next to the
/// observed `survivors / live_before` so per-round shrink can be checked
/// against the paper's trajectory without post-processing.
pub const KEEP_THEORY_C8: f64 = 0.353_553_390_593_273_8;

/// Stable exporter name for an event kind.
pub fn kind_name(kind: EventKind) -> &'static str {
    match kind {
        EventKind::Job => "job",
        EventKind::SsRound => "ss_round",
        EventKind::Cohort => "cohort",
        EventKind::KernelDispatch => "kernel_dispatch",
        EventKind::WalFlush => "wal_flush",
        EventKind::Checkpoint => "checkpoint",
        EventKind::Window => "window",
        EventKind::Quarantine => "quarantine",
        EventKind::RpcSend => "rpc_send",
        EventKind::RpcRecv => "rpc_recv",
        EventKind::ShardPrune => "shard_prune",
        EventKind::Merge => "merge",
    }
}

/// Per-kind names of the four payload slots (`a..d`, in order) — the one
/// schema table both exporters read, mirroring the [`EventKind`] docs.
pub fn field_names(kind: EventKind) -> [&'static str; 4] {
    match kind {
        EventKind::Job => ["items_in", "reduced", "k", "ss_rounds"],
        EventKind::SsRound => ["live_before", "survivors", "divergence_evals", "probes"],
        EventKind::Cohort => ["cohort", "gain_evals", "dispatches", "_d"],
        EventKind::KernelDispatch => ["probes", "items", "evals", "_d"],
        EventKind::WalFlush => ["rows", "wal_seq", "_c", "_d"],
        EventKind::Checkpoint => ["wal_seq", "live", "bytes", "_d"],
        EventKind::Window => ["live_before", "retained", "evicted", "ss_rounds"],
        EventKind::Quarantine => ["_a", "_b", "_c", "_d"],
        EventKind::RpcSend => ["tag", "bytes", "job", "shard"],
        EventKind::RpcRecv => ["tag", "bytes", "job", "shard"],
        EventKind::ShardPrune => ["shard", "items_in", "kept", "ss_rounds"],
        EventKind::Merge => ["union", "final_kept", "k", "ss_rounds"],
    }
}

/// One event as a self-describing JSON object (named payload fields;
/// unused slots elided).
fn event_obj(scope: &str, ev: &TraceEvent) -> Json {
    let names = field_names(ev.kind);
    let mut fields: Vec<(&str, Json)> = vec![
        ("scope", Json::Str(scope.to_string())),
        ("event", Json::Str(kind_name(ev.kind).to_string())),
        ("seq", Json::Num(ev.seq as f64)),
        ("t_ns", Json::Num(ev.t_ns as f64)),
        ("dur_ns", Json::Num(ev.dur_ns as f64)),
    ];
    for (name, val) in names.iter().zip([ev.a, ev.b, ev.c, ev.d]) {
        if !name.starts_with('_') {
            fields.push((name, Json::Num(val as f64)));
        }
    }
    if ev.kind == EventKind::SsRound && ev.a > 0 {
        fields.push(("keep_observed", Json::Num(ev.b as f64 / ev.a as f64)));
        fields.push(("keep_theory_c8", Json::Num(KEEP_THEORY_C8)));
    }
    Json::obj(fields)
}

/// Export a tracer's ring as JSON Lines: one compact object per event,
/// oldest-first, newline-terminated — `grep`/`jq`-friendly, streamable,
/// and the flight-recorder dump format.
pub fn to_json_lines(tracer: &Tracer) -> String {
    let scope = tracer.label();
    let mut out = String::new();
    for ev in tracer.events() {
        out.push_str(&event_obj(&scope, &ev).to_string());
        out.push('\n');
    }
    out
}

/// Export one or more tracers as a Chrome trace-event document
/// (`{"traceEvents": [...]}`). Each tracer becomes one track (`tid` =
/// its index, named by a `thread_name` metadata event); spans are
/// complete `"X"` events with microsecond `ts`/`dur`, so temporal
/// nesting (job → round → dispatch) renders as stacked slices in
/// Perfetto. Payload slots ride in `args` under their schema names.
pub fn to_chrome_trace(tracers: &[&Tracer]) -> Json {
    let mut events = Vec::new();
    for (tid, tracer) in tracers.iter().enumerate() {
        let label = tracer.label();
        events.push(Json::obj(vec![
            ("name", Json::Str("thread_name".into())),
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            (
                "args",
                Json::obj(vec![(
                    "name",
                    Json::Str(if label.is_empty() { format!("trace-{tid}") } else { label.clone() }),
                )]),
            ),
        ]));
        for ev in tracer.events() {
            let names = field_names(ev.kind);
            let mut args: Vec<(&str, Json)> = vec![("seq", Json::Num(ev.seq as f64))];
            for (name, val) in names.iter().zip([ev.a, ev.b, ev.c, ev.d]) {
                if !name.starts_with('_') {
                    args.push((name, Json::Num(val as f64)));
                }
            }
            events.push(Json::obj(vec![
                ("name", Json::Str(kind_name(ev.kind).to_string())),
                ("cat", Json::Str("ss".into())),
                ("ph", Json::Str("X".into())),
                ("ts", Json::Num(ev.t_ns as f64 / 1e3)),
                ("dur", Json::Num(ev.dur_ns as f64 / 1e3)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(tid as f64)),
                ("args", Json::obj(args)),
            ]));
        }
    }
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

/// The flight-recorder dump document (what the service's
/// `submit_flight_dump` job resolves with): ring accounting plus every
/// retained event as a self-describing object, oldest-first —
///
/// ```json
/// {"scope": "stream-3", "capacity": 1024, "dropped": 12, "recording": true,
///  "events": [{"event": "ss_round", ...}, ...]}
/// ```
///
/// `dropped` counts events the bounded ring overwrote before the dump;
/// a non-zero value means the `events` array is the *suffix* of the
/// stream's history, which for a post-quarantine post-mortem is the part
/// that matters.
pub fn flight_dump(tracer: &Tracer) -> Json {
    let scope = tracer.label();
    let events: Vec<Json> = tracer.events().iter().map(|ev| event_obj(&scope, ev)).collect();
    Json::obj(vec![
        ("scope", Json::Str(scope)),
        ("capacity", Json::Num(tracer.capacity() as f64)),
        ("dropped", Json::Num(tracer.dropped() as f64)),
        ("recording", Json::Bool(tracer.is_enabled())),
        ("events", Json::Arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample_tracer() -> Tracer {
        let t = Tracer::disabled();
        t.enable("svc", 16);
        let s = t.start();
        t.record_since(EventKind::SsRound, s, 1000, 353, 250_000, 88);
        t.record_now(EventKind::WalFlush, 64, 7, 0, 0);
        t
    }

    #[test]
    fn json_lines_are_parseable_and_self_describing() {
        let t = sample_tracer();
        let lines = to_json_lines(&t);
        let parsed: Vec<Json> =
            lines.lines().map(|l| json::parse(l).expect("each line parses")).collect();
        assert_eq!(parsed.len(), 2);
        let round = &parsed[0];
        assert_eq!(round.get("scope").unwrap().as_str(), Some("svc"));
        assert_eq!(round.get("event").unwrap().as_str(), Some("ss_round"));
        assert_eq!(round.get("live_before").unwrap().as_f64(), Some(1000.0));
        assert_eq!(round.get("survivors").unwrap().as_f64(), Some(353.0));
        let keep = round.get("keep_observed").unwrap().as_f64().unwrap();
        assert!((keep - 0.353).abs() < 1e-12);
        assert_eq!(round.get("keep_theory_c8").unwrap().as_f64(), Some(KEEP_THEORY_C8));
        assert_eq!(parsed[1].get("event").unwrap().as_str(), Some("wal_flush"));
        assert_eq!(parsed[1].get("wal_seq").unwrap().as_f64(), Some(7.0));
        assert!(parsed[1].get("_c").is_none(), "unused slots are elided");
    }

    #[test]
    fn chrome_trace_shape_is_perfetto_loadable() {
        let t = sample_tracer();
        let other = Tracer::disabled();
        other.enable("stream-0", 4);
        other.record_now(EventKind::Quarantine, 0, 0, 0, 0);
        let doc = to_chrome_trace(&[&t, &other]);
        // round-trips through the writer/parser
        let parsed = json::parse(&doc.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata events + 2 spans + 1 marker
        assert_eq!(evs.len(), 5);
        let meta = &evs[0];
        assert_eq!(meta.get("ph").unwrap().as_str(), Some("M"));
        assert_eq!(meta.get("args").unwrap().get("name").unwrap().as_str(), Some("svc"));
        let span = &evs[1];
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("name").unwrap().as_str(), Some("ss_round"));
        assert_eq!(span.get("tid").unwrap().as_f64(), Some(0.0));
        assert!(span.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(span.get("args").unwrap().get("probes").unwrap().as_f64(), Some(88.0));
        // second tracer lands on its own track
        let q = &evs[4];
        assert_eq!(q.get("name").unwrap().as_str(), Some("quarantine"));
        assert_eq!(q.get("tid").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn flight_dump_carries_ring_accounting_and_events() {
        let t = sample_tracer();
        let d = flight_dump(&t);
        assert_eq!(d.get("scope").unwrap().as_str(), Some("svc"));
        assert_eq!(d.get("capacity").unwrap().as_f64(), Some(16.0));
        assert_eq!(d.get("dropped").unwrap().as_f64(), Some(0.0));
        assert_eq!(d.get("recording").unwrap().as_bool(), Some(true));
        let evs = d.get("events").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("event").unwrap().as_str(), Some("ss_round"));
        // round-trips through the writer/parser
        json::parse(&d.to_string()).expect("dump document parses");
    }

    #[test]
    fn every_kind_has_a_name_and_schema() {
        for kind in [
            EventKind::Job,
            EventKind::SsRound,
            EventKind::Cohort,
            EventKind::KernelDispatch,
            EventKind::WalFlush,
            EventKind::Checkpoint,
            EventKind::Window,
            EventKind::Quarantine,
            EventKind::RpcSend,
            EventKind::RpcRecv,
            EventKind::ShardPrune,
            EventKind::Merge,
        ] {
            assert!(!kind_name(kind).is_empty());
            assert_eq!(field_names(kind).len(), 4);
        }
    }
}
