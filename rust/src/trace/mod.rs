//! Structured tracing: a bounded, pre-allocated ring-buffer span collector
//! threaded through the whole stack, plus exporters ([`export`]) and the
//! per-stream quarantine flight recorder the service builds on it.
//!
//! # Design constraints (in priority order)
//!
//! 1. **Provably inert.** Instrumentation only *reads* algorithm state —
//!    it never feeds a value back into any computation — so traced and
//!    untraced runs are bit-identical (kept sets, committed solutions,
//!    f64 value bits). The SS round loop goes further: it is
//!    monomorphized over a `const TRACED: bool`
//!    ([`sparsify_candidates`](crate::algorithms::sparsify_candidates)
//!    vs [`sparsify_candidates_traced`](crate::algorithms::sparsify_candidates_traced)),
//!    so the untraced production path compiles the tracing calls out
//!    entirely. `benches/perf_trace.rs` gates both properties.
//! 2. **Zero heap allocations per event in steady state.** The ring is
//!    reserved once at [`Tracer::enable`]; recording an event is a
//!    monotonic-clock read, a short mutex hold and a slot write. Once
//!    the ring is full, new events overwrite the oldest (`dropped`
//!    counts the overwritten ones) — the flight-recorder semantics: the
//!    *most recent* window of activity is always retained.
//! 3. **Compile-out-cheap when disabled.** A disabled tracer costs one
//!    relaxed atomic load per potential event and never touches the
//!    clock or the ring mutex; [`Tracer::start`] returns a dummy
//!    timestamp without reading the clock at all.
//!
//! # Event model
//!
//! Events are fixed-size PODs ([`TraceEvent`]): a sequence number, start
//! timestamp + duration in nanoseconds against the tracer's own epoch, an
//! [`EventKind`], and four `u64` payload slots whose meaning is per-kind
//! (see [`EventKind`] — e.g. an SS round span carries live-before,
//! survivors, divergence-eval delta and probe count, from which the
//! exporters derive the observed shrink rate against the theoretical
//! `1/√c` = √2/4 ≈ 0.3536 at the paper's c = 8). There is no string
//! payload and no per-event scope tag: **the scope is the tracer** — each
//! [`Metrics`](crate::coordinator::Metrics) scope (service-wide,
//! per-stream) owns one tracer, whose label names every event in it.
//!
//! # Span hierarchy
//!
//! ```text
//! Job (service summarize request)
//! └── SsRound (one prune round of the SS pass)
//!     └── KernelDispatch (one sharded divergence/gain batch)
//! └── Cohort (one batched-gain dispatch of the maximizer engine)
//! Window (stream re-sparsification)   WalFlush / Checkpoint (durable I/O)
//! Quarantine (instantaneous marker — the flight recorder's tombstone)
//! ```
//!
//! Parentage is temporal, not pointer-based: a child span's
//! `[t_ns, t_ns + dur_ns]` interval nests inside its parent's, which is
//! exactly what the Chrome trace-event exporter
//! ([`export::to_chrome_trace`]) renders as stacked slices in Perfetto.
//!
//! # The flight recorder
//!
//! Every stream session's scoped `Metrics` owns an *enabled* tracer; the
//! service additionally holds the same `Arc<Tracer>` outside the session
//! lock, so when a session quarantines (poisoned lock, failed durable
//! store) the ring of its final moments is still reachable — the
//! `FlightDump` service job reads it without ever taking the session
//! lock. The ring mutex itself is poison-tolerant (`into_inner`), so a
//! panic mid-record cannot brick the dump.

pub mod export;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// What a [`TraceEvent`] describes. The payload slots `a..d` are per-kind:
///
/// | kind | a | b | c | d |
/// |------|---|---|---|---|
/// | `Job` | items in (n) | reduced (\|V′\|) | budget k | SS rounds |
/// | `SsRound` | live before | survivors after | divergence-eval delta | probes drawn |
/// | `Cohort` | cohort size | gain-eval delta | dispatch count delta | 0 |
/// | `KernelDispatch` | probes | items | pairwise evals | 0 |
/// | `WalFlush` | rows logged | WAL seq | 0 | 0 |
/// | `Checkpoint` | covered WAL seq | live elements | blob bytes | 0 |
/// | `Window` | live before | retained after | evicted | SS rounds |
/// | `Quarantine` | 0 | 0 | 0 | 0 (instantaneous marker) |
/// | `RpcSend` | frame tag | frame bytes | job id | shard |
/// | `RpcRecv` | frame tag | frame bytes | job id | shard |
/// | `ShardPrune` | shard | items in | kept | SS rounds |
/// | `Merge` | union size | final kept | budget k | merge SS rounds |
///
/// `SsRound.b / SsRound.a` is the observed per-round keep fraction; the
/// theory value is `1/√c` (√2/4 ≈ 0.35355 at the default c = 8) — the
/// JSON-lines exporter emits both so trajectory claims are checkable
/// per round without post-processing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    Job = 0,
    SsRound = 1,
    Cohort = 2,
    KernelDispatch = 3,
    WalFlush = 4,
    Checkpoint = 5,
    Window = 6,
    Quarantine = 7,
    /// One framed message written to a cluster peer (coordinator → worker).
    RpcSend = 8,
    /// One framed message read from a cluster peer (worker → coordinator).
    RpcRecv = 9,
    /// One worker-local shard SS pass, as observed by the coordinator.
    ShardPrune = 10,
    /// The coordinator's final union → SS → maximizer merge pass.
    Merge = 11,
}

/// One recorded span: fixed-size POD, no heap references — what makes a
/// ring slot write allocation-free and the whole ring pre-reservable.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Monotone per-tracer sequence number (survives ring wrap — the
    /// exporters use it to order and to report drops).
    pub seq: u64,
    /// Span start, nanoseconds since the tracer's epoch.
    pub t_ns: u64,
    /// Span duration in nanoseconds (0 for instantaneous markers).
    pub dur_ns: u64,
    pub kind: EventKind,
    pub a: u64,
    pub b: u64,
    pub c: u64,
    pub d: u64,
}

/// The ring storage behind one mutex hold: pre-allocated slot buffer,
/// the next sequence number, and the scope label.
struct Ring {
    /// Pre-allocated at `enable`; pushed until `len == capacity`, then
    /// overwritten at `seq % capacity` (oldest-first eviction).
    buf: Vec<TraceEvent>,
    cap: usize,
    next_seq: u64,
    label: String,
}

impl Ring {
    fn record(&mut self, mut ev: TraceEvent) {
        if self.cap == 0 {
            return;
        }
        ev.seq = self.next_seq;
        self.next_seq += 1;
        if self.buf.len() < self.cap {
            // capacity was reserved up front, so this push cannot allocate
            self.buf.push(ev);
        } else {
            let i = (ev.seq % self.cap as u64) as usize;
            self.buf[i] = ev;
        }
    }

    /// Events oldest-first (ring order restored across wraps).
    fn events(&self) -> Vec<TraceEvent> {
        let len = self.buf.len() as u64;
        let first = self.next_seq - len;
        (first..self.next_seq)
            .map(|s| self.buf[(s % self.cap.max(1) as u64) as usize])
            .collect()
    }
}

/// A bounded, pre-allocated span collector — one per [`Metrics`] scope.
///
/// All methods take `&self`; recording is safe from any thread (one short
/// mutex hold per event). See the module docs for the cost model; the
/// summary: disabled ⇒ one relaxed load, enabled ⇒ clock read + lock +
/// slot write, never an allocation after [`enable`](Self::enable).
///
/// [`Metrics`]: crate::coordinator::Metrics
pub struct Tracer {
    enabled: AtomicBool,
    /// Per-tracer time origin — event timestamps are offsets from it, so
    /// they fit u64 nanoseconds and need no wall-clock at record time.
    epoch: Instant,
    ring: Mutex<Ring>,
}

impl Tracer {
    /// A disabled tracer with an empty (capacity-0) ring — the default a
    /// [`Metrics`](crate::coordinator::Metrics) scope starts with.
    pub fn disabled() -> Self {
        Self {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            ring: Mutex::new(Ring {
                buf: Vec::new(),
                cap: 0,
                next_seq: 0,
                label: String::new(),
            }),
        }
    }

    /// The shared always-disabled tracer — for call sites that need *a*
    /// tracer reference but have none threaded in (e.g. a bare
    /// [`MaximizerEngine`](crate::algorithms::MaximizerEngine)).
    pub fn noop() -> &'static Tracer {
        static NOOP: OnceLock<Tracer> = OnceLock::new();
        NOOP.get_or_init(Tracer::disabled)
    }

    /// Turn recording on with a freshly reserved ring of `capacity`
    /// events under `label` (the scope name the exporters attach).
    /// Discards anything previously recorded. This is the *only* method
    /// that allocates.
    pub fn enable(&self, label: &str, capacity: usize) {
        let mut ring = self.lock();
        ring.buf = Vec::with_capacity(capacity);
        ring.cap = capacity;
        ring.next_seq = 0;
        ring.label = label.to_string();
        drop(ring);
        self.enabled.store(true, Ordering::Release);
    }

    /// Stop recording; the ring's contents stay readable (dumpable).
    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Release);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Span-start timestamp for a later
    /// [`record_since`](Self::record_since). Disabled ⇒ returns 0 without
    /// reading the clock (the matching `record_since` will discard it).
    #[inline]
    pub fn start(&self) -> u64 {
        if !self.is_enabled() {
            return 0;
        }
        self.now_ns()
    }

    /// Record a span that started at `start_ns` (from [`start`](Self::start))
    /// and ends now. No-op when disabled.
    #[inline]
    pub fn record_since(&self, kind: EventKind, start_ns: u64, a: u64, b: u64, c: u64, d: u64) {
        if !self.is_enabled() {
            return;
        }
        let end = self.now_ns();
        self.push(TraceEvent {
            seq: 0,
            t_ns: start_ns,
            dur_ns: end.saturating_sub(start_ns),
            kind,
            a,
            b,
            c,
            d,
        });
    }

    /// Record an instantaneous marker (e.g. [`EventKind::Quarantine`]).
    /// No-op when disabled.
    #[inline]
    pub fn record_now(&self, kind: EventKind, a: u64, b: u64, c: u64, d: u64) {
        if !self.is_enabled() {
            return;
        }
        let t = self.now_ns();
        self.push(TraceEvent { seq: 0, t_ns: t, dur_ns: 0, kind, a, b, c, d });
    }

    /// Events currently held, oldest-first. Allocates the return vector
    /// (export path, not the hot path).
    pub fn events(&self) -> Vec<TraceEvent> {
        self.lock().events()
    }

    /// Events currently held in the ring.
    pub fn len(&self) -> usize {
        self.lock().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ring capacity (0 until [`enable`](Self::enable)).
    pub fn capacity(&self) -> usize {
        self.lock().cap
    }

    /// Events overwritten after the ring filled (flight-recorder drops).
    pub fn dropped(&self) -> u64 {
        let ring = self.lock();
        ring.next_seq - ring.buf.len() as u64
    }

    /// The scope label [`enable`](Self::enable) was called with.
    pub fn label(&self) -> String {
        self.lock().label.clone()
    }

    /// Discard recorded events, keeping the reserved ring and label.
    pub fn clear(&self) {
        let mut ring = self.lock();
        ring.buf.clear();
        ring.next_seq = 0;
    }

    #[inline]
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn push(&self, ev: TraceEvent) {
        self.lock().record(ev);
    }

    /// Poison-tolerant lock: a recorder that panicked mid-hold left at
    /// worst one half-written POD slot — the flight recorder must stay
    /// dumpable after exactly such a panic, so recover the guard.
    fn lock(&self) -> std::sync::MutexGuard<'_, Ring> {
        self.ring.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Self::disabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing_and_skips_the_clock() {
        let t = Tracer::disabled();
        assert!(!t.is_enabled());
        assert_eq!(t.start(), 0, "disabled start must not read the clock");
        t.record_since(EventKind::SsRound, 0, 1, 2, 3, 4);
        t.record_now(EventKind::Quarantine, 0, 0, 0, 0);
        assert_eq!(t.len(), 0);
        assert_eq!(t.capacity(), 0);
        assert!(Tracer::noop().events().is_empty());
    }

    #[test]
    fn enable_record_export_roundtrip() {
        let t = Tracer::disabled();
        t.enable("svc", 8);
        assert!(t.is_enabled());
        assert_eq!(t.capacity(), 8);
        assert_eq!(t.label(), "svc");
        let s = t.start();
        t.record_since(EventKind::SsRound, s, 100, 35, 6500, 10);
        t.record_now(EventKind::Quarantine, 0, 0, 0, 0);
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, EventKind::SsRound);
        assert_eq!(evs[0].seq, 0);
        assert_eq!((evs[0].a, evs[0].b), (100, 35));
        assert_eq!(evs[1].kind, EventKind::Quarantine);
        assert_eq!(evs[1].dur_ns, 0);
        assert!(evs[1].t_ns >= evs[0].t_ns, "events carry monotone timestamps");
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let t = Tracer::disabled();
        t.enable("ring", 4);
        for i in 0..10u64 {
            t.record_now(EventKind::Cohort, i, 0, 0, 0);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 6);
        let evs = t.events();
        // oldest-first, the final window of activity: payloads 6..=9
        let got: Vec<u64> = evs.iter().map(|e| e.a).collect();
        assert_eq!(got, vec![6, 7, 8, 9]);
        let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "seq numbers survive the wrap");
    }

    #[test]
    fn disable_retains_ring_and_clear_resets_it() {
        let t = Tracer::disabled();
        t.enable("fr", 4);
        t.record_now(EventKind::WalFlush, 64, 3, 0, 0);
        t.disable();
        assert!(!t.is_enabled());
        t.record_now(EventKind::WalFlush, 1, 4, 0, 0);
        assert_eq!(t.len(), 1, "disabled tracer must stop recording but keep the ring");
        assert_eq!(t.events()[0].a, 64);
        t.clear();
        assert_eq!(t.len(), 0);
        assert_eq!(t.capacity(), 4, "clear keeps the reservation");
    }

    #[test]
    fn re_enable_resets_sequence_and_label() {
        let t = Tracer::disabled();
        t.enable("first", 2);
        t.record_now(EventKind::Job, 1, 0, 0, 0);
        t.enable("second", 3);
        assert_eq!(t.label(), "second");
        assert_eq!(t.len(), 0);
        t.record_now(EventKind::Job, 2, 0, 0, 0);
        assert_eq!(t.events()[0].seq, 0, "enable restarts the sequence");
    }
}
