//! Minimal JSON value model + parser + writer (offline `serde_json` substitute).
//!
//! Used for: the artifacts manifest (read), experiment result dumps (write),
//! service request/response bodies, and metrics snapshots. Supports the full
//! JSON grammar except exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null"); // JSON has no inf/nan; metrics code never emits them
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Recursive-descent parser. Returns a descriptive error with byte offset.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing garbage at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("invalid number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // advance one UTF-8 scalar
                let ch_len = utf8_len(b[*pos]);
                let chunk = std::str::from_utf8(&b[*pos..*pos + ch_len])
                    .map_err(|_| format!("invalid utf8 at byte {pos}", pos = *pos))?;
                s.push_str(chunk);
                *pos += ch_len;
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected , or ] at byte {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected key string at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at byte {pos}", pos = *pos));
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(format!("expected , or }} at byte {pos}", pos = *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for (txt, v) in [
            ("null", Json::Null),
            ("true", Json::Bool(true)),
            ("false", Json::Bool(false)),
            ("42", Json::Num(42.0)),
            ("-1.5e3", Json::Num(-1500.0)),
            ("\"hi\"", Json::Str("hi".into())),
        ] {
            assert_eq!(parse(txt).unwrap(), v, "{txt}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5), Json::Null])),
            ("b", Json::obj(vec![("x", Json::Str("y\"z\n".into()))])),
            ("c", Json::Bool(false)),
        ]);
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn parses_manifest_like() {
        let text = r#"{"p": 32, "b": 256, "artifacts": {"edge_weights": {"file": "e.hlo.txt", "inputs": [[32,256],[32],[256,256]]}}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("p").unwrap().as_usize(), Some(32));
        let inputs =
            v.get("artifacts").unwrap().get("edge_weights").unwrap().get("inputs").unwrap();
        assert_eq!(inputs.as_arr().unwrap()[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"\\q\""] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
        let v = Json::Str("héllo→".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
