//! Dense/sparse feature-vector math shared by the submodular evaluators,
//! the CPU fallback kernels, and the dataset substrates.
//!
//! Item features live in a [`FeatureMatrix`] — row-major dense `f32` with a
//! fixed hashed dimension `d` (matching the AOT artifact geometry). Sparse
//! inputs (TF-IDF bags) are hashed into it at ingest.

/// Row-major dense matrix of item features, shape `(n, d)`.
#[derive(Clone, Debug, PartialEq)]
pub struct FeatureMatrix {
    pub d: usize,
    data: Vec<f32>,
}

impl FeatureMatrix {
    pub fn zeros(n: usize, d: usize) -> Self {
        Self { d, data: vec![0.0; n * d] }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in &rows {
            assert_eq!(r.len(), d, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { d, data }
    }

    pub fn n(&self) -> usize {
        if self.d == 0 {
            0
        } else {
            self.data.len() / self.d
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Append one row (streaming ingest). `row.len()` must equal `d`.
    #[inline]
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.d, "row width must match d");
        self.data.extend_from_slice(row);
    }

    /// Reserve capacity for `additional` more rows, so a streaming
    /// steady state of `push_row` calls never touches the allocator.
    pub fn reserve_rows(&mut self, additional: usize) {
        self.data.reserve(additional * self.d);
    }

    /// In-place compaction to the rows in `keep` (ascending, distinct):
    /// survivor `keep[i]` becomes row `i`. The streaming re-sparsifier uses
    /// this to drop evicted elements without reallocating the matrix.
    pub fn retain_rows(&mut self, keep: &[usize]) {
        let n = self.n();
        let d = self.d;
        let mut prev = None;
        for (new_i, &old_i) in keep.iter().enumerate() {
            assert!(old_i < n, "retain_rows index {old_i} out of range (n={n})");
            assert!(prev.map_or(true, |p| p < old_i), "retain_rows requires ascending indices");
            prev = Some(old_i);
            // old_i >= new_i always (ascending + distinct), so the source
            // block has not been overwritten yet
            if old_i != new_i {
                self.data.copy_within(old_i * d..(old_i + 1) * d, new_i * d);
            }
        }
        self.data.truncate(keep.len() * d);
    }

    /// Gather rows by index into a new matrix.
    pub fn gather(&self, idx: &[usize]) -> FeatureMatrix {
        let mut out = FeatureMatrix::zeros(idx.len(), self.d);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Column sums = total feature mass c(V).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut total = vec![0.0f32; self.d];
        for i in 0..self.n() {
            add_into(&mut total, self.row(i));
        }
        total
    }

    /// Scale all entries (e.g. normalizing synthetic features).
    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }
}

/// `acc += x` elementwise.
#[inline]
pub fn add_into(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, &b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

/// `acc -= x` elementwise, clamped at 0 (float-safe mass removal).
#[inline]
pub fn sub_clamp_into(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, &b) in acc.iter_mut().zip(x) {
        *a = (*a - b).max(0.0);
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Cosine similarity, 0 if either vector is zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let (na, nb) = (norm2(a), norm2(b));
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        (dot(a, b) / (na * nb)).clamp(-1.0, 1.0)
    }
}

/// Sparse vector in coordinate form (sorted unique indices).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl SparseVec {
    pub fn from_pairs(mut pairs: Vec<(u32, f32)>) -> Self {
        pairs.sort_unstable_by_key(|&(i, _)| i);
        let mut idx = Vec::with_capacity(pairs.len());
        let mut val: Vec<f32> = Vec::with_capacity(pairs.len());
        for (i, v) in pairs {
            if let Some(&last) = idx.last() {
                if last == i {
                    *val.last_mut().unwrap() += v;
                    continue;
                }
            }
            idx.push(i);
            val.push(v);
        }
        Self { idx, val }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Feature-hash into `d` dense dims with a sign hash (unsigned variant:
    /// the submodular objective needs non-negative mass, so we take |.| of
    /// the signed-hash accumulation per the "hashing trick, non-negative"
    /// convention).
    pub fn hash_into(&self, d: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), d);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            let h = hash_u32(i);
            out[(h as usize) % d] += v;
        }
    }
}

/// 32-bit finalizer (murmur3 fmix32) — stable feature hashing.
#[inline]
pub fn hash_u32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x85EB_CA6B);
    x ^= x >> 13;
    x = x.wrapping_mul(0xC2B2_AE35);
    x ^= x >> 16;
    x
}

/// FNV-1a for strings (token ids in the text pipeline).
pub fn hash_str(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_shape_and_rows() {
        let m = FeatureMatrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!((m.n(), m.d), (3, 2));
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.col_sums(), vec![9.0, 12.0]);
    }

    #[test]
    fn push_and_retain_rows() {
        let mut m = FeatureMatrix::zeros(0, 2);
        for i in 0..4 {
            m.push_row(&[i as f32, 10.0 + i as f32]);
        }
        assert_eq!(m.n(), 4);
        assert_eq!(m.row(3), &[3.0, 13.0]);
        m.retain_rows(&[0, 2, 3]);
        assert_eq!(m.n(), 3);
        assert_eq!(m.row(0), &[0.0, 10.0]);
        assert_eq!(m.row(1), &[2.0, 12.0]);
        assert_eq!(m.row(2), &[3.0, 13.0]);
        // identity retain is a no-op
        m.retain_rows(&[0, 1, 2]);
        assert_eq!(m.n(), 3);
        assert_eq!(m.row(1), &[2.0, 12.0]);
        // reserve keeps pushes allocation-free afterwards (behavioral check
        // lives in tests/alloc_steady_state.rs; here just exercise the API)
        m.reserve_rows(8);
        m.push_row(&[9.0, 19.0]);
        assert_eq!(m.n(), 4);
    }

    #[test]
    fn gather_rows() {
        let m = FeatureMatrix::from_rows(vec![vec![1.0], vec![2.0], vec![3.0]]);
        let g = m.gather(&[2, 0]);
        assert_eq!(g.row(0), &[3.0]);
        assert_eq!(g.row(1), &[1.0]);
    }

    #[test]
    fn add_sub_clamp() {
        let mut acc = vec![1.0f32, 2.0];
        add_into(&mut acc, &[0.5, 0.5]);
        assert_eq!(acc, vec![1.5, 2.5]);
        sub_clamp_into(&mut acc, &[2.0, 1.0]);
        assert_eq!(acc, vec![0.0, 1.5]);
    }

    #[test]
    fn cosine_props() {
        let a = vec![1.0f32, 0.0];
        let b = vec![0.0f32, 2.0];
        assert_eq!(cosine(&a, &b), 0.0);
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-6);
        assert_eq!(cosine(&a, &[0.0, 0.0]), 0.0);
    }

    #[test]
    fn sparse_from_pairs_merges_dups() {
        let s = SparseVec::from_pairs(vec![(5, 1.0), (2, 2.0), (5, 3.0)]);
        assert_eq!(s.idx, vec![2, 5]);
        assert_eq!(s.val, vec![2.0, 4.0]);
    }

    #[test]
    fn hashing_deterministic_and_spread() {
        let s = SparseVec::from_pairs((0..100).map(|i| (i, 1.0)).collect());
        let mut a = vec![0.0f32; 16];
        let mut b = vec![0.0f32; 16];
        s.hash_into(16, &mut a);
        s.hash_into(16, &mut b);
        assert_eq!(a, b);
        assert_eq!(a.iter().sum::<f32>(), 100.0, "mass preserved");
        let occupied = a.iter().filter(|&&x| x > 0.0).count();
        assert!(occupied >= 12, "hash should spread: {occupied}/16");
    }

    #[test]
    fn str_hash_stable() {
        assert_eq!(hash_str("summarize"), hash_str("summarize"));
        assert_ne!(hash_str("a"), hash_str("b"));
    }
}
