//! Foundation substrates built in-repo (the offline environment has no
//! rand / rayon / tokio / clap / serde / proptest — see DESIGN.md §3).

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod select;
pub mod stats;
pub mod vecmath;
