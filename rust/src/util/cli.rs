//! Minimal subcommand-style CLI parser (offline `clap` substitute).
//!
//! Grammar: `ssctl <subcommand> [--flag] [--key value] [positional...]`.
//! Flags declared ahead of parsing get typed accessors + generated help;
//! unknown flags are an error (catches typos in experiment scripts).

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A declared subcommand with its flags.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, flags: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, takes_value: true, default: Some(default) });
        self
    }

    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, takes_value: true, default: None });
        self
    }
}

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.get(name).unwrap_or_else(|| panic!("missing required --{name}")).to_string()
    }

    pub fn usize(&self, name: &str) -> usize {
        self.str(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.str(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.str(name).parse().unwrap_or_else(|_| panic!("--{name} must be a number"))
    }
}

/// Top-level application: subcommand registry + help.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

pub enum Parsed {
    /// (subcommand name, parsed args)
    Run(String, Args),
    /// help text to print, exit 0
    Help(String),
    /// error text to print, exit 2
    Error(String),
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, commands: Vec::new() }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    pub fn parse(&self, argv: &[String]) -> Parsed {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Parsed::Help(self.help());
        }
        let sub = &argv[0];
        let Some(cmd) = self.commands.iter().find(|c| c.name == sub) else {
            return Parsed::Error(format!(
                "unknown subcommand '{sub}'\n\n{help}",
                help = self.help()
            ));
        };
        let mut args = Args::default();
        // seed defaults
        for f in &cmd.flags {
            if let Some(d) = f.default {
                args.values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Parsed::Help(self.command_help(cmd));
            }
            if let Some(name) = tok.strip_prefix("--") {
                // allow --key=value
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let Some(spec) = cmd.flags.iter().find(|f| f.name == name) else {
                    return Parsed::Error(format!(
                        "unknown flag --{name} for '{sub}'\n\n{help}",
                        help = self.command_help(cmd)
                    ));
                };
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            match argv.get(i) {
                                Some(v) => v.clone(),
                                None => return Parsed::Error(format!("--{name} needs a value")),
                            }
                        }
                    };
                    args.values.insert(name.to_string(), value);
                } else {
                    if inline.is_some() {
                        return Parsed::Error(format!("--{name} takes no value"));
                    }
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        // check required opts
        for f in &cmd.flags {
            if f.takes_value && f.default.is_none() && !args.values.contains_key(f.name) {
                return Parsed::Error(format!("'{sub}' requires --{name}", name = f.name));
            }
        }
        Parsed::Run(sub.clone(), args)
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [flags]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        s.push_str("\nRun '<command> --help' for per-command flags.\n");
        s
    }

    fn command_help(&self, c: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nFLAGS:\n", self.name, c.name, c.about);
        for f in &c.flags {
            let kind = if f.takes_value {
                match f.default {
                    Some(d) => format!("<value> (default: {d})"),
                    None => "<value> (required)".to_string(),
                }
            } else {
                String::new()
            };
            s.push_str(&format!("  --{:<18} {} {}\n", f.name, f.help, kind));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("ssctl", "test app").command(
            Command::new("summarize", "run a summary")
                .opt("k", "10", "budget")
                .opt("method", "ss", "algorithm")
                .opt_req("dataset", "dataset name")
                .flag("verbose", "log more"),
        )
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let p = app().parse(&sv(&[
            "summarize", "--k", "25", "--dataset", "news", "--verbose", "extra",
        ]));
        match p {
            Parsed::Run(name, args) => {
                assert_eq!(name, "summarize");
                assert_eq!(args.usize("k"), 25);
                assert_eq!(args.str("method"), "ss"); // default
                assert_eq!(args.str("dataset"), "news");
                assert!(args.has("verbose"));
                assert_eq!(args.positional, vec!["extra"]);
            }
            _ => panic!("expected Run"),
        }
    }

    #[test]
    fn equals_syntax() {
        match app().parse(&sv(&["summarize", "--k=7", "--dataset=x"])) {
            Parsed::Run(_, args) => assert_eq!(args.usize("k"), 7),
            _ => panic!(),
        }
    }

    #[test]
    fn missing_required_is_error() {
        assert!(matches!(app().parse(&sv(&["summarize"])), Parsed::Error(_)));
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(matches!(
            app().parse(&sv(&["summarize", "--dataset", "d", "--bogus"])),
            Parsed::Error(_)
        ));
    }

    #[test]
    fn unknown_subcommand_is_error() {
        assert!(matches!(app().parse(&sv(&["frobnicate"])), Parsed::Error(_)));
    }

    #[test]
    fn help_paths() {
        assert!(matches!(app().parse(&sv(&[])), Parsed::Help(_)));
        assert!(matches!(app().parse(&sv(&["--help"])), Parsed::Help(_)));
        match app().parse(&sv(&["summarize", "--help"])) {
            Parsed::Help(h) => assert!(h.contains("--dataset")),
            _ => panic!(),
        }
    }
}
