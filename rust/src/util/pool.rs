//! Thread pool + parallel-for (the offline substitute for rayon/tokio).
//!
//! The SS coordinator's per-round edge-weight computation is embarrassingly
//! parallel across item shards; this pool is the substrate that carries it.
//! Design points:
//!
//! * **bounded injection queue** — `submit` blocks when the queue is full,
//!   which is the coordinator's backpressure mechanism (a leader cannot race
//!   ahead of PJRT executors);
//! * **positional gather** — [`parallel_map`] returns results in input
//!   order regardless of scheduling, so parallel SS is bit-deterministic;
//! * **panic propagation** — a panicking job poisons the pool and surfaces
//!   on the next call rather than deadlocking.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    rx: Mutex<Receiver<Job>>,
    panicked: AtomicBool,
    active: AtomicUsize,
}

/// Fixed-size worker pool over a bounded MPMC (mutexed mpsc) queue.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// `threads` workers, queue bounded at `queue_cap` pending jobs.
    pub fn new(threads: usize, queue_cap: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = sync_channel::<Job>(queue_cap.max(1));
        let shared = Arc::new(Shared {
            rx: Mutex::new(rx),
            panicked: AtomicBool::new(false),
            active: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ss-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let rx = shared.rx.lock().unwrap();
                            rx.recv()
                        };
                        match job {
                            Ok(job) => {
                                shared.active.fetch_add(1, Ordering::SeqCst);
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    shared.panicked.store(true, Ordering::SeqCst);
                                }
                                shared.active.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), shared, workers }
    }

    /// Pool sized for this machine (≥2 so copy/compute overlap exists even
    /// on the 1-core CI container).
    pub fn default_for_host() -> Self {
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Self::new(n.max(2), 64)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job. Blocks when the queue is full
    /// (backpressure). Panics if a previous job panicked.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        assert!(!self.shared.panicked.load(Ordering::SeqCst), "pool poisoned by a panicked job");
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool shut down");
    }

    /// Map `f` over `items` in parallel; results are gathered positionally.
    ///
    /// Chunking: items are dealt in contiguous chunks of `chunk` to bound
    /// per-job overhead; `chunk = 0` auto-sizes to `len / (4 * threads)`.
    pub fn parallel_map<T, R, F>(&self, items: Vec<T>, chunk: usize, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let chunk = if chunk == 0 { (n / (4 * self.threads().max(1))).max(1) } else { chunk };
        let f = Arc::new(f);
        let (rtx, rrx) = std::sync::mpsc::channel::<(usize, Vec<R>)>();
        let mut jobs = 0usize;
        let mut items = items.into_iter();
        let mut start = 0usize;
        loop {
            let batch: Vec<T> = items.by_ref().take(chunk).collect();
            if batch.is_empty() {
                break;
            }
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            let idx = start;
            start += batch.len();
            jobs += 1;
            self.submit(move || {
                let out: Vec<R> = batch.into_iter().map(|x| f(x)).collect();
                let _ = rtx.send((idx, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<Vec<R>>> = (0..jobs).map(|_| None).collect();
        let mut order: Vec<(usize, usize)> = Vec::with_capacity(jobs); // (start, slot)
        for k in 0..jobs {
            let (idx, out) = rrx.recv().expect("worker dropped result (panic?)");
            order.push((idx, k));
            slots[k] = Some(out);
        }
        order.sort_unstable();
        let mut result = Vec::with_capacity(n);
        for (_, slot) in order {
            result.extend(slots[slot].take().unwrap());
        }
        assert!(!self.shared.panicked.load(Ordering::SeqCst), "job panicked during parallel_map");
        result
    }

    /// Parallel-for over index ranges: `f(lo, hi)` per shard, results
    /// gathered in shard order. The coordinator uses this to process item
    /// shards against a shared read-only context.
    pub fn parallel_ranges<R, F>(&self, n: usize, shards: usize, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(usize, usize) -> R + Send + Sync + 'static,
    {
        if n == 0 {
            return Vec::new();
        }
        let shards = shards.clamp(1, n);
        let per = n.div_ceil(shards);
        let ranges: Vec<(usize, usize)> =
            (0..shards).map(|s| (s * per, ((s + 1) * per).min(n))).filter(|(a, b)| a < b).collect();
        self.parallel_map(ranges, 1, move |(lo, hi)| f(lo, hi))
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel: workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 8);
        let counter = Arc::new(AtomicU64::new(0));
        let done = Arc::new(std::sync::Barrier::new(1));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(done);
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(4, 8);
        let items: Vec<usize> = (0..1000).collect();
        let out = pool.parallel_map(items, 7, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let pool = ThreadPool::new(2, 4);
        let out: Vec<usize> = pool.parallel_map(Vec::<usize>::new(), 0, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_ranges_cover_exactly() {
        let pool = ThreadPool::new(3, 8);
        let out = pool.parallel_ranges(103, 7, |lo, hi| (lo, hi));
        let mut total = 0;
        let mut expect_lo = 0;
        for (lo, hi) in out {
            assert_eq!(lo, expect_lo);
            assert!(hi > lo);
            total += hi - lo;
            expect_lo = hi;
        }
        assert_eq!(total, 103);
    }

    #[test]
    fn parallel_ranges_more_shards_than_items() {
        let pool = ThreadPool::new(2, 4);
        let out = pool.parallel_ranges(3, 16, |lo, hi| hi - lo);
        assert_eq!(out.iter().sum::<usize>(), 3);
    }

    #[test]
    #[should_panic(expected = "panic")]
    fn panic_propagates() {
        let pool = ThreadPool::new(2, 4);
        let out = pool.parallel_map(vec![1, 2, 3], 1, |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
        let _ = out;
    }

    #[test]
    fn heavy_contention_smoke() {
        let pool = ThreadPool::new(8, 4); // queue smaller than job count
        let out = pool.parallel_map((0..10_000).collect::<Vec<u64>>(), 13, |x| x % 7);
        assert_eq!(out.len(), 10_000);
        assert_eq!(out[6], 6 % 7);
    }
}
