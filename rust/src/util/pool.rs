//! Thread pool + parallel-for (the offline substitute for rayon/tokio).
//!
//! The SS coordinator's per-round edge-weight computation is embarrassingly
//! parallel across item shards; this pool is the substrate that carries it.
//! Design points:
//!
//! * **bounded injection queue** — `submit` blocks when the queue is full,
//!   which is the coordinator's backpressure mechanism (a leader cannot race
//!   ahead of PJRT executors);
//! * **positional gather** — [`parallel_map`] returns results in input
//!   order regardless of scheduling, so parallel SS is bit-deterministic;
//! * **panic propagation** — a panicking job poisons the pool and surfaces
//!   on the next call rather than deadlocking.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    rx: Mutex<Receiver<Job>>,
    panicked: AtomicBool,
    active: AtomicUsize,
}

/// Fixed-size worker pool over a bounded MPMC (mutexed mpsc) queue.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// `threads` workers, queue bounded at `queue_cap` pending jobs.
    pub fn new(threads: usize, queue_cap: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = sync_channel::<Job>(queue_cap.max(1));
        let shared = Arc::new(Shared {
            rx: Mutex::new(rx),
            panicked: AtomicBool::new(false),
            active: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ss-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let rx = shared.rx.lock().unwrap();
                            rx.recv()
                        };
                        match job {
                            Ok(job) => {
                                shared.active.fetch_add(1, Ordering::SeqCst);
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    shared.panicked.store(true, Ordering::SeqCst);
                                }
                                shared.active.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), shared, workers }
    }

    /// Pool sized for this machine (≥2 so copy/compute overlap exists even
    /// on the 1-core CI container).
    pub fn default_for_host() -> Self {
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Self::new(n.max(2), 64)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job. Blocks when the queue is full
    /// (backpressure). Panics if a previous job panicked.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        assert!(!self.shared.panicked.load(Ordering::SeqCst), "pool poisoned by a panicked job");
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool shut down");
    }

    /// Map `f` over `items` in parallel; results are gathered positionally.
    ///
    /// Chunking: items are dealt in contiguous chunks of `chunk` to bound
    /// per-job overhead; `chunk = 0` auto-sizes to `len / (4 * threads)`.
    pub fn parallel_map<T, R, F>(&self, items: Vec<T>, chunk: usize, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let chunk = if chunk == 0 { (n / (4 * self.threads().max(1))).max(1) } else { chunk };
        let f = Arc::new(f);
        let (rtx, rrx) = std::sync::mpsc::channel::<(usize, Vec<R>)>();
        let mut jobs = 0usize;
        let mut items = items.into_iter();
        let mut start = 0usize;
        loop {
            let batch: Vec<T> = items.by_ref().take(chunk).collect();
            if batch.is_empty() {
                break;
            }
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            let idx = start;
            start += batch.len();
            jobs += 1;
            self.submit(move || {
                let out: Vec<R> = batch.into_iter().map(|x| f(x)).collect();
                let _ = rtx.send((idx, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<Vec<R>>> = (0..jobs).map(|_| None).collect();
        let mut order: Vec<(usize, usize)> = Vec::with_capacity(jobs); // (start, slot)
        for k in 0..jobs {
            let (idx, out) = rrx.recv().expect("worker dropped result (panic?)");
            order.push((idx, k));
            slots[k] = Some(out);
        }
        order.sort_unstable();
        let mut result = Vec::with_capacity(n);
        for (_, slot) in order {
            result.extend(slots[slot].take().unwrap());
        }
        assert!(!self.shared.panicked.load(Ordering::SeqCst), "job panicked during parallel_map");
        result
    }

    /// Scoped parallel-for over **disjoint slices of one output buffer**:
    /// shard `s` covering `[lo, hi)` runs `f(lo, hi, &mut out[lo..hi])`.
    ///
    /// This is the write-into substrate of the SS round loop: divergence
    /// shards write straight into the caller's preallocated round buffer,
    /// so there is no per-shard `Vec`, no gather/flatten copy, and —
    /// because neither `f` nor `out` needs `'static` — the closure borrows
    /// round-local state (probes, items, singleton slices) directly
    /// instead of cloning it into `Arc`s.
    ///
    /// Blocks until every shard has completed; a panicking shard poisons
    /// the pool and re-panics here after the remaining shards finish.
    /// Shard geometry matches [`parallel_ranges`] (`ceil(n/shards)` per
    /// shard), and each output element belongs to exactly one shard.
    pub fn parallel_ranges_into<T, F>(&self, out: &mut [T], shards: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        let n = out.len();
        if n == 0 {
            return;
        }
        let shards = shards.clamp(1, n);
        let per = n.div_ceil(shards);
        let latch = Arc::new(Latch::default());
        let enqueued = std::cell::Cell::new(0usize);
        // Declared after `latch`/`enqueued` so it drops first: whether this
        // frame exits normally or unwinds (e.g. `submit` panicking on a
        // poisoned pool), we wait for every enqueued job before the borrows
        // of `out` and `f` end. That wait is what makes the lifetime
        // erasure below sound.
        let guard = WaitGuard { latch: &latch, enqueued: &enqueued };
        for (s, chunk) in out.chunks_mut(per).enumerate() {
            let lo = s * per;
            let hi = lo + chunk.len();
            let fref = &f;
            let job_latch = Arc::clone(&latch);
            let job = move || {
                // bump-on-drop: the latch fires even if `fref` panics
                let _done = CompletionGuard(job_latch);
                fref(lo, hi, chunk);
            };
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(job);
            // SAFETY: `WaitGuard` blocks this frame until the completion
            // latch has fired once per enqueued job, and the latch fires in
            // a drop guard that runs even on panic — so the borrows inside
            // `job` (the `out` chunk and `&f`) strictly outlive its
            // execution. The transmute only erases the borrow lifetime; the
            // layout of the boxed trait object is unchanged.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            self.submit(job);
            enqueued.set(enqueued.get() + 1);
        }
        drop(guard); // wait for all shards
        // the latch's own flag, stored before the final bump, is the
        // deterministic signal — the pool's global `panicked` flag may not
        // be set yet when the leader wakes
        assert!(
            !latch.panicked.load(Ordering::SeqCst),
            "job panicked during parallel_ranges_into"
        );
    }

    /// Parallel-for over index ranges: `f(lo, hi)` per shard, results
    /// gathered in shard order. The coordinator uses this to process item
    /// shards against a shared read-only context.
    pub fn parallel_ranges<R, F>(&self, n: usize, shards: usize, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(usize, usize) -> R + Send + Sync + 'static,
    {
        if n == 0 {
            return Vec::new();
        }
        let shards = shards.clamp(1, n);
        let per = n.div_ceil(shards);
        let ranges: Vec<(usize, usize)> =
            (0..shards).map(|s| (s * per, ((s + 1) * per).min(n))).filter(|(a, b)| a < b).collect();
        self.parallel_map(ranges, 1, move |(lo, hi)| f(lo, hi))
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel: workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Count-up completion latch for [`ThreadPool::parallel_ranges_into`].
/// Carries its own panic flag so the leader's check is deterministic: the
/// flag is stored *before* the completion bump that wakes the leader
/// (the pool's global `panicked` flag is only set after the worker's
/// `catch_unwind` returns, which can race the leader's wakeup).
#[derive(Default)]
struct Latch {
    done: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn bump(&self) {
        let mut d = self.done.lock().unwrap();
        *d += 1;
        self.cv.notify_all();
    }

    fn wait_for(&self, target: usize) {
        let mut d = self.done.lock().unwrap();
        while *d < target {
            d = self.cv.wait(d).unwrap();
        }
    }
}

/// Fires the latch when a job finishes — including by panic, since drop
/// guards run during unwinding (detected via `std::thread::panicking`,
/// recorded before the bump so the leader always observes it).
struct CompletionGuard(Arc<Latch>);

impl Drop for CompletionGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.panicked.store(true, Ordering::SeqCst);
        }
        self.0.bump();
    }
}

/// Blocks (on drop) until every job enqueued so far has completed.
struct WaitGuard<'a> {
    latch: &'a Latch,
    enqueued: &'a std::cell::Cell<usize>,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.latch.wait_for(self.enqueued.get());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 8);
        let counter = Arc::new(AtomicU64::new(0));
        let done = Arc::new(std::sync::Barrier::new(1));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(done);
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(4, 8);
        let items: Vec<usize> = (0..1000).collect();
        let out = pool.parallel_map(items, 7, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let pool = ThreadPool::new(2, 4);
        let out: Vec<usize> = pool.parallel_map(Vec::<usize>::new(), 0, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_ranges_cover_exactly() {
        let pool = ThreadPool::new(3, 8);
        let out = pool.parallel_ranges(103, 7, |lo, hi| (lo, hi));
        let mut total = 0;
        let mut expect_lo = 0;
        for (lo, hi) in out {
            assert_eq!(lo, expect_lo);
            assert!(hi > lo);
            total += hi - lo;
            expect_lo = hi;
        }
        assert_eq!(total, 103);
    }

    #[test]
    fn parallel_ranges_more_shards_than_items() {
        let pool = ThreadPool::new(2, 4);
        let out = pool.parallel_ranges(3, 16, |lo, hi| hi - lo);
        assert_eq!(out.iter().sum::<usize>(), 3);
    }

    #[test]
    fn parallel_ranges_into_writes_each_slot_exactly_once() {
        let pool = ThreadPool::new(4, 8);
        for (n, shards) in [(103usize, 7usize), (64, 64), (5, 16), (1000, 3), (17, 1)] {
            // each shard *adds* to its slots, so a double write (overlapping
            // shards) or a missed write would both break the value check
            let mut out: Vec<usize> = (0..n).map(|i| i * 1000).collect();
            pool.parallel_ranges_into(&mut out[..], shards, |lo, _hi, chunk| {
                for (off, slot) in chunk.iter_mut().enumerate() {
                    *slot += lo + off + 1;
                }
            });
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i * 1000 + i + 1, "slot {i} written exactly once (n={n}, shards={shards})");
            }
        }
    }

    #[test]
    fn parallel_ranges_into_borrows_without_arc() {
        // the whole point of the scoped API: borrow non-'static state
        let pool = ThreadPool::new(3, 8);
        let items: Vec<usize> = (0..257).map(|i| i * 2).collect();
        let bias = 7usize;
        let mut out = vec![0usize; items.len()];
        pool.parallel_ranges_into(&mut out[..], 5, |lo, hi, chunk| {
            for (slot, &v) in chunk.iter_mut().zip(&items[lo..hi]) {
                *slot = v + bias;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * 2 + 7);
        }
    }

    #[test]
    fn parallel_ranges_into_empty_is_noop() {
        let pool = ThreadPool::new(2, 4);
        let mut out: Vec<f32> = Vec::new();
        pool.parallel_ranges_into(&mut out[..], 4, |_, _, _| panic!("must not run"));
    }

    #[test]
    #[should_panic(expected = "parallel_ranges_into")]
    fn parallel_ranges_into_propagates_panic() {
        let pool = ThreadPool::new(2, 4);
        let mut out = vec![0u8; 16];
        pool.parallel_ranges_into(&mut out[..], 4, |lo, _, _| {
            if lo == 0 {
                panic!("shard boom");
            }
        });
    }

    #[test]
    #[should_panic(expected = "panic")]
    fn panic_propagates() {
        let pool = ThreadPool::new(2, 4);
        let out = pool.parallel_map(vec![1, 2, 3], 1, |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
        let _ = out;
    }

    #[test]
    fn heavy_contention_smoke() {
        let pool = ThreadPool::new(8, 4); // queue smaller than job count
        let out = pool.parallel_map((0..10_000).collect::<Vec<u64>>(), 13, |x| x % 7);
        assert_eq!(out.len(), 10_000);
        assert_eq!(out[6], 6 % 7);
    }
}
