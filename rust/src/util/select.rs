//! Selection primitives on `f32` keys: quickselect, top-k, lazy max-heap.
//!
//! SS's per-round prune (Algorithm 1 line 11: "remove the `(1-1/√c)|V|`
//! items with smallest `w_{Uv}`") is a selection problem — sorting the whole
//! weight vector every round would add an `O(n log n)` term the paper
//! explicitly avoids. `partition_smallest` is the O(n) hot-path version;
//! [`LazyMaxHeap`] carries the lazy-greedy algorithm [Minoux '78].

use std::cmp::Ordering;

/// Indices of the `k` smallest keys (unordered), via iterative quickselect
/// on an index permutation. Ties broken arbitrarily but deterministically
/// (pivot choice is deterministic). O(n) expected.
pub fn partition_smallest(keys: &[f32], k: usize) -> Vec<usize> {
    let n = keys.len();
    assert!(k <= n, "k={k} > n={n}");
    if k == 0 {
        return Vec::new();
    }
    if k == n {
        return (0..n).collect();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    let (mut lo, mut hi) = (0usize, n);
    let mut want = k;
    // Invariant: idx[..lo] are all among the k smallest; we still need
    // `want - 0` more from idx[lo..hi]... maintained via want relative to lo.
    while lo < hi {
        // median-of-three pivot for adversarial robustness
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (keys[idx[lo]], keys[idx[mid]], keys[idx[hi - 1]]);
        let pivot = median3(a, b, c);
        // 3-way partition by key vs pivot
        let (mut i, mut j, mut p) = (lo, lo, hi);
        // [lo,i): < pivot, [i,j): == pivot, [j,p): unseen, [p,hi): > pivot
        while j < p {
            let kj = keys[idx[j]];
            match kj.partial_cmp(&pivot).unwrap_or(Ordering::Equal) {
                Ordering::Less => {
                    idx.swap(i, j);
                    i += 1;
                    j += 1;
                }
                Ordering::Equal => j += 1,
                Ordering::Greater => {
                    p -= 1;
                    idx.swap(j, p);
                }
            }
        }
        let less = i - lo;
        let eq = j - i;
        if want < less {
            hi = i;
        } else if want <= less + eq {
            // the boundary falls inside the equal run: take what we need
            let _boundary = i + (want - less);
            break;
        } else {
            want -= less + eq;
            lo = j;
        }
        if want == 0 {
            break;
        }
        // `want` is relative to current lo after the narrowing above
        if hi <= lo {
            break;
        }
    }
    idx.truncate(k);
    idx
}

fn median3(a: f32, b: f32, c: f32) -> f32 {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    if c < lo {
        lo
    } else if c > hi {
        hi
    } else {
        c
    }
}

/// Indices of the `k` largest keys, descending by key. O(n log k).
pub fn top_k_desc(keys: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(keys.len());
    if k == 0 {
        return Vec::new();
    }
    // min-heap of (key, idx) capped at k
    let mut heap: Vec<(f32, usize)> = Vec::with_capacity(k + 1);
    for (i, &key) in keys.iter().enumerate() {
        if heap.len() < k {
            heap.push((key, i));
            if heap.len() == k {
                heap.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            }
        } else if key > heap[0].0 {
            // replace min; keep sorted-ascending (k is small in our uses)
            heap[0] = (key, i);
            let mut j = 0;
            while j + 1 < heap.len() && heap[j].0 > heap[j + 1].0 {
                heap.swap(j, j + 1);
                j += 1;
            }
        }
    }
    heap.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    heap.into_iter().map(|(_, i)| i).collect()
}

/// The k-th smallest key value (0-indexed: `kth_smallest(keys, 0)` = min).
pub fn kth_smallest(keys: &[f32], k: usize) -> f32 {
    let idx = partition_smallest(keys, k + 1);
    idx.iter().map(|&i| keys[i]).fold(f32::NEG_INFINITY, f32::max)
}

/// Max-heap over `(priority, id)` with *lazy* stale-entry invalidation —
/// the data structure behind lazy greedy [Minoux '78] and the batcher's
/// deadline queue. `push` never removes old entries; `pop_if_fresh`
/// validates against a user version map.
pub struct LazyMaxHeap {
    heap: std::collections::BinaryHeap<HeapEntry>,
}

#[derive(PartialEq)]
struct HeapEntry {
    priority: f32,
    id: usize,
    version: u64,
}

impl Eq for HeapEntry {}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.priority
            .partial_cmp(&other.priority)
            .unwrap_or(Ordering::Equal)
            .then(other.id.cmp(&self.id)) // deterministic tie-break: lower id wins
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Default for LazyMaxHeap {
    fn default() -> Self {
        Self::new()
    }
}

impl LazyMaxHeap {
    pub fn new() -> Self {
        Self { heap: std::collections::BinaryHeap::new() }
    }

    pub fn push(&mut self, id: usize, priority: f32, version: u64) {
        self.heap.push(HeapEntry { priority, id, version });
    }

    /// Pop the max entry whose version matches `current[id]`; stale entries
    /// are discarded on the way. Returns `(id, priority)`.
    pub fn pop_fresh(&mut self, current: &[u64]) -> Option<(usize, f32)> {
        while let Some(e) = self.heap.pop() {
            if current[e.id] == e.version {
                return Some((e.id, e.priority));
            }
        }
        None
    }

    /// Peek at the max entry (possibly stale).
    pub fn peek(&self) -> Option<(usize, f32)> {
        self.heap.peek().map(|e| (e.id, e.priority))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_partition(keys: &[f32], k: usize) {
        let got = partition_smallest(keys, k);
        assert_eq!(got.len(), k);
        let mut sorted: Vec<f32> = keys.to_vec();
        sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let thresh = if k == 0 { f32::NEG_INFINITY } else { sorted[k - 1] };
        // every selected key <= threshold, and the multiset matches
        let mut got_keys: Vec<f32> = got.iter().map(|&i| keys[i]).collect();
        got_keys.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(&got_keys[..], &sorted[..k], "k={k}");
        assert!(got_keys.iter().all(|&x| x <= thresh));
        // indices distinct
        let mut g = got.clone();
        g.sort_unstable();
        g.dedup();
        assert_eq!(g.len(), k);
    }

    #[test]
    fn partition_matches_sort_random() {
        let mut rng = Rng::new(1);
        for trial in 0..100 {
            let n = rng.range(1, 200);
            let keys: Vec<f32> = (0..n).map(|_| rng.f32() * 10.0 - 5.0).collect();
            let k = rng.range(0, n + 1);
            check_partition(&keys, k);
            let _ = trial;
        }
    }

    #[test]
    fn partition_with_ties() {
        let keys = vec![1.0f32, 1.0, 1.0, 1.0, 2.0, 0.5];
        for k in 0..=6 {
            check_partition(&keys, k);
        }
    }

    #[test]
    fn partition_all_equal() {
        let keys = vec![3.3f32; 17];
        for k in [0, 1, 8, 17] {
            check_partition(&keys, k);
        }
    }

    #[test]
    fn kth_smallest_matches_sort() {
        let mut rng = Rng::new(2);
        let keys: Vec<f32> = (0..101).map(|_| rng.f32()).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        for k in [0, 1, 50, 100] {
            assert_eq!(kth_smallest(&keys, k), sorted[k]);
        }
    }

    #[test]
    fn top_k_desc_ordered() {
        let keys = vec![0.1f32, 5.0, 3.0, 3.0, -1.0, 7.5];
        assert_eq!(top_k_desc(&keys, 3), vec![5, 1, 2]);
        assert_eq!(top_k_desc(&keys, 0), Vec::<usize>::new());
        assert_eq!(top_k_desc(&keys, 100).len(), 6);
    }

    #[test]
    fn lazy_heap_basic() {
        let mut h = LazyMaxHeap::new();
        let versions = vec![0u64, 0, 0];
        h.push(0, 1.0, 0);
        h.push(1, 3.0, 0);
        h.push(2, 2.0, 0);
        assert_eq!(h.pop_fresh(&versions), Some((1, 3.0)));
        assert_eq!(h.pop_fresh(&versions), Some((2, 2.0)));
        assert_eq!(h.pop_fresh(&versions), Some((0, 1.0)));
        assert_eq!(h.pop_fresh(&versions), None);
    }

    #[test]
    fn lazy_heap_discards_stale() {
        let mut h = LazyMaxHeap::new();
        let mut versions = vec![0u64, 0];
        h.push(0, 5.0, 0); // will become stale
        versions[0] = 1;
        h.push(0, 2.0, 1);
        h.push(1, 3.0, 0);
        assert_eq!(h.pop_fresh(&versions), Some((1, 3.0)));
        assert_eq!(h.pop_fresh(&versions), Some((0, 2.0)));
    }

    #[test]
    fn lazy_heap_deterministic_ties() {
        let mut h = LazyMaxHeap::new();
        let versions = vec![0u64; 4];
        for id in [3, 1, 2, 0] {
            h.push(id, 1.0, 0);
        }
        assert_eq!(h.pop_fresh(&versions).unwrap().0, 0, "lowest id wins ties");
    }
}
