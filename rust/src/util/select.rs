//! Selection primitives on `f32` keys: quickselect, top-k, lazy max-heap.
//!
//! SS's per-round prune (Algorithm 1 line 11: "remove the `(1-1/√c)|V|`
//! items with smallest `w_{Uv}`") is a selection problem — sorting the whole
//! weight vector every round would add an `O(n log n)` term the paper
//! explicitly avoids. [`partition_smallest`] is the allocating O(n)
//! version; [`prune_smallest_paired`] is its in-place successor, fusing
//! selection and compaction over parallel `(keys, values)` arrays so the
//! SS round loop prunes with zero steady-state allocations.
//! [`LazyMaxHeap`] carries the lazy-greedy algorithm [Minoux '78].
//!
//! ## Canonical selection order (NaN and tie policy)
//!
//! Both selectors rank elements by the **same total order**: `f32::total_cmp`
//! on the key, so `−NaN < −∞ < finite < +∞ < NaN` — a NaN with the sign
//! bit clear (the usual result of float arithmetic) ranks *largest* and is
//! pruned last, while a sign-bit-set −NaN ranks smallest and is pruned
//! first; ties are broken by **ascending index/position**. The selected
//! set is therefore a pure function of the input — no dependence on pivot
//! luck — which is what lets the arena round loop in
//! [`crate::algorithms::ss`] stay bit-identical to its fresh-allocation
//! reference on tied and non-finite inputs alike (both paths apply this
//! same order, whatever the NaN's sign).

use std::cmp::Ordering;

/// The module-wide canonical order: key by `total_cmp`, then index.
/// Distinct indices make this a strict total order — no two elements ever
/// compare equal, so every selection below is uniquely determined.
#[inline]
fn cmp_key_idx(a: (f32, usize), b: (f32, usize)) -> Ordering {
    a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1))
}

/// Indices of the `k` smallest keys (unordered) under the canonical
/// `(total_cmp key, index)` order — among equal keys, **lower indices are
/// selected first**; positive NaNs rank after `+∞`, −NaNs before `−∞`
/// (see the module docs). Iterative quickselect on an index permutation,
/// O(n) expected.
pub fn partition_smallest(keys: &[f32], k: usize) -> Vec<usize> {
    let n = keys.len();
    assert!(k <= n, "k={k} > n={n}");
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    if k < n {
        select_k_smallest(keys, &mut idx, k);
    }
    idx.truncate(k);
    idx
}

/// Reorder `idx` so its first `k` entries are the canonically k smallest
/// (in arbitrary internal order). `1 <= k < idx.len()`.
fn select_k_smallest(keys: &[f32], idx: &mut [usize], k: usize) {
    let (mut lo, mut hi) = (0usize, idx.len());
    // Invariant: idx[..lo] are among the k smallest, idx[hi..] are not;
    // `want = k - lo` more must come from idx[lo..hi].
    let mut want = k;
    while lo < hi {
        // median-of-three pivot (by the canonical order) for robustness
        let mid = lo + (hi - lo) / 2;
        let pair = |i: usize| (keys[idx[i]], idx[i]);
        let pivot = median3(pair(lo), pair(mid), pair(hi - 1));
        // 3-way partition vs pivot; the canonical order is strict, so the
        // equal run is exactly the pivot element itself.
        let (mut i, mut j, mut p) = (lo, lo, hi);
        // [lo,i): < pivot, [i,j): == pivot, [j,p): unseen, [p,hi): > pivot
        while j < p {
            match cmp_key_idx((keys[idx[j]], idx[j]), pivot) {
                Ordering::Less => {
                    idx.swap(i, j);
                    i += 1;
                    j += 1;
                }
                Ordering::Equal => j += 1,
                Ordering::Greater => {
                    p -= 1;
                    idx.swap(j, p);
                }
            }
        }
        let less = i - lo;
        let eq = j - i;
        if want < less {
            hi = i;
        } else if want <= less + eq {
            // idx[lo..lo+want] = [lo,i) plus (want-less) of the equal run;
            // with a strict order eq == 1, so this is exact.
            return;
        } else {
            want -= less + eq;
            lo = j;
        }
    }
}

fn median3(a: (f32, usize), b: (f32, usize), c: (f32, usize)) -> (f32, usize) {
    let (lo, hi) = if cmp_key_idx(a, b) == Ordering::Less { (a, b) } else { (b, a) };
    if cmp_key_idx(c, lo) == Ordering::Less {
        lo
    } else if cmp_key_idx(hi, c) == Ordering::Less {
        hi
    } else {
        c
    }
}

/// Fused SS prune — the in-place successor of [`partition_smallest`]: drop
/// the `k` canonically smallest keys from the parallel `(keys, vals)`
/// arrays, **preserving the relative order of survivors**, and return the
/// round's ε̂ contribution — `f64::max` folded over the dropped keys
/// (upcast to f64), exactly the fold the fresh-allocation reference loop
/// performs over its drop set, so the two stay bit-identical even on
/// non-finite inputs. `f64::max` skips NaN operands (of either sign), so
/// NaN keys never poison ε̂; if every dropped key is NaN the result is
/// `NEG_INFINITY`, which the caller's running `max` ignores. Both vectors
/// are compacted and truncated to `len − k` in one pass.
///
/// Selection policy is identical to [`partition_smallest`] (see the module
/// docs): keys ranked by `total_cmp` with NaN largest, ties at the
/// threshold dropped from the **earliest positions**. Equivalence of the
/// two formulations is asserted property-style in the tests below.
///
/// `scratch` holds the quickselect threshold copy and is reused across
/// calls — with warm capacity the whole prune allocates nothing, which is
/// what the SS round arena relies on.
pub fn prune_smallest_paired(
    keys: &mut Vec<f32>,
    vals: &mut Vec<usize>,
    k: usize,
    scratch: &mut Vec<f32>,
) -> f64 {
    let n = keys.len();
    assert_eq!(n, vals.len(), "parallel arrays must agree: {n} vs {}", vals.len());
    assert!(k >= 1 && k <= n, "k={k} out of range (n={n})");
    scratch.clear();
    scratch.extend_from_slice(keys);
    let (_, &mut t, _) = scratch.select_nth_unstable_by(k - 1, f32::total_cmp);
    // Canonical drop set = {key < t} ∪ {first (k − #less) positions with
    // key == t}: exactly the k lexicographically smallest (key, position)
    // pairs, since t is the k-th smallest key value.
    let less = keys.iter().filter(|key| key.total_cmp(&t) == Ordering::Less).count();
    let mut eq_budget = k - less;
    let mut write = 0usize;
    let mut max_dropped = f64::NEG_INFINITY;
    for read in 0..n {
        let key = keys[read];
        let drop = match key.total_cmp(&t) {
            Ordering::Less => true,
            Ordering::Equal if eq_budget > 0 => {
                eq_budget -= 1;
                true
            }
            _ => false,
        };
        if drop {
            max_dropped = max_dropped.max(key as f64);
        } else {
            keys[write] = key;
            vals[write] = vals[read];
            write += 1;
        }
    }
    debug_assert_eq!(write, n - k, "prune must drop exactly k elements");
    keys.truncate(write);
    vals.truncate(write);
    max_dropped
}

/// Indices of the `k` largest keys, descending by key. O(n log k).
pub fn top_k_desc(keys: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(keys.len());
    if k == 0 {
        return Vec::new();
    }
    // min-heap of (key, idx) capped at k
    let mut heap: Vec<(f32, usize)> = Vec::with_capacity(k + 1);
    for (i, &key) in keys.iter().enumerate() {
        if heap.len() < k {
            heap.push((key, i));
            if heap.len() == k {
                heap.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            }
        } else if key > heap[0].0 {
            // replace min; keep sorted-ascending (k is small in our uses)
            heap[0] = (key, i);
            let mut j = 0;
            while j + 1 < heap.len() && heap[j].0 > heap[j + 1].0 {
                heap.swap(j, j + 1);
                j += 1;
            }
        }
    }
    heap.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    heap.into_iter().map(|(_, i)| i).collect()
}

/// The k-th smallest key value (0-indexed: `kth_smallest(keys, 0)` = min).
pub fn kth_smallest(keys: &[f32], k: usize) -> f32 {
    let idx = partition_smallest(keys, k + 1);
    idx.iter().map(|&i| keys[i]).fold(f32::NEG_INFINITY, f32::max)
}

/// Max-heap over `(priority, id)` with *lazy* stale-entry invalidation —
/// the data structure behind lazy greedy [Minoux '78] and the batcher's
/// deadline queue. `push` never removes old entries; `pop_if_fresh`
/// validates against a user version map.
pub struct LazyMaxHeap {
    heap: std::collections::BinaryHeap<HeapEntry>,
}

#[derive(PartialEq)]
struct HeapEntry {
    priority: f32,
    id: usize,
    version: u64,
}

impl Eq for HeapEntry {}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.priority
            .partial_cmp(&other.priority)
            .unwrap_or(Ordering::Equal)
            .then(other.id.cmp(&self.id)) // deterministic tie-break: lower id wins
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Default for LazyMaxHeap {
    fn default() -> Self {
        Self::new()
    }
}

impl LazyMaxHeap {
    pub fn new() -> Self {
        Self { heap: std::collections::BinaryHeap::new() }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { heap: std::collections::BinaryHeap::with_capacity(cap) }
    }

    /// Reserve room for `additional` more entries beyond the current
    /// length. The maximizer engine sizes the heap to the candidate count
    /// up front — its pop/push cycles never grow past it, so steady-state
    /// iterations stay allocation-free.
    pub fn reserve(&mut self, additional: usize) {
        self.heap.reserve(additional);
    }

    /// Drop all entries, keeping the allocation (arena reuse across runs).
    pub fn clear(&mut self) {
        self.heap.clear();
    }

    pub fn push(&mut self, id: usize, priority: f32, version: u64) {
        self.heap.push(HeapEntry { priority, id, version });
    }

    /// Pop the max entry whose version matches `current[id]`; stale entries
    /// are discarded on the way. Returns `(id, priority)`.
    pub fn pop_fresh(&mut self, current: &[u64]) -> Option<(usize, f32)> {
        while let Some(e) = self.heap.pop() {
            if current[e.id] == e.version {
                return Some((e.id, e.priority));
            }
        }
        None
    }

    /// Peek at the max entry (possibly stale).
    pub fn peek(&self) -> Option<(usize, f32)> {
        self.heap.peek().map(|e| (e.id, e.priority))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_partition(keys: &[f32], k: usize) {
        let got = partition_smallest(keys, k);
        assert_eq!(got.len(), k);
        let mut sorted: Vec<f32> = keys.to_vec();
        sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let thresh = if k == 0 { f32::NEG_INFINITY } else { sorted[k - 1] };
        // every selected key <= threshold, and the multiset matches
        let mut got_keys: Vec<f32> = got.iter().map(|&i| keys[i]).collect();
        got_keys.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(&got_keys[..], &sorted[..k], "k={k}");
        assert!(got_keys.iter().all(|&x| x <= thresh));
        // indices distinct
        let mut g = got.clone();
        g.sort_unstable();
        g.dedup();
        assert_eq!(g.len(), k);
    }

    #[test]
    fn partition_matches_sort_random() {
        let mut rng = Rng::new(1);
        for trial in 0..100 {
            let n = rng.range(1, 200);
            let keys: Vec<f32> = (0..n).map(|_| rng.f32() * 10.0 - 5.0).collect();
            let k = rng.range(0, n + 1);
            check_partition(&keys, k);
            let _ = trial;
        }
    }

    #[test]
    fn partition_with_ties() {
        let keys = vec![1.0f32, 1.0, 1.0, 1.0, 2.0, 0.5];
        for k in 0..=6 {
            check_partition(&keys, k);
        }
    }

    #[test]
    fn partition_all_equal() {
        let keys = vec![3.3f32; 17];
        for k in [0, 1, 8, 17] {
            check_partition(&keys, k);
        }
    }

    /// The canonical reference: sort (key, index) pairs by the module
    /// order and take the first k indices. Both selectors must agree with
    /// this exactly — not just on the key multiset.
    fn canonical_smallest(keys: &[f32], k: usize) -> Vec<usize> {
        let mut pairs: Vec<(f32, usize)> = keys.iter().copied().zip(0..).collect();
        pairs.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
        let mut out: Vec<usize> = pairs[..k].iter().map(|&(_, i)| i).collect();
        out.sort_unstable();
        out
    }

    #[test]
    fn ties_break_toward_lower_indices() {
        // four-way tie at 1.0: k=2 must take indices 0 and 1, never 2 or 3
        let keys = vec![1.0f32, 1.0, 1.0, 1.0, 0.5];
        let mut got = partition_smallest(&keys, 2);
        got.sort_unstable();
        assert_eq!(got, vec![0, 4], "lowest index wins the tie");
        let mut got3 = partition_smallest(&keys, 3);
        got3.sort_unstable();
        assert_eq!(got3, vec![0, 1, 4]);
    }

    #[test]
    fn nan_ranks_largest() {
        // NaN must never be selected before a finite/infinite key
        let keys = vec![f32::NAN, 2.0, f32::INFINITY, -1.0, f32::NAN];
        let mut got = partition_smallest(&keys, 3);
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3], "NaNs rank after +inf");
        // only once everything else is taken do NaNs appear, lowest index first
        let mut got4 = partition_smallest(&keys, 4);
        got4.sort_unstable();
        assert_eq!(got4, vec![0, 1, 2, 3]);
    }

    #[test]
    fn partition_matches_canonical_reference_random() {
        let mut rng = Rng::new(77);
        for _ in 0..200 {
            let n = rng.range(1, 120);
            // coarse quantization forces heavy ties; sprinkle NaN/inf
            let keys: Vec<f32> = (0..n)
                .map(|_| match rng.below(12) {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    2 => f32::NEG_INFINITY,
                    _ => (rng.below(5) as f32) * 0.25,
                })
                .collect();
            let k = rng.range(0, n + 1);
            let mut got = partition_smallest(&keys, k);
            got.sort_unstable();
            assert_eq!(got, canonical_smallest(&keys, k), "n={n} k={k} keys={keys:?}");
        }
    }

    #[test]
    fn prune_paired_matches_partition_and_preserves_order() {
        let mut rng = Rng::new(91);
        let mut scratch = Vec::new();
        for _ in 0..200 {
            let n = rng.range(1, 150);
            let keys: Vec<f32> = (0..n)
                .map(|_| match rng.below(15) {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    _ => (rng.below(6) as f32) * 0.5 - 1.0,
                })
                .collect();
            let vals: Vec<usize> = (0..n).map(|i| 1000 + i).collect();
            let k = rng.range(1, n + 1);

            // reference: partition_smallest + bitmap + rebuild with the
            // reference loop's per-key f64::max ε̂ fold (the old path)
            let drop_pos = partition_smallest(&keys, k);
            let mut dropped = vec![false; n];
            let mut want_max = f64::NEG_INFINITY;
            for &p in &drop_pos {
                dropped[p] = true;
                want_max = want_max.max(keys[p] as f64);
            }
            let want_keys: Vec<f32> =
                (0..n).filter(|&i| !dropped[i]).map(|i| keys[i]).collect();
            let want_vals: Vec<usize> =
                (0..n).filter(|&i| !dropped[i]).map(|i| vals[i]).collect();

            let mut got_keys = keys.clone();
            let mut got_vals = vals.clone();
            let got_max = prune_smallest_paired(&mut got_keys, &mut got_vals, k, &mut scratch);
            assert_eq!(got_vals, want_vals, "survivor set/order must match the old path");
            assert_eq!(got_keys.len(), n - k);
            for (a, b) in got_keys.iter().zip(&want_keys) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(
                got_max, want_max,
                "ε̂ fold must match the reference (NaN-skipping f64::max over dropped keys)"
            );
        }
    }

    #[test]
    fn prune_paired_drop_all_and_scratch_reuse() {
        let mut scratch = Vec::new();
        let mut keys = vec![3.0f32, 1.0, 2.0];
        let mut vals = vec![30usize, 10, 20];
        let t = prune_smallest_paired(&mut keys, &mut vals, 3, &mut scratch);
        assert!(keys.is_empty() && vals.is_empty());
        assert_eq!(t, 3.0, "max dropped is the overall max");
        // reuse the same scratch on a second, larger input
        let mut keys = vec![5.0f32, -1.0, 4.0, 0.0];
        let mut vals = vec![0usize, 1, 2, 3];
        let t = prune_smallest_paired(&mut keys, &mut vals, 2, &mut scratch);
        assert_eq!(vals, vec![0, 2]);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn kth_smallest_matches_sort() {
        let mut rng = Rng::new(2);
        let keys: Vec<f32> = (0..101).map(|_| rng.f32()).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        for k in [0, 1, 50, 100] {
            assert_eq!(kth_smallest(&keys, k), sorted[k]);
        }
    }

    #[test]
    fn top_k_desc_ordered() {
        let keys = vec![0.1f32, 5.0, 3.0, 3.0, -1.0, 7.5];
        assert_eq!(top_k_desc(&keys, 3), vec![5, 1, 2]);
        assert_eq!(top_k_desc(&keys, 0), Vec::<usize>::new());
        assert_eq!(top_k_desc(&keys, 100).len(), 6);
    }

    #[test]
    fn lazy_heap_basic() {
        let mut h = LazyMaxHeap::new();
        let versions = vec![0u64, 0, 0];
        h.push(0, 1.0, 0);
        h.push(1, 3.0, 0);
        h.push(2, 2.0, 0);
        assert_eq!(h.pop_fresh(&versions), Some((1, 3.0)));
        assert_eq!(h.pop_fresh(&versions), Some((2, 2.0)));
        assert_eq!(h.pop_fresh(&versions), Some((0, 1.0)));
        assert_eq!(h.pop_fresh(&versions), None);
    }

    #[test]
    fn lazy_heap_discards_stale() {
        let mut h = LazyMaxHeap::new();
        let mut versions = vec![0u64, 0];
        h.push(0, 5.0, 0); // will become stale
        versions[0] = 1;
        h.push(0, 2.0, 1);
        h.push(1, 3.0, 0);
        assert_eq!(h.pop_fresh(&versions), Some((1, 3.0)));
        assert_eq!(h.pop_fresh(&versions), Some((0, 2.0)));
    }

    #[test]
    fn lazy_heap_deterministic_ties() {
        let mut h = LazyMaxHeap::new();
        let versions = vec![0u64; 4];
        for id in [3, 1, 2, 0] {
            h.push(id, 1.0, 0);
        }
        assert_eq!(h.pop_fresh(&versions).unwrap().0, 0, "lowest id wins ties");
    }
}
