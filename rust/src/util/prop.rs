//! Property-based testing mini-framework (offline `proptest` substitute).
//!
//! A property is a closure over a [`Gen`]-drawn input; the runner executes it
//! across many seeds and, on failure, *shrinks* the input (generator-aware:
//! generators draw from a recorded byte stream, shrinking truncates/zeroes
//! the stream — the Hypothesis design, minus the database).
//!
//! Usage:
//! ```ignore
//! check(100, |g| {
//!     let xs = g.vec(0..50, |g| g.f32_in(0.0, 10.0));
//!     let k = g.usize_in(0, xs.len() + 1);
//!     // ... assert the property, panic on violation
//! });
//! ```

use crate::util::rng::Rng;

/// Draw source handed to properties. Wraps an RNG and records all draws so
/// the shrinker can replay simplified streams.
pub struct Gen {
    rng: Rng,
    /// When `Some`, draws replay from this stream (shrink phase); draws past
    /// the end return zeros (the "simplest" value by convention).
    replay: Option<(Vec<u64>, usize)>,
    /// Record of raw u64 draws for shrink replay.
    trace: Vec<u64>,
}

impl Gen {
    fn fresh(seed: u64) -> Self {
        Self { rng: Rng::new(seed), replay: None, trace: Vec::new() }
    }

    fn replaying(stream: Vec<u64>) -> Self {
        Self { rng: Rng::new(0), replay: Some((stream, 0)), trace: Vec::new() }
    }

    #[inline]
    fn draw_u64(&mut self) -> u64 {
        let v = match &mut self.replay {
            Some((stream, pos)) => {
                let v = stream.get(*pos).copied().unwrap_or(0);
                *pos += 1;
                v
            }
            None => self.rng.next_u64(),
        };
        self.trace.push(v);
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + (self.draw_u64() % (hi - lo) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let u = (self.draw_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        lo + u * (hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.draw_u64() & 1 == 1
    }

    pub fn vec<T>(&mut self, len_range: std::ops::Range<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize_in(len_range.start, len_range.end.max(len_range.start + 1));
        (0..n).map(|_| f(self)).collect()
    }

    /// Distinct sorted subset of [0, n) with size in `k_range`.
    pub fn subset(&mut self, n: usize, k_range: std::ops::Range<usize>) -> Vec<usize> {
        if n == 0 {
            return Vec::new();
        }
        let k = self.usize_in(k_range.start.min(n), (k_range.end).min(n + 1).max(1));
        let mut rng = Rng::new(self.draw_u64());
        rng.sample_indices(n, k.min(n))
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }
}

/// Outcome of one property execution.
fn run_once<F: Fn(&mut Gen)>(
    g: &mut Gen,
    prop: &F,
) -> Result<(), String> {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut *g)));
    match result {
        Ok(()) => Ok(()),
        Err(e) => {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<panic>".to_string());
            Err(msg)
        }
    }
}

/// Run `prop` for `cases` random cases. On failure, shrink the draw stream
/// and panic with the minimal reproduction (seed + shrunken case message).
pub fn check<F>(cases: usize, prop: F)
where
    F: Fn(&mut Gen),
{
    check_seeded(0xC0FFEE, cases, prop)
}

pub fn check_seeded<F>(base_seed: u64, cases: usize, prop: F)
where
    F: Fn(&mut Gen),
{
    // silence the default panic hook during exploration; restore after
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let mut failure: Option<(u64, Vec<u64>, String)> = None;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::fresh(seed);
        if let Err(msg) = run_once(&mut g, &prop) {
            failure = Some((seed, g.trace.clone(), msg));
            break;
        }
    }
    let Some((seed, trace, first_msg)) = failure else {
        std::panic::set_hook(hook);
        return;
    };

    // Shrink: try truncations and zeroing of the draw stream.
    let mut best = trace;
    let mut best_msg = first_msg;
    let mut improved = true;
    let mut budget = 500usize;
    while improved && budget > 0 {
        improved = false;
        // 1) truncate tail (shorter stream = simpler: out-of-stream draws are 0)
        let mut candidates: Vec<Vec<u64>> = Vec::new();
        for cut in [best.len() / 2, best.len().saturating_sub(1)] {
            if cut < best.len() {
                candidates.push(best[..cut].to_vec());
            }
        }
        // 2) zero each nonzero position
        for i in 0..best.len() {
            if best[i] != 0 {
                let mut c = best.clone();
                c[i] = 0;
                candidates.push(c);
                let mut h = best.clone();
                h[i] /= 2;
                candidates.push(h);
            }
            if candidates.len() > 64 {
                break;
            }
        }
        for cand in candidates {
            if budget == 0 {
                break;
            }
            budget -= 1;
            let mut g = Gen::replaying(cand.clone());
            if let Err(msg) = run_once(&mut g, &prop) {
                let cand_mass: u128 = cand.iter().map(|&x| x as u128).sum();
                let best_mass: u128 = best.iter().map(|&x| x as u128).sum();
                if cand.len() < best.len() || cand_mass < best_mass {
                    best = cand;
                    best_msg = msg;
                    improved = true;
                    break;
                }
            }
        }
    }
    std::panic::set_hook(hook);
    panic!(
        "property failed (seed={seed:#x}, shrunk to {} draws): {best_msg}",
        best.len()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(200, |g| {
            let xs = g.vec(0..20, |g| g.f32_in(0.0, 1.0));
            let s: f32 = xs.iter().sum();
            assert!(s >= 0.0);
        });
    }

    #[test]
    fn failing_property_fails_with_shrink() {
        let r = std::panic::catch_unwind(|| {
            check(200, |g| {
                let x = g.usize_in(0, 1000);
                assert!(x < 500, "x={x}");
            });
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("property failed"), "{msg}");
    }

    #[test]
    fn subset_well_formed() {
        check(100, |g| {
            let n = g.usize_in(1, 50);
            let s = g.subset(n, 0..n + 1);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < n));
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Gen::fresh(9);
        let mut b = Gen::fresh(9);
        for _ in 0..32 {
            assert_eq!(a.draw_u64(), b.draw_u64());
        }
    }

    #[test]
    fn replay_reproduces() {
        let mut g = Gen::fresh(4);
        let x1 = g.usize_in(0, 100);
        let y1 = g.f64_in(-1.0, 1.0);
        let trace = g.trace.clone();
        let mut r = Gen::replaying(trace);
        assert_eq!(r.usize_in(0, 100), x1);
        assert_eq!(r.f64_in(-1.0, 1.0), y1);
    }
}
