//! Deterministic pseudo-random generators (the offline substitute for `rand`).
//!
//! Everything in this crate that samples — SS probe selection, synthetic
//! dataset generation, property tests — goes through [`Rng`], a
//! xoshiro256** generator seeded via SplitMix64. Determinism given a seed is
//! a hard requirement: the coordinator's parallel SS must produce bit-equal
//! prunings to the single-threaded reference, and experiments must be
//! re-runnable.

/// SplitMix64: used to expand a 64-bit seed into xoshiro state and as a
/// cheap standalone generator for stream splitting.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent child stream (for per-worker/per-day RNGs).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection-free-ish method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply keeps the bias < 2^-64 — fine for our purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (the slower branchless-unfriendly of
    /// the pair is discarded; dataset generation is not hot).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` without replacement.
    ///
    /// Uses Floyd's algorithm: O(k) expected time, no O(n) scratch, and the
    /// result is sorted for cache-friendly downstream gathers.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out.sort_unstable();
        out
    }

    /// Write-into form of [`sample_indices`]: identical draw sequence and
    /// identical result (asserted in tests), reusing `out`'s capacity so
    /// the SS round loop — which calls this every round with a constant
    /// `k = r·log₂ n` — allocates nothing in the steady state. Membership
    /// is checked by scanning `out` itself: O(k) per draw, and k ≪ n on
    /// every SS call site, so the O(k²) total is noise next to the O(nk)
    /// divergence batch it feeds.
    ///
    /// [`sample_indices`]: Rng::sample_indices
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        assert!(k <= n, "cannot sample {k} from {n}");
        out.clear();
        out.reserve(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if out.contains(&t) { j } else { t };
            out.push(pick);
        }
        out.sort_unstable();
    }

    /// Weighted sampling without replacement via exponential races
    /// (Efraimidis–Spirakis): key_i = w_i / Exp(1); take the k largest keys.
    /// Weights must be non-negative; zero-weight items are only chosen after
    /// all positive-weight items are exhausted.
    pub fn weighted_indices(&mut self, weights: &[f64], k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k);
        let mut keyed = Vec::new();
        self.weighted_indices_into(weights, k, &mut out, &mut keyed);
        out
    }

    /// Write-into form of [`weighted_indices`]: identical draws and result,
    /// with the keyed race array living in caller-owned `keyed` scratch so
    /// importance-sampled SS rounds reuse it instead of reallocating.
    ///
    /// Selection is **partial** — `select_nth_unstable_by` moves the `k`
    /// largest keys to the front in O(m) expected instead of the former
    /// full O(m log m) descending sort. The order is the strict total
    /// order `(key desc by total_cmp, index asc)`, so the selected *set*
    /// (and therefore the ascending-sorted output) is a pure function of
    /// the draws — exactly what the full sort produced, asserted by the
    /// equivalence test below. The Exp(1) draw sequence is unchanged.
    ///
    /// [`weighted_indices`]: Rng::weighted_indices
    pub fn weighted_indices_into(
        &mut self,
        weights: &[f64],
        k: usize,
        out: &mut Vec<usize>,
        keyed: &mut Vec<(f64, usize)>,
    ) {
        assert!(k <= weights.len());
        keyed.clear();
        keyed.extend(weights.iter().enumerate().map(|(i, &w)| {
            let e = -self.f64().max(1e-300).ln(); // Exp(1)
            let key = if w > 0.0 { w / e } else { -e }; // zero-weight sinks
            (key, i)
        }));
        out.clear();
        if k == 0 {
            return;
        }
        if k < keyed.len() {
            keyed.select_nth_unstable_by(k - 1, |a, b| {
                b.0.total_cmp(&a.0).then_with(|| a.1.cmp(&b.1))
            });
        }
        out.extend(keyed[..k].iter().map(|&(_, i)| i));
        out.sort_unstable();
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (vocabulary
    /// sampling for the synthetic corpus). Inverse-CDF on a precomputed
    /// table is the caller's job when hot; this is the direct method.
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.f64();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

/// Precompute a Zipf CDF table for [`Rng::zipf`].
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut cdf = Vec::with_capacity(n);
    let mut acc = 0.0;
    for i in 1..=n {
        acc += 1.0 / (i as f64).powf(s);
        cdf.push(acc);
    }
    let z = acc;
    for p in &mut cdf {
        *p /= z;
    }
    cdf
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(11);
        for _ in 0..200 {
            let k = r.range(0, 50);
            let v = r.sample_indices(100, k);
            assert_eq!(v.len(), k);
            assert!(v.windows(2).all(|w| w[0] < w[1]), "sorted + distinct");
            assert!(v.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn sample_indices_full_range() {
        let mut r = Rng::new(5);
        let v = r.sample_indices(10, 10);
        assert_eq!(v, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_into_bit_identical_to_allocating_form() {
        // the SS arena loop's determinism rests on this equivalence
        let mut out = Vec::new();
        for seed in 0..20u64 {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            for trial in 0..20 {
                let n = 1 + ((seed as usize * 31 + trial * 7) % 200);
                let k = (trial * 13) % (n + 1);
                let want = a.sample_indices(n, k);
                b.sample_indices_into(n, k, &mut out);
                assert_eq!(out, want, "n={n} k={k}");
                assert_eq!(a.next_u64(), b.next_u64(), "draw streams must stay aligned");
            }
        }
    }

    #[test]
    fn weighted_indices_into_bit_identical_to_allocating_form() {
        let mut keyed = Vec::new();
        let mut out = Vec::new();
        for seed in 0..10u64 {
            let mut gen_w = Rng::new(seed ^ 0xABCD);
            let w: Vec<f64> = (0..60).map(|_| gen_w.f64() * 3.0).collect();
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            for k in [0usize, 1, 7, 30, 60] {
                let want = a.weighted_indices(&w, k);
                b.weighted_indices_into(&w, k, &mut out, &mut keyed);
                assert_eq!(out, want, "k={k}");
            }
        }
    }

    /// The pre-refactor path, frozen: full descending sort of the keyed
    /// race array. Canonicalized with the same strict `(key desc, index
    /// asc)` total order the partial selection uses, so the comparison is
    /// well-defined even under exact key ties (duplicate weights alone
    /// never tie — each key carries an independent Exp(1) draw).
    fn weighted_indices_full_sort_reference(rng: &mut Rng, weights: &[f64], k: usize) -> Vec<usize> {
        let mut keyed: Vec<(f64, usize)> = weights
            .iter()
            .enumerate()
            .map(|(i, &w)| {
                let e = -rng.f64().max(1e-300).ln();
                let key = if w > 0.0 { w / e } else { -e };
                (key, i)
            })
            .collect();
        keyed.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
        let mut out: Vec<usize> = keyed[..k].iter().map(|&(_, i)| i).collect();
        out.sort_unstable();
        out
    }

    #[test]
    fn weighted_partial_selection_matches_full_sort_reference() {
        // the satellite invariant: O(m) expected selection, identical draw
        // sequence, identical result — across weight shapes (uniform,
        // heavy-tailed, duplicates, zeros) and every k regime
        let mut keyed = Vec::new();
        let mut out = Vec::new();
        for seed in 0..12u64 {
            let mut gen_w = Rng::new(seed ^ 0x5EED);
            let m = 1 + gen_w.below(120);
            let w: Vec<f64> = (0..m)
                .map(|_| match gen_w.below(4) {
                    0 => 0.0,
                    1 => 1.0, // duplicates
                    2 => gen_w.f64() * 1e6,
                    _ => gen_w.f64(),
                })
                .collect();
            for k in [0usize, 1, m / 3, m.saturating_sub(1), m] {
                let mut a = Rng::new(seed.wrapping_mul(31).wrapping_add(k as u64));
                let mut b = a.clone();
                let want = weighted_indices_full_sort_reference(&mut a, &w, k);
                b.weighted_indices_into(&w, k, &mut out, &mut keyed);
                assert_eq!(out, want, "m={m} k={k} seed={seed}");
                assert_eq!(
                    a.next_u64(),
                    b.next_u64(),
                    "draw streams must stay aligned after selection"
                );
            }
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(13);
        let mut w = vec![0.01; 100];
        w[7] = 1000.0;
        let mut hits = 0;
        for _ in 0..200 {
            if r.weighted_indices(&w, 5).contains(&7) {
                hits += 1;
            }
        }
        assert!(hits > 190, "heavy item chosen in {hits}/200 draws");
    }

    #[test]
    fn weighted_zero_weights_ok() {
        let mut r = Rng::new(17);
        let w = vec![0.0; 8];
        let v = r.weighted_indices(&w, 3);
        assert_eq!(v.len(), 3);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(29);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_rank_ordering() {
        let cdf = zipf_cdf(100, 1.1);
        let mut r = Rng::new(31);
        let mut counts = [0usize; 100];
        for _ in 0..20_000 {
            counts[r.zipf(&cdf)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.split(0);
        let mut b = root.split(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
