//! Online statistics, timers and histograms for metrics + experiment reports.

use std::time::Instant;

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact-percentile sample buffer (stores everything; fine at our scales).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self { xs: Vec::new() }
    }
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
    pub fn values(&self) -> &[f64] {
        &self.xs
    }

    /// Percentile in [0, 100], linear interpolation between order stats.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    /// Five-number summary (min, q1, median, q3, max) — the boxplot rows the
    /// paper's Figure 3/6/7 report.
    pub fn five_number(&self) -> [f64; 5] {
        [
            self.percentile(0.0),
            self.percentile(25.0),
            self.percentile(50.0),
            self.percentile(75.0),
            self.percentile(100.0),
        ]
    }
}

/// Wall-clock stopwatch returning seconds.
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Fixed-bucket latency histogram (log-spaced), lock-free-ish via atomics.
pub struct LatencyHistogram {
    /// bucket i covers [base * ratio^i, base * ratio^(i+1))
    buckets: Vec<std::sync::atomic::AtomicU64>,
    base_us: f64,
    ratio: f64,
}

impl LatencyHistogram {
    /// ~5% resolution from 1 µs to ~100 s in 64 log buckets.
    pub fn new() -> Self {
        Self {
            buckets: (0..384).map(|_| std::sync::atomic::AtomicU64::new(0)).collect(),
            base_us: 1.0,
            ratio: 1.05,
        }
    }

    pub fn record_secs(&self, secs: f64) {
        let us = (secs * 1e6).max(self.base_us);
        let idx = ((us / self.base_us).ln() / self.ratio.ln()) as usize;
        let idx = idx.min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(std::sync::atomic::Ordering::Relaxed)).sum()
    }

    /// Zero every bucket (scoped metering — e.g. a streaming session
    /// resetting its per-window metrics). Concurrent recorders may land a
    /// sample on either side of the reset; counts never go negative.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, std::sync::atomic::Ordering::Relaxed);
        }
    }

    /// JSON summary — count plus p50/p95/p99 in seconds — so service
    /// metrics are readable without post-processing raw bucket arrays.
    /// The one shape every [`Metrics`](crate::coordinator::Metrics)
    /// snapshot embeds per histogram.
    pub fn snapshot_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("p50_s", Json::Num(self.percentile_secs(50.0))),
            ("p95_s", Json::Num(self.percentile_secs(95.0))),
            ("p99_s", Json::Num(self.percentile_secs(99.0))),
        ])
    }

    /// Approximate percentile in seconds.
    pub fn percentile_secs(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(std::sync::atomic::Ordering::Relaxed);
            if acc >= target {
                return self.base_us * self.ratio.powi(i as i32 + 1) / 1e6;
            }
        }
        self.base_us * self.ratio.powi(self.buckets.len() as i32) / 1e6
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn online_stats_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        let mut all = OnlineStats::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
            all.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.var() - all.var()).abs() < 1e-10);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!(s.percentile(95.0) > 94.0);
        let f = s.five_number();
        assert!(f[0] <= f[1] && f[1] <= f[2] && f[2] <= f[3] && f[3] <= f[4]);
    }

    #[test]
    fn latency_histogram_percentiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record_secs(i as f64 * 1e-5);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile_secs(50.0);
        let p99 = h.percentile_secs(99.0);
        assert!(p50 < p99);
        // ~5% bucket resolution around the true values
        assert!((p50 / 5e-3 - 1.0).abs() < 0.15, "p50={p50}");
        assert!((p99 / 9.9e-3 - 1.0).abs() < 0.15, "p99={p99}");
    }

    #[test]
    fn histogram_json_snapshot_names_percentiles() {
        let h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record_secs(i as f64 * 1e-4);
        }
        let s = h.snapshot_json();
        assert_eq!(s.get("count").unwrap().as_f64(), Some(100.0));
        let p50 = s.get("p50_s").unwrap().as_f64().unwrap();
        let p95 = s.get("p95_s").unwrap().as_f64().unwrap();
        let p99 = s.get("p99_s").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
