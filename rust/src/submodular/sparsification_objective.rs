//! The sparsification objective `h` of paper Eq. (9):
//!
//! `h(V') = |{ v ∈ V∖V' : w_{V'v} ≤ ε }|`
//!
//! Proposition 1 shows `h(V') = |∪_{u∈V'} A_u| − |V'|` with
//! `A_u = {v : w_{uv} ≤ ε}` — set cover minus cardinality, hence
//! non-monotone submodular. The paper notes solving Eq. (9) directly is a
//! chicken-and-egg problem (it *is* submodular maximization and needs all
//! n(n−1) edge weights); SS exists to avoid it. We still implement `h`
//! faithfully because:
//!
//! * §3.4's third improvement runs bi-directional greedy on `h` restricted
//!   to the (small) SS output `V'` to shrink it further;
//! * tests validate Proposition 1 (submodularity, non-monotonicity) and
//!   Theorem 1 empirically against this exact objective.

use super::{BidirState, SolState, SubmodularFn};

pub struct SparsificationObjective {
    /// `a_sets[u]` = sorted ids of v with `w_{uv} <= eps` (including u itself:
    /// `w_{uu} = -f(u|V\u) <= 0 <= eps`).
    a_sets: Vec<Vec<u32>>,
    n: usize,
}

impl SparsificationObjective {
    /// Build from a dense edge-weight oracle. O(n²) weight evaluations —
    /// intended for the *reduced* set (paper §3.4) or tests.
    pub fn from_weights(n: usize, eps: f64, w: impl Fn(usize, usize) -> f64) -> Self {
        let a_sets = (0..n)
            .map(|u| {
                (0..n)
                    .filter(|&v| v == u || w(u, v) <= eps)
                    .map(|v| v as u32)
                    .collect()
            })
            .collect();
        Self { a_sets, n }
    }

    pub fn covered_by(&self, u: usize) -> &[u32] {
        &self.a_sets[u]
    }
}

impl SubmodularFn for SparsificationObjective {
    fn n(&self) -> usize {
        self.n
    }

    fn eval(&self, s: &[usize]) -> f64 {
        let mut hit = vec![false; self.n];
        let mut covered = 0usize;
        for &u in s {
            for &v in &self.a_sets[u] {
                if !hit[v as usize] {
                    hit[v as usize] = true;
                    covered += 1;
                }
            }
        }
        covered as f64 - s.len() as f64
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(HState { f: self, count: vec![0; self.n], value: 0.0, set: Vec::new() })
    }

    fn bidir_state<'a>(&'a self, init: &[usize]) -> Option<Box<dyn BidirState + 'a>> {
        let mut st = HState { f: self, count: vec![0; self.n], value: 0.0, set: Vec::new() };
        let mut member = vec![false; self.n];
        for &v in init {
            st.add(v);
            member[v] = true;
        }
        Some(Box::new(HBidir { inner: st, member }))
    }
}

struct HState<'a> {
    f: &'a SparsificationObjective,
    count: Vec<u32>,
    value: f64,
    set: Vec<usize>,
}

impl HState<'_> {
    fn add_gain(&self, u: usize) -> f64 {
        let fresh =
            self.f.a_sets[u].iter().filter(|&&v| self.count[v as usize] == 0).count();
        fresh as f64 - 1.0
    }
}

impl SolState for HState<'_> {
    fn value(&self) -> f64 {
        self.value
    }
    fn gain(&self, u: usize) -> f64 {
        self.add_gain(u)
    }
    fn add(&mut self, u: usize) {
        self.value += self.add_gain(u);
        for &v in &self.f.a_sets[u] {
            self.count[v as usize] += 1;
        }
        self.set.push(u);
    }
    fn set(&self) -> &[usize] {
        &self.set
    }
}

struct HBidir<'a> {
    inner: HState<'a>,
    member: Vec<bool>,
}

impl BidirState for HBidir<'_> {
    fn value(&self) -> f64 {
        self.inner.value
    }
    fn gain_add(&self, u: usize) -> f64 {
        self.inner.add_gain(u)
    }
    fn gain_remove(&self, u: usize) -> f64 {
        let lost =
            self.inner.f.a_sets[u].iter().filter(|&&v| self.inner.count[v as usize] == 1).count();
        1.0 - lost as f64
    }
    fn add(&mut self, u: usize) {
        debug_assert!(!self.member[u]);
        self.inner.add(u);
        self.member[u] = true;
    }
    fn remove(&mut self, u: usize) {
        debug_assert!(self.member[u]);
        self.inner.value += self.gain_remove(u);
        for &v in &self.inner.f.a_sets[u] {
            self.inner.count[v as usize] -= 1;
        }
        self.member[u] = false;
    }
    fn contains(&self, u: usize) -> bool {
        self.member[u]
    }
    fn members(&self) -> Vec<usize> {
        (0..self.member.len()).filter(|&v| self.member[v]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::*;
    use crate::util::rng::Rng;

    fn instance(n: usize, eps: f64, seed: u64) -> SparsificationObjective {
        // random asymmetric "weights" in [-0.5, 1.5]
        let mut rng = Rng::new(seed);
        let w: Vec<f64> = (0..n * n).map(|_| rng.f64() * 2.0 - 0.5).collect();
        SparsificationObjective::from_weights(n, eps, move |u, v| w[u * n + v])
    }

    #[test]
    fn h_is_submodular_nonmonotone() {
        let f = instance(14, 0.5, 1);
        check_submodular(&f, false, 100, 150);
        check_state_consistency(&f, 101, 100);
    }

    #[test]
    fn h_empty_zero_and_self_coverage() {
        let f = instance(8, 0.2, 2);
        assert_eq!(f.eval(&[]), 0.0);
        for u in 0..8 {
            assert!(f.covered_by(u).contains(&(u as u32)), "u must cover itself");
        }
    }

    #[test]
    fn h_counts_match_definition() {
        // tiny hand-checkable instance: w(u,v) <= eps iff v == u+1 (mod n)
        let n = 5;
        let f = SparsificationObjective::from_weights(n, 0.0, |u, v| {
            if (u + 1) % n == v {
                -1.0
            } else {
                1.0
            }
        });
        // V' = {0}: covers {0, 1} → h = |{1}| ... = 2 covered - 1 = 1
        assert_eq!(f.eval(&[0]), 1.0);
        // V' = {0, 1}: covers {0,1,2} → 3 - 2 = 1
        assert_eq!(f.eval(&[0, 1]), 1.0);
        // full set: covers all 5, h = 5 - 5 = 0
        assert_eq!(f.eval(&[0, 1, 2, 3, 4]), 0.0);
    }

    #[test]
    fn bidir_consistency() {
        let f = instance(10, 0.4, 3);
        let mut st = f.bidir_state(&[2, 5]).unwrap();
        assert!((st.value() - f.eval(&[2, 5])).abs() < 1e-9);
        st.add(7);
        st.remove(2);
        assert!((st.value() - f.eval(&[5, 7])).abs() < 1e-9);
    }
}
