//! Coverage-family objectives: weighted set cover and saturated coverage.

use super::{BidirState, SolState, SubmodularFn};

/// Weighted set cover: `f(S) = Σ_{j ∈ ∪_{v∈S} Γ(v)} w_j` where `Γ(v)` is the
/// set of "concepts" element v covers.
pub struct SetCover {
    /// concepts covered by each element (sorted, deduped)
    covers: Vec<Vec<u32>>,
    /// weight per concept id
    weights: Vec<f64>,
}

impl SetCover {
    pub fn new(mut covers: Vec<Vec<u32>>, weights: Vec<f64>) -> Self {
        for c in &mut covers {
            c.sort_unstable();
            c.dedup();
            if let Some(&m) = c.last() {
                assert!((m as usize) < weights.len(), "concept id out of range");
            }
        }
        debug_assert!(weights.iter().all(|&w| w >= 0.0));
        Self { covers, weights }
    }

    /// Unit weights over `m` concepts.
    pub fn unit(covers: Vec<Vec<u32>>, m: usize) -> Self {
        Self::new(covers, vec![1.0; m])
    }
}

impl SubmodularFn for SetCover {
    fn n(&self) -> usize {
        self.covers.len()
    }

    fn eval(&self, s: &[usize]) -> f64 {
        let mut hit = vec![false; self.weights.len()];
        let mut acc = 0.0;
        for &v in s {
            for &j in &self.covers[v] {
                if !hit[j as usize] {
                    hit[j as usize] = true;
                    acc += self.weights[j as usize];
                }
            }
        }
        acc
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(CoverState {
            f: self,
            count: vec![0u32; self.weights.len()],
            value: 0.0,
            set: Vec::new(),
        })
    }

    fn singleton_complements(&self) -> Vec<f64> {
        // f(v|V\v) = weight of concepts covered *only* by v.
        let mut cover_count = vec![0u32; self.weights.len()];
        for c in &self.covers {
            for &j in c {
                cover_count[j as usize] += 1;
            }
        }
        self.covers
            .iter()
            .map(|c| {
                c.iter()
                    .filter(|&&j| cover_count[j as usize] == 1)
                    .map(|&j| self.weights[j as usize])
                    .sum()
            })
            .collect()
    }

    fn bidir_state<'a>(&'a self, init: &[usize]) -> Option<Box<dyn BidirState + 'a>> {
        let mut st = CoverState {
            f: self,
            count: vec![0u32; self.weights.len()],
            value: 0.0,
            set: Vec::new(),
        };
        let mut member = vec![false; self.n()];
        for &v in init {
            st.add(v);
            member[v] = true;
        }
        Some(Box::new(CoverBidir { inner: st, member }))
    }
}

struct CoverState<'a> {
    f: &'a SetCover,
    /// multiplicity of coverage per concept (for removal support)
    count: Vec<u32>,
    value: f64,
    set: Vec<usize>,
}

impl SolState for CoverState<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&self, v: usize) -> f64 {
        self.f.covers[v]
            .iter()
            .filter(|&&j| self.count[j as usize] == 0)
            .map(|&j| self.f.weights[j as usize])
            .sum()
    }

    fn add(&mut self, v: usize) {
        for &j in &self.f.covers[v] {
            if self.count[j as usize] == 0 {
                self.value += self.f.weights[j as usize];
            }
            self.count[j as usize] += 1;
        }
        self.set.push(v);
    }

    fn set(&self) -> &[usize] {
        &self.set
    }
}

struct CoverBidir<'a> {
    inner: CoverState<'a>,
    member: Vec<bool>,
}

impl BidirState for CoverBidir<'_> {
    fn value(&self) -> f64 {
        self.inner.value
    }

    fn gain_add(&self, v: usize) -> f64 {
        self.inner.gain(v)
    }

    fn gain_remove(&self, v: usize) -> f64 {
        -self.inner.f.covers[v]
            .iter()
            .filter(|&&j| self.inner.count[j as usize] == 1)
            .map(|&j| self.inner.f.weights[j as usize])
            .sum::<f64>()
    }

    fn add(&mut self, v: usize) {
        debug_assert!(!self.member[v]);
        self.inner.add(v);
        self.member[v] = true;
    }

    fn remove(&mut self, v: usize) {
        debug_assert!(self.member[v]);
        for &j in &self.inner.f.covers[v] {
            self.inner.count[j as usize] -= 1;
            if self.inner.count[j as usize] == 0 {
                self.inner.value -= self.inner.f.weights[j as usize];
            }
        }
        self.member[v] = false;
    }

    fn contains(&self, v: usize) -> bool {
        self.member[v]
    }

    fn members(&self) -> Vec<usize> {
        (0..self.member.len()).filter(|&v| self.member[v]).collect()
    }
}

/// Saturated coverage: `f(S) = Σ_i min( Σ_{u∈S} sim(i,u), α · Σ_{u∈V} sim(i,u) )`
/// — Lin & Bilmes' saturation objective; monotone submodular for α ∈ (0, 1].
pub struct SaturatedCoverage {
    n: usize,
    sim: Vec<f32>,
    /// per-row saturation cap α·Σ_u sim(i,u)
    cap: Vec<f64>,
}

impl SaturatedCoverage {
    pub fn new(n: usize, sim: Vec<f32>, alpha: f64) -> Self {
        assert_eq!(sim.len(), n * n);
        assert!(alpha > 0.0 && alpha <= 1.0);
        let cap: Vec<f64> = (0..n)
            .map(|i| alpha * sim[i * n..(i + 1) * n].iter().map(|&x| x as f64).sum::<f64>())
            .collect();
        Self { n, sim, cap }
    }

    #[inline]
    fn sim(&self, i: usize, u: usize) -> f64 {
        self.sim[i * self.n + u] as f64
    }
}

impl SubmodularFn for SaturatedCoverage {
    fn n(&self) -> usize {
        self.n
    }

    fn eval(&self, s: &[usize]) -> f64 {
        (0..self.n)
            .map(|i| {
                let tot: f64 = s.iter().map(|&u| self.sim(i, u)).sum();
                tot.min(self.cap[i])
            })
            .sum()
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(SatState { f: self, row: vec![0.0; self.n], value: 0.0, set: Vec::new() })
    }
}

struct SatState<'a> {
    f: &'a SaturatedCoverage,
    /// per-row accumulated (unsaturated) mass
    row: Vec<f64>,
    value: f64,
    set: Vec<usize>,
}

impl SolState for SatState<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&self, v: usize) -> f64 {
        let mut acc = 0.0;
        for i in 0..self.f.n {
            let before = self.row[i].min(self.f.cap[i]);
            let after = (self.row[i] + self.f.sim(i, v)).min(self.f.cap[i]);
            acc += after - before;
        }
        acc
    }

    fn add(&mut self, v: usize) {
        self.value += self.gain(v);
        for i in 0..self.f.n {
            self.row[i] += self.f.sim(i, v);
        }
        self.set.push(v);
    }

    fn set(&self) -> &[usize] {
        &self.set
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::*;
    use crate::util::rng::Rng;

    fn cover_instance(n: usize, m: usize, seed: u64) -> SetCover {
        let mut rng = Rng::new(seed);
        let covers = (0..n)
            .map(|_| {
                let k = rng.range(1, (m / 2).max(2));
                rng.sample_indices(m, k).into_iter().map(|x| x as u32).collect()
            })
            .collect();
        let weights = (0..m).map(|_| rng.f64()).collect();
        SetCover::new(covers, weights)
    }

    #[test]
    fn set_cover_properties() {
        let f = cover_instance(18, 30, 1);
        check_submodular(&f, true, 50, 150);
        check_state_consistency(&f, 51, 100);
        check_edge_ingredients(&f, 52, 80);
    }

    #[test]
    fn set_cover_bidir() {
        let f = cover_instance(12, 20, 2);
        let mut st = f.bidir_state(&[0, 1, 2]).unwrap();
        assert!((st.value() - f.eval(&[0, 1, 2])).abs() < 1e-9);
        st.remove(1);
        assert!((st.value() - f.eval(&[0, 2])).abs() < 1e-9);
        st.add(5);
        assert!((st.value() - f.eval(&[0, 2, 5])).abs() < 1e-9);
    }

    #[test]
    fn saturated_properties() {
        let mut rng = Rng::new(3);
        let n = 12;
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            for u in 0..n {
                sim[i * n + u] = rng.f32();
            }
        }
        let f = SaturatedCoverage::new(n, sim, 0.3);
        check_submodular(&f, true, 60, 150);
        check_state_consistency(&f, 61, 100);
    }

    #[test]
    fn saturation_caps_full_set() {
        let n = 6;
        let sim = vec![1.0f32; n * n];
        let f = SaturatedCoverage::new(n, sim, 0.5);
        let full: Vec<usize> = (0..n).collect();
        // each row caps at 0.5 * 6 = 3.0
        assert!((f.eval(&full) - (n as f64 * 3.0)).abs() < 1e-9);
    }

    #[test]
    fn unique_coverage_is_singleton_complement() {
        let f = SetCover::unit(vec![vec![0, 1], vec![1, 2], vec![3]], 4);
        let sing = f.singleton_complements();
        assert_eq!(sing, vec![1.0, 1.0, 1.0]); // concepts 0, 2, 3 unique
    }
}
