//! Weighted mixtures `f = Σ_k α_k f_k` of submodular components — closed
//! under non-negative combination; the standard way summarization systems
//! trade coverage against diversity.
//!
//! Components are [`BatchedDivergence`] handles, so a mixture delegates its
//! batched pair gains to each part's kernel: a mix of feature-based and
//! facility-location terms keeps both blocked fast paths instead of
//! falling back to the scalar loop.

use std::cell::RefCell;

use super::{BatchedDivergence, SolState, SubmodularFn};
use crate::util::pool::ThreadPool;

thread_local! {
    /// Per-thread delegation scratch: the combined accumulator and the
    /// per-component pair-gain tile. Buffers are *taken out* of the cell
    /// for the duration of a call (and restored after), so a nested
    /// mixture component re-entering this path sees empty temporaries
    /// instead of a `RefCell` double-borrow.
    static MIX_SCRATCH: RefCell<MixScratch> = RefCell::new(MixScratch::default());
}

#[derive(Default)]
struct MixScratch {
    /// Σ_k α_k · pair-gain tile (ITEM_BLOCK × P)
    acc: Vec<f64>,
    /// current component's pair-gain tile
    part: Vec<f64>,
    /// current component's stateful-gain cohort (maximizer engine path)
    gains: Vec<f64>,
}

pub struct Mixture {
    parts: Vec<(f64, Box<dyn BatchedDivergence>)>,
}

impl Mixture {
    pub fn new(parts: Vec<(f64, Box<dyn BatchedDivergence>)>) -> Self {
        assert!(!parts.is_empty());
        let n = parts[0].1.n();
        for (a, p) in &parts {
            assert!(*a >= 0.0, "mixture weights must be non-negative");
            assert_eq!(p.n(), n, "components must share a ground set");
        }
        Self { parts }
    }
}

impl SubmodularFn for Mixture {
    fn n(&self) -> usize {
        self.parts[0].1.n()
    }

    fn eval(&self, s: &[usize]) -> f64 {
        self.parts.iter().map(|(a, p)| a * p.eval(s)).sum()
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(MixState {
            states: self.parts.iter().map(|(a, p)| (*a, p.state())).collect(),
            set: Vec::new(),
        })
    }

    fn pair_gain(&self, u: usize, v: usize) -> f64 {
        self.parts.iter().map(|(a, p)| a * p.pair_gain(u, v)).sum()
    }

    fn singleton(&self, v: usize) -> f64 {
        self.parts.iter().map(|(a, p)| a * p.singleton(v)).sum()
    }

    fn singleton_complements(&self) -> Vec<f64> {
        let mut acc = vec![0.0; self.n()];
        for (a, p) in &self.parts {
            for (dst, s) in acc.iter_mut().zip(p.singleton_complements()) {
                *dst += a * s;
            }
        }
        acc
    }

    /// Decomposable exactly when every component is — a facility-location
    /// part (whole-vector top-2 scan) makes the whole mixture fall back to
    /// the serial precompute rather than multiplying its O(n²) per shard.
    fn singleton_complements_decomposable(&self) -> bool {
        self.parts.iter().all(|(_, p)| p.singleton_complements_decomposable())
    }

    /// Same part order and `+= a·s` accumulation as the whole-vector form,
    /// so the sharded precompute is bit-identical to the serial one.
    fn singleton_complements_into(&self, items: &[usize], out: &mut [f64]) {
        debug_assert_eq!(items.len(), out.len());
        out.fill(0.0);
        let mut part = vec![0.0f64; items.len()];
        for (a, p) in &self.parts {
            p.singleton_complements_into(items, &mut part);
            for (dst, &s) in out.iter_mut().zip(&part) {
                *dst += a * s;
            }
        }
    }

    /// Sum of the components' sparse residency — a mixture wrapping a
    /// sparse facility-location term meters it through unchanged.
    fn sparse_rows(&self) -> usize {
        self.parts.iter().map(|(_, p)| p.sparse_rows()).sum()
    }

    /// Sum of the components' store residency, like [`Self::sparse_rows`].
    fn resident_bytes(&self) -> usize {
        self.parts.iter().map(|(_, p)| p.resident_bytes()).sum()
    }

    /// A mixture can compact exactly when every component can — partial
    /// compaction would desynchronize the parts' ground sets.
    fn supports_retain(&self) -> bool {
        self.parts.iter().all(|(_, p)| p.supports_retain())
    }

    fn retain_elements(&mut self, keep: &[usize]) -> bool {
        if !self.supports_retain() {
            return false;
        }
        for (_, p) in &mut self.parts {
            let ok = p.retain_elements(keep);
            debug_assert!(ok, "component claimed supports_retain but refused");
        }
        true
    }

    /// Pool-backed precompute: each part takes its best available route —
    /// its own pooled variant (facility location's row-sharded scan), the
    /// decomposable per-element shard, or the serial fallback — and the
    /// combination keeps the serial form's part order and `+= a·s` fold,
    /// so the result is bit-identical to [`Self::singleton_complements`].
    /// Before this, one facility-location term forced the whole mixture
    /// onto the serial O(n²) path at request start.
    fn singleton_complements_pooled(&self, pool: &ThreadPool, shards: usize) -> Option<Vec<f64>> {
        let n = self.n();
        let items: Vec<usize> = (0..n).collect();
        let mut acc = vec![0.0f64; n];
        let mut part = vec![0.0f64; n];
        for (a, p) in &self.parts {
            if let Some(v) = p.singleton_complements_pooled(pool, shards) {
                part.copy_from_slice(&v);
            } else if p.singleton_complements_decomposable() {
                let pref: &dyn BatchedDivergence = p.as_ref();
                pool.parallel_ranges_into(&mut part[..], shards, |lo, hi, chunk| {
                    pref.singleton_complements_into(&items[lo..hi], chunk);
                });
            } else {
                part.copy_from_slice(&p.singleton_complements());
            }
            for (dst, &s) in acc.iter_mut().zip(&part) {
                *dst += a * s;
            }
        }
        Some(acc)
    }
}

impl BatchedDivergence for Mixture {
    fn as_submodular(&self) -> &dyn SubmodularFn {
        self
    }

    /// Delegate the batch to each component's kernel and combine. The
    /// per-pair accumulation order (parts in declaration order, starting
    /// from 0.0) matches the scalar [`SubmodularFn::pair_gain`] sum, so the
    /// delegated batch stays bit-identical to the scalar path as long as
    /// each component's kernel is (the [`batched`](super::batched)
    /// contract).
    fn pair_gains_batch(&self, probes: &[usize], items: &[usize]) -> Vec<f64> {
        let mut acc = vec![0.0f64; items.len() * probes.len()];
        self.pair_gains_into(probes, items, &mut acc);
        acc
    }

    /// Write-into delegation over the components' own write-into kernels;
    /// the per-component tile lives in thread-local scratch.
    fn pair_gains_into(&self, probes: &[usize], items: &[usize], out: &mut [f64]) {
        debug_assert_eq!(out.len(), items.len() * probes.len());
        out.fill(0.0);
        let mut part = MIX_SCRATCH.with(|cell| std::mem::take(&mut cell.borrow_mut().part));
        part.resize(out.len(), 0.0);
        for (a, component) in &self.parts {
            component.pair_gains_into(probes, items, &mut part[..out.len()]);
            for (dst, &g) in out.iter_mut().zip(&part[..out.len()]) {
                *dst += a * g;
            }
        }
        MIX_SCRATCH.with(|cell| cell.borrow_mut().part = part);
    }

    /// Chunk items so the transient pair-gain matrices stay bounded
    /// (`block × P` per component) instead of `items × P` — the first SS
    /// round passes the whole live set through the reference backend.
    /// Per-item values are unchanged, so this stays bit-identical to the
    /// unchunked default.
    fn divergences_batch(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; items.len()];
        self.divergences_into(probes, probe_sing, items, &mut out);
        out
    }

    /// Write-into delegation: per item chunk, each component writes its
    /// pair-gain tile into thread-local scratch (through its own
    /// `pair_gains_into` kernel) and is combined into the Σ_k α_k
    /// accumulator, then the min-fold lands in `out` — zero steady-state
    /// allocations, and bit-identical to [`Self::divergences_batch`]'s
    /// historical accumulation order (parts in declaration order, from
    /// 0.0, per-chunk).
    fn divergences_into(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
        out: &mut [f32],
    ) {
        debug_assert_eq!(probes.len(), probe_sing.len());
        debug_assert_eq!(out.len(), items.len());
        if probes.is_empty() {
            out.fill(f32::INFINITY);
            return;
        }
        const ITEM_BLOCK: usize = 512;
        let p = probes.len();
        // take the accumulator out of the TLS cell so a nested mixture
        // re-entering this path sees an empty temporary, not a double
        // borrow (`pair_gains_into` below manages the `part` buffer the
        // same way)
        let mut acc = MIX_SCRATCH.with(|cell| std::mem::take(&mut cell.borrow_mut().acc));
        for (chunk, out_block) in items.chunks(ITEM_BLOCK).zip(out.chunks_mut(ITEM_BLOCK)) {
            let len = chunk.len() * p;
            acc.resize(len, 0.0);
            self.pair_gains_into(probes, chunk, &mut acc[..len]);
            for (slot, row) in out_block.iter_mut().zip(acc[..len].chunks_exact(p)) {
                *slot = row
                    .iter()
                    .zip(probe_sing)
                    .map(|(&g, &su)| (g - su) as f32)
                    .fold(f32::INFINITY, f32::min);
            }
        }
        MIX_SCRATCH.with(|cell| cell.borrow_mut().acc = acc);
    }
}

struct MixState<'a> {
    states: Vec<(f64, Box<dyn SolState + 'a>)>,
    set: Vec<usize>,
}

impl SolState for MixState<'_> {
    fn value(&self) -> f64 {
        self.states.iter().map(|(a, s)| a * s.value()).sum()
    }
    fn gain(&self, v: usize) -> f64 {
        self.states.iter().map(|(a, s)| a * s.gain(v)).sum()
    }
    fn add(&mut self, v: usize) {
        for (_, s) in &mut self.states {
            s.add(v);
        }
        self.set.push(v);
    }
    fn set(&self) -> &[usize] {
        &self.set
    }

    /// Delegate the cohort to each part's batched kernel and combine with
    /// the scalar loop's exact fold: per candidate, parts in declaration
    /// order starting from 0.0 — the same left fold `Σ a_k · g_k` the
    /// scalar [`SolState::gain`] performs, so the delegated batch stays
    /// bit-identical as long as each part's kernel is. The per-part cohort
    /// lives in thread-local scratch (take/restore, so a nested mixture
    /// re-entering this path sees an empty temporary, not a double
    /// borrow).
    fn gains_into(&self, candidates: &[usize], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        out.fill(0.0);
        let mut part = MIX_SCRATCH.with(|cell| std::mem::take(&mut cell.borrow_mut().gains));
        part.resize(out.len(), 0.0);
        for (a, st) in &self.states {
            st.gains_into(candidates, &mut part[..out.len()]);
            for (dst, &g) in out.iter_mut().zip(&part[..out.len()]) {
                *dst += a * g;
            }
        }
        MIX_SCRATCH.with(|cell| cell.borrow_mut().gains = part);
    }

    fn reserve_additions(&mut self, additional: usize) {
        self.set.reserve(additional);
        for (_, s) in &mut self.states {
            s.reserve_additions(additional);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FacilityLocation, FeatureBased, Modular};
    use super::*;
    use crate::submodular::test_support::*;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = rng.f32();
            }
        }
        m
    }

    fn instance(seed: u64) -> Mixture {
        let mut rng = Rng::new(seed);
        let n = 12;
        let m = feats(n, 6, seed);
        Mixture::new(vec![
            (0.7, Box::new(FeatureBased::sqrt(m)) as Box<dyn BatchedDivergence>),
            (0.3, Box::new(Modular::new((0..n).map(|_| rng.f64()).collect()))),
        ])
    }

    #[test]
    fn mixture_properties() {
        let f = instance(1);
        check_submodular(&f, true, 90, 120);
        check_state_consistency(&f, 91, 80);
        check_edge_ingredients(&f, 92, 60);
    }

    #[test]
    fn delegated_batch_bitwise_matches_scalar() {
        // feature-based + facility-location parts: both blocked kernels in play
        let n = 40;
        let m = feats(n, 8, 5);
        let f = Mixture::new(vec![
            (0.6, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
            (0.4, Box::new(FacilityLocation::from_features(&m))),
        ]);
        let sing = f.singleton_complements();
        let probes = vec![1usize, 17, 33];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..n).filter(|v| !probes.contains(v)).collect();
        let got = f.divergences_batch(&probes, &probe_sing, &items);
        let want = scalar_reference_divergences(&f, &probes, &probe_sing, &items);
        assert_eq!(got, want, "delegated mixture batch must match the scalar path bit-for-bit");
    }

    #[test]
    fn write_into_delegation_bitwise_matches_batch() {
        let n = 90; // spans one ragged ITEM_BLOCK... (block = 512, so single chunk) —
                    // the multi-chunk case is covered by the SS e2e suites at larger n
        let m = feats(n, 7, 8);
        let f = Mixture::new(vec![
            (0.5, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
            (0.5, Box::new(FacilityLocation::from_features(&m))),
        ]);
        let sing = f.singleton_complements();
        let probes = vec![0usize, 44, 89];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..n).filter(|v| !probes.contains(v)).collect();
        let want = scalar_reference_divergences(&f, &probes, &probe_sing, &items);
        let mut out = vec![f32::NAN; items.len()];
        for _ in 0..2 {
            // twice: TLS scratch reuse must not leak state across calls
            f.divergences_into(&probes, &probe_sing, &items, &mut out);
            assert_eq!(out, want);
        }
    }

    #[test]
    fn nested_mixture_reenters_scratch_safely() {
        // a mixture containing a mixture re-enters MIX_SCRATCH on the same
        // thread — the take/restore discipline must not double-borrow
        let n = 20;
        let m = feats(n, 5, 11);
        let inner = Mixture::new(vec![
            (1.0, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
            (0.5, Box::new(Modular::new(vec![0.3; n]))),
        ]);
        let outer = Mixture::new(vec![
            (0.8, Box::new(inner) as Box<dyn BatchedDivergence>),
            (0.2, Box::new(FacilityLocation::from_features(&m))),
        ]);
        let sing = outer.singleton_complements();
        let probes = vec![1usize, 9];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..n).filter(|v| !probes.contains(v)).collect();
        let want = scalar_reference_divergences(&outer, &probes, &probe_sing, &items);
        let mut out = vec![0.0f32; items.len()];
        outer.divergences_into(&probes, &probe_sing, &items, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn batched_state_gains_bitwise_match_scalar() {
        // feature-based + facility-location parts: both blocked stateful
        // kernels in the delegation, plus a nested-mixture re-entrancy leg
        let n = 30;
        let m = feats(n, 6, 15);
        let f = Mixture::new(vec![
            (0.6, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
            (0.4, Box::new(FacilityLocation::from_features(&m))),
        ]);
        check_batched_gains(&f, 150, 40);
        let inner = Mixture::new(vec![
            (1.0, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
            (0.5, Box::new(Modular::new(vec![0.3; n]))),
        ]);
        let outer = Mixture::new(vec![
            (0.8, Box::new(inner) as Box<dyn BatchedDivergence>),
            (0.2, Box::new(FacilityLocation::from_features(&m))),
        ]);
        check_batched_gains(&outer, 151, 25);
    }

    #[test]
    fn pooled_singleton_precompute_bitwise_matches_serial() {
        use crate::util::pool::ThreadPool;
        // FL part takes its row-sharded route, FB part the decomposable
        // shard, modular part the serial fallback — combination must stay
        // bit-identical to the fully serial form
        let n = 90;
        let m = feats(n, 7, 16);
        let f = Mixture::new(vec![
            (0.5, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
            (0.3, Box::new(FacilityLocation::from_features(&m))),
            (0.2, Box::new(Modular::new(vec![0.7; n]))),
        ]);
        let want = f.singleton_complements();
        let pool = ThreadPool::new(3, 16);
        for shards in [1usize, 4, 9] {
            let got = f.singleton_complements_pooled(&pool, shards).unwrap();
            for (v, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "slot {v} diverged (shards={shards})");
            }
        }
    }

    #[test]
    fn retain_delegates_to_all_parts_or_none() {
        let n = 24;
        let m = feats(n, 5, 21);
        let mut f = Mixture::new(vec![
            (0.6, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
            (0.4, Box::new(FacilityLocation::from_features(&m))),
        ]);
        assert!(f.supports_retain());
        let keep: Vec<usize> = (0..n).step_by(2).collect();
        assert!(f.retain_elements(&keep));
        assert_eq!(f.n(), keep.len());
        let fresh = Mixture::new(vec![
            (0.6, Box::new(FeatureBased::sqrt(m.gather(&keep))) as Box<dyn BatchedDivergence>),
            (0.4, Box::new(FacilityLocation::from_features(&m.gather(&keep)))),
        ]);
        for v in 0..keep.len() {
            assert_eq!(f.singleton(v).to_bits(), fresh.singleton(v).to_bits());
        }
        // a modular part (no retain support) makes the whole mixture refuse
        let mut with_modular = Mixture::new(vec![
            (1.0, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
            (0.5, Box::new(Modular::new(vec![0.3; n]))),
        ]);
        assert!(!with_modular.supports_retain());
        assert!(!with_modular.retain_elements(&keep));
        assert_eq!(with_modular.n(), n, "failed retain must leave the mixture untouched");
    }

    #[test]
    #[should_panic(expected = "share a ground set")]
    fn mismatched_ground_sets_rejected() {
        let _ = Mixture::new(vec![
            (1.0, Box::new(Modular::new(vec![1.0; 4])) as Box<dyn BatchedDivergence>),
            (1.0, Box::new(Modular::new(vec![1.0; 5]))),
        ]);
    }
}
