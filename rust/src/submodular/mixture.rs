//! Weighted mixtures `f = Σ_k α_k f_k` of submodular components — closed
//! under non-negative combination; the standard way summarization systems
//! trade coverage against diversity.

use super::{SolState, SubmodularFn};

pub struct Mixture {
    parts: Vec<(f64, Box<dyn SubmodularFn>)>,
}

impl Mixture {
    pub fn new(parts: Vec<(f64, Box<dyn SubmodularFn>)>) -> Self {
        assert!(!parts.is_empty());
        let n = parts[0].1.n();
        for (a, p) in &parts {
            assert!(*a >= 0.0, "mixture weights must be non-negative");
            assert_eq!(p.n(), n, "components must share a ground set");
        }
        Self { parts }
    }
}

impl SubmodularFn for Mixture {
    fn n(&self) -> usize {
        self.parts[0].1.n()
    }

    fn eval(&self, s: &[usize]) -> f64 {
        self.parts.iter().map(|(a, p)| a * p.eval(s)).sum()
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(MixState {
            states: self.parts.iter().map(|(a, p)| (*a, p.state())).collect(),
            set: Vec::new(),
        })
    }

    fn pair_gain(&self, u: usize, v: usize) -> f64 {
        self.parts.iter().map(|(a, p)| a * p.pair_gain(u, v)).sum()
    }

    fn singleton(&self, v: usize) -> f64 {
        self.parts.iter().map(|(a, p)| a * p.singleton(v)).sum()
    }

    fn singleton_complements(&self) -> Vec<f64> {
        let mut acc = vec![0.0; self.n()];
        for (a, p) in &self.parts {
            for (dst, s) in acc.iter_mut().zip(p.singleton_complements()) {
                *dst += a * s;
            }
        }
        acc
    }
}

struct MixState<'a> {
    states: Vec<(f64, Box<dyn SolState + 'a>)>,
    set: Vec<usize>,
}

impl SolState for MixState<'_> {
    fn value(&self) -> f64 {
        self.states.iter().map(|(a, s)| a * s.value()).sum()
    }
    fn gain(&self, v: usize) -> f64 {
        self.states.iter().map(|(a, s)| a * s.gain(v)).sum()
    }
    fn add(&mut self, v: usize) {
        for (_, s) in &mut self.states {
            s.add(v);
        }
        self.set.push(v);
    }
    fn set(&self) -> &[usize] {
        &self.set
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FeatureBased, Modular};
    use super::*;
    use crate::submodular::test_support::*;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn instance(seed: u64) -> Mixture {
        let mut rng = Rng::new(seed);
        let n = 12;
        let mut m = FeatureMatrix::zeros(n, 6);
        for i in 0..n {
            for j in 0..6 {
                m.row_mut(i)[j] = rng.f32();
            }
        }
        Mixture::new(vec![
            (0.7, Box::new(FeatureBased::sqrt(m)) as Box<dyn SubmodularFn>),
            (0.3, Box::new(Modular::new((0..n).map(|_| rng.f64()).collect()))),
        ])
    }

    #[test]
    fn mixture_properties() {
        let f = instance(1);
        check_submodular(&f, true, 90, 120);
        check_state_consistency(&f, 91, 80);
        check_edge_ingredients(&f, 92, 60);
    }

    #[test]
    #[should_panic(expected = "share a ground set")]
    fn mismatched_ground_sets_rejected() {
        let _ = Mixture::new(vec![
            (1.0, Box::new(Modular::new(vec![1.0; 4])) as Box<dyn SubmodularFn>),
            (1.0, Box::new(Modular::new(vec![1.0; 5]))),
        ]);
    }
}
