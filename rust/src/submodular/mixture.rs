//! Weighted mixtures `f = Σ_k α_k f_k` of submodular components — closed
//! under non-negative combination; the standard way summarization systems
//! trade coverage against diversity.
//!
//! Components are [`BatchedDivergence`] handles, so a mixture delegates its
//! batched pair gains to each part's kernel: a mix of feature-based and
//! facility-location terms keeps both blocked fast paths instead of
//! falling back to the scalar loop.

use super::{BatchedDivergence, SolState, SubmodularFn};

pub struct Mixture {
    parts: Vec<(f64, Box<dyn BatchedDivergence>)>,
}

impl Mixture {
    pub fn new(parts: Vec<(f64, Box<dyn BatchedDivergence>)>) -> Self {
        assert!(!parts.is_empty());
        let n = parts[0].1.n();
        for (a, p) in &parts {
            assert!(*a >= 0.0, "mixture weights must be non-negative");
            assert_eq!(p.n(), n, "components must share a ground set");
        }
        Self { parts }
    }
}

impl SubmodularFn for Mixture {
    fn n(&self) -> usize {
        self.parts[0].1.n()
    }

    fn eval(&self, s: &[usize]) -> f64 {
        self.parts.iter().map(|(a, p)| a * p.eval(s)).sum()
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(MixState {
            states: self.parts.iter().map(|(a, p)| (*a, p.state())).collect(),
            set: Vec::new(),
        })
    }

    fn pair_gain(&self, u: usize, v: usize) -> f64 {
        self.parts.iter().map(|(a, p)| a * p.pair_gain(u, v)).sum()
    }

    fn singleton(&self, v: usize) -> f64 {
        self.parts.iter().map(|(a, p)| a * p.singleton(v)).sum()
    }

    fn singleton_complements(&self) -> Vec<f64> {
        let mut acc = vec![0.0; self.n()];
        for (a, p) in &self.parts {
            for (dst, s) in acc.iter_mut().zip(p.singleton_complements()) {
                *dst += a * s;
            }
        }
        acc
    }
}

impl BatchedDivergence for Mixture {
    fn as_submodular(&self) -> &dyn SubmodularFn {
        self
    }

    /// Delegate the batch to each component's kernel and combine. The
    /// per-pair accumulation order (parts in declaration order, starting
    /// from 0.0) matches the scalar [`SubmodularFn::pair_gain`] sum, so the
    /// delegated batch stays bit-identical to the scalar path as long as
    /// each component's kernel is (the [`batched`](super::batched)
    /// contract).
    fn pair_gains_batch(&self, probes: &[usize], items: &[usize]) -> Vec<f64> {
        let mut acc = vec![0.0f64; items.len() * probes.len()];
        for (a, p) in &self.parts {
            for (dst, g) in acc.iter_mut().zip(p.pair_gains_batch(probes, items)) {
                *dst += a * g;
            }
        }
        acc
    }

    /// Chunk items so the transient pair-gain matrices stay bounded
    /// (`block × P` per component) instead of `items × P` — the first SS
    /// round passes the whole live set through the reference backend.
    /// Per-item values are unchanged, so this stays bit-identical to the
    /// unchunked default.
    fn divergences_batch(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
    ) -> Vec<f32> {
        debug_assert_eq!(probes.len(), probe_sing.len());
        if probes.is_empty() {
            return vec![f32::INFINITY; items.len()];
        }
        const ITEM_BLOCK: usize = 512;
        let mut out = Vec::with_capacity(items.len());
        for chunk in items.chunks(ITEM_BLOCK) {
            let pg = self.pair_gains_batch(probes, chunk);
            out.extend(pg.chunks(probes.len()).map(|row| {
                row.iter()
                    .zip(probe_sing)
                    .map(|(&g, &su)| (g - su) as f32)
                    .fold(f32::INFINITY, f32::min)
            }));
        }
        out
    }
}

struct MixState<'a> {
    states: Vec<(f64, Box<dyn SolState + 'a>)>,
    set: Vec<usize>,
}

impl SolState for MixState<'_> {
    fn value(&self) -> f64 {
        self.states.iter().map(|(a, s)| a * s.value()).sum()
    }
    fn gain(&self, v: usize) -> f64 {
        self.states.iter().map(|(a, s)| a * s.gain(v)).sum()
    }
    fn add(&mut self, v: usize) {
        for (_, s) in &mut self.states {
            s.add(v);
        }
        self.set.push(v);
    }
    fn set(&self) -> &[usize] {
        &self.set
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FacilityLocation, FeatureBased, Modular};
    use super::*;
    use crate::submodular::test_support::*;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = rng.f32();
            }
        }
        m
    }

    fn instance(seed: u64) -> Mixture {
        let mut rng = Rng::new(seed);
        let n = 12;
        let m = feats(n, 6, seed);
        Mixture::new(vec![
            (0.7, Box::new(FeatureBased::sqrt(m)) as Box<dyn BatchedDivergence>),
            (0.3, Box::new(Modular::new((0..n).map(|_| rng.f64()).collect()))),
        ])
    }

    #[test]
    fn mixture_properties() {
        let f = instance(1);
        check_submodular(&f, true, 90, 120);
        check_state_consistency(&f, 91, 80);
        check_edge_ingredients(&f, 92, 60);
    }

    #[test]
    fn delegated_batch_bitwise_matches_scalar() {
        // feature-based + facility-location parts: both blocked kernels in play
        let n = 40;
        let m = feats(n, 8, 5);
        let f = Mixture::new(vec![
            (0.6, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
            (0.4, Box::new(FacilityLocation::from_features(&m))),
        ]);
        let sing = f.singleton_complements();
        let probes = vec![1usize, 17, 33];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..n).filter(|v| !probes.contains(v)).collect();
        let got = f.divergences_batch(&probes, &probe_sing, &items);
        let want = scalar_reference_divergences(&f, &probes, &probe_sing, &items);
        assert_eq!(got, want, "delegated mixture batch must match the scalar path bit-for-bit");
    }

    #[test]
    #[should_panic(expected = "share a ground set")]
    fn mismatched_ground_sets_rejected() {
        let _ = Mixture::new(vec![
            (1.0, Box::new(Modular::new(vec![1.0; 4])) as Box<dyn BatchedDivergence>),
            (1.0, Box::new(Modular::new(vec![1.0; 5]))),
        ]);
    }
}
