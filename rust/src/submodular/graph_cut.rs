//! Graph-cut style objective: `f(S) = λ Σ_{i∈V} Σ_{u∈S} sim(i,u) − Σ_{u,v∈S, u<v} sim(u,v)`.
//!
//! Coverage-minus-redundancy; submodular for any λ, non-monotone unless λ is
//! large. With λ < 1 this is the crate's stock *non-monotone* test objective
//! (SS's Lemmas 1–3 only need submodularity + non-negativity, and §3.4 of
//! the paper extends SS to the non-monotone case — our ablation bench
//! exercises that path with this function).

use super::{BidirState, SolState, SubmodularFn};

pub struct GraphCut {
    n: usize,
    sim: Vec<f32>,
    lambda: f64,
    /// cached column mass Σ_i sim(i,u)
    col: Vec<f64>,
}

impl GraphCut {
    pub fn new(n: usize, sim: Vec<f32>, lambda: f64) -> Self {
        assert_eq!(sim.len(), n * n);
        let col: Vec<f64> =
            (0..n).map(|u| (0..n).map(|i| sim[i * n + u] as f64).sum()).collect();
        Self { n, sim, lambda, col }
    }

    #[inline]
    fn sim(&self, i: usize, u: usize) -> f64 {
        self.sim[i * self.n + u] as f64
    }

    /// Marginal gain given the member indicator + current internal mass.
    fn gain_given(&self, members: &[bool], v: usize) -> f64 {
        let internal: f64 = (0..self.n).filter(|&u| members[u]).map(|u| self.sim(u, v)).sum();
        self.lambda * self.col[v] - internal
    }
}

impl SubmodularFn for GraphCut {
    fn n(&self) -> usize {
        self.n
    }

    fn eval(&self, s: &[usize]) -> f64 {
        let mut acc = 0.0;
        for &u in s {
            acc += self.lambda * self.col[u];
        }
        for (a, &u) in s.iter().enumerate() {
            for &v in &s[a + 1..] {
                acc -= self.sim(u, v);
            }
        }
        acc
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(GcState { f: self, member: vec![false; self.n], value: 0.0, set: Vec::new() })
    }

    fn bidir_state<'a>(&'a self, init: &[usize]) -> Option<Box<dyn BidirState + 'a>> {
        let mut member = vec![false; self.n];
        let mut value = 0.0;
        for &v in init {
            value += self.gain_given(&member, v);
            member[v] = true;
        }
        Some(Box::new(GcBidir { f: self, member, value }))
    }
}

struct GcState<'a> {
    f: &'a GraphCut,
    member: Vec<bool>,
    value: f64,
    set: Vec<usize>,
}

impl SolState for GcState<'_> {
    fn value(&self) -> f64 {
        self.value
    }
    fn gain(&self, v: usize) -> f64 {
        self.f.gain_given(&self.member, v)
    }
    fn add(&mut self, v: usize) {
        self.value += self.gain(v);
        self.member[v] = true;
        self.set.push(v);
    }
    fn set(&self) -> &[usize] {
        &self.set
    }
}

struct GcBidir<'a> {
    f: &'a GraphCut,
    member: Vec<bool>,
    value: f64,
}

impl BidirState for GcBidir<'_> {
    fn value(&self) -> f64 {
        self.value
    }
    fn gain_add(&self, v: usize) -> f64 {
        self.f.gain_given(&self.member, v)
    }
    fn gain_remove(&self, v: usize) -> f64 {
        let mut members = self.member.clone();
        members[v] = false;
        -self.f.gain_given(&members, v)
    }
    fn add(&mut self, v: usize) {
        self.value += self.gain_add(v);
        self.member[v] = true;
    }
    fn remove(&mut self, v: usize) {
        self.value += self.gain_remove(v);
        self.member[v] = false;
    }
    fn contains(&self, v: usize) -> bool {
        self.member[v]
    }
    fn members(&self) -> Vec<usize> {
        (0..self.member.len()).filter(|&v| self.member[v]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::*;
    use crate::util::rng::Rng;

    fn instance(n: usize, lambda: f64, seed: u64) -> GraphCut {
        let mut rng = Rng::new(seed);
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            for u in (i + 1)..n {
                let s = rng.f32();
                sim[i * n + u] = s;
                sim[u * n + i] = s;
            }
        }
        GraphCut::new(n, sim, lambda)
    }

    #[test]
    fn submodular_nonmonotone() {
        let f = instance(14, 0.4, 1);
        check_submodular(&f, false, 70, 150);
        check_state_consistency(&f, 71, 100);
    }

    #[test]
    fn large_lambda_behaves_monotone_on_small_sets() {
        let f = instance(10, 10.0, 2);
        let st = f.state();
        for v in 0..10 {
            assert!(st.gain(v) > 0.0);
        }
    }

    #[test]
    fn bidir_matches_eval() {
        let f = instance(10, 0.5, 3);
        let mut st = f.bidir_state(&[0, 4, 7]).unwrap();
        assert!((st.value() - f.eval(&[0, 4, 7])).abs() < 1e-6);
        st.remove(4);
        assert!((st.value() - f.eval(&[0, 7])).abs() < 1e-6);
        st.add(2);
        assert!((st.value() - f.eval(&[0, 2, 7])).abs() < 1e-6);
    }
}
