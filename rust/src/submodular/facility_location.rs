//! Facility location: `f(S) = Σ_{i∈V} max_{u∈S} sim(i, u)` — the classic
//! representativeness objective for video/image summarization.
//!
//! Backed by a dense similarity matrix (`n × n`, f32). Similarities must be
//! non-negative for monotonicity + normalization; [`FacilityLocation::from_features`]
//! builds clamped cosine similarities from a feature matrix.
//!
//! Memory note: dense `n²` storage caps practical `n` around ~8k in this
//! repo's benches; the paper's experiments use the feature-based objective
//! for exactly this reason, and so do ours — facility location exists for
//! the video examples and for objective-diversity in tests/ablations.

use super::{SolState, SubmodularFn};
use crate::util::vecmath::{cosine, FeatureMatrix};

pub struct FacilityLocation {
    n: usize,
    /// row-major `sim[i*n + u]` = attraction of ground element i to facility u
    sim: Vec<f32>,
}

impl FacilityLocation {
    pub fn new(n: usize, sim: Vec<f32>) -> Self {
        assert_eq!(sim.len(), n * n);
        debug_assert!(sim.iter().all(|&x| x >= 0.0), "similarities must be non-negative");
        Self { n, sim }
    }

    /// Clamped-cosine similarity from features: `max(0, cos(x_i, x_u))`.
    pub fn from_features(feats: &FeatureMatrix) -> Self {
        let n = feats.n();
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            sim[i * n + i] = 1.0;
            for u in (i + 1)..n {
                let s = cosine(feats.row(i), feats.row(u)).max(0.0);
                sim[i * n + u] = s;
                sim[u * n + i] = s;
            }
        }
        Self { n, sim }
    }

    #[inline]
    pub fn sim(&self, i: usize, u: usize) -> f32 {
        self.sim[i * self.n + u]
    }
}

impl SubmodularFn for FacilityLocation {
    fn n(&self) -> usize {
        self.n
    }

    fn eval(&self, s: &[usize]) -> f64 {
        if s.is_empty() {
            return 0.0;
        }
        let mut acc = 0.0f64;
        for i in 0..self.n {
            let mut best = 0.0f32;
            for &u in s {
                best = best.max(self.sim(i, u));
            }
            acc += best as f64;
        }
        acc
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(FlState { f: self, best: vec![0.0; self.n], value: 0.0, set: Vec::new() })
    }

    fn pair_gain(&self, u: usize, v: usize) -> f64 {
        // f(v|{u}) = Σ_i max(0, sim(i,v) - sim(i,u))
        let mut acc = 0.0f64;
        for i in 0..self.n {
            let d = self.sim(i, v) - self.sim(i, u);
            if d > 0.0 {
                acc += d as f64;
            }
        }
        acc
    }

    fn singleton(&self, v: usize) -> f64 {
        (0..self.n).map(|i| self.sim(i, v) as f64).sum()
    }

    fn singleton_complements(&self) -> Vec<f64> {
        // f(v|V\v) = Σ_i max(0, sim(i,v) - max_{u≠v} sim(i,u))
        //          = Σ_i [sim(i,v) == top1(i)] * (top1(i) - top2(i))  (v unique argmax)
        // Computed with a top-2 scan per row i: O(n²) once.
        let mut out = vec![0.0f64; self.n];
        for i in 0..self.n {
            let row = &self.sim[i * self.n..(i + 1) * self.n];
            let (mut top1, mut arg1, mut top2) = (f32::NEG_INFINITY, usize::MAX, f32::NEG_INFINITY);
            for (u, &s) in row.iter().enumerate() {
                if s > top1 {
                    top2 = top1;
                    top1 = s;
                    arg1 = u;
                } else if s > top2 {
                    top2 = s;
                }
            }
            if arg1 != usize::MAX && top1 > top2 {
                out[arg1] += (top1 - top2) as f64;
            }
        }
        out
    }
}

struct FlState<'a> {
    f: &'a FacilityLocation,
    /// per-ground-element current best similarity to the solution
    best: Vec<f32>,
    value: f64,
    set: Vec<usize>,
}

impl SolState for FlState<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&self, v: usize) -> f64 {
        let mut acc = 0.0f64;
        for i in 0..self.f.n {
            let d = self.f.sim(i, v) - self.best[i];
            if d > 0.0 {
                acc += d as f64;
            }
        }
        acc
    }

    fn add(&mut self, v: usize) {
        let mut acc = 0.0f64;
        for i in 0..self.f.n {
            let s = self.f.sim(i, v);
            if s > self.best[i] {
                acc += (s - self.best[i]) as f64;
                self.best[i] = s;
            }
        }
        self.value += acc;
        self.set.push(v);
    }

    fn set(&self) -> &[usize] {
        &self.set
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::*;
    use crate::util::rng::Rng;

    fn instance(n: usize, seed: u64) -> FacilityLocation {
        let mut rng = Rng::new(seed);
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            sim[i * n + i] = 1.0;
            for u in (i + 1)..n {
                let s = rng.f32();
                sim[i * n + u] = s;
                sim[u * n + i] = s;
            }
        }
        FacilityLocation::new(n, sim)
    }

    #[test]
    fn properties() {
        let f = instance(15, 1);
        check_submodular(&f, true, 40, 150);
        check_state_consistency(&f, 41, 100);
        check_edge_ingredients(&f, 42, 80);
    }

    #[test]
    fn from_features_symmetric_unit_diag() {
        let mut rng = Rng::new(2);
        let feats = FeatureMatrix::from_rows(
            (0..8).map(|_| (0..5).map(|_| rng.f32()).collect()).collect(),
        );
        let f = FacilityLocation::from_features(&feats);
        for i in 0..8 {
            assert!((f.sim(i, i) - 1.0).abs() < 1e-6);
            for u in 0..8 {
                assert_eq!(f.sim(i, u), f.sim(u, i));
                assert!(f.sim(i, u) >= 0.0);
            }
        }
    }

    #[test]
    fn full_set_attains_row_maxima() {
        let f = instance(10, 3);
        let full: Vec<usize> = (0..10).collect();
        let want: f64 = (0..10)
            .map(|i| (0..10).map(|u| f.sim(i, u)).fold(f32::MIN, f32::max) as f64)
            .sum();
        assert!((f.eval(&full) - want).abs() < 1e-6);
    }
}
