//! Facility location: `f(S) = Σ_{i∈V} max_{u∈S} sim(i, u)` — the classic
//! representativeness objective for video/image summarization.
//!
//! Backed by a dense similarity matrix (`n × n`, f32). Similarities must be
//! non-negative for monotonicity + normalization; [`FacilityLocation::from_features`]
//! builds clamped cosine similarities from a feature matrix.
//!
//! Memory note: dense `n²` storage caps practical `n` around ~8k in this
//! repo's benches; the paper's experiments use the feature-based objective
//! for exactly this reason, and so do ours — facility location exists for
//! the video examples and for objective-diversity in tests/ablations.

use std::cell::RefCell;

use super::{BatchedDivergence, SolState, SubmodularFn};
use crate::util::pool::ThreadPool;
use crate::util::vecmath::{cosine, FeatureMatrix};

/// Items per block of the cache-blocked kernels: the `block × P` f64
/// accumulator (≲ 64·128·8B = 64 KiB at the largest realistic probe count)
/// stays L2-resident while similarity rows stream through once per block.
const ITEM_BLOCK: usize = 64;

thread_local! {
    /// Per-thread kernel scratch (accumulator tile + probe gather row),
    /// reused across rounds and shards so the write-into divergence path
    /// never touches the allocator in the steady state.
    static FL_SCRATCH: RefCell<FlScratch> = RefCell::new(FlScratch::default());
}

#[derive(Default)]
struct FlScratch {
    /// `ITEM_BLOCK × P` pair-gain accumulator tile
    acc: Vec<f64>,
    /// per-row probe-entry gather (length P)
    pu: Vec<f32>,
}

pub struct FacilityLocation {
    n: usize,
    /// row-major `sim[i*n + u]` = attraction of ground element i to facility u
    sim: Vec<f32>,
}

impl FacilityLocation {
    pub fn new(n: usize, sim: Vec<f32>) -> Self {
        assert_eq!(sim.len(), n * n);
        debug_assert!(sim.iter().all(|&x| x >= 0.0), "similarities must be non-negative");
        Self { n, sim }
    }

    /// Clamped-cosine similarity from features: `max(0, cos(x_i, x_u))`.
    pub fn from_features(feats: &FeatureMatrix) -> Self {
        let n = feats.n();
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            sim[i * n + i] = 1.0;
            for u in (i + 1)..n {
                let s = cosine(feats.row(i), feats.row(u)).max(0.0);
                sim[i * n + u] = s;
                sim[u * n + i] = s;
            }
        }
        Self { n, sim }
    }

    #[inline]
    pub fn sim(&self, i: usize, u: usize) -> f32 {
        self.sim[i * self.n + u]
    }

    /// Shared inner loop of both blocked kernels: accumulate the pair-gain
    /// tile `acc[bi * P + ui] += max(0, sim(i, v_bi) − sim(i, u_ui))` over
    /// all ground elements `i`, streaming similarity rows contiguously.
    /// `acc` must be zeroed, length `vblock.len() × probes.len()`; `pu` is
    /// a `probes.len()` gather scratch. Keeping this in one place is what
    /// guarantees `pair_gains_block` and `divergences_block` can never
    /// drift apart bit-wise.
    fn accumulate_pair_gain_tile(
        &self,
        probes: &[usize],
        vblock: &[usize],
        acc: &mut [f64],
        pu: &mut [f32],
    ) {
        let p = probes.len();
        debug_assert_eq!(acc.len(), vblock.len() * p);
        debug_assert_eq!(pu.len(), p);
        for i in 0..self.n {
            let row = &self.sim[i * self.n..(i + 1) * self.n];
            for (slot, &u) in probes.iter().enumerate() {
                pu[slot] = row[u];
            }
            for (bi, &v) in vblock.iter().enumerate() {
                let sv = row[v];
                let tile = &mut acc[bi * p..(bi + 1) * p];
                for (a, &su) in tile.iter_mut().zip(pu.iter()) {
                    let d = sv - su;
                    if d > 0.0 {
                        *a += d as f64;
                    }
                }
            }
        }
    }

    /// Cache-blocked batched marginal gains against a per-ground-element
    /// best-similarity vector: `out[j] = Σ_i max(0, sim(i, c_j) − best_i)`
    /// — the maximizer engine's hot kernel for this objective. The scalar
    /// [`SolState::gain`] walks one stride-`n` similarity *column* per
    /// candidate (a cache miss per ground element); this kernel streams
    /// rows contiguously and accumulates an `ITEM_BLOCK`-wide f64 tile per
    /// row — the same loop inversion as [`Self::pair_gains_block`]. Per
    /// candidate the ground elements are visited in the same ascending
    /// order with the same f32-subtract / f64-accumulate widths as the
    /// scalar loop, so the result is bit-identical regardless of how the
    /// cohort is chunked.
    pub fn gains_over_best_into(&self, best: &[f32], candidates: &[usize], out: &mut [f64]) {
        debug_assert_eq!(best.len(), self.n);
        debug_assert_eq!(candidates.len(), out.len());
        for (cblock, out_block) in candidates.chunks(ITEM_BLOCK).zip(out.chunks_mut(ITEM_BLOCK)) {
            out_block.fill(0.0);
            for (i, &b) in best.iter().enumerate() {
                let row = &self.sim[i * self.n..(i + 1) * self.n];
                for (slot, &v) in out_block.iter_mut().zip(cblock) {
                    let d = row[v] - b;
                    if d > 0.0 {
                        *slot += d as f64;
                    }
                }
            }
        }
    }

    /// The serial top-2 scan of similarity row `i` — shared by the serial
    /// and row-sharded singleton precomputes so the two can never drift:
    /// `(top1, argmax, top2)` under strict-`>` promotion (first occurrence
    /// wins ties, duplicates count toward top2).
    #[inline]
    fn row_top2(&self, i: usize) -> (f32, usize, f32) {
        let row = &self.sim[i * self.n..(i + 1) * self.n];
        let (mut top1, mut arg1, mut top2) = (f32::NEG_INFINITY, usize::MAX, f32::NEG_INFINITY);
        for (u, &s) in row.iter().enumerate() {
            if s > top1 {
                top2 = top1;
                top1 = s;
                arg1 = u;
            } else if s > top2 {
                top2 = s;
            }
        }
        (top1, arg1, top2)
    }

    /// Row-sharded singleton-complement precompute — the parallel form of
    /// the O(n²) top-2 scan that used to run serially at request start.
    /// Phase 1 shards the *reduction* (row) dimension: each shard writes
    /// its rows' `(argmax, top1 − top2)` results into disjoint slices of a
    /// row-indexed buffer. Phase 2 scatters them serially in ascending-row
    /// order — exactly the add sequence of the serial scan, so every
    /// output slot's f64 fold is bit-identical (asserted in tests and by
    /// the sharded-backend precompute suite).
    pub fn singleton_complements_rowsharded(
        &self,
        pool: &ThreadPool,
        shards: usize,
    ) -> Vec<f64> {
        let n = self.n;
        let mut rows: Vec<(usize, f64)> = vec![(usize::MAX, 0.0); n];
        pool.parallel_ranges_into(&mut rows[..], shards, |lo, _hi, chunk| {
            for (slot, i) in chunk.iter_mut().zip(lo..) {
                let (top1, arg1, top2) = self.row_top2(i);
                *slot = if arg1 != usize::MAX && top1 > top2 {
                    (arg1, (top1 - top2) as f64)
                } else {
                    (usize::MAX, 0.0)
                };
            }
        });
        let mut out = vec![0.0f64; n];
        for &(arg, delta) in &rows {
            if arg != usize::MAX {
                out[arg] += delta;
            }
        }
        out
    }

    /// Cache-blocked batched pair gains `f(v|u) = Σ_i max(0, sim(i,v) −
    /// sim(i,u))`, item-major like
    /// [`BatchedDivergence::pair_gains_batch`].
    ///
    /// The scalar [`SubmodularFn::pair_gain`] walks two *columns* of the
    /// similarity matrix per `(u, v)` pair — stride-`n` loads that miss
    /// cache on every ground element. This kernel inverts the loops: it
    /// streams similarity *rows* contiguously, gathers the probe entries of
    /// each row once, and accumulates a `block × P` pair-gain tile that
    /// stays cache-resident (numbers in EXPERIMENTS.md §Perf; bench:
    /// `perf_facility_divergence`).
    ///
    /// Per `(u, v)` the accumulation visits ground elements in the same
    /// ascending order, with the same f32-subtract / f64-accumulate widths,
    /// as `pair_gain` — so the result is bit-identical to the scalar path
    /// and sharded pruning decisions match the reference exactly.
    pub fn pair_gains_block(&self, probes: &[usize], items: &[usize]) -> Vec<f64> {
        let mut out = vec![0.0f64; items.len() * probes.len()];
        self.pair_gains_into_block(probes, items, &mut out);
        out
    }

    /// Write-into form of [`Self::pair_gains_block`]: same tiles, same
    /// bits, with the probe gather row in thread-local scratch and the
    /// (possibly dirty) output zeroed before accumulation.
    pub fn pair_gains_into_block(&self, probes: &[usize], items: &[usize], out: &mut [f64]) {
        let p = probes.len();
        debug_assert_eq!(out.len(), items.len() * p);
        out.fill(0.0);
        FL_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.pu.resize(p, 0.0);
            for (block, vblock) in items.chunks(ITEM_BLOCK).enumerate() {
                let base = block * ITEM_BLOCK * p;
                self.accumulate_pair_gain_tile(
                    probes,
                    vblock,
                    &mut out[base..base + vblock.len() * p],
                    &mut s.pu,
                );
            }
        });
    }

    /// Fused form of [`Self::pair_gains_block`]: folds the per-item min
    /// over probes without materializing the full pair-gain matrix, so the
    /// working set is one `ITEM_BLOCK × P` tile regardless of item count.
    /// Bit-identical to the default scalar divergence path (tested below).
    pub fn divergences_block(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; items.len()];
        self.divergences_into_block(probes, probe_sing, items, &mut out);
        out
    }

    /// Write-into form of [`Self::divergences_block`] — the zero-allocation
    /// hot path: the `ITEM_BLOCK × P` accumulator tile and the probe
    /// gather row live in thread-local scratch, warm after the first SS
    /// round, so steady-state calls are pure kernel work. Bit-identical to
    /// the allocating form (same tiles, same fold order).
    pub fn divergences_into_block(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
        out: &mut [f32],
    ) {
        debug_assert_eq!(probes.len(), probe_sing.len());
        debug_assert_eq!(out.len(), items.len());
        if probes.is_empty() {
            out.fill(f32::INFINITY);
            return;
        }
        let p = probes.len();
        FL_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.acc.resize(ITEM_BLOCK * p, 0.0);
            s.pu.resize(p, 0.0);
            for (vblock, out_block) in items.chunks(ITEM_BLOCK).zip(out.chunks_mut(ITEM_BLOCK)) {
                let tile = &mut s.acc[..vblock.len() * p];
                tile.fill(0.0);
                self.accumulate_pair_gain_tile(probes, vblock, tile, &mut s.pu);
                for (bi, slot) in out_block.iter_mut().enumerate() {
                    *slot = s.acc[bi * p..(bi + 1) * p]
                        .iter()
                        .zip(probe_sing)
                        .map(|(&g, &su)| (g - su) as f32)
                        .fold(f32::INFINITY, f32::min);
                }
            }
        });
    }
}

impl BatchedDivergence for FacilityLocation {
    fn as_submodular(&self) -> &dyn SubmodularFn {
        self
    }

    fn pair_gains_batch(&self, probes: &[usize], items: &[usize]) -> Vec<f64> {
        self.pair_gains_block(probes, items)
    }

    fn pair_gains_into(&self, probes: &[usize], items: &[usize], out: &mut [f64]) {
        self.pair_gains_into_block(probes, items, out);
    }

    fn divergences_batch(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
    ) -> Vec<f32> {
        self.divergences_block(probes, probe_sing, items)
    }

    fn divergences_into(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
        out: &mut [f32],
    ) {
        self.divergences_into_block(probes, probe_sing, items, out);
    }
}

impl SubmodularFn for FacilityLocation {
    fn n(&self) -> usize {
        self.n
    }

    fn eval(&self, s: &[usize]) -> f64 {
        if s.is_empty() {
            return 0.0;
        }
        let mut acc = 0.0f64;
        for i in 0..self.n {
            let mut best = 0.0f32;
            for &u in s {
                best = best.max(self.sim(i, u));
            }
            acc += best as f64;
        }
        acc
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(FlState { f: self, best: vec![0.0; self.n], value: 0.0, set: Vec::new() })
    }

    fn pair_gain(&self, u: usize, v: usize) -> f64 {
        // f(v|{u}) = Σ_i max(0, sim(i,v) - sim(i,u))
        let mut acc = 0.0f64;
        for i in 0..self.n {
            let d = self.sim(i, v) - self.sim(i, u);
            if d > 0.0 {
                acc += d as f64;
            }
        }
        acc
    }

    fn singleton(&self, v: usize) -> f64 {
        (0..self.n).map(|i| self.sim(i, v) as f64).sum()
    }

    fn singleton_complements(&self) -> Vec<f64> {
        // f(v|V\v) = Σ_i max(0, sim(i,v) - max_{u≠v} sim(i,u))
        //          = Σ_i [sim(i,v) == top1(i)] * (top1(i) - top2(i))  (v unique argmax)
        // Computed with a top-2 scan per row i: O(n²) once.
        let mut out = vec![0.0f64; self.n];
        for i in 0..self.n {
            let (top1, arg1, top2) = self.row_top2(i);
            if arg1 != usize::MAX && top1 > top2 {
                out[arg1] += (top1 - top2) as f64;
            }
        }
        out
    }

    /// The top-2 scan scatters into arbitrary output slots, so the
    /// per-element-decomposable route stays closed — but the scan *is*
    /// shardable over rows: see [`Self::singleton_complements_rowsharded`].
    fn singleton_complements_pooled(&self, pool: &ThreadPool, shards: usize) -> Option<Vec<f64>> {
        Some(self.singleton_complements_rowsharded(pool, shards))
    }

    fn supports_retain(&self) -> bool {
        true
    }

    /// Compact the dense similarity matrix to the `keep × keep` principal
    /// submatrix, in place: with `keep` ascending every source cell sits
    /// at or after its destination, so a forward row-major walk never
    /// reads an overwritten slot. The result is indistinguishable from a
    /// `FacilityLocation::new` over the gathered submatrix.
    fn retain_elements(&mut self, keep: &[usize]) -> bool {
        let n = self.n;
        let m = keep.len();
        let mut prev = None;
        for &old in keep {
            assert!(old < n, "retain_elements index {old} out of range (n={n})");
            assert!(prev.map_or(true, |p| p < old), "retain_elements requires ascending indices");
            prev = Some(old);
        }
        for (ni, &oi) in keep.iter().enumerate() {
            for (nj, &oj) in keep.iter().enumerate() {
                // oi*n + oj >= ni*m + nj because oi >= ni, oj >= nj, n >= m
                self.sim[ni * m + nj] = self.sim[oi * n + oj];
            }
        }
        self.sim.truncate(m * m);
        self.n = m;
        true
    }
}

struct FlState<'a> {
    f: &'a FacilityLocation,
    /// per-ground-element current best similarity to the solution
    best: Vec<f32>,
    value: f64,
    set: Vec<usize>,
}

impl SolState for FlState<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&self, v: usize) -> f64 {
        let mut acc = 0.0f64;
        for i in 0..self.f.n {
            let d = self.f.sim(i, v) - self.best[i];
            if d > 0.0 {
                acc += d as f64;
            }
        }
        acc
    }

    fn add(&mut self, v: usize) {
        let mut acc = 0.0f64;
        for i in 0..self.f.n {
            let s = self.f.sim(i, v);
            if s > self.best[i] {
                acc += (s - self.best[i]) as f64;
                self.best[i] = s;
            }
        }
        self.value += acc;
        self.set.push(v);
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn gains_into(&self, candidates: &[usize], out: &mut [f64]) {
        self.f.gains_over_best_into(&self.best, candidates, out);
    }

    fn reserve_additions(&mut self, additional: usize) {
        self.set.reserve(additional);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::*;
    use crate::util::rng::Rng;

    fn instance(n: usize, seed: u64) -> FacilityLocation {
        let mut rng = Rng::new(seed);
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            sim[i * n + i] = 1.0;
            for u in (i + 1)..n {
                let s = rng.f32();
                sim[i * n + u] = s;
                sim[u * n + i] = s;
            }
        }
        FacilityLocation::new(n, sim)
    }

    #[test]
    fn properties() {
        let f = instance(15, 1);
        check_submodular(&f, true, 40, 150);
        check_state_consistency(&f, 41, 100);
        check_edge_ingredients(&f, 42, 80);
    }

    #[test]
    fn from_features_symmetric_unit_diag() {
        let mut rng = Rng::new(2);
        let feats = FeatureMatrix::from_rows(
            (0..8).map(|_| (0..5).map(|_| rng.f32()).collect()).collect(),
        );
        let f = FacilityLocation::from_features(&feats);
        for i in 0..8 {
            assert!((f.sim(i, i) - 1.0).abs() < 1e-6);
            for u in 0..8 {
                assert_eq!(f.sim(i, u), f.sim(u, i));
                assert!(f.sim(i, u) >= 0.0);
            }
        }
    }

    #[test]
    fn retain_elements_bitwise_matches_fresh_submatrix() {
        let mut f = instance(30, 9);
        let keep: Vec<usize> = (0..30).filter(|i| i % 4 != 2).collect();
        // fresh construction over the gathered principal submatrix
        let m = keep.len();
        let mut sub = vec![0.0f32; m * m];
        for (ni, &oi) in keep.iter().enumerate() {
            for (nj, &oj) in keep.iter().enumerate() {
                sub[ni * m + nj] = f.sim(oi, oj);
            }
        }
        let fresh = FacilityLocation::new(m, sub);
        assert!(f.supports_retain());
        assert!(f.retain_elements(&keep));
        assert_eq!(f.n(), m);
        for i in 0..m {
            for u in 0..m {
                assert_eq!(f.sim(i, u).to_bits(), fresh.sim(i, u).to_bits());
            }
        }
        let a = f.singleton_complements();
        let b = fresh.singleton_complements();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn blocked_pair_gains_bitwise_match_scalar() {
        // 150 items spans multiple ITEM_BLOCK chunks incl. a ragged tail
        let f = instance(150, 4);
        let probes = vec![0usize, 7, 149, 42];
        let items: Vec<usize> = (0..150).filter(|v| !probes.contains(v)).collect();
        let pg = f.pair_gains_block(&probes, &items);
        for (vi, &v) in items.iter().enumerate() {
            for (ui, &u) in probes.iter().enumerate() {
                assert_eq!(
                    pg[vi * probes.len() + ui],
                    f.pair_gain(u, v),
                    "blocked pair gain must be bit-identical at (u={u}, v={v})"
                );
            }
        }
    }

    #[test]
    fn blocked_divergences_bitwise_match_scalar_reference() {
        let f = instance(200, 5);
        let sing = f.singleton_complements();
        let probes = vec![3usize, 50, 199, 120, 77];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..200).filter(|v| !probes.contains(v)).collect();
        let got = f.divergences_block(&probes, &probe_sing, &items);
        let want = scalar_reference_divergences(&f, &probes, &probe_sing, &items);
        assert_eq!(got, want, "fused kernel must equal the scalar divergence path bit-for-bit");
    }

    #[test]
    fn write_into_kernels_bitwise_match_allocating_kernels() {
        // 150 items spans multiple ITEM_BLOCK chunks incl. a ragged tail
        let f = instance(150, 8);
        let sing = f.singleton_complements();
        let probes = vec![3usize, 149, 77];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..150).filter(|v| !probes.contains(v)).collect();
        let want = scalar_reference_divergences(&f, &probes, &probe_sing, &items);
        let mut out = vec![f32::NAN; items.len()];
        for _ in 0..2 {
            // twice: thread-local scratch reuse must not leak state
            f.divergences_into_block(&probes, &probe_sing, &items, &mut out);
            assert_eq!(out, want);
        }
        let mut out_pg = vec![f64::NAN; items.len() * probes.len()];
        f.pair_gains_into_block(&probes, &items, &mut out_pg);
        for (vi, &v) in items.iter().enumerate() {
            for (ui, &u) in probes.iter().enumerate() {
                assert_eq!(out_pg[vi * probes.len() + ui], f.pair_gain(u, v));
            }
        }
    }

    #[test]
    fn batched_state_gains_bitwise_match_scalar() {
        // 150 candidates spans multiple ITEM_BLOCK chunks incl. a ragged
        // tail; the property driver also covers dirty buffers + reuse
        let f = instance(150, 6);
        check_batched_gains(&f, 140, 40);
        let cands: Vec<usize> = (0..150).collect();
        let mut st = f.state();
        for &v in &[3usize, 77, 149] {
            st.add(v);
        }
        let want: Vec<f64> = cands.iter().map(|&v| st.gain(v)).collect();
        let mut out = vec![f64::NAN; cands.len()];
        st.gains_into(&cands, &mut out);
        for (got, w) in out.iter().zip(&want) {
            assert_eq!(got.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn rowsharded_singleton_precompute_bitwise_matches_serial() {
        use crate::util::pool::ThreadPool;
        // sizes chosen to exercise ragged shard tails and shards > rows
        for (n, seed) in [(97usize, 7u64), (150, 8), (16, 9)] {
            let f = instance(n, seed);
            let want = f.singleton_complements();
            let pool = ThreadPool::new(3, 16);
            for shards in [1usize, 2, 7, 64] {
                let got = f.singleton_complements_rowsharded(&pool, shards);
                assert_eq!(got.len(), want.len());
                for (v, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "slot {v} diverged (n={n}, shards={shards})"
                    );
                }
                // the trait hook must route to the same computation
                let hooked = f.singleton_complements_pooled(&pool, shards).unwrap();
                assert_eq!(hooked, got);
            }
        }
    }

    #[test]
    fn full_set_attains_row_maxima() {
        let f = instance(10, 3);
        let full: Vec<usize> = (0..10).collect();
        let want: f64 = (0..10)
            .map(|i| (0..10).map(|u| f.sim(i, u)).fold(f32::MIN, f32::max) as f64)
            .sum();
        assert!((f.eval(&full) - want).abs() < 1e-6);
    }
}
