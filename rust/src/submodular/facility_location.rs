//! Facility location: `f(S) = Σ_{i∈V} max_{u∈S} sim(i, u)` — the classic
//! representativeness objective for video/image summarization.
//!
//! Similarities live behind [`SimStore`]: a dense `n × n` f32 matrix for
//! small ground sets (the exact small-n oracle), or a
//! [`SparseSimStore`](super::sparse_sim::SparseSimStore) of per-row top-`t`
//! neighbor lists for large ones. Construction through
//! [`FacilityLocation::from_features`] auto-selects dense below
//! [`DENSE_CROSSOVER`] and sparse above it; [`from_features_with`]
//! overrides both the crossover and `t` (the `ObjectiveSpec` surface).
//! Similarities must be non-negative for monotonicity + normalization;
//! both builders use clamped cosine. In the sparse store an absent entry
//! reads `0.0` — a lower bound on the true similarity, so the induced
//! objective stays monotone submodular; at `t = n − 1` nothing is absent
//! and every kernel below is bit-identical to the dense path (pinned by
//! `rust/tests/sparse_fl_equivalence.rs`).
//!
//! Memory note: the dense store is `O(n²)` and caps practical `n` around
//! ~8k; the sparse store is `O(n·t)` and is what the large-n batch and
//! streaming paths ride (EXPERIMENTS.md §Sparse FL).
//!
//! [`from_features_with`]: FacilityLocation::from_features_with

use std::cell::RefCell;

use super::sparse_sim::{BuildStrategy, SparseSimStore};
use super::{BatchedDivergence, SolState, SubmodularFn};
use crate::util::pool::ThreadPool;
use crate::util::vecmath::{cosine, FeatureMatrix};

/// Items per block of the cache-blocked kernels: the `block × P` f64
/// accumulator (≲ 64·128·8B = 64 KiB at the largest realistic probe count)
/// stays L2-resident while similarity rows stream through once per block.
const ITEM_BLOCK: usize = 64;

/// Ground-set size at which [`FacilityLocation::from_features`] switches
/// from the dense matrix to the sparse top-`t` store. Below it the dense
/// build (≤ ~64 MiB of similarities) is both exact and faster to query;
/// above it the `O(n²)` footprint dominates everything else in the stack.
/// Methodology for the default in EXPERIMENTS.md §Sparse FL.
pub const DENSE_CROSSOVER: usize = 4096;

thread_local! {
    /// Per-thread kernel scratch (accumulator tile + probe gather row),
    /// reused across rounds and shards so the write-into divergence path
    /// never touches the allocator in the steady state.
    static FL_SCRATCH: RefCell<FlScratch> = RefCell::new(FlScratch::default());
    /// Per-thread dense row image for the sparse store: row `i`'s live
    /// entries are scattered in, the kernel body reads it exactly like a
    /// dense row (absent columns are `0.0`), and the entries are zeroed
    /// again afterwards — `O(t)` per row, never `O(n)`. Separate cell from
    /// `FL_SCRATCH` because the tile kernels hold that one borrowed while
    /// streaming rows.
    static FL_ROW_SCRATCH: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

#[derive(Default)]
struct FlScratch {
    /// `ITEM_BLOCK × P` pair-gain accumulator tile
    acc: Vec<f64>,
    /// per-row probe-entry gather (length P)
    pu: Vec<f32>,
}

/// The similarity backing: dense small-n oracle or sparse top-`t` lists.
#[derive(Clone)]
enum SimStore {
    /// row-major `sim[i*n + u]`
    Dense(Vec<f32>),
    Sparse(SparseSimStore),
}

#[derive(Clone)]
pub struct FacilityLocation {
    n: usize,
    store: SimStore,
}

impl FacilityLocation {
    pub fn new(n: usize, sim: Vec<f32>) -> Self {
        assert_eq!(sim.len(), n * n);
        debug_assert!(sim.iter().all(|&x| x >= 0.0), "similarities must be non-negative");
        Self { n, store: SimStore::Dense(sim) }
    }

    /// Clamped-cosine similarity from features, auto-selecting the store:
    /// dense below [`DENSE_CROSSOVER`], sparse (auto `t`) at or above it.
    pub fn from_features(feats: &FeatureMatrix) -> Self {
        Self::from_features_with(feats, DENSE_CROSSOVER, None, None)
    }

    /// The dense small-n oracle: `max(0, cos(x_i, x_u))`, full matrix.
    pub fn from_features_dense(feats: &FeatureMatrix) -> Self {
        let n = feats.n();
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            sim[i * n + i] = 1.0;
            for u in (i + 1)..n {
                let s = cosine(feats.row(i), feats.row(u)).max(0.0);
                sim[i * n + u] = s;
                sim[u * n + i] = s;
            }
        }
        Self { n, store: SimStore::Dense(sim) }
    }

    /// Sparse top-`t` store regardless of size (serial exact kNN build).
    pub fn from_features_sparse(feats: &FeatureMatrix, t: usize) -> Self {
        Self { n: feats.n(), store: SimStore::Sparse(SparseSimStore::from_features(feats, t)) }
    }

    /// Wrap an already-materialized sparse store — the checkpoint-restore
    /// seam: a stream session's post-eviction neighbor history is not
    /// reproducible from the surviving feature rows, so recovery rebuilds
    /// the store from persisted lists and adopts it here verbatim.
    pub fn from_sparse_store(store: SparseSimStore) -> Self {
        Self { n: store.n(), store: SimStore::Sparse(store) }
    }

    /// Configurable construction — the `ObjectiveSpec` seam: dense iff
    /// `n < crossover`; otherwise sparse with `t` neighbors (auto-sized
    /// [`auto_neighbors`] when `None`), shard-parallel over `pooled` when
    /// a pool is supplied. Neighbor candidates come from
    /// [`BuildStrategy::Auto`]: exact all-pairs below
    /// [`LSH_CROSSOVER`](super::sparse_sim::LSH_CROSSOVER), LSH-bucketed
    /// above — use [`from_features_strat`](Self::from_features_strat) to
    /// pin a builder explicitly.
    ///
    /// [`auto_neighbors`]: FacilityLocation::auto_neighbors
    pub fn from_features_with(
        feats: &FeatureMatrix,
        crossover: usize,
        t: Option<usize>,
        pooled: Option<(&ThreadPool, usize)>,
    ) -> Self {
        Self::from_features_strat(feats, crossover, t, BuildStrategy::Auto, pooled)
    }

    /// [`from_features_with`](Self::from_features_with) with an explicit
    /// neighbor [`BuildStrategy`]. Under `Lsh`, an explicit `t` keeps the
    /// exact top-`t` of the bucket candidates (so saturated tables are
    /// bit-identical to `Exact`); auto `t` engages the adaptive budget —
    /// per-row cap `4·auto_neighbors(n)` with the mass-coverage floor
    /// `max(8, auto_neighbors(n)/2)` — so rows in large redundant
    /// clusters keep enough neighbors to hold the utility floor where
    /// the fixed `t = O(log n)` budget collapses (EXPERIMENTS.md §Sparse
    /// facility location).
    pub fn from_features_strat(
        feats: &FeatureMatrix,
        crossover: usize,
        t: Option<usize>,
        build: BuildStrategy,
        pooled: Option<(&ThreadPool, usize)>,
    ) -> Self {
        let n = feats.n();
        if n < crossover {
            return Self::from_features_dense(feats);
        }
        let store = match build.resolve(n) {
            None => {
                let t = t.unwrap_or_else(|| Self::auto_neighbors(n));
                match pooled {
                    Some((pool, shards)) => {
                        SparseSimStore::from_features_pooled(feats, t, pool, shards)
                    }
                    None => SparseSimStore::from_features(feats, t),
                }
            }
            Some((tables, bits)) => {
                let (cap, floor) = match t {
                    Some(t) => (t, None),
                    None => {
                        let base = Self::auto_neighbors(n);
                        ((base * 4).min(n.saturating_sub(1)).max(1), Some((base / 2).max(8)))
                    }
                };
                match pooled {
                    Some((pool, shards)) => SparseSimStore::from_features_lsh_pooled(
                        feats, cap, floor, tables, bits, pool, shards,
                    ),
                    None => SparseSimStore::from_features_lsh(feats, cap, floor, tables, bits),
                }
            }
        };
        Self { n, store: SimStore::Sparse(store) }
    }

    /// Default neighbor budget at auto-sparse construction: `⌈8·ln n⌉`,
    /// floored at 16 — the `t = O(log n)` regime whose ≥0.95 utility floor
    /// the equivalence suite pins on clustered data.
    pub fn auto_neighbors(n: usize) -> usize {
        ((((n.max(2)) as f64).ln() * 8.0).ceil() as usize).max(16)
    }

    /// Whether the similarities are backed by the sparse top-`t` store.
    pub fn is_sparse(&self) -> bool {
        matches!(self.store, SimStore::Sparse(_))
    }

    /// The sparse store, when active (stats introspection for metrics,
    /// memory tests and benches).
    pub fn sparse_store(&self) -> Option<&SparseSimStore> {
        match &self.store {
            SimStore::Sparse(s) => Some(s),
            SimStore::Dense(_) => None,
        }
    }

    /// Resident bytes of the similarity storage (dense matrix or sparse
    /// slots) — what the `O(n·t)` peak-memory assertions measure.
    pub fn resident_bytes(&self) -> usize {
        match &self.store {
            SimStore::Dense(sim) => sim.capacity() * std::mem::size_of::<f32>(),
            SimStore::Sparse(s) => s.resident_bytes(),
        }
    }

    /// Row-border append (streaming fast path): the new element's feature
    /// row must be the last row of `feats` with `feats.n() == n + 1`.
    /// Returns the number of existing-row neighbor-list updates, or `None`
    /// when the store is dense — dense growth re-strides the whole matrix,
    /// so callers rebuild through [`from_features`] instead (which also
    /// rides the crossover once `n` outgrows it).
    ///
    /// [`from_features`]: FacilityLocation::from_features
    pub fn append_row_from_features(&mut self, feats: &FeatureMatrix) -> Option<u64> {
        match &mut self.store {
            SimStore::Dense(_) => None,
            SimStore::Sparse(s) => {
                let updates = s.append_row(feats);
                self.n = s.n();
                Some(updates)
            }
        }
    }

    #[inline]
    pub fn sim(&self, i: usize, u: usize) -> f32 {
        match &self.store {
            SimStore::Dense(sim) => sim[i * self.n + u],
            SimStore::Sparse(s) => s.get(i, u),
        }
    }

    /// Write `sim(lo + k, v)` into `out[k]` — the commit-step gather:
    /// [`FlState::add_pooled`] fans this over the pool into disjoint
    /// slices, and each value is exactly what the serial `add` loop reads
    /// (`row[v]` of the dense row or the scattered sparse image), so the
    /// subsequent serial fold is bit-identical to `add`.
    #[inline]
    fn gather_column_into(&self, v: usize, lo: usize, out: &mut [f32]) {
        match &self.store {
            SimStore::Dense(sim) => {
                for (k, slot) in out.iter_mut().enumerate() {
                    *slot = sim[(lo + k) * self.n + v];
                }
            }
            SimStore::Sparse(s) => {
                for (k, slot) in out.iter_mut().enumerate() {
                    *slot = s.get(lo + k, v);
                }
            }
        }
    }

    /// Stream every similarity row through `f` in ascending ground order.
    /// Dense rows are borrowed straight from the matrix; sparse rows are
    /// scattered into a thread-local dense image first (absent columns
    /// `0.0`) and cleared after — so the kernel bodies are *one* piece of
    /// code whose arithmetic cannot differ between the stores, which is
    /// the whole bit-identity argument at `t = n − 1`.
    #[inline]
    fn with_rows<F: FnMut(usize, &[f32])>(&self, mut f: F) {
        match &self.store {
            SimStore::Dense(sim) => {
                for i in 0..self.n {
                    f(i, &sim[i * self.n..(i + 1) * self.n]);
                }
            }
            SimStore::Sparse(s) => FL_ROW_SCRATCH.with(|cell| {
                let row = &mut *cell.borrow_mut();
                row.resize(self.n, 0.0);
                for i in 0..self.n {
                    let (cols, vals) = s.row(i);
                    for (&c, &v) in cols.iter().zip(vals) {
                        row[c as usize] = v;
                    }
                    f(i, row);
                    for &c in cols {
                        row[c as usize] = 0.0;
                    }
                }
            }),
        }
    }

    /// Shared inner loop of both blocked kernels: accumulate the pair-gain
    /// tile `acc[bi * P + ui] += max(0, sim(i, v_bi) − sim(i, u_ui))` over
    /// all ground elements `i`, streaming similarity rows contiguously.
    /// `acc` must be zeroed, length `vblock.len() × probes.len()`; `pu` is
    /// a `probes.len()` gather scratch. Keeping this in one place is what
    /// guarantees `pair_gains_block` and `divergences_block` can never
    /// drift apart bit-wise.
    fn accumulate_pair_gain_tile(
        &self,
        probes: &[usize],
        vblock: &[usize],
        acc: &mut [f64],
        pu: &mut [f32],
    ) {
        let p = probes.len();
        debug_assert_eq!(acc.len(), vblock.len() * p);
        debug_assert_eq!(pu.len(), p);
        self.with_rows(|_i, row| {
            for (slot, &u) in probes.iter().enumerate() {
                pu[slot] = row[u];
            }
            for (bi, &v) in vblock.iter().enumerate() {
                let sv = row[v];
                let tile = &mut acc[bi * p..(bi + 1) * p];
                for (a, &su) in tile.iter_mut().zip(pu.iter()) {
                    let d = sv - su;
                    if d > 0.0 {
                        *a += d as f64;
                    }
                }
            }
        });
    }

    /// Cache-blocked batched marginal gains against a per-ground-element
    /// best-similarity vector: `out[j] = Σ_i max(0, sim(i, c_j) − best_i)`
    /// — the maximizer engine's hot kernel for this objective. The scalar
    /// [`SolState::gain`] walks one similarity *column* per candidate (a
    /// cache miss per ground element dense, a binary search sparse); this
    /// kernel streams rows contiguously and accumulates an `ITEM_BLOCK`-
    /// wide f64 tile per row — the same loop inversion as
    /// [`Self::pair_gains_block`]. Per candidate the ground elements are
    /// visited in the same ascending order with the same f32-subtract /
    /// f64-accumulate widths as the scalar loop, so the result is
    /// bit-identical regardless of how the cohort is chunked.
    pub fn gains_over_best_into(&self, best: &[f32], candidates: &[usize], out: &mut [f64]) {
        debug_assert_eq!(best.len(), self.n);
        debug_assert_eq!(candidates.len(), out.len());
        for (cblock, out_block) in candidates.chunks(ITEM_BLOCK).zip(out.chunks_mut(ITEM_BLOCK)) {
            out_block.fill(0.0);
            self.with_rows(|i, row| {
                let b = best[i];
                for (slot, &v) in out_block.iter_mut().zip(cblock) {
                    let d = row[v] - b;
                    if d > 0.0 {
                        *slot += d as f64;
                    }
                }
            });
        }
    }

    /// The top-2 scan of similarity row `i` — shared by the serial and
    /// row-sharded singleton precomputes so the two can never drift:
    /// `(top1, argmax, top2)` under strict-`>` promotion (first occurrence
    /// wins ties, duplicates count toward top2). The sparse store's scan
    /// folds its implicit zeros in position order, reproducing the dense
    /// scan exactly.
    #[inline]
    fn row_top2(&self, i: usize) -> (f32, usize, f32) {
        match &self.store {
            SimStore::Dense(sim) => {
                let row = &sim[i * self.n..(i + 1) * self.n];
                let (mut top1, mut arg1, mut top2) =
                    (f32::NEG_INFINITY, usize::MAX, f32::NEG_INFINITY);
                for (u, &s) in row.iter().enumerate() {
                    if s > top1 {
                        top2 = top1;
                        top1 = s;
                        arg1 = u;
                    } else if s > top2 {
                        top2 = s;
                    }
                }
                (top1, arg1, top2)
            }
            SimStore::Sparse(s) => s.row_top2(i),
        }
    }

    /// Row-sharded singleton-complement precompute — the parallel form of
    /// the top-2 scan that used to run serially at request start.
    /// Phase 1 shards the *reduction* (row) dimension: each shard writes
    /// its rows' `(argmax, top1 − top2)` results into disjoint slices of a
    /// row-indexed buffer. Phase 2 scatters them serially in ascending-row
    /// order — exactly the add sequence of the serial scan, so every
    /// output slot's f64 fold is bit-identical (asserted in tests and by
    /// the sharded-backend precompute suite).
    pub fn singleton_complements_rowsharded(
        &self,
        pool: &ThreadPool,
        shards: usize,
    ) -> Vec<f64> {
        let n = self.n;
        let mut rows: Vec<(usize, f64)> = vec![(usize::MAX, 0.0); n];
        pool.parallel_ranges_into(&mut rows[..], shards, |lo, _hi, chunk| {
            for (slot, i) in chunk.iter_mut().zip(lo..) {
                let (top1, arg1, top2) = self.row_top2(i);
                *slot = if arg1 != usize::MAX && top1 > top2 {
                    (arg1, (top1 - top2) as f64)
                } else {
                    (usize::MAX, 0.0)
                };
            }
        });
        let mut out = vec![0.0f64; n];
        for &(arg, delta) in &rows {
            if arg != usize::MAX {
                out[arg] += delta;
            }
        }
        out
    }

    /// Cache-blocked batched pair gains `f(v|u) = Σ_i max(0, sim(i,v) −
    /// sim(i,u))`, item-major like
    /// [`BatchedDivergence::pair_gains_batch`].
    ///
    /// The scalar [`SubmodularFn::pair_gain`] walks two *columns* of the
    /// similarity store per `(u, v)` pair. This kernel inverts the loops:
    /// it streams similarity *rows* contiguously, gathers the probe
    /// entries of each row once, and accumulates a `block × P` pair-gain
    /// tile that stays cache-resident (numbers in EXPERIMENTS.md §Perf;
    /// bench: `perf_facility_divergence`).
    ///
    /// Per `(u, v)` the accumulation visits ground elements in the same
    /// ascending order, with the same f32-subtract / f64-accumulate widths,
    /// as `pair_gain` — so the result is bit-identical to the scalar path
    /// and sharded pruning decisions match the reference exactly.
    pub fn pair_gains_block(&self, probes: &[usize], items: &[usize]) -> Vec<f64> {
        let mut out = vec![0.0f64; items.len() * probes.len()];
        self.pair_gains_into_block(probes, items, &mut out);
        out
    }

    /// Write-into form of [`Self::pair_gains_block`]: same tiles, same
    /// bits, with the probe gather row in thread-local scratch and the
    /// (possibly dirty) output zeroed before accumulation.
    pub fn pair_gains_into_block(&self, probes: &[usize], items: &[usize], out: &mut [f64]) {
        let p = probes.len();
        debug_assert_eq!(out.len(), items.len() * p);
        out.fill(0.0);
        FL_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.pu.resize(p, 0.0);
            for (block, vblock) in items.chunks(ITEM_BLOCK).enumerate() {
                let base = block * ITEM_BLOCK * p;
                self.accumulate_pair_gain_tile(
                    probes,
                    vblock,
                    &mut out[base..base + vblock.len() * p],
                    &mut s.pu,
                );
            }
        });
    }

    /// Fused form of [`Self::pair_gains_block`]: folds the per-item min
    /// over probes without materializing the full pair-gain matrix, so the
    /// working set is one `ITEM_BLOCK × P` tile regardless of item count.
    /// Bit-identical to the default scalar divergence path (tested below).
    pub fn divergences_block(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; items.len()];
        self.divergences_into_block(probes, probe_sing, items, &mut out);
        out
    }

    /// Write-into form of [`Self::divergences_block`] — the zero-allocation
    /// hot path: the `ITEM_BLOCK × P` accumulator tile and the probe
    /// gather row live in thread-local scratch, warm after the first SS
    /// round, so steady-state calls are pure kernel work. Bit-identical to
    /// the allocating form (same tiles, same fold order).
    pub fn divergences_into_block(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
        out: &mut [f32],
    ) {
        debug_assert_eq!(probes.len(), probe_sing.len());
        debug_assert_eq!(out.len(), items.len());
        if probes.is_empty() {
            out.fill(f32::INFINITY);
            return;
        }
        let p = probes.len();
        FL_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.acc.resize(ITEM_BLOCK * p, 0.0);
            s.pu.resize(p, 0.0);
            for (vblock, out_block) in items.chunks(ITEM_BLOCK).zip(out.chunks_mut(ITEM_BLOCK)) {
                let tile = &mut s.acc[..vblock.len() * p];
                tile.fill(0.0);
                self.accumulate_pair_gain_tile(probes, vblock, tile, &mut s.pu);
                for (bi, slot) in out_block.iter_mut().enumerate() {
                    *slot = s.acc[bi * p..(bi + 1) * p]
                        .iter()
                        .zip(probe_sing)
                        .map(|(&g, &su)| (g - su) as f32)
                        .fold(f32::INFINITY, f32::min);
                }
            }
        });
    }
}

impl BatchedDivergence for FacilityLocation {
    fn as_submodular(&self) -> &dyn SubmodularFn {
        self
    }

    fn pair_gains_batch(&self, probes: &[usize], items: &[usize]) -> Vec<f64> {
        self.pair_gains_block(probes, items)
    }

    fn pair_gains_into(&self, probes: &[usize], items: &[usize], out: &mut [f64]) {
        self.pair_gains_into_block(probes, items, out);
    }

    fn divergences_batch(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
    ) -> Vec<f32> {
        self.divergences_block(probes, probe_sing, items)
    }

    fn divergences_into(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
        out: &mut [f32],
    ) {
        self.divergences_into_block(probes, probe_sing, items, out);
    }
}

impl SubmodularFn for FacilityLocation {
    fn n(&self) -> usize {
        self.n
    }

    fn eval(&self, s: &[usize]) -> f64 {
        if s.is_empty() {
            return 0.0;
        }
        let mut acc = 0.0f64;
        self.with_rows(|_i, row| {
            let mut best = 0.0f32;
            for &u in s {
                best = best.max(row[u]);
            }
            acc += best as f64;
        });
        acc
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(FlState {
            f: self,
            best: vec![0.0; self.n],
            value: 0.0,
            set: Vec::new(),
            col_scratch: Vec::new(),
        })
    }

    fn pair_gain(&self, u: usize, v: usize) -> f64 {
        // f(v|{u}) = Σ_i max(0, sim(i,v) - sim(i,u))
        let mut acc = 0.0f64;
        self.with_rows(|_i, row| {
            let d = row[v] - row[u];
            if d > 0.0 {
                acc += d as f64;
            }
        });
        acc
    }

    fn singleton(&self, v: usize) -> f64 {
        match &self.store {
            SimStore::Dense(sim) => (0..self.n).map(|i| sim[i * self.n + v] as f64).sum(),
            // the store's column sums fold the same ascending-`i` add
            // sequence (absent entries are exact `+0.0` no-ops)
            SimStore::Sparse(s) => s.col_sum(v),
        }
    }

    fn singleton_complements(&self) -> Vec<f64> {
        // f(v|V\v) = Σ_i max(0, sim(i,v) - max_{u≠v} sim(i,u))
        //          = Σ_i [sim(i,v) == top1(i)] * (top1(i) - top2(i))  (v unique argmax)
        // Computed with a top-2 scan per row i: O(n²) dense, O(nnz) sparse.
        let mut out = vec![0.0f64; self.n];
        for i in 0..self.n {
            let (top1, arg1, top2) = self.row_top2(i);
            if arg1 != usize::MAX && top1 > top2 {
                out[arg1] += (top1 - top2) as f64;
            }
        }
        out
    }

    /// The top-2 scan scatters into arbitrary output slots, so the
    /// per-element-decomposable route stays closed — but the scan *is*
    /// shardable over rows: see [`Self::singleton_complements_rowsharded`].
    fn singleton_complements_pooled(&self, pool: &ThreadPool, shards: usize) -> Option<Vec<f64>> {
        Some(self.singleton_complements_rowsharded(pool, shards))
    }

    fn supports_retain(&self) -> bool {
        true
    }

    fn sparse_rows(&self) -> usize {
        match &self.store {
            SimStore::Dense(_) => 0,
            SimStore::Sparse(s) => s.n(),
        }
    }

    fn lsh_stats(&self) -> (u64, u64) {
        match &self.store {
            SimStore::Dense(_) => (0, 0),
            SimStore::Sparse(s) => s.lsh_stats().unwrap_or((0, 0)),
        }
    }

    fn resident_bytes(&self) -> usize {
        FacilityLocation::resident_bytes(self)
    }

    /// Compact the store to the surviving elements, in place. Dense: the
    /// `keep × keep` principal submatrix via a forward row-major walk
    /// (with `keep` ascending every source cell sits at or after its
    /// destination, so no slot is read after being overwritten) —
    /// indistinguishable from a fresh `FacilityLocation::new` over the
    /// gathered submatrix. Sparse: neighbor-list compaction with an
    /// old→new column rewrite ([`SparseSimStore::retain`]); entries whose
    /// column was evicted are dropped, not refilled.
    fn retain_elements(&mut self, keep: &[usize]) -> bool {
        let n = self.n;
        let m = keep.len();
        let mut prev = None;
        for &old in keep {
            assert!(old < n, "retain_elements index {old} out of range (n={n})");
            assert!(prev.map_or(true, |p| p < old), "retain_elements requires ascending indices");
            prev = Some(old);
        }
        match &mut self.store {
            SimStore::Dense(sim) => {
                for (ni, &oi) in keep.iter().enumerate() {
                    for (nj, &oj) in keep.iter().enumerate() {
                        // oi*n + oj >= ni*m + nj because oi >= ni, oj >= nj, n >= m
                        sim[ni * m + nj] = sim[oi * n + oj];
                    }
                }
                sim.truncate(m * m);
            }
            SimStore::Sparse(s) => s.retain(keep),
        }
        self.n = m;
        true
    }
}

struct FlState<'a> {
    f: &'a FacilityLocation,
    /// per-ground-element current best similarity to the solution
    best: Vec<f32>,
    value: f64,
    set: Vec<usize>,
    /// reused column gather for [`add_pooled`](SolState::add_pooled)
    /// (warm after the first pooled commit)
    col_scratch: Vec<f32>,
}

impl SolState for FlState<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&self, v: usize) -> f64 {
        let best = &self.best;
        let mut acc = 0.0f64;
        self.f.with_rows(|i, row| {
            let d = row[v] - best[i];
            if d > 0.0 {
                acc += d as f64;
            }
        });
        acc
    }

    fn add(&mut self, v: usize) {
        let best = &mut self.best;
        let mut acc = 0.0f64;
        self.f.with_rows(|i, row| {
            let s = row[v];
            if s > best[i] {
                acc += (s - best[i]) as f64;
                best[i] = s;
            }
        });
        self.value += acc;
        self.set.push(v);
    }

    /// The sharded commit: phase 1 gathers column `v` over the pool into
    /// disjoint scratch slices (pure reads of the store — each slot holds
    /// exactly the `row[v]` the serial loop would read); phase 2 runs the
    /// serial best-vector fold over the gathered column in ascending `i`
    /// with the identical compare-and-accumulate, so `value`/`best` end
    /// bit-identical to [`add`](SolState::add). This closes the serial
    /// O(n) half of the maximizer commit step (the other half — batching
    /// commits themselves — needs an ε-tolerant multi-add, which exact
    /// Minoux forbids).
    fn add_pooled(&mut self, v: usize, pool: &ThreadPool, shards: usize) {
        let n = self.f.n;
        let mut col = std::mem::take(&mut self.col_scratch);
        col.clear();
        col.resize(n, 0.0);
        let f = self.f;
        pool.parallel_ranges_into(&mut col[..], shards, |lo, _hi, chunk| {
            f.gather_column_into(v, lo, chunk);
        });
        let best = &mut self.best;
        let mut acc = 0.0f64;
        for (i, &s) in col.iter().enumerate() {
            if s > best[i] {
                acc += (s - best[i]) as f64;
                best[i] = s;
            }
        }
        self.value += acc;
        self.set.push(v);
        self.col_scratch = col;
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn gains_into(&self, candidates: &[usize], out: &mut [f64]) {
        self.f.gains_over_best_into(&self.best, candidates, out);
    }

    fn reserve_additions(&mut self, additional: usize) {
        self.set.reserve(additional);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::*;
    use crate::util::rng::Rng;

    fn instance(n: usize, seed: u64) -> FacilityLocation {
        let mut rng = Rng::new(seed);
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            sim[i * n + i] = 1.0;
            for u in (i + 1)..n {
                let s = rng.f32();
                sim[i * n + u] = s;
                sim[u * n + i] = s;
            }
        }
        FacilityLocation::new(n, sim)
    }

    fn feature_rows(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = rng.f32() - 0.3;
            }
        }
        m
    }

    #[test]
    fn properties() {
        let f = instance(15, 1);
        check_submodular(&f, true, 40, 150);
        check_state_consistency(&f, 41, 100);
        check_edge_ingredients(&f, 42, 80);
    }

    #[test]
    fn sparse_store_properties() {
        // the truncated (asymmetric) store must still be monotone
        // submodular — absent entries are 0.0, a valid similarity
        let feats = feature_rows(18, 5, 13);
        let f = FacilityLocation::from_features_sparse(&feats, 4);
        assert!(f.is_sparse());
        check_submodular(&f, true, 50, 150);
        check_state_consistency(&f, 51, 100);
        check_edge_ingredients(&f, 52, 80);
        check_batched_gains(&f, 53, 40);
    }

    #[test]
    fn add_pooled_is_bit_identical_to_serial_add() {
        let pool = ThreadPool::new(3, 16);
        let feats = feature_rows(90, 6, 21);
        let cases: Vec<FacilityLocation> = vec![
            FacilityLocation::from_features_dense(&feats),
            FacilityLocation::from_features_sparse(&feats, 7),
            FacilityLocation::from_features_strat(&feats, 0, Some(7), BuildStrategy::Lsh { tables: 4, bits: 3 }, None),
        ];
        for (ci, f) in cases.iter().enumerate() {
            for shards in [1usize, 2, 5, 16] {
                let mut serial = f.state();
                let mut pooled = f.state();
                for &v in &[3usize, 41, 3, 77, 12] {
                    serial.add(v);
                    pooled.add_pooled(v, &pool, shards);
                    assert_eq!(
                        pooled.value().to_bits(),
                        serial.value().to_bits(),
                        "case {ci} shards {shards} after add({v})"
                    );
                }
                assert_eq!(pooled.set(), serial.set());
                // identical gains downstream → identical best vectors
                let cands: Vec<usize> = (0..90).collect();
                let (mut gs, mut gp) = (vec![0.0f64; 90], vec![0.0f64; 90]);
                serial.gains_into(&cands, &mut gs);
                pooled.gains_into(&cands, &mut gp);
                for v in 0..90 {
                    assert_eq!(gp[v].to_bits(), gs[v].to_bits(), "gain({v})");
                }
            }
        }
    }

    #[test]
    fn strat_seam_defaults_and_saturated_lsh_match_exact() {
        let feats = feature_rows(60, 5, 22);
        // Auto at small n = exact: same rows as the explicit exact build
        let auto = FacilityLocation::from_features_strat(&feats, 0, Some(6), BuildStrategy::Auto, None);
        let exact = FacilityLocation::from_features_strat(&feats, 0, Some(6), BuildStrategy::Exact, None);
        let saturated = FacilityLocation::from_features_strat(
            &feats,
            0,
            Some(6),
            BuildStrategy::Lsh { tables: 1, bits: 0 },
            None,
        );
        assert!(auto.sparse_store().unwrap().lsh_params().is_none());
        assert_eq!(saturated.sparse_store().unwrap().lsh_params(), Some((1, 0)));
        for i in 0..60 {
            for u in 0..60 {
                let want = exact.sim(i, u).to_bits();
                assert_eq!(auto.sim(i, u).to_bits(), want, "auto ({i},{u})");
                assert_eq!(saturated.sim(i, u).to_bits(), want, "saturated ({i},{u})");
            }
        }
        assert_eq!(exact.lsh_stats(), (0, 0));
        let (cands, bmax) = saturated.lsh_stats();
        assert_eq!((cands, bmax), (60 * 59, 60));
        // dense below the crossover regardless of strategy
        let dense = FacilityLocation::from_features_strat(
            &feats,
            100,
            None,
            BuildStrategy::Lsh { tables: 2, bits: 2 },
            None,
        );
        assert!(!dense.is_sparse());
    }

    #[test]
    fn auto_t_lsh_engages_the_adaptive_budget() {
        let feats = feature_rows(50, 5, 23);
        let f = FacilityLocation::from_features_strat(
            &feats,
            0,
            None,
            BuildStrategy::Lsh { tables: 2, bits: 2 },
            None,
        );
        let s = f.sparse_store().unwrap();
        let base = FacilityLocation::auto_neighbors(50);
        assert_eq!(s.t(), (base * 4).min(49));
        assert_eq!(s.adapt_floor(), Some((base / 2).max(8)));
        // explicit t: no adaptivity
        let f = FacilityLocation::from_features_strat(
            &feats,
            0,
            Some(5),
            BuildStrategy::Lsh { tables: 2, bits: 2 },
            None,
        );
        assert_eq!(f.sparse_store().unwrap().adapt_floor(), None);
    }

    #[test]
    fn from_features_symmetric_unit_diag() {
        let mut rng = Rng::new(2);
        let feats = FeatureMatrix::from_rows(
            (0..8).map(|_| (0..5).map(|_| rng.f32()).collect()).collect(),
        );
        let f = FacilityLocation::from_features(&feats);
        assert!(!f.is_sparse(), "below the crossover construction stays dense");
        for i in 0..8 {
            assert!((f.sim(i, i) - 1.0).abs() < 1e-6);
            for u in 0..8 {
                assert_eq!(f.sim(i, u), f.sim(u, i));
                assert!(f.sim(i, u) >= 0.0);
            }
        }
    }

    #[test]
    fn crossover_selects_the_store() {
        let feats = feature_rows(24, 4, 3);
        assert!(!FacilityLocation::from_features_with(&feats, 25, None, None).is_sparse());
        let sparse = FacilityLocation::from_features_with(&feats, 0, None, None);
        assert!(sparse.is_sparse());
        assert_eq!(sparse.sparse_rows(), 24);
        assert_eq!(FacilityLocation::from_features(&feats).sparse_rows(), 0);
    }

    #[test]
    fn sparse_full_t_bitwise_matches_dense_on_every_kernel() {
        let feats = feature_rows(70, 6, 11);
        let dense = FacilityLocation::from_features_dense(&feats);
        let sparse = FacilityLocation::from_features_sparse(&feats, 69);
        // point lookups
        for i in 0..70 {
            for u in 0..70 {
                assert_eq!(sparse.sim(i, u).to_bits(), dense.sim(i, u).to_bits());
            }
        }
        // singletons + complements
        for v in 0..70 {
            assert_eq!(sparse.singleton(v).to_bits(), dense.singleton(v).to_bits());
        }
        let (a, b) = (sparse.singleton_complements(), dense.singleton_complements());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // blocked divergences + pair gains
        let probes = vec![3usize, 69, 41];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| b[u]).collect();
        let items: Vec<usize> = (0..70).filter(|v| !probes.contains(v)).collect();
        assert_eq!(
            sparse.divergences_block(&probes, &probe_sing, &items),
            dense.divergences_block(&probes, &probe_sing, &items)
        );
        let (pg_s, pg_d) =
            (sparse.pair_gains_block(&probes, &items), dense.pair_gains_block(&probes, &items));
        for (x, y) in pg_s.iter().zip(&pg_d) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // stateful gains along a chain
        let (mut ss, mut ds) = (sparse.state(), dense.state());
        let cands: Vec<usize> = (0..70).collect();
        for &v in &[5usize, 44, 69] {
            let mut gs = vec![f64::NAN; cands.len()];
            let mut gd = vec![f64::NAN; cands.len()];
            ss.gains_into(&cands, &mut gs);
            ds.gains_into(&cands, &mut gd);
            for (x, y) in gs.iter().zip(&gd) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            ss.add(v);
            ds.add(v);
            assert_eq!(ss.value().to_bits(), ds.value().to_bits());
        }
        // whole-set eval
        let full: Vec<usize> = (0..70).collect();
        assert_eq!(sparse.eval(&full).to_bits(), dense.eval(&full).to_bits());
    }

    #[test]
    fn sparse_truncated_underapproximates_dense() {
        let feats = feature_rows(40, 5, 17);
        let dense = FacilityLocation::from_features_dense(&feats);
        let sparse = FacilityLocation::from_features_sparse(&feats, 5);
        let mut rng = Rng::new(18);
        for _ in 0..40 {
            let s: Vec<usize> = (0..40).filter(|_| rng.bool(0.2)).collect();
            let (fs, fd) = (sparse.eval(&s), dense.eval(&s));
            assert!(fs <= fd + 1e-9, "sparse eval {fs} must lower-bound dense {fd}");
        }
    }

    #[test]
    fn retain_elements_bitwise_matches_fresh_submatrix() {
        let mut f = instance(30, 9);
        let keep: Vec<usize> = (0..30).filter(|i| i % 4 != 2).collect();
        // fresh construction over the gathered principal submatrix
        let m = keep.len();
        let mut sub = vec![0.0f32; m * m];
        for (ni, &oi) in keep.iter().enumerate() {
            for (nj, &oj) in keep.iter().enumerate() {
                sub[ni * m + nj] = f.sim(oi, oj);
            }
        }
        let fresh = FacilityLocation::new(m, sub);
        assert!(f.supports_retain());
        assert!(f.retain_elements(&keep));
        assert_eq!(f.n(), m);
        for i in 0..m {
            for u in 0..m {
                assert_eq!(f.sim(i, u).to_bits(), fresh.sim(i, u).to_bits());
            }
        }
        let a = f.singleton_complements();
        let b = fresh.singleton_complements();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn sparse_append_and_retain_ride_the_store() {
        let feats = feature_rows(26, 5, 19);
        let head = feats.gather(&(0..20).collect::<Vec<_>>());
        let mut grown = FacilityLocation::from_features_sparse(&head, 25);
        let mut partial = head.clone();
        for i in 20..26 {
            partial.push_row(feats.row(i));
            assert!(grown.append_row_from_features(&partial).is_some());
        }
        assert_eq!(grown.n(), 26);
        let fresh = FacilityLocation::from_features_sparse(&feats, 25);
        let full: Vec<usize> = (0..26).collect();
        assert_eq!(grown.eval(&full).to_bits(), fresh.eval(&full).to_bits());
        let keep: Vec<usize> = (0..26).filter(|i| i % 5 != 3).collect();
        assert!(grown.retain_elements(&keep));
        assert_eq!(grown.n(), keep.len());
        // dense growth declines the fast path
        let mut dense = FacilityLocation::from_features_dense(&head);
        let mut with_new = head.clone();
        with_new.push_row(feats.row(20));
        assert!(dense.append_row_from_features(&with_new).is_none());
    }

    #[test]
    fn blocked_pair_gains_bitwise_match_scalar() {
        // 150 items spans multiple ITEM_BLOCK chunks incl. a ragged tail
        let f = instance(150, 4);
        let probes = vec![0usize, 7, 149, 42];
        let items: Vec<usize> = (0..150).filter(|v| !probes.contains(v)).collect();
        let pg = f.pair_gains_block(&probes, &items);
        for (vi, &v) in items.iter().enumerate() {
            for (ui, &u) in probes.iter().enumerate() {
                assert_eq!(
                    pg[vi * probes.len() + ui],
                    f.pair_gain(u, v),
                    "blocked pair gain must be bit-identical at (u={u}, v={v})"
                );
            }
        }
    }

    #[test]
    fn blocked_divergences_bitwise_match_scalar_reference() {
        let f = instance(200, 5);
        let sing = f.singleton_complements();
        let probes = vec![3usize, 50, 199, 120, 77];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..200).filter(|v| !probes.contains(v)).collect();
        let got = f.divergences_block(&probes, &probe_sing, &items);
        let want = scalar_reference_divergences(&f, &probes, &probe_sing, &items);
        assert_eq!(got, want, "fused kernel must equal the scalar divergence path bit-for-bit");
    }

    #[test]
    fn sparse_blocked_kernels_bitwise_match_scalar_paths() {
        // same contracts as the dense blocked-kernel tests, on a truncated
        // sparse store (the kernels share one row stream, but pin it)
        let feats = feature_rows(150, 6, 21);
        let f = FacilityLocation::from_features_sparse(&feats, 9);
        let sing = f.singleton_complements();
        let probes = vec![3usize, 149, 77, 12];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..150).filter(|v| !probes.contains(v)).collect();
        let got = f.divergences_block(&probes, &probe_sing, &items);
        let want = scalar_reference_divergences(&f, &probes, &probe_sing, &items);
        assert_eq!(got, want);
        let pg = f.pair_gains_block(&probes, &items);
        for (vi, &v) in items.iter().enumerate() {
            for (ui, &u) in probes.iter().enumerate() {
                assert_eq!(pg[vi * probes.len() + ui], f.pair_gain(u, v));
            }
        }
    }

    #[test]
    fn write_into_kernels_bitwise_match_allocating_kernels() {
        // 150 items spans multiple ITEM_BLOCK chunks incl. a ragged tail
        let f = instance(150, 8);
        let sing = f.singleton_complements();
        let probes = vec![3usize, 149, 77];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..150).filter(|v| !probes.contains(v)).collect();
        let want = scalar_reference_divergences(&f, &probes, &probe_sing, &items);
        let mut out = vec![f32::NAN; items.len()];
        for _ in 0..2 {
            // twice: thread-local scratch reuse must not leak state
            f.divergences_into_block(&probes, &probe_sing, &items, &mut out);
            assert_eq!(out, want);
        }
        let mut out_pg = vec![f64::NAN; items.len() * probes.len()];
        f.pair_gains_into_block(&probes, &items, &mut out_pg);
        for (vi, &v) in items.iter().enumerate() {
            for (ui, &u) in probes.iter().enumerate() {
                assert_eq!(out_pg[vi * probes.len() + ui], f.pair_gain(u, v));
            }
        }
    }

    #[test]
    fn batched_state_gains_bitwise_match_scalar() {
        // 150 candidates spans multiple ITEM_BLOCK chunks incl. a ragged
        // tail; the property driver also covers dirty buffers + reuse
        let f = instance(150, 6);
        check_batched_gains(&f, 140, 40);
        let cands: Vec<usize> = (0..150).collect();
        let mut st = f.state();
        for &v in &[3usize, 77, 149] {
            st.add(v);
        }
        let want: Vec<f64> = cands.iter().map(|&v| st.gain(v)).collect();
        let mut out = vec![f64::NAN; cands.len()];
        st.gains_into(&cands, &mut out);
        for (got, w) in out.iter().zip(&want) {
            assert_eq!(got.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn rowsharded_singleton_precompute_bitwise_matches_serial() {
        use crate::util::pool::ThreadPool;
        // sizes chosen to exercise ragged shard tails and shards > rows;
        // the sparse store must ride the same sharded scatter
        let sparse_inst =
            |n: usize, seed: u64| FacilityLocation::from_features_sparse(&feature_rows(n, 5, seed), 7);
        for (dense_store, n, seed) in
            [(true, 97usize, 7u64), (true, 150, 8), (true, 16, 9), (false, 97, 7), (false, 150, 8)]
        {
            let f = if dense_store { instance(n, seed) } else { sparse_inst(n, seed) };
            let want = f.singleton_complements();
            let pool = ThreadPool::new(3, 16);
            for shards in [1usize, 2, 7, 64] {
                let got = f.singleton_complements_rowsharded(&pool, shards);
                assert_eq!(got.len(), want.len());
                for (v, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "slot {v} diverged (n={n}, shards={shards})"
                    );
                }
                // the trait hook must route to the same computation
                let hooked = f.singleton_complements_pooled(&pool, shards).unwrap();
                assert_eq!(hooked, got);
            }
        }
    }

    #[test]
    fn full_set_attains_row_maxima() {
        let f = instance(10, 3);
        let full: Vec<usize> = (0..10).collect();
        let want: f64 = (0..10)
            .map(|i| (0..10).map(|u| f.sim(i, u)).fold(f32::MIN, f32::max) as f64)
            .sum();
        assert!((f.eval(&full) - want).abs() < 1e-6);
    }
}
