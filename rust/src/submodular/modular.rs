//! Modular (additive) functions — the degenerate boundary of submodularity.
//! Used in tests (every inequality in the paper must hold with equality-ish
//! slack on modular functions) and as components of [`super::Mixture`].

use super::{BidirState, SolState, SubmodularFn};

pub struct Modular {
    w: Vec<f64>,
}

impl Modular {
    pub fn new(w: Vec<f64>) -> Self {
        debug_assert!(w.iter().all(|&x| x >= 0.0), "normalized non-negative modular");
        Self { w }
    }
}

impl SubmodularFn for Modular {
    fn n(&self) -> usize {
        self.w.len()
    }

    fn eval(&self, s: &[usize]) -> f64 {
        s.iter().map(|&v| self.w[v]).sum()
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(ModState { f: self, value: 0.0, set: Vec::new() })
    }

    fn pair_gain(&self, _u: usize, v: usize) -> f64 {
        self.w[v]
    }

    fn singleton(&self, v: usize) -> f64 {
        self.w[v]
    }

    fn singleton_complements(&self) -> Vec<f64> {
        self.w.clone()
    }

    fn bidir_state<'a>(&'a self, init: &[usize]) -> Option<Box<dyn BidirState + 'a>> {
        let mut member = vec![false; self.n()];
        let mut value = 0.0;
        for &v in init {
            member[v] = true;
            value += self.w[v];
        }
        Some(Box::new(ModBidir { f: self, member, value }))
    }
}

struct ModState<'a> {
    f: &'a Modular,
    value: f64,
    set: Vec<usize>,
}

impl SolState for ModState<'_> {
    fn value(&self) -> f64 {
        self.value
    }
    fn gain(&self, v: usize) -> f64 {
        self.f.w[v]
    }
    fn add(&mut self, v: usize) {
        self.value += self.f.w[v];
        self.set.push(v);
    }
    fn set(&self) -> &[usize] {
        &self.set
    }
}

struct ModBidir<'a> {
    f: &'a Modular,
    member: Vec<bool>,
    value: f64,
}

impl BidirState for ModBidir<'_> {
    fn value(&self) -> f64 {
        self.value
    }
    fn gain_add(&self, v: usize) -> f64 {
        self.f.w[v]
    }
    fn gain_remove(&self, v: usize) -> f64 {
        -self.f.w[v]
    }
    fn add(&mut self, v: usize) {
        self.member[v] = true;
        self.value += self.f.w[v];
    }
    fn remove(&mut self, v: usize) {
        self.member[v] = false;
        self.value -= self.f.w[v];
    }
    fn contains(&self, v: usize) -> bool {
        self.member[v]
    }
    fn members(&self) -> Vec<usize> {
        (0..self.member.len()).filter(|&v| self.member[v]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::*;

    #[test]
    fn modular_is_submodular_and_monotone() {
        let f = Modular::new((0..12).map(|i| i as f64 * 0.5).collect());
        check_submodular(&f, true, 80, 100);
        check_state_consistency(&f, 81, 80);
        check_edge_ingredients(&f, 82, 80);
    }

    #[test]
    fn edge_weights_vanish_for_equal_weights() {
        // w_uv = f(v|u) - f(u|V\u) = w_v - w_u = 0 when all weights equal:
        // pruning is "free" on redundancy-free modular ground sets.
        let f = Modular::new(vec![2.0; 6]);
        let sing = f.singleton_complements();
        for u in 0..6 {
            for v in 0..6 {
                if u != v {
                    assert_eq!(f.pair_gain(u, v) - sing[u], 0.0);
                }
            }
        }
    }
}
