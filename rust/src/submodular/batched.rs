//! **Batched divergence capability** — the bridge between the objective
//! library and the SS hot loop.
//!
//! The per-round cost of Algorithm 1 is the divergence batch
//! `w_{U,v} = min_{u∈U} [f(v|u) − f(u|V∖u)]` over all live items `v`. Every
//! [`SubmodularFn`] can compute it through the scalar [`pair_gain`] loop,
//! but the memory-access pattern of that loop is objective-specific — and
//! that is exactly where blocked kernels pay off (cf. Lindgren et al.,
//! "Leveraging Sparsity for Efficient Submodular Data Summarization").
//!
//! [`BatchedDivergence`] makes the batch a *capability* with a universal
//! default:
//!
//! * the default [`pair_gains_batch`] / [`divergences_batch`] ride the
//!   scalar [`pair_gain`] loop — correct for every objective, no override
//!   needed (the coverage / graph-cut / modular family use it as-is);
//! * [`FeatureBased`] overrides with the blocked concave-coverage kernel
//!   (`divergences_block`, per-probe cached `g(u)` rows);
//! * [`FacilityLocation`] overrides with a cache-blocked kernel that walks
//!   similarity rows contiguously instead of striding down columns
//!   (`rust/benches/perf_facility_divergence.rs`, EXPERIMENTS.md §Perf);
//! * [`Mixture`] delegates [`pair_gains_batch`] to its components, so a
//!   mixture of accelerated objectives stays accelerated.
//!
//! Every override must be **bit-identical** to the scalar default — the
//! sharded coordinator and the single-threaded reference both route through
//! this trait, and `rust/tests/coordinator_e2e.rs` asserts their pruning
//! decisions match exactly. Overrides achieve this by accumulating in the
//! same order (ascending dim / ascending ground element) with the same
//! float widths as [`pair_gain`].
//!
//! [`pair_gain`]: SubmodularFn::pair_gain
//! [`pair_gains_batch`]: BatchedDivergence::pair_gains_batch
//! [`divergences_batch`]: BatchedDivergence::divergences_batch
//! [`FeatureBased`]: super::FeatureBased
//! [`FacilityLocation`]: super::FacilityLocation
//! [`Mixture`]: super::Mixture

use super::{GraphCut, Modular, SaturatedCoverage, SetCover, SparsificationObjective, SubmodularFn};

/// A [`SubmodularFn`] that can evaluate divergence batches, with scalar
/// defaults and objective-specific blocked kernels. This is the objective
/// handle the production stack holds (`Arc<dyn BatchedDivergence>` in
/// [`crate::coordinator::ShardedBackend`] and the summarization service).
pub trait BatchedDivergence: SubmodularFn {
    /// Upcast to the plain objective trait (for the maximizers, which take
    /// `&dyn SubmodularFn`). Implementations return `self`; this exists
    /// because stable trait-object upcasting cannot be assumed from the
    /// pinned toolchain.
    fn as_submodular(&self) -> &dyn SubmodularFn;

    /// Batch pairwise gains: `out[vi * probes.len() + ui] = f(v_vi | u_ui)`
    /// (row-major over items). The default is the scalar [`pair_gain`]
    /// loop; overrides must match it bit-for-bit.
    ///
    /// [`pair_gain`]: SubmodularFn::pair_gain
    fn pair_gains_batch(&self, probes: &[usize], items: &[usize]) -> Vec<f64> {
        let mut out = Vec::with_capacity(items.len() * probes.len());
        for &v in items {
            for &u in probes {
                out.push(self.pair_gain(u, v));
            }
        }
        out
    }

    /// Write-into batch pair gains: same layout and bit-identical values as
    /// [`pair_gains_batch`], written into `out` (length `items × probes`).
    /// The default allocates through `pair_gains_batch`; blocked kernels
    /// override with in-place writes so [`Mixture`](super::Mixture)'s
    /// delegation loop stays allocation-free in the steady state.
    ///
    /// [`pair_gains_batch`]: BatchedDivergence::pair_gains_batch
    fn pair_gains_into(&self, probes: &[usize], items: &[usize], out: &mut [f64]) {
        debug_assert_eq!(out.len(), items.len() * probes.len());
        out.copy_from_slice(&self.pair_gains_batch(probes, items));
    }

    /// Divergence batch `w_{U,v} = min_{u} [f(v|u) − sing_u]` for each `v`
    /// in `items`, with `probe_sing[i] = f(u_i|V∖u_i)` aligned to `probes`.
    /// The default routes through [`pair_gains_batch`]; fused kernels
    /// (which never materialize the pair-gain matrix) override it.
    ///
    /// [`pair_gains_batch`]: BatchedDivergence::pair_gains_batch
    fn divergences_batch(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
    ) -> Vec<f32> {
        debug_assert_eq!(probes.len(), probe_sing.len());
        if probes.is_empty() {
            return vec![f32::INFINITY; items.len()];
        }
        let pg = self.pair_gains_batch(probes, items);
        pg.chunks(probes.len())
            .map(|row| {
                row.iter()
                    .zip(probe_sing)
                    .map(|(&g, &su)| (g - su) as f32)
                    .fold(f32::INFINITY, f32::min)
            })
            .collect()
    }

    /// Write-into divergence batch — the SS round loop's hot entry point:
    /// `out[i]` receives the divergence of `items[i]`, bit-identical to
    /// [`divergences_batch`]. Backends hand shards **disjoint slices of one
    /// preallocated round buffer**, so with the blocked overrides
    /// ([`FeatureBased`], [`FacilityLocation`], [`Mixture`] — all of which
    /// keep their internal tiles in thread-local scratch) the per-round
    /// cost converges to kernel FLOPs: no allocation, no gather copy. The
    /// default delegates to the allocating path so scalar objectives stay
    /// correct without an override.
    ///
    /// [`divergences_batch`]: BatchedDivergence::divergences_batch
    /// [`FeatureBased`]: super::FeatureBased
    /// [`FacilityLocation`]: super::FacilityLocation
    /// [`Mixture`]: super::Mixture
    fn divergences_into(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), items.len());
        out.copy_from_slice(&self.divergences_batch(probes, probe_sing, items));
    }
}

/// The coverage / graph-cut / modular family rides the scalar default:
/// their [`pair_gain`](SubmodularFn::pair_gain) closed forms are already
/// index-local, so there is no blocked layout to exploit yet.
macro_rules! scalar_batched {
    ($($ty:ty),+ $(,)?) => {$(
        impl BatchedDivergence for $ty {
            fn as_submodular(&self) -> &dyn SubmodularFn {
                self
            }
        }
    )+};
}

scalar_batched!(Modular, SetCover, SaturatedCoverage, GraphCut, SparsificationObjective);

#[cfg(test)]
mod tests {
    use super::super::test_support::scalar_reference_divergences;
    use super::*;
    use crate::util::rng::Rng;

    fn graph_cut_instance(n: usize, seed: u64) -> GraphCut {
        let mut rng = Rng::new(seed);
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            sim[i * n + i] = 1.0;
            for u in (i + 1)..n {
                let s = rng.f32();
                sim[i * n + u] = s;
                sim[u * n + i] = s;
            }
        }
        GraphCut::new(n, sim, 2.0)
    }

    #[test]
    fn default_batch_matches_scalar_loop() {
        let f = graph_cut_instance(40, 1);
        let sing = f.singleton_complements();
        let probes = vec![3usize, 11, 27];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..40).filter(|v| !probes.contains(v)).collect();
        let got = f.divergences_batch(&probes, &probe_sing, &items);
        let want = scalar_reference_divergences(&f, &probes, &probe_sing, &items);
        assert_eq!(got, want, "default batch must equal the scalar reference bit-for-bit");
    }

    #[test]
    fn empty_probes_yield_infinite_divergences() {
        let f = Modular::new(vec![1.0; 8]);
        let w = f.divergences_batch(&[], &[], &[0, 1, 2]);
        assert_eq!(w, vec![f32::INFINITY; 3]);
        let mut out = vec![0.0f32; 3];
        f.divergences_into(&[], &[], &[0, 1, 2], &mut out);
        assert_eq!(out, vec![f32::INFINITY; 3]);
    }

    #[test]
    fn default_into_paths_match_allocating_paths() {
        // scalar objectives ride the defaults; dirty output buffers must be
        // fully overwritten
        let f = graph_cut_instance(30, 9);
        let sing = f.singleton_complements();
        let probes = vec![1usize, 8, 22];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..30).filter(|v| !probes.contains(v)).collect();
        let want = f.divergences_batch(&probes, &probe_sing, &items);
        let mut out = vec![f32::NAN; items.len()];
        f.divergences_into(&probes, &probe_sing, &items, &mut out);
        assert_eq!(out, want);
        let want_pg = f.pair_gains_batch(&probes, &items);
        let mut out_pg = vec![f64::NAN; items.len() * probes.len()];
        f.pair_gains_into(&probes, &items, &mut out_pg);
        assert_eq!(out_pg, want_pg);
    }

    #[test]
    fn pair_gains_batch_layout_is_item_major() {
        let f = Modular::new((0..6).map(|i| i as f64).collect());
        let pg = f.pair_gains_batch(&[1, 2], &[3, 4]);
        // modular: f(v|u) = w_v regardless of u
        assert_eq!(pg, vec![3.0, 3.0, 4.0, 4.0]);
    }
}
