//! The paper's experimental objective: feature-based concave-over-modular
//! `f(S) = Σ_j g(c_j(S))`, `c_j(S) = Σ_{v∈S} ω_{vj}`, `g` concave with
//! `g(0)=0` (√ in the paper, `log1p` as an extension).
//!
//! This is the one objective with a PJRT-accelerated path: its marginal
//! gains, pairwise gains and singleton complements are exactly the Layer-1
//! Pallas kernels (`python/compile/kernels/`), and the CPU implementations
//! here are the bit-level reference the runtime parity tests compare
//! against.

use std::cell::RefCell;

use super::{BatchedDivergence, BidirState, SolState, SubmodularFn};
use crate::util::vecmath::{add_into, sub_clamp_into, FeatureMatrix};

thread_local! {
    /// Per-thread kernel scratch, reused across rounds *and* across
    /// instances: the flattened `g(u)` probe rows (f32 for the divergence
    /// kernel, f64 for the pair-gain batch) and the CSR-style per-item
    /// nonzero compression. Thread-local rather than per-call because the
    /// same objective is hit concurrently from pool workers, and
    /// thread-local rather than per-instance so the SS round loop's steady
    /// state allocates nothing (the arena invariant asserted by
    /// `rust/tests/alloc_steady_state.rs`).
    static FB_SCRATCH: RefCell<FbScratch> = RefCell::new(FbScratch::default());
}

#[derive(Default)]
struct FbScratch {
    /// g(u) probe rows, f32, flattened row-major (P × D)
    gu: Vec<f32>,
    /// g(u) probe rows, f64, for the pair-gain batch path
    gu64: Vec<f64>,
    /// nonzero dims of the current item
    nz_d: Vec<u32>,
    /// nonzero values of the current item, aligned with `nz_d`
    nz_v: Vec<f32>,
    /// g(cov) row, f64, for the batched marginal-gain path
    gcov: Vec<f64>,
}

/// Concave scalarizer `g`. Must satisfy `g(0) = 0`, `g' > 0`, `g'' < 0`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Concave {
    Sqrt,
    Log1p,
    /// `x^p` for `0 < p < 1` (p fixed at construction as milli-units to keep
    /// the enum `Eq`/hashable: `Pow(500)` = x^0.5).
    Pow(u16),
}

impl Concave {
    #[inline]
    pub fn apply(self, x: f64) -> f64 {
        match self {
            Concave::Sqrt => x.sqrt(),
            Concave::Log1p => x.ln_1p(),
            Concave::Pow(milli) => x.powf(milli as f64 / 1000.0),
        }
    }

    /// `f({row}) = Σ_d g(row_d)` over a raw feature row — the singleton
    /// kernel in row form. [`FeatureBased::singleton`] delegates here, and
    /// the streaming admission filter prices not-yet-stored arrivals with
    /// the same function, so the two can never drift apart bit-wise.
    #[inline]
    pub fn row_singleton(self, row: &[f32]) -> f64 {
        row.iter().map(|&x| self.apply(x as f64)).sum()
    }

    /// `f(row | cov) = Σ_{d: row_d > 0} g(cov_d + row_d) − g(cov_d)` — the
    /// scalar marginal-gain kernel in row form. [`FeatureBased::gain_over_cov`]
    /// delegates here (same delegation note as [`Self::row_singleton`]).
    #[inline]
    pub fn row_gain(self, cov: &[f32], row: &[f32]) -> f64 {
        debug_assert_eq!(cov.len(), row.len());
        let mut acc = 0.0f64;
        for (&c, &x) in cov.iter().zip(row) {
            if x > 0.0 {
                acc += self.apply((c + x) as f64) - self.apply(c as f64);
            }
        }
        acc
    }
}

/// Feature-based submodular function over dense hashed features.
///
/// `Clone` is a deep copy of rows + cached totals (bit-identical by
/// construction) — what the streaming copy-on-snapshot path hands to the
/// worker pool so appends can keep mutating the original.
#[derive(Clone)]
pub struct FeatureBased {
    feats: FeatureMatrix,
    g: Concave,
    /// cached c(V) (column sums) for singleton-complement batches
    total: Vec<f32>,
}

impl FeatureBased {
    pub fn new(feats: FeatureMatrix, g: Concave) -> Self {
        debug_assert!(feats.data().iter().all(|&x| x >= 0.0), "features must be non-negative");
        let total = feats.col_sums();
        Self { feats, g, total }
    }

    pub fn sqrt(feats: FeatureMatrix) -> Self {
        Self::new(feats, Concave::Sqrt)
    }

    pub fn feats(&self) -> &FeatureMatrix {
        &self.feats
    }

    pub fn concave(&self) -> Concave {
        self.g
    }

    pub fn d(&self) -> usize {
        self.feats.d
    }

    /// `Σ_d g(cov_d + v_d) - g(cov_d)` — the marginal-gain kernel's scalar form.
    #[inline]
    pub fn gain_over_cov(&self, cov: &[f32], v: usize) -> f64 {
        self.g.row_gain(cov, self.feats.row(v))
    }

    /// Total feature mass c(V) (cached).
    pub fn total_mass(&self) -> &[f32] {
        &self.total
    }

    /// Append one element (streaming ingest). The cached total mass is
    /// updated incrementally with the same `add_into` row-order
    /// accumulation [`FeatureMatrix::col_sums`] performs, so an objective
    /// grown row by row is **bit-identical** to one constructed over the
    /// final matrix — the invariant the stream ↔ batch equivalence suite
    /// rests on.
    pub fn push_element(&mut self, row: &[f32]) {
        debug_assert!(row.iter().all(|&x| x >= 0.0), "features must be non-negative");
        self.feats.push_row(row);
        add_into(&mut self.total, row);
    }

    /// Reserve row capacity so a steady state of [`Self::push_element`]
    /// calls never touches the allocator.
    pub fn reserve_elements(&mut self, additional: usize) {
        self.feats.reserve_rows(additional);
    }

    /// Batched form of [`Self::gain_over_cov`]: `out[j] = f(c_j | S)` for a
    /// cohort of candidates against one coverage vector — the maximizer
    /// engine's hot kernel. The scalar loop re-evaluates `g(cov_d)` for
    /// every (candidate, dim) pair; here the `g(cov)` row is computed once
    /// per call (thread-local scratch, warm across cohorts since D is
    /// constant) and reused by the whole cohort, halving the concave-eval
    /// count on the √ path. Bit-identical to the scalar loop: same dims
    /// visited in the same order with the same f64 widths, and the cached
    /// `g(cov_d)` is the very value the scalar path recomputes.
    pub fn gains_over_cov_into(&self, cov: &[f32], candidates: &[usize], out: &mut [f64]) {
        debug_assert_eq!(cov.len(), self.feats.d);
        debug_assert_eq!(candidates.len(), out.len());
        let g = self.g;
        FB_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.gcov.clear();
            s.gcov.extend(cov.iter().map(|&c| g.apply(c as f64)));
            for (slot, &v) in out.iter_mut().zip(candidates) {
                let row = self.feats.row(v);
                let mut acc = 0.0f64;
                for ((&c, &x), &gc) in cov.iter().zip(row).zip(&s.gcov) {
                    if x > 0.0 {
                        acc += g.apply((c + x) as f64) - gc;
                    }
                }
                *slot = acc;
            }
        });
    }

    /// Blocked divergence kernel: `w_{U,v} = min_u [f(v|u) − sing_u]` for a
    /// batch of items — the CPU hot path of SS (perf log in EXPERIMENTS.md
    /// §Perf).
    ///
    /// Structure (perf-pass result, ~1.7× over the naive `pair_gain` loop
    /// at 30% feature density — iteration log in EXPERIMENTS.md §Perf):
    /// * `g(u_d)` precomputed per probe (f32) and reused across all items;
    /// * per-item nonzero compression (CSR-style) built once and reused
    ///   across probes — the inner loop touches only `nnz(v)` dims;
    /// * the `Sqrt` path accumulates in f32 (2× hardware sqrt throughput;
    ///   ~1e-5 relative error, far below SS's own randomization noise).
    /// Both the reference `CpuBackend` and the sharded coordinator route
    /// through this same kernel, so parallel == sequential exactly.
    pub fn divergences_block(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; items.len()];
        self.divergences_into_block(probes, probe_sing, items, &mut out);
        out
    }

    /// Write-into form of [`Self::divergences_block`] — the zero-allocation
    /// hot path. The per-probe `g(u)` rows and the per-item nonzero
    /// compression live in thread-local scratch whose capacity is warm
    /// after the first round (P·D and D are constant within a `sparsify`
    /// run), so steady-state calls do not touch the allocator at all.
    /// Bit-identical to the allocating form: same dims visited in the same
    /// order with the same float widths.
    pub fn divergences_into_block(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
        out: &mut [f32],
    ) {
        debug_assert_eq!(probes.len(), probe_sing.len());
        debug_assert_eq!(out.len(), items.len());
        if probes.is_empty() {
            out.fill(f32::INFINITY);
            return;
        }
        let d = self.feats.d;
        let g = self.g;
        if d == 0 {
            // degenerate zero-dim matrix: every item row is empty, so the
            // kernel reduces to min_u (0 − sing_u) — same float ops as the
            // pre-refactor loop with an empty nonzero list
            let w0 = probes
                .iter()
                .zip(probe_sing)
                .map(|(_, &su)| match g {
                    Concave::Sqrt => 0.0f32 - su as f32,
                    _ => (0.0f64 - su) as f32,
                })
                .fold(f32::INFINITY, f32::min);
            out.fill(w0);
            return;
        }
        FB_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            // precompute g(u) rows once per call: (P, D) flattened, f32
            // (the hot Sqrt path consumes them natively; the generic path
            // upcasts)
            s.gu.clear();
            for &u in probes {
                s.gu.extend(self.feats.row(u).iter().map(|&a| g.apply(a as f64) as f32));
            }
            for (slot, &v) in out.iter_mut().zip(items) {
                let rv = self.feats.row(v);
                // per-item nonzero compression, reused across probes
                s.nz_d.clear();
                s.nz_v.clear();
                for (dim, &b) in rv.iter().enumerate() {
                    if b > 0.0 {
                        s.nz_d.push(dim as u32);
                        s.nz_v.push(b);
                    }
                }
                let mut best = f32::INFINITY;
                for ((&u, &su), gu_row) in
                    probes.iter().zip(probe_sing).zip(s.gu.chunks_exact(d))
                {
                    let ru = self.feats.row(u);
                    // Accumulation visits nonzero dims in ascending order.
                    // The Sqrt fast path runs in f32 (2× hardware sqrt
                    // throughput; ~1e-5 relative error is far below SS's
                    // own randomization noise). Both the reference
                    // CpuBackend and the sharded coordinator route through
                    // this same kernel, so parallel == sequential
                    // determinism is preserved exactly.
                    let w = match g {
                        Concave::Sqrt => {
                            let mut acc = 0.0f32;
                            for (&dim, &b) in s.nz_d.iter().zip(&s.nz_v) {
                                let a = ru[dim as usize];
                                acc += (a + b).sqrt() - gu_row[dim as usize];
                            }
                            acc - su as f32
                        }
                        _ => {
                            let mut acc = 0.0f64;
                            for (&dim, &b) in s.nz_d.iter().zip(&s.nz_v) {
                                let a = ru[dim as usize];
                                acc += g.apply((a + b) as f64) - gu_row[dim as usize] as f64;
                            }
                            (acc - su) as f32
                        }
                    };
                    if w < best {
                        best = w;
                    }
                }
                *slot = best;
            }
        });
    }
}

impl SubmodularFn for FeatureBased {
    fn n(&self) -> usize {
        self.feats.n()
    }

    fn eval(&self, s: &[usize]) -> f64 {
        let mut cov = vec![0.0f32; self.feats.d];
        for &v in s {
            add_into(&mut cov, self.feats.row(v));
        }
        cov.iter().map(|&c| self.g.apply(c as f64)).sum()
    }

    fn state<'a>(&'a self) -> Box<dyn SolState + 'a> {
        Box::new(FeatureState {
            f: self,
            cov: vec![0.0; self.feats.d],
            value: 0.0,
            set: Vec::new(),
        })
    }

    fn pair_gain(&self, u: usize, v: usize) -> f64 {
        let (ru, rv) = (self.feats.row(u), self.feats.row(v));
        let mut acc = 0.0f64;
        for (&a, &b) in ru.iter().zip(rv) {
            if b > 0.0 {
                acc += self.g.apply((a + b) as f64) - self.g.apply(a as f64);
            }
        }
        acc
    }

    fn singleton(&self, v: usize) -> f64 {
        self.g.row_singleton(self.feats.row(v))
    }

    fn singleton_complements(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n()];
        let items: Vec<usize> = (0..self.n()).collect();
        self.singleton_complements_into(&items, &mut out);
        out
    }

    fn singleton_complements_decomposable(&self) -> bool {
        true
    }

    fn singleton_complements_into(&self, items: &[usize], out: &mut [f64]) {
        // f(v|V\v) = Σ_d [ g(t_d) - g(t_d - v_d) ]  — the singleton kernel,
        // per-element over the cached totals (so backends can shard it).
        debug_assert_eq!(items.len(), out.len());
        let g_total: Vec<f64> = self.total.iter().map(|&t| self.g.apply(t as f64)).collect();
        for (slot, &v) in out.iter_mut().zip(items) {
            let row = self.feats.row(v);
            let mut acc = 0.0f64;
            for ((&t, &x), &gt) in self.total.iter().zip(row).zip(&g_total) {
                if x > 0.0 {
                    acc += gt - self.g.apply(((t - x).max(0.0)) as f64);
                }
            }
            *slot = acc;
        }
    }

    fn supports_retain(&self) -> bool {
        true
    }

    /// Compact to `keep`: rows shift in place, and the total mass is
    /// recomputed with the fresh-construction `col_sums` accumulation, so
    /// the result is bit-identical to `FeatureBased::new` over the
    /// surviving rows.
    fn retain_elements(&mut self, keep: &[usize]) -> bool {
        self.feats.retain_rows(keep);
        self.total = self.feats.col_sums();
        true
    }

    fn as_feature_based(&self) -> Option<&FeatureBased> {
        Some(self)
    }

    fn bidir_state<'a>(&'a self, init: &[usize]) -> Option<Box<dyn BidirState + 'a>> {
        let mut cov = vec![0.0f32; self.feats.d];
        let mut member = vec![false; self.n()];
        for &v in init {
            add_into(&mut cov, self.feats.row(v));
            member[v] = true;
        }
        let value = cov.iter().map(|&c| self.g.apply(c as f64)).sum();
        Some(Box::new(FeatureBidir { f: self, cov, member, value }))
    }
}

impl BatchedDivergence for FeatureBased {
    fn as_submodular(&self) -> &dyn SubmodularFn {
        self
    }

    /// Per-probe cached `g(u)` rows in f64 — bit-identical to the scalar
    /// [`SubmodularFn::pair_gain`] (same dims visited in the same order
    /// with the same widths), which [`super::Mixture`] relies on when it
    /// delegates here.
    fn pair_gains_batch(&self, probes: &[usize], items: &[usize]) -> Vec<f64> {
        let mut out = vec![0.0f64; items.len() * probes.len()];
        self.pair_gains_into(probes, items, &mut out);
        out
    }

    /// Write-into form with the `g(u)` cache in thread-local scratch —
    /// what keeps the mixture delegation loop allocation-free.
    fn pair_gains_into(&self, probes: &[usize], items: &[usize], out: &mut [f64]) {
        let p = probes.len();
        debug_assert_eq!(out.len(), items.len() * p);
        let d = self.feats.d;
        let g = self.g;
        if d == 0 {
            // zero-dim matrix: every pair gain is the empty sum
            out.fill(0.0);
            return;
        }
        FB_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.gu64.clear();
            for &u in probes {
                s.gu64.extend(self.feats.row(u).iter().map(|&a| g.apply(a as f64)));
            }
            for (vi, &v) in items.iter().enumerate() {
                let rv = self.feats.row(v);
                let row_out = &mut out[vi * p..(vi + 1) * p];
                for ((slot, &u), gu_row) in
                    row_out.iter_mut().zip(probes).zip(s.gu64.chunks_exact(d))
                {
                    let ru = self.feats.row(u);
                    let mut acc = 0.0f64;
                    for ((&a, &b), &ga) in ru.iter().zip(rv).zip(gu_row) {
                        if b > 0.0 {
                            acc += g.apply((a + b) as f64) - ga;
                        }
                    }
                    *slot = acc;
                }
            }
        });
    }

    fn divergences_batch(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
    ) -> Vec<f32> {
        self.divergences_block(probes, probe_sing, items)
    }

    fn divergences_into(
        &self,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
        out: &mut [f32],
    ) {
        self.divergences_into_block(probes, probe_sing, items, out);
    }
}

struct FeatureState<'a> {
    f: &'a FeatureBased,
    cov: Vec<f32>,
    value: f64,
    set: Vec<usize>,
}

impl SolState for FeatureState<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&self, v: usize) -> f64 {
        self.f.gain_over_cov(&self.cov, v)
    }

    fn add(&mut self, v: usize) {
        self.value += self.f.gain_over_cov(&self.cov, v);
        add_into(&mut self.cov, self.f.feats.row(v));
        self.set.push(v);
    }

    fn set(&self) -> &[usize] {
        &self.set
    }

    fn gains_into(&self, candidates: &[usize], out: &mut [f64]) {
        self.f.gains_over_cov_into(&self.cov, candidates, out);
    }

    fn reserve_additions(&mut self, additional: usize) {
        self.set.reserve(additional);
    }

    fn feature_coverage(&self) -> Option<&[f32]> {
        Some(&self.cov)
    }
}

struct FeatureBidir<'a> {
    f: &'a FeatureBased,
    cov: Vec<f32>,
    member: Vec<bool>,
    value: f64,
}

impl BidirState for FeatureBidir<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain_add(&self, v: usize) -> f64 {
        debug_assert!(!self.member[v]);
        self.f.gain_over_cov(&self.cov, v)
    }

    fn gain_remove(&self, v: usize) -> f64 {
        debug_assert!(self.member[v]);
        let row = self.f.feats.row(v);
        let mut acc = 0.0f64;
        for (&c, &x) in self.cov.iter().zip(row) {
            if x > 0.0 {
                acc += self.f.g.apply(((c - x).max(0.0)) as f64) - self.f.g.apply(c as f64);
            }
        }
        acc
    }

    fn add(&mut self, v: usize) {
        self.value += self.gain_add(v);
        add_into(&mut self.cov, self.f.feats.row(v));
        self.member[v] = true;
    }

    fn remove(&mut self, v: usize) {
        self.value += self.gain_remove(v);
        sub_clamp_into(&mut self.cov, self.f.feats.row(v));
        self.member[v] = false;
    }

    fn contains(&self, v: usize) -> bool {
        self.member[v]
    }

    fn members(&self) -> Vec<usize> {
        (0..self.member.len()).filter(|&v| self.member[v]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::test_support::*;
    use crate::util::rng::Rng;

    fn instance(n: usize, d: usize, seed: u64) -> FeatureBased {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                // sparse-ish non-negative features
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() * 2.0 } else { 0.0 };
            }
        }
        FeatureBased::sqrt(m)
    }

    #[test]
    fn properties_sqrt() {
        let f = instance(20, 8, 1);
        check_submodular(&f, true, 10, 150);
        check_state_consistency(&f, 11, 100);
        check_edge_ingredients(&f, 12, 100);
    }

    #[test]
    fn properties_log1p() {
        let mut rng = Rng::new(2);
        let mut m = FeatureMatrix::zeros(15, 6);
        for i in 0..15 {
            for j in 0..6 {
                m.row_mut(i)[j] = rng.f32();
            }
        }
        let f = FeatureBased::new(m, Concave::Log1p);
        check_submodular(&f, true, 20, 100);
        check_state_consistency(&f, 21, 80);
    }

    #[test]
    fn properties_pow() {
        let mut rng = Rng::new(3);
        let mut m = FeatureMatrix::zeros(12, 5);
        for i in 0..12 {
            for j in 0..5 {
                m.row_mut(i)[j] = rng.f32() * 3.0;
            }
        }
        let f = FeatureBased::new(m, Concave::Pow(700));
        check_submodular(&f, true, 30, 100);
    }

    #[test]
    fn bidir_state_roundtrip() {
        let f = instance(10, 4, 4);
        let mut st = f.bidir_state(&[1, 3, 5]).unwrap();
        let v0 = st.value();
        assert!((v0 - f.eval(&[1, 3, 5])).abs() < 1e-6);
        let g_add = st.gain_add(7);
        st.add(7);
        assert!((st.value() - (v0 + g_add)).abs() < 1e-6);
        let g_rm = st.gain_remove(3);
        st.remove(3);
        assert!((st.value() - f.eval(&[1, 5, 7])).abs() < 1e-4, "remove drift");
        assert!(g_rm <= 1e-9, "removing from a monotone fn cannot gain");
        assert_eq!(st.members(), vec![1, 5, 7]);
    }

    #[test]
    fn singleton_complement_le_singleton() {
        // submodularity: f(v|V\v) <= f(v|∅) = f({v})
        let f = instance(25, 10, 5);
        let sing = f.singleton_complements();
        for v in 0..f.n() {
            assert!(
                sing[v] <= f.singleton(v) + 1e-6,
                "v={v}: f(v|V\\v)={} > f(v)={}",
                sing[v],
                f.singleton(v)
            );
        }
    }

    #[test]
    fn pair_gains_batch_bitwise_matches_scalar() {
        let f = instance(30, 8, 7);
        let probes = vec![0usize, 5, 9];
        let items: Vec<usize> = (10..30).collect();
        let pg = f.pair_gains_batch(&probes, &items);
        for (vi, &v) in items.iter().enumerate() {
            for (ui, &u) in probes.iter().enumerate() {
                assert_eq!(
                    pg[vi * probes.len() + ui],
                    f.pair_gain(u, v),
                    "cached-g(u) batch must be bit-identical at (u={u}, v={v})"
                );
            }
        }
    }

    #[test]
    fn grown_and_retained_objective_bitwise_matches_fresh_construction() {
        // push_element row by row == FeatureBased::new over the final
        // matrix (totals accumulate in the same order), and
        // retain_elements == FeatureBased::new over the surviving rows —
        // the two invariants the streaming session relies on
        let full = instance(40, 7, 19);
        let mut grown = FeatureBased::sqrt(FeatureMatrix::zeros(0, 7));
        grown.reserve_elements(40);
        for i in 0..40 {
            grown.push_element(full.feats().row(i));
        }
        assert_eq!(grown.feats(), full.feats());
        for (a, b) in grown.total_mass().iter().zip(full.total_mass()) {
            assert_eq!(a.to_bits(), b.to_bits(), "grown totals must match col_sums");
        }
        let keep: Vec<usize> = (0..40).filter(|i| i % 3 != 1).collect();
        assert!(grown.supports_retain());
        assert!(grown.retain_elements(&keep));
        let fresh = FeatureBased::sqrt(full.feats().gather(&keep));
        assert_eq!(grown.n(), keep.len());
        assert_eq!(grown.feats(), fresh.feats());
        for (a, b) in grown.total_mass().iter().zip(fresh.total_mass()) {
            assert_eq!(a.to_bits(), b.to_bits(), "retained totals must match fresh");
        }
        // downstream quantities agree bit-for-bit too
        let sg = grown.singleton_complements();
        let sf = fresh.singleton_complements();
        for (a, b) in sg.iter().zip(&sf) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(grown.pair_gain(0, 5).to_bits(), fresh.pair_gain(0, 5).to_bits());
    }

    #[test]
    fn eval_empty_zero() {
        let f = instance(5, 3, 6);
        assert_eq!(f.eval(&[]), 0.0);
    }

    #[test]
    fn batched_state_gains_bitwise_match_scalar() {
        // sqrt and log1p paths, dirty buffers, repeated calls
        let f = instance(25, 9, 13);
        check_batched_gains(&f, 130, 60);
        let mut rng = Rng::new(14);
        let mut m = FeatureMatrix::zeros(18, 5);
        for i in 0..18 {
            for j in 0..5 {
                m.row_mut(i)[j] = if rng.bool(0.5) { rng.f32() } else { 0.0 };
            }
        }
        let f = FeatureBased::new(m, Concave::Log1p);
        check_batched_gains(&f, 131, 40);
    }

    #[test]
    fn write_into_kernels_bitwise_match_allocating_kernels() {
        let f = instance(60, 10, 12);
        let sing = f.singleton_complements();
        let probes = vec![2usize, 17, 40, 59];
        let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
        let items: Vec<usize> = (0..60).filter(|v| !probes.contains(v)).collect();
        let want = f.divergences_block(&probes, &probe_sing, &items);
        // dirty buffer must be fully overwritten, twice in a row (scratch
        // reuse across calls must not leak state)
        let mut out = vec![f32::NAN; items.len()];
        for _ in 0..2 {
            f.divergences_into_block(&probes, &probe_sing, &items, &mut out);
            assert_eq!(out, want);
        }
        let want_pg = {
            // scalar oracle, not the batch (which now routes through _into)
            let mut pg = Vec::new();
            for &v in &items {
                for &u in &probes {
                    pg.push(f.pair_gain(u, v));
                }
            }
            pg
        };
        let mut out_pg = vec![f64::NAN; items.len() * probes.len()];
        f.pair_gains_into(&probes, &items, &mut out_pg);
        assert_eq!(out_pg, want_pg);
    }
}
