//! Submodular objective library.
//!
//! Everything SS touches goes through [`SubmodularFn`]: the paper's
//! feature-based concave-over-modular function (the experiments' objective),
//! facility location, coverage families, graph cut, plain modular functions,
//! and weighted mixtures. Each function exposes
//!
//! * whole-set evaluation `f(S)` (the ground-truth oracle),
//! * an incremental [`SolState`] with `O(gain)` marginal evaluation — the
//!   contract every maximization algorithm in [`crate::algorithms`] relies
//!   on,
//! * the pairwise gain `f(v|{u})` and the batch singleton-complement vector
//!   `f(v|V\v)` — the two ingredients of the submodularity-graph edge
//!   weight `w_{uv} = f(v|u) - f(u|V\u)` (paper Eq. 3).
//!
//! Functions that additionally support removal implement [`bidir_state`]
//! (used by the unconstrained bi-directional greedy of Buchbinder et al.,
//! which §3.4 of the paper applies to the sparsification objective).
//!
//! Objectives additionally implement [`BatchedDivergence`] — the batched
//! form of the edge-weight computation that the SS backends (CPU reference,
//! sharded coordinator, summarization service) dispatch through. The
//! default implementation is the scalar `pair_gain` loop; [`FeatureBased`],
//! [`FacilityLocation`] and [`Mixture`] override it with blocked kernels
//! (see [`batched`] for the contract).
//!
//! The *stateful* counterpart is [`SolState::gains_into`]: batched marginal
//! gains `f(v|S)` under the current solution, which the maximizer engine
//! ([`crate::algorithms::MaximizerEngine`]) dispatches per cohort instead
//! of calling the scalar [`SolState::gain`] once per element. Every
//! override must be bit-identical to the scalar loop — the engine's lazy
//! greedy is only Minoux-exact against the scalar reference because the
//! gains themselves never differ by a bit.
//!
//! [`bidir_state`]: SubmodularFn::bidir_state

pub mod batched;
mod coverage;
mod facility_location;
mod feature_based;
mod graph_cut;
mod mixture;
mod modular;
mod sparse_sim;
mod sparsification_objective;

pub use batched::BatchedDivergence;
pub use coverage::{SaturatedCoverage, SetCover};
pub use facility_location::{FacilityLocation, DENSE_CROSSOVER};
pub use feature_based::{Concave, FeatureBased};
pub use graph_cut::GraphCut;
pub use mixture::Mixture;
pub use modular::Modular;
pub use sparse_sim::{BuildStrategy, SparseSimStore, LSH_CROSSOVER};
pub use sparsification_objective::SparsificationObjective;

use crate::util::pool::ThreadPool;
use crate::util::vecmath::FeatureMatrix;

/// Which objective *family* to run over a set of feature rows — the single
/// spec type the whole service surface speaks: batch requests pair it with
/// a materialized row matrix
/// ([`Objective::from_rows`](crate::coordinator::Objective::from_rows)),
/// streaming sessions grow the rows incrementally
/// ([`open_stream`](crate::coordinator::SummarizationService::open_stream)).
/// It replaces the former stream-only `StreamObjective` (kept one release
/// as a deprecated alias).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjectiveSpec {
    /// Feature-based concave-over-modular over the rows — the paper's news
    /// objective; PJRT-accelerable, grows incrementally (bit-identical to
    /// fresh construction) and supports sieve admission filtering.
    Features(Concave),
    /// Facility location over clamped-cosine similarities of the rows —
    /// video-style representativeness. Construction auto-selects the
    /// similarity store: a dense matrix below
    /// [`DENSE_CROSSOVER`](crate::submodular::DENSE_CROSSOVER), sparse
    /// top-`t` neighbor lists (`O(n·t)` memory, row-border streaming
    /// appends) at or above it. Admission filtering is unavailable (its
    /// gains depend on the whole ground set).
    FacilityLocation,
    /// Facility location with the store choice pinned: dense below
    /// `crossover` rows, sparse with `t` neighbors per row otherwise
    /// (`t == 0` means the auto budget
    /// [`FacilityLocation::auto_neighbors`]). `crossover == 0` forces the
    /// sparse store at any size; `t: 0` with `crossover` equal to
    /// [`DENSE_CROSSOVER`](crate::submodular::DENSE_CROSSOVER) reproduces
    /// the plain `FacilityLocation` default. `build` picks the neighbor
    /// builder ([`BuildStrategy::Auto`] = exact all-pairs below
    /// [`LSH_CROSSOVER`], LSH-bucketed candidates above) and threads
    /// through every production path — sharded backend, maximizer engine,
    /// stream sessions and snapshot cores — with no call-site changes.
    FacilityLocationSparse { t: u32, crossover: u32, build: BuildStrategy },
}

impl ObjectiveSpec {
    /// Whether rows must be non-negative (feature-based coverage needs
    /// non-negative mass; facility location accepts signed embeddings).
    pub fn needs_nonneg(self) -> bool {
        matches!(self, ObjectiveSpec::Features(_))
    }

    /// Materialize the objective over a full row matrix — the batch path.
    /// Bit-identical to a streaming session grown row by row from the same
    /// matrix (the invariant `rust/tests/stream_equivalence.rs` pins).
    pub fn build(self, rows: FeatureMatrix) -> std::sync::Arc<dyn BatchedDivergence> {
        match self {
            ObjectiveSpec::Features(g) => std::sync::Arc::new(FeatureBased::new(rows, g)),
            ObjectiveSpec::FacilityLocation => {
                std::sync::Arc::new(FacilityLocation::from_features(&rows))
            }
            ObjectiveSpec::FacilityLocationSparse { t, crossover, build } => {
                let t = if t == 0 { None } else { Some(t as usize) };
                std::sync::Arc::new(FacilityLocation::from_features_strat(
                    &rows,
                    crossover as usize,
                    t,
                    build,
                    None,
                ))
            }
        }
    }

    /// The facility-location store parameters
    /// `(crossover, explicit t, build strategy)` this spec pins, or `None`
    /// for non-FL objectives — the single place streaming sessions and
    /// snapshot cores read the build config from.
    pub fn facility_store_params(self) -> Option<(usize, Option<usize>, BuildStrategy)> {
        match self {
            ObjectiveSpec::Features(_) => None,
            ObjectiveSpec::FacilityLocation => {
                Some((DENSE_CROSSOVER, None, BuildStrategy::Auto))
            }
            ObjectiveSpec::FacilityLocationSparse { t, crossover, build } => Some((
                crossover as usize,
                if t == 0 { None } else { Some(t as usize) },
                build,
            )),
        }
    }
}

/// A normalized (`f(∅) = 0`) non-negative submodular set function over a
/// ground set `{0, .., n-1}`.
pub trait SubmodularFn: Send + Sync {
    /// Ground-set size `n = |V|`.
    fn n(&self) -> usize;

    /// Evaluate `f(S)` from scratch. `s` may be unsorted; duplicates are a
    /// caller bug (checked in debug builds by implementations).
    fn eval(&self, s: &[usize]) -> f64;

    /// Fresh incremental solution state at `S = ∅`.
    fn state<'a>(&'a self) -> Box<dyn SolState + 'a>;

    /// Pairwise gain `f(v | {u})` — the "local importance" half of the
    /// submodularity-graph edge weight. Implementations override the
    /// two-eval default when a cheaper closed form exists.
    fn pair_gain(&self, u: usize, v: usize) -> f64 {
        self.eval(&[u, v]) - self.eval(&[u])
    }

    /// Singleton value `f({v})`.
    fn singleton(&self, v: usize) -> f64 {
        self.eval(&[v])
    }

    /// Batch `f(v | V∖v)` for all `v` — the "global importance" half of the
    /// edge weight, precomputed once per SS invocation (paper §3.2: "may be
    /// precomputed once in linear time"). The default is the O(n) eval
    /// fallback per element (O(n²) total) — fine for tests, overridden by
    /// every real objective.
    fn singleton_complements(&self) -> Vec<f64> {
        let full: Vec<usize> = (0..self.n()).collect();
        let f_v = self.eval(&full);
        (0..self.n())
            .map(|v| {
                let rest: Vec<usize> = (0..self.n()).filter(|&u| u != v).collect();
                f_v - self.eval(&rest)
            })
            .collect()
    }

    /// Whether [`singleton_complements_into`] computes a range of elements
    /// in time proportional to that range (true for per-element-decomposable
    /// objectives like [`FeatureBased`], false when the whole-vector form
    /// shares work across elements — e.g. facility location's top-2 row
    /// scan, which scatters into arbitrary output slots). Backends shard
    /// the one-time singleton precompute over their pool **only** when
    /// this is true; sharding the fallback would multiply total work by
    /// the shard count.
    ///
    /// [`singleton_complements_into`]: SubmodularFn::singleton_complements_into
    fn singleton_complements_decomposable(&self) -> bool {
        false
    }

    /// Per-element form of [`singleton_complements`]: `out[i] = f(items[i] |
    /// V∖items[i])`, bit-identical to the whole-vector computation. The
    /// default computes the full vector and gathers — correct everywhere,
    /// efficient only where [`singleton_complements_decomposable`] says so.
    ///
    /// [`singleton_complements`]: SubmodularFn::singleton_complements
    /// [`singleton_complements_decomposable`]: SubmodularFn::singleton_complements_decomposable
    fn singleton_complements_into(&self, items: &[usize], out: &mut [f64]) {
        debug_assert_eq!(items.len(), out.len());
        let all = self.singleton_complements();
        for (slot, &v) in out.iter_mut().zip(items) {
            *slot = all[v];
        }
    }

    /// Pool-sharded variant of [`singleton_complements`] for objectives
    /// whose whole-vector precompute is **not** per-element decomposable
    /// but *is* shardable over its reduction dimension — facility
    /// location's top-2 row scan being the canonical case: each shard
    /// computes its rows' `(argmax, top1 − top2)` results, and the leader
    /// scatters them in ascending-row order, so every output slot sees the
    /// exact add sequence of the serial scan (bit-identity preserved).
    /// Backends try this after [`singleton_complements_decomposable`];
    /// `None` (the default) means no such variant exists and the serial
    /// whole-vector form is the only option.
    ///
    /// [`singleton_complements`]: SubmodularFn::singleton_complements
    /// [`singleton_complements_decomposable`]: SubmodularFn::singleton_complements_decomposable
    fn singleton_complements_pooled(
        &self,
        _pool: &ThreadPool,
        _shards: usize,
    ) -> Option<Vec<f64>> {
        None
    }

    /// Ground elements backed by sparse (top-`t` neighbor) storage —
    /// introspection the backends meter into the coordinator's
    /// `sparse_rows` counter. `0` (the default) means dense or
    /// storage-free; [`FacilityLocation`] reports `n` when its sparse
    /// store is active, and mixtures sum their components.
    fn sparse_rows(&self) -> usize {
        0
    }

    /// `(candidate pairs scored, largest bucket)` of an LSH-bucketed
    /// neighbor build, when one backs this objective — introspection the
    /// backends meter into the coordinator's `lsh_candidates` /
    /// `lsh_bucket_max` gauges. `(0, 0)` (the default) means no LSH index;
    /// [`FacilityLocation`] forwards its sparse store's stats.
    fn lsh_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Bytes resident in this objective's backing store (dense similarity
    /// matrix, sparse neighbor lists, …) — introspection the backends
    /// meter into the coordinator's `resident_bytes` gauge for capacity
    /// planning. `0` (the default) means no accounted storage; mixtures
    /// sum their components.
    fn resident_bytes(&self) -> usize {
        0
    }

    /// Whether [`retain_elements`] is implemented — the streaming
    /// subsystem ([`crate::stream`]) requires it to compact the live
    /// ground set after a windowed re-sparsification. Defaults to `false`;
    /// objectives that own per-element storage ([`FeatureBased`],
    /// [`FacilityLocation`], mixtures of such) opt in.
    ///
    /// [`retain_elements`]: SubmodularFn::retain_elements
    fn supports_retain(&self) -> bool {
        false
    }

    /// Compact the ground set to `keep` (ascending, distinct internal
    /// indices): survivor `keep[i]` is renumbered to element `i`, every
    /// other element's storage (feature row, similarity row/column, cached
    /// totals) is dropped, and `n()` becomes `keep.len()`. Returns `false`
    /// (and must leave the objective untouched) when the capability is
    /// unsupported — check [`supports_retain`] first; implementations that
    /// return `true` must make the compacted objective indistinguishable
    /// from one freshly constructed over the surviving elements in `keep`
    /// order, which is what the stream ↔ batch equivalence tests pin down.
    ///
    /// [`supports_retain`]: SubmodularFn::supports_retain
    fn retain_elements(&mut self, _keep: &[usize]) -> bool {
        false
    }

    /// Add/remove-capable state starting from an arbitrary set, when the
    /// objective supports efficient removal (needed by bi-directional
    /// greedy). `None` (the default) opts out.
    fn bidir_state<'a>(&'a self, _init: &[usize]) -> Option<Box<dyn BidirState + 'a>> {
        None
    }

    /// Specialization hook: objectives that are (or wrap) a
    /// [`FeatureBased`] expose it so generic backends can route the SS hot
    /// loop through the blocked/vectorized divergence kernel.
    fn as_feature_based(&self) -> Option<&FeatureBased> {
        None
    }
}

/// Incremental solution state: supports gain queries and additions.
///
/// `Sync` because the maximizer engine fans gain cohorts over the worker
/// pool: shards evaluate [`gains_into`] on disjoint candidate ranges
/// against one shared `&dyn SolState`. All queries are `&self`; mutation
/// (`add`) stays exclusive to the single-threaded commit step.
///
/// [`gains_into`]: SolState::gains_into
pub trait SolState: Send + Sync {
    /// Current `f(S)`.
    fn value(&self) -> f64;
    /// Marginal gain `f(v | S)`.
    fn gain(&self, v: usize) -> f64;
    /// Commit `S ← S + v`.
    fn add(&mut self, v: usize);

    /// Commit `S ← S + v` with the per-element bookkeeping walk fanned
    /// over `pool` — **bit-identical** to [`add`]: states may parallelize
    /// only the pure *gather* phase (disjoint writes into scratch) and
    /// must keep the value fold serial in the same element order, since
    /// f64 addition is not associative. The default is the serial [`add`]
    /// (correct everywhere); [`FacilityLocation`]'s state overrides it to
    /// shard its O(n) best-similarity update — the maximizer commit step
    /// that used to serialize every epoch. Callers gate on ground-set
    /// size (the sharded backend uses its commit threshold): below it,
    /// dispatch overhead beats the win.
    ///
    /// [`add`]: SolState::add
    fn add_pooled(&mut self, v: usize, _pool: &ThreadPool, _shards: usize) {
        self.add(v);
    }

    /// Elements committed so far, in insertion order.
    fn set(&self) -> &[usize];

    /// Batched marginal gains: `out[i] = f(candidates[i] | S)`,
    /// **bit-identical** to the scalar [`gain`] loop. The default is that
    /// loop — correct for every objective with no override; the production
    /// states override it with blocked kernels ([`FeatureBased`] caches
    /// `g(cov)` across the cohort, [`FacilityLocation`] streams similarity
    /// rows instead of striding columns, [`Mixture`] delegates to its
    /// parts). Per-element values are independent of how `candidates` is
    /// chunked, so callers may split a cohort across threads into disjoint
    /// `out` slices without changing a bit.
    ///
    /// [`gain`]: SolState::gain
    fn gains_into(&self, candidates: &[usize], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        for (slot, &v) in out.iter_mut().zip(candidates) {
            *slot = self.gain(v);
        }
    }

    /// Capacity hint: the caller will `add` at most `additional` more
    /// elements. Default no-op; production states reserve their solution
    /// vector so steady-state maximizer iterations never touch the
    /// allocator (the invariant `rust/tests/alloc_steady_state.rs`
    /// enforces).
    fn reserve_additions(&mut self, _additional: usize) {}

    /// Specialization hook: states whose gains are a function of a dense
    /// coverage vector over a [`FeatureBased`] core expose it, so
    /// accelerated routes can batch cohorts through the PJRT marginal-gain
    /// artifact (`runtime/tiled.rs`). `None` (the default) opts out.
    fn feature_coverage(&self) -> Option<&[f32]> {
        None
    }
}

/// Add/remove state over an explicit member set (bi-directional greedy).
pub trait BidirState: Send {
    fn value(&self) -> f64;
    /// `f(S + v) - f(S)`.
    fn gain_add(&self, v: usize) -> f64;
    /// `f(S - v) - f(S)`.
    fn gain_remove(&self, v: usize) -> f64;
    fn add(&mut self, v: usize);
    fn remove(&mut self, v: usize);
    fn contains(&self, v: usize) -> bool;
    fn members(&self) -> Vec<usize>;
}

#[cfg(test)]
pub(crate) mod test_support {
    //! Shared property-test drivers: every objective must pass these.
    use super::*;
    use crate::util::prop::{check_seeded, Gen};

    /// Draw a random (A ⊆ B, v ∉ B) triple and verify diminishing returns,
    /// monotone non-negativity of gains where `monotone`, and consistency of
    /// the incremental state against from-scratch eval.
    pub fn check_submodular(f: &dyn SubmodularFn, monotone: bool, seed: u64, cases: usize) {
        let n = f.n();
        assert!(n >= 3, "need n >= 3 for the property driver");
        check_seeded(seed, cases, |g: &mut Gen| {
            let b = g.subset(n, 0..n.min(12));
            // A = random subset of B
            let a: Vec<usize> = b.iter().copied().filter(|_| g.bool()).collect();
            let outside: Vec<usize> = (0..n).filter(|x| !b.contains(x)).collect();
            if outside.is_empty() {
                return;
            }
            let v = *g.choose(&outside);
            let fa = f.eval(&a);
            let fb = f.eval(&b);
            let fav = f.eval(&[a.clone(), vec![v]].concat());
            let fbv = f.eval(&[b.clone(), vec![v]].concat());
            let ga = fav - fa;
            let gb = fbv - fb;
            assert!(
                ga >= gb - 1e-6 * (1.0 + ga.abs() + gb.abs()),
                "diminishing returns violated: f(v|A)={ga} < f(v|B)={gb} (A={a:?} B={b:?} v={v})"
            );
            if monotone {
                assert!(gb >= -1e-9, "monotone objective has negative gain {gb}");
                assert!(fa >= -1e-9 && fb >= -1e-9, "non-negativity");
            }
            // normalization
            assert!(f.eval(&[]).abs() < 1e-9, "f(empty) != 0");
        });
    }

    /// Incremental state must track from-scratch eval along random chains.
    pub fn check_state_consistency(f: &dyn SubmodularFn, seed: u64, cases: usize) {
        let n = f.n();
        check_seeded(seed, cases, |g: &mut Gen| {
            let chain = g.subset(n, 1..n.min(10));
            let mut st = f.state();
            let mut so_far: Vec<usize> = Vec::new();
            for &v in &chain {
                let want_gain = f.eval(&[so_far.clone(), vec![v]].concat()) - f.eval(&so_far);
                let got_gain = st.gain(v);
                assert!(
                    (want_gain - got_gain).abs() < 1e-5 * (1.0 + want_gain.abs()),
                    "state gain mismatch at v={v}: got {got_gain}, want {want_gain}"
                );
                st.add(v);
                so_far.push(v);
                let want_val = f.eval(&so_far);
                assert!(
                    (st.value() - want_val).abs() < 1e-5 * (1.0 + want_val.abs()),
                    "state value drift: got {}, want {want_val}",
                    st.value()
                );
            }
            assert_eq!(st.set(), &so_far[..]);
        });
    }

    /// Scalar reference for divergence batches: the exact float sequence of
    /// the default [`BatchedDivergence`] path. Blocked-kernel tests assert
    /// bitwise equality against this.
    pub fn scalar_reference_divergences(
        f: &dyn SubmodularFn,
        probes: &[usize],
        probe_sing: &[f64],
        items: &[usize],
    ) -> Vec<f32> {
        items
            .iter()
            .map(|&v| {
                probes
                    .iter()
                    .zip(probe_sing)
                    .map(|(&u, &su)| (f.pair_gain(u, v) - su) as f32)
                    .fold(f32::INFINITY, f32::min)
            })
            .collect()
    }

    /// Batched stateful gains must be bit-identical to the scalar loop at
    /// every prefix of a random add-chain — the contract the maximizer
    /// engine's Minoux-exactness rests on. Exercises dirty output buffers
    /// and repeated calls (scratch reuse must not leak state).
    pub fn check_batched_gains(f: &dyn SubmodularFn, seed: u64, cases: usize) {
        let n = f.n();
        check_seeded(seed, cases, |g: &mut Gen| {
            let chain = g.subset(n, 0..n.min(8));
            let cands = g.subset(n, 1..n.min(16).max(2));
            let mut st = f.state();
            for step in 0..=chain.len() {
                let want: Vec<f64> = cands.iter().map(|&v| st.gain(v)).collect();
                let mut out = vec![f64::NAN; cands.len()];
                for _ in 0..2 {
                    st.gains_into(&cands, &mut out);
                    for (i, (&got, &w)) in out.iter().zip(&want).enumerate() {
                        assert_eq!(
                            got.to_bits(),
                            w.to_bits(),
                            "gains_into[{i}] (v={}) diverged from scalar gain at chain step {step}",
                            cands[i]
                        );
                    }
                }
                if step < chain.len() {
                    st.add(chain[step]);
                }
            }
        });
    }

    /// pair_gain and singleton_complements must agree with eval.
    pub fn check_edge_ingredients(f: &dyn SubmodularFn, seed: u64, cases: usize) {
        let n = f.n();
        let sing = f.singleton_complements();
        let full: Vec<usize> = (0..n).collect();
        let f_full = f.eval(&full);
        check_seeded(seed, cases, |g: &mut Gen| {
            let u = g.usize_in(0, n);
            let v = g.usize_in(0, n);
            if u == v {
                return;
            }
            let want = f.eval(&[u, v]) - f.eval(&[u]);
            let got = f.pair_gain(u, v);
            assert!((want - got).abs() < 1e-5 * (1.0 + want.abs()), "pair_gain({u},{v})");
            let rest: Vec<usize> = (0..n).filter(|&x| x != u).collect();
            let want_sc = f_full - f.eval(&rest);
            assert!(
                (sing[u] - want_sc).abs() < 1e-4 * (1.0 + want_sc.abs()),
                "singleton_complements[{u}]: got {}, want {want_sc}",
                sing[u]
            );
        });
    }
}
