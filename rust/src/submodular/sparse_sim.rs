//! **Sparse similarity store** — CSR-style per-row top-`t` neighbor lists
//! backing [`FacilityLocation`](super::FacilityLocation) at scale.
//!
//! Lindgren et al., *Leveraging Sparsity for Efficient Submodular Data
//! Summarization* (PAPERS.md), observe that facility location only needs
//! each ground element's strongest few neighbors to preserve greedy
//! quality. This store keeps, per row `i`, at most `t` non-diagonal
//! entries `(u, sim(i, u))` — the exact clamped-cosine top-`t` — plus the
//! pinned diagonal `(i, 1.0)`. Every absent entry reads as `0.0`, which is
//! a *lower bound* on the true (non-negative) similarity, so the induced
//! objective stays monotone submodular and under-approximates the dense
//! one; at `t = n − 1` no entry is absent and the store reproduces the
//! dense matrix bit-for-bit.
//!
//! Layout: fixed-capacity row slots (`cap = t + 1` entries each) in two
//! flat arrays, columns ascending within a row. The slotted layout is what
//! makes the two mutation paths in-place:
//!
//! * **row-border append** ([`append_row`](SparseSimStore::append_row)):
//!   a new element scans the live rows once (`O(n·d)`), simultaneously
//!   selecting its own top-`t` and candidate-updating each existing row's
//!   list (the new column index is the largest, so an accepted candidate
//!   lands at the row's end — no interior shift);
//! * **retain compaction** ([`retain`](SparseSimStore::retain)): an
//!   `IdRemap`-style old→new column rewrite walks surviving rows forward,
//!   dropping entries whose column was evicted.
//!
//! Selection uses the total order *(value descending, column ascending)*,
//! so the top-`t` set of any candidate stream is unique — which is exactly
//! why incremental appends land on the same lists as a fresh batch build
//! (pinned by `rust/tests/sparse_fl_equivalence.rs`).
//!
//! **LSH-bucketed build** ([`from_features_lsh`](SparseSimStore::from_features_lsh)):
//! the exact all-pairs build scores `O(n²·d)` pairs, which dominates the
//! whole pipeline at scale. The bucketed builder hashes each feature row
//! into `tables` signatures of `bits` signed random projections each
//! (hyperplane LSH: two rows collide on a bit with probability
//! `1 − θ/π`, so cosine-similar rows share buckets), generates candidate
//! pairs only within buckets, and runs the *same* exact top-`t` selection
//! over the candidates. Projections derive from a fixed internal seed, so
//! the index is a pure function of `(tables, bits, d)` — batch builds,
//! streaming appends and checkpoint-recovery rebuilds all agree without
//! plumbing. Because row signatures depend only on the row's own features,
//! "i and j share a bucket" is symmetric and insertion-order-invariant:
//! incremental appends probe exactly the candidate set a fresh build would
//! enumerate, so append ≡ fresh-build bit-identity carries over from the
//! exact builder (at a fixed explicit `t`). With `bits = 0` every row
//! lands in one bucket per table, the candidate set is all pairs, and the
//! build is bit-identical to the exact oracle — the saturation property
//! `rust/tests/lsh_build_equivalence.rs` pins.
//!
//! The exact builder stays compiled-in as the equivalence/bench oracle;
//! [`BuildStrategy`] picks between them (`Auto` = exact below
//! [`LSH_CROSSOVER`], bucketed above).

use std::collections::HashMap;

use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;
use crate::util::vecmath::{cosine, dot, FeatureMatrix};

/// Sentinel for "column evicted" in the retain rewrite map.
const GONE: u32 = u32::MAX;

/// Ground-set size at which [`BuildStrategy::Auto`] switches the neighbor
/// build from exact all-pairs to LSH-bucketed candidates. Below it the
/// quadratic build is cheap (and the dense path usually wins anyway via
/// `DENSE_CROSSOVER`); above it the bucketed build's near-linear candidate
/// generation dominates.
pub const LSH_CROSSOVER: usize = 8192;

/// Fixed seed for the LSH projection directions. A constant (not a knob):
/// it makes the index a pure function of `(tables, bits, d)`, so every
/// construction site — batch build, streaming append, snapshot rebuild,
/// checkpoint recovery — derives identical buckets with zero plumbing.
const LSH_PROJ_SEED: u64 = 0x9e37_79b9_7f4a_7c15;

/// Mass-coverage threshold for the adaptive-`t` truncation rule: a row
/// keeps the smallest top prefix of its candidates holding ≥ this share
/// of the candidate pool's total similarity mass.
const ADAPT_PHI: f64 = 0.90;

/// How [`SparseSimStore`] selects neighbor candidates at build time.
///
/// `Exact` scores every pair (`O(n²·d)`, the oracle); `Lsh` generates
/// candidates from multi-table signed-projection buckets (near-linear,
/// exact top-`t` *within* candidates — the bounded recall loss is
/// absorbed by the truncation lower-bound argument, see the module docs);
/// `Auto` picks `Exact` below [`LSH_CROSSOVER`] and sized LSH parameters
/// above it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BuildStrategy {
    /// exact all-pairs top-`t` (the equivalence/bench oracle)
    Exact,
    /// LSH-bucketed candidates: `tables` hash tables of `bits` signed
    /// projections each. `bits = 0` saturates (all pairs are candidates —
    /// bit-identical to `Exact`); more bits mean smaller buckets.
    Lsh { tables: u32, bits: u32 },
    /// `Exact` below [`LSH_CROSSOVER`], `Lsh` with
    /// [`auto_lsh_params`](BuildStrategy::auto_lsh_params) above.
    Auto,
}

impl BuildStrategy {
    /// Resolve to a concrete builder for ground-set size `n`:
    /// `None` = exact all-pairs, `Some((tables, bits))` = LSH-bucketed.
    pub fn resolve(self, n: usize) -> Option<(u32, u32)> {
        match self {
            BuildStrategy::Exact => None,
            BuildStrategy::Lsh { tables, bits } => Some((tables.max(1), bits.min(24))),
            BuildStrategy::Auto => (n >= LSH_CROSSOVER).then(|| Self::auto_lsh_params(n)),
        }
    }

    /// Default LSH geometry for ground-set size `n`: 8 tables, and enough
    /// bits that the mean bucket holds ≈128 rows (clamped to 4..=16 bits).
    /// 8 independent tables keep per-pair recall high (a pair is missed
    /// only if it splits in *every* table) while the per-row candidate
    /// pool stays `O(tables · bucket)` ≪ n.
    pub fn auto_lsh_params(n: usize) -> (u32, u32) {
        let mut bits = 0u32;
        while (n >> bits) > 128 && bits < 16 {
            bits += 1;
        }
        (8, bits.clamp(4, 16))
    }
}

/// Multi-table hyperplane-LSH index over the store's feature rows. Bucket
/// vectors hold row ids ascending (build inserts rows in order, appends
/// push the new maximum id, retain compacts monotonically), and signatures
/// are pure per-row functions — the two facts behind the append ≡ fresh
/// equivalence (module docs).
#[derive(Clone, Debug)]
struct LshIndex {
    tables: u32,
    bits: u32,
    d: usize,
    /// `tables × bits × d` signed projection directions from
    /// [`LSH_PROJ_SEED`] — a pure function of the geometry
    projs: Vec<f32>,
    /// per-table: signature → ascending row ids
    buckets: Vec<HashMap<u32, Vec<u32>>>,
}

impl LshIndex {
    fn new(tables: u32, bits: u32, d: usize) -> Self {
        let mut rng =
            Rng::new(LSH_PROJ_SEED ^ ((tables as u64) << 40) ^ ((bits as u64) << 20) ^ d as u64);
        let count = tables as usize * bits as usize * d;
        let mut projs = Vec::with_capacity(count);
        for _ in 0..count {
            projs.push(rng.f32() * 2.0 - 1.0);
        }
        Self { tables, bits, d, projs, buckets: vec![HashMap::new(); tables as usize] }
    }

    /// `bits`-bit signature of `x` under table `k`'s projections.
    /// `bits = 0` yields key 0 for every row (saturation).
    #[inline]
    fn key(&self, x: &[f32], k: usize) -> u32 {
        let b = self.bits as usize;
        let base = k * b * self.d;
        let mut key = 0u32;
        for i in 0..b {
            let p = &self.projs[base + i * self.d..base + (i + 1) * self.d];
            if dot(p, x) >= 0.0 {
                key |= 1 << i;
            }
        }
        key
    }

    /// Insert row `id` (the current maximum) into its bucket per table.
    fn insert(&mut self, id: u32, x: &[f32]) {
        for k in 0..self.tables as usize {
            let key = self.key(x, k);
            self.buckets[k].entry(key).or_default().push(id);
        }
    }

    /// Deduplicated candidate ids for a row with features `x` (union of
    /// its buckets across tables, minus `exclude`), ascending. `stamp` is
    /// caller scratch with no live entry equal to `mark`; visited ids are
    /// stamped so multi-table duplicates are emitted once.
    fn candidates_into(
        &self,
        x: &[f32],
        exclude: u32,
        stamp: &mut [u32],
        mark: u32,
        out: &mut Vec<u32>,
    ) {
        out.clear();
        for k in 0..self.tables as usize {
            if let Some(bucket) = self.buckets[k].get(&self.key(x, k)) {
                for &j in bucket {
                    if j != exclude && stamp[j as usize] != mark {
                        stamp[j as usize] = mark;
                        out.push(j);
                    }
                }
            }
        }
        out.sort_unstable();
    }

    /// Hash all `n` rows (pool-parallel when available — signatures are
    /// independent) and insert them in ascending order.
    fn build(
        feats: &FeatureMatrix,
        tables: u32,
        bits: u32,
        pooled: Option<(&ThreadPool, usize)>,
    ) -> Self {
        let n = feats.n();
        let mut idx = Self::new(tables, bits, feats.d);
        let mut keys = vec![0u32; n];
        for k in 0..tables as usize {
            {
                let idx = &idx;
                let fill = |lo: usize, _hi: usize, chunk: &mut [u32]| {
                    for (slot, i) in chunk.iter_mut().zip(lo..) {
                        *slot = idx.key(feats.row(i), k);
                    }
                };
                match pooled {
                    Some((pool, shards)) if n > 0 => {
                        pool.parallel_ranges_into(&mut keys[..], shards, fill)
                    }
                    _ => fill(0, n, &mut keys[..]),
                }
            }
            for (i, &key) in keys.iter().enumerate() {
                idx.buckets[k].entry(key).or_default().push(i as u32);
            }
        }
        idx
    }

    /// Heap bytes of the index (projections + hash tables + bucket ids) —
    /// counted into [`SparseSimStore::resident_bytes`] so the ≥4× memory
    /// gates price the LSH builder honestly. The per-entry hash-table term
    /// is an estimate (key + bucket `Vec` header + 1 control byte per
    /// slot); bucket contents are exact.
    fn resident_bytes(&self) -> usize {
        let mut b = self.projs.capacity() * std::mem::size_of::<f32>();
        for m in &self.buckets {
            b += m.capacity()
                * (std::mem::size_of::<u32>() + std::mem::size_of::<Vec<u32>>() + 1);
            b += m.values().map(|v| v.capacity() * std::mem::size_of::<u32>()).sum::<usize>();
        }
        b
    }
}

/// Per-row top-`t` neighbor lists over clamped-cosine similarities, with a
/// pinned diagonal. See the module docs for the layout and mutation model.
#[derive(Clone, Debug)]
pub struct SparseSimStore {
    n: usize,
    /// max non-diagonal neighbors per row (the `t` of "top-t")
    t: usize,
    /// slot width per row: `t` neighbors + the pinned diagonal
    cap: usize,
    /// live entries per row (`len[i] <= cap`)
    len: Vec<u32>,
    /// column indices, ascending within row slot `[i*cap, i*cap + len[i])`
    cols: Vec<u32>,
    /// values aligned to `cols`
    vals: Vec<f32>,
    /// per-column sums `Σ_i sim(i, v)` (ascending-`i` f64 fold — the exact
    /// add sequence of the dense `singleton` loop), refreshed after every
    /// mutation batch
    col_sums: Vec<f64>,
    /// LSH bucket index when this store was built (or re-attached) with
    /// the bucketed builder; `None` = exact all-pairs appends
    lsh: Option<LshIndex>,
    /// total candidate pairs scored by the LSH builder and its appends
    /// (the `lsh_candidates` counter's source)
    lsh_candidates: u64,
    /// adaptive-`t` floor: when set, each row keeps the smallest
    /// [`ADAPT_PHI`]-mass prefix of its candidates of at least this many
    /// entries (auto-`t` LSH builds only; explicit `t` keeps exact top-`t`)
    adapt_floor: Option<u32>,
}

/// `(new, old)` beats `(old_v, old_c)` under the selection total order:
/// value descending, column ascending as the tiebreak.
#[inline]
fn beats(av: f32, ac: u32, bv: f32, bc: u32) -> bool {
    av > bv || (av == bv && ac < bc)
}

/// Candidate-stream top-`t` selection into `sel` (unsorted), maintaining
/// exactly the top-`t` of everything pushed so far under [`beats`].
#[inline]
fn topt_push(sel: &mut Vec<(u32, f32)>, t: usize, c: u32, v: f32) -> bool {
    if sel.len() < t {
        sel.push((c, v));
        return true;
    }
    if t == 0 {
        return false;
    }
    // find the worst live entry (the one every other entry beats)
    let mut worst = 0usize;
    for (k, &(kc, kv)) in sel.iter().enumerate().skip(1) {
        let (wc, wv) = (sel[worst].0, sel[worst].1);
        if beats(wv, wc, kv, kc) {
            worst = k;
        }
    }
    let (wc, wv) = sel[worst];
    if beats(v, c, wv, wc) {
        sel[worst] = (c, v);
        return true;
    }
    false
}

/// Adaptive-`t` truncation: sort `sel` by the selection order (value
/// descending, column ascending — a strict total order, so the sorted
/// sequence is unique regardless of how candidates were enumerated), then
/// keep the smallest prefix of ≥ `floor` entries holding [`ADAPT_PHI`] of
/// the total similarity mass (f64 fold in sorted order — deterministic).
/// Concentrated rows (a few dominant neighbors) shrink toward `floor`;
/// flat rows (large redundant clusters) keep growing toward the cap —
/// which is exactly the regime where a fixed `t = O(log n)` budget
/// collapses the utility floor (EXPERIMENTS.md §Sparse facility location).
fn adaptive_truncate(sel: &mut Vec<(u32, f32)>, floor: usize) {
    if sel.len() <= floor {
        return;
    }
    sel.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    let total: f64 = sel.iter().map(|&(_, v)| v as f64).sum();
    let mut acc = 0.0f64;
    for k in 0..sel.len() {
        acc += sel[k].1 as f64;
        if k + 1 >= floor && acc >= ADAPT_PHI * total {
            sel.truncate(k + 1);
            return;
        }
    }
}

impl SparseSimStore {
    /// Exact top-`t` build over clamped-cosine similarities of `feats`,
    /// serial. Rows with fewer than `t` candidates simply hold them all;
    /// the capacity stays `t` so the store can grow past the initial `n`
    /// by row-border appends.
    pub fn from_features(feats: &FeatureMatrix, t: usize) -> Self {
        Self::build(feats, t, None)
    }

    /// Shard-parallel exact top-`t` build: rows are independent, so each
    /// pool shard fills a disjoint range of them. Bit-identical to the
    /// serial build (per-row work is untouched by the sharding).
    pub fn from_features_pooled(
        feats: &FeatureMatrix,
        t: usize,
        pool: &ThreadPool,
        shards: usize,
    ) -> Self {
        Self::build(feats, t, Some((pool, shards)))
    }

    fn build(feats: &FeatureMatrix, t: usize, pooled: Option<(&ThreadPool, usize)>) -> Self {
        let n = feats.n();
        let cap = t + 1;
        let mut tmp: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n];
        let fill = |lo: usize, _hi: usize, chunk: &mut [Vec<(u32, f32)>]| {
            for (slot, i) in chunk.iter_mut().zip(lo..) {
                *slot = row_topt(feats, i, t, n);
            }
        };
        match pooled {
            Some((pool, shards)) if n > 0 => pool.parallel_ranges_into(&mut tmp[..], shards, fill),
            _ => fill(0, n, &mut tmp[..]),
        }
        let mut store = Self {
            n,
            t,
            cap,
            len: vec![0; n],
            cols: vec![0; n * cap],
            vals: vec![0.0; n * cap],
            col_sums: Vec::new(),
            lsh: None,
            lsh_candidates: 0,
            adapt_floor: None,
        };
        for (i, row) in tmp.into_iter().enumerate() {
            debug_assert!(row.len() <= cap);
            store.len[i] = row.len() as u32;
            for (k, (c, v)) in row.into_iter().enumerate() {
                store.cols[i * cap + k] = c;
                store.vals[i * cap + k] = v;
            }
        }
        store.recompute_col_sums();
        store
    }

    /// LSH-bucketed top-`t` build, serial: candidates come from the bucket
    /// index, selection within them is the exact [`topt_push`] rule. With
    /// `adapt_floor = Some(floor)` each row is additionally truncated to
    /// the smallest ≥`floor` prefix holding [`ADAPT_PHI`] of its candidate
    /// similarity mass (`t` then acts as the per-row cap); `None` keeps
    /// the exact top-`t` of the candidates. See the module docs for the
    /// equivalence and recall arguments.
    pub fn from_features_lsh(
        feats: &FeatureMatrix,
        t: usize,
        adapt_floor: Option<usize>,
        tables: u32,
        bits: u32,
    ) -> Self {
        Self::lsh_build(feats, t, adapt_floor, tables, bits, None)
    }

    /// Shard-parallel [`from_features_lsh`](Self::from_features_lsh):
    /// hashing and per-row candidate selection both fan over the pool with
    /// disjoint writes; bucket insertion stays serial ascending. Bit-
    /// identical to the serial LSH build.
    pub fn from_features_lsh_pooled(
        feats: &FeatureMatrix,
        t: usize,
        adapt_floor: Option<usize>,
        tables: u32,
        bits: u32,
        pool: &ThreadPool,
        shards: usize,
    ) -> Self {
        Self::lsh_build(feats, t, adapt_floor, tables, bits, Some((pool, shards)))
    }

    fn lsh_build(
        feats: &FeatureMatrix,
        t: usize,
        adapt_floor: Option<usize>,
        tables: u32,
        bits: u32,
        pooled: Option<(&ThreadPool, usize)>,
    ) -> Self {
        let n = feats.n();
        let cap = t + 1;
        let idx = LshIndex::build(feats, tables.max(1), bits, pooled);
        // per row: (selected entries sorted by column, candidates scored)
        let mut tmp: Vec<(Vec<(u32, f32)>, u32)> = vec![(Vec::new(), 0); n];
        {
            let idx = &idx;
            let fill = |lo: usize, _hi: usize, chunk: &mut [(Vec<(u32, f32)>, u32)]| {
                let mut stamp = vec![u32::MAX; n];
                let mut cand: Vec<u32> = Vec::new();
                for (slot, i) in chunk.iter_mut().zip(lo..) {
                    let xi = feats.row(i);
                    idx.candidates_into(xi, i as u32, &mut stamp, i as u32, &mut cand);
                    let mut sel: Vec<(u32, f32)> = Vec::with_capacity(t.min(cand.len()) + 1);
                    for &u in &cand {
                        let s = cosine(xi, feats.row(u as usize)).max(0.0);
                        topt_push(&mut sel, t, u, s);
                    }
                    if let Some(floor) = adapt_floor {
                        adaptive_truncate(&mut sel, floor);
                    }
                    sel.push((i as u32, 1.0));
                    sel.sort_unstable_by_key(|&(c, _)| c);
                    *slot = (sel, cand.len() as u32);
                }
            };
            match pooled {
                Some((pool, shards)) if n > 0 => {
                    pool.parallel_ranges_into(&mut tmp[..], shards, fill)
                }
                _ => fill(0, n, &mut tmp[..]),
            }
        }
        let mut store = Self {
            n,
            t,
            cap,
            len: vec![0; n],
            cols: vec![0; n * cap],
            vals: vec![0.0; n * cap],
            col_sums: Vec::new(),
            lsh: None,
            lsh_candidates: 0,
            adapt_floor: adapt_floor.map(|f| f as u32),
        };
        let mut cand_total = 0u64;
        for (i, (row, cands)) in tmp.into_iter().enumerate() {
            debug_assert!(row.len() <= cap);
            cand_total += cands as u64;
            store.len[i] = row.len() as u32;
            for (k, (c, v)) in row.into_iter().enumerate() {
                store.cols[i * cap + k] = c;
                store.vals[i * cap + k] = v;
            }
        }
        store.lsh_candidates = cand_total;
        store.lsh = Some(idx);
        store.recompute_col_sums();
        store
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Max non-diagonal neighbors per row.
    pub fn t(&self) -> usize {
        self.t
    }

    /// Live `(cols, vals)` of row `i`, columns ascending.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let lo = i * self.cap;
        let hi = lo + self.len[i] as usize;
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// Point lookup `sim(i, u)`; absent entries read `0.0`.
    #[inline]
    pub fn get(&self, i: usize, u: usize) -> f32 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(u as u32)) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// Column sum `Σ_i sim(i, v)` — the sparse `singleton` closed form.
    #[inline]
    pub fn col_sum(&self, v: usize) -> f64 {
        self.col_sums[v]
    }

    /// Total live entries across all rows.
    pub fn entries(&self) -> usize {
        self.len.iter().map(|&l| l as usize).sum()
    }

    /// Resident heap bytes of the store (slots + lengths + column sums,
    /// plus the LSH bucket index when attached) — the `O(n·t)` footprint
    /// the memory tests and benches assert against the dense `O(n²)`
    /// matrix. The index is included precisely so the ≥4× memory gates
    /// can't be gamed by moving bytes from slots into hash tables.
    pub fn resident_bytes(&self) -> usize {
        self.cols.capacity() * std::mem::size_of::<u32>()
            + self.vals.capacity() * std::mem::size_of::<f32>()
            + self.len.capacity() * std::mem::size_of::<u32>()
            + self.col_sums.capacity() * std::mem::size_of::<f64>()
            + self.lsh.as_ref().map_or(0, |l| l.resident_bytes())
    }

    /// Top-2 scan of row `i` over (present entries ∪ implicit zeros),
    /// replicating the dense strict-`>` promotion scan exactly: `arg1` is
    /// the first ground index attaining the row maximum, `top2` the best
    /// of the rest (duplicates of the max count). Implicit zeros beyond
    /// the first two encountered cannot change the state (`top1 ≥ 0` after
    /// the first, `top2 ≥ 0` after the second), so the scan is `O(len)`.
    pub fn row_top2(&self, i: usize) -> (f32, usize, f32) {
        let (cols, vals) = self.row(i);
        let (mut top1, mut arg1, mut top2) = (f32::NEG_INFINITY, usize::MAX, f32::NEG_INFINITY);
        let mut step = |u: usize, s: f32| {
            if s > top1 {
                top2 = top1;
                top1 = s;
                arg1 = u;
            } else if s > top2 {
                top2 = s;
            }
        };
        let mut u = 0usize;
        let mut zeros = 0u32;
        for (k, &c) in cols.iter().enumerate() {
            let c = c as usize;
            while u < c && zeros < 2 {
                step(u, 0.0);
                zeros += 1;
                u += 1;
            }
            step(c, vals[k]);
            u = c + 1;
        }
        while u < self.n && zeros < 2 {
            step(u, 0.0);
            zeros += 1;
            u += 1;
        }
        (top1, arg1, top2)
    }

    /// Row-border append: element `j = n` arrives with its feature row as
    /// the last row of `feats`. Exact stores scan all live rows
    /// (`O(n·d)`); LSH-built stores hash the new row, probe its buckets,
    /// and touch only candidate rows (`O(tables·bucket·d)`) — both paths
    /// compute `s_i = max(0, cos(x_i, x_j))` feeding the new row's top-`t`
    /// selection and a border-candidate update of each visited existing
    /// row (the new column is the largest index, so accepted candidates
    /// append at the row end). Returns the number of existing-row
    /// neighbor-list updates (the `neighbor_updates` counter).
    pub fn append_row(&mut self, feats: &FeatureMatrix) -> u64 {
        let j = self.n;
        assert_eq!(feats.n(), j + 1, "feats must contain exactly the live rows plus the new one");
        let cap = self.cap;
        self.cols.resize((j + 1) * cap, 0);
        self.vals.resize((j + 1) * cap, 0.0);
        self.len.push(0);
        let xj = feats.row(j);
        let mut sel: Vec<(u32, f32)> = Vec::with_capacity(self.t.min(j) + 1);
        let mut updates = 0u64;
        // take the index out so candidate iteration can borrow-update rows
        match self.lsh.take() {
            None => {
                for i in 0..j {
                    let s = cosine(feats.row(i), xj).max(0.0);
                    if self.row_accept_border(i, j as u32, s) {
                        updates += 1;
                    }
                    topt_push(&mut sel, self.t, i as u32, s);
                }
            }
            Some(mut idx) => {
                let mut stamp = vec![u32::MAX; j];
                let mut cand: Vec<u32> = Vec::new();
                idx.candidates_into(xj, j as u32, &mut stamp, j as u32, &mut cand);
                self.lsh_candidates += cand.len() as u64;
                for &i in &cand {
                    let s = cosine(feats.row(i as usize), xj).max(0.0);
                    if self.row_accept_border(i as usize, j as u32, s) {
                        updates += 1;
                    }
                    topt_push(&mut sel, self.t, i, s);
                }
                if let Some(floor) = self.adapt_floor {
                    adaptive_truncate(&mut sel, floor as usize);
                }
                idx.insert(j as u32, xj);
                self.lsh = Some(idx);
            }
        }
        sel.sort_unstable_by_key(|&(c, _)| c);
        let lo = j * cap;
        for (k, &(c, v)) in sel.iter().enumerate() {
            self.cols[lo + k] = c;
            self.vals[lo + k] = v;
        }
        // pinned diagonal: j is the largest column, so it goes last
        self.cols[lo + sel.len()] = j as u32;
        self.vals[lo + sel.len()] = 1.0;
        self.len[j] = (sel.len() + 1) as u32;
        self.n = j + 1;
        self.recompute_col_sums();
        updates
    }

    /// Candidate-update row `i` with the border column `(c, v)`, where `c`
    /// is strictly larger than every column in the row. Accepts when the
    /// row has a free slot or when `(v, c)` beats the worst non-diagonal
    /// entry under the selection order — the same rule [`topt_push`]
    /// applies at build time, so append-grown rows match fresh builds.
    fn row_accept_border(&mut self, i: usize, c: u32, v: f32) -> bool {
        let cap = self.cap;
        let lo = i * cap;
        let l = self.len[i] as usize;
        debug_assert!(l >= 1, "every row holds at least its diagonal");
        debug_assert!(self.cols[lo + l - 1] < c, "border column must be the largest");
        if l < cap {
            self.cols[lo + l] = c;
            self.vals[lo + l] = v;
            self.len[i] = (l + 1) as u32;
            return true;
        }
        // full: find the worst non-diagonal entry
        let diag = i as u32;
        let mut worst = usize::MAX;
        for k in 0..l {
            if self.cols[lo + k] == diag {
                continue;
            }
            if worst == usize::MAX
                || beats(
                    self.vals[lo + worst],
                    self.cols[lo + worst],
                    self.vals[lo + k],
                    self.cols[lo + k],
                )
            {
                worst = k;
            }
        }
        if worst == usize::MAX {
            return false; // t == 0: nothing but the diagonal is ever stored
        }
        if !beats(v, c, self.vals[lo + worst], self.cols[lo + worst]) {
            return false;
        }
        // drop the worst entry (shift the tail left one slot), append (c, v)
        for k in worst..l - 1 {
            self.cols[lo + k] = self.cols[lo + k + 1];
            self.vals[lo + k] = self.vals[lo + k + 1];
        }
        self.cols[lo + l - 1] = c;
        self.vals[lo + l - 1] = v;
        true
    }

    /// In-place compaction to the surviving elements in `keep` (ascending,
    /// distinct): survivor `keep[i]` becomes row and column `i`; entries
    /// whose column was evicted are dropped (their slots are *not*
    /// refilled — absent reads stay `0.0`, the documented lower bound).
    /// Rows move forward only (`old ≥ new`), so the walk never reads an
    /// overwritten slot.
    pub fn retain(&mut self, keep: &[usize]) {
        let n = self.n;
        let m = keep.len();
        let mut map = vec![GONE; n];
        let mut prev = None;
        for (new, &old) in keep.iter().enumerate() {
            assert!(old < n, "retain index {old} out of range (n={n})");
            assert!(prev.map_or(true, |p| p < old), "retain requires ascending indices");
            prev = Some(old);
            map[old] = new as u32;
        }
        let cap = self.cap;
        for (ni, &oi) in keep.iter().enumerate() {
            let (src, dst) = (oi * cap, ni * cap);
            let l = self.len[oi] as usize;
            let mut w = 0usize;
            for k in 0..l {
                let mapped = map[self.cols[src + k] as usize];
                if mapped != GONE {
                    // ascending columns stay ascending: the map is
                    // monotone on survivors
                    self.cols[dst + w] = mapped;
                    self.vals[dst + w] = self.vals[src + k];
                    w += 1;
                }
            }
            self.len[ni] = w as u32;
        }
        self.len.truncate(m);
        self.cols.truncate(m * cap);
        self.vals.truncate(m * cap);
        // bucket index: survivors keep their features, hence their
        // signatures — only the ids need the same old→new rewrite. The
        // map is monotone on survivors, so bucket vectors stay ascending
        // (what a fresh build of the surviving rows would produce).
        if let Some(idx) = &mut self.lsh {
            for table in &mut idx.buckets {
                for ids in table.values_mut() {
                    let mut w = 0usize;
                    for r in 0..ids.len() {
                        let mapped = map[ids[r] as usize];
                        if mapped != GONE {
                            ids[w] = mapped;
                            w += 1;
                        }
                    }
                    ids.truncate(w);
                }
            }
        }
        self.n = m;
        self.recompute_col_sums();
    }

    /// `(tables, bits)` of the attached LSH index, when present.
    pub fn lsh_params(&self) -> Option<(u32, u32)> {
        self.lsh.as_ref().map(|l| (l.tables, l.bits))
    }

    /// Adaptive-`t` floor this store was built with (auto-`t` LSH builds).
    pub fn adapt_floor(&self) -> Option<usize> {
        self.adapt_floor.map(|f| f as usize)
    }

    /// `(candidate pairs scored so far, largest bucket)` of the attached
    /// LSH index — the sources of the `lsh_candidates` / `lsh_bucket_max`
    /// metrics gauges.
    pub fn lsh_stats(&self) -> Option<(u64, u64)> {
        self.lsh.as_ref().map(|l| {
            let bmax = l
                .buckets
                .iter()
                .flat_map(|m| m.values())
                .map(|v| v.len() as u64)
                .max()
                .unwrap_or(0);
            (self.lsh_candidates, bmax)
        })
    }

    /// Rebuild and attach the LSH index for a store restored via
    /// [`from_parts`](Self::from_parts) (checkpoints persist only the
    /// `(tables, bits, floor)` geometry — signatures are pure per-row
    /// functions of the surviving features, so rehashing reproduces the
    /// exact buckets the uninterrupted session held, and post-recovery
    /// appends probe identically). `feats` must hold exactly the live
    /// rows.
    pub fn attach_lsh(
        &mut self,
        tables: u32,
        bits: u32,
        adapt_floor: Option<usize>,
        feats: &FeatureMatrix,
    ) {
        assert_eq!(feats.n(), self.n, "attach_lsh: features must cover exactly the live rows");
        let mut idx = LshIndex::new(tables.max(1), bits, feats.d);
        for i in 0..self.n {
            idx.insert(i as u32, feats.row(i));
        }
        self.lsh = Some(idx);
        self.adapt_floor = adapt_floor.map(|f| f as u32);
    }

    /// Clone out the complete durable state: `(n, t, len, cols, vals)`.
    /// Neighbor lists are *history* — after an eviction they are not
    /// reproducible from the surviving feature rows (dropped entries are
    /// gone, not refilled) — so checkpoints must carry them verbatim.
    /// `col_sums` is deliberately excluded: it is a pure function of the
    /// lists (see [`from_parts`](Self::from_parts)).
    pub fn export_parts(&self) -> (usize, usize, Vec<u32>, Vec<u32>, Vec<f32>) {
        (self.n, self.t, self.len.clone(), self.cols.clone(), self.vals.clone())
    }

    /// Rebuild from [`export_parts`](Self::export_parts) output,
    /// revalidating the layout invariants (slot bounds, ascending
    /// columns) and recomputing `col_sums` with the exact fold order —
    /// so the restored store is bit-identical to the exported one, and
    /// corrupt checkpoint bytes surface as a typed error, not a panic.
    pub fn from_parts(
        n: usize,
        t: usize,
        len: Vec<u32>,
        cols: Vec<u32>,
        vals: Vec<f32>,
    ) -> Result<Self, String> {
        let cap = t + 1;
        if len.len() != n {
            return Err(format!("sparse store: {} row lengths for n={n}", len.len()));
        }
        if cols.len() != n * cap || vals.len() != n * cap {
            return Err(format!(
                "sparse store: slot arrays {}x{} don't match n*cap={}",
                cols.len(),
                vals.len(),
                n * cap
            ));
        }
        for (i, &l) in len.iter().enumerate() {
            let l = l as usize;
            if l > cap {
                return Err(format!("sparse store: row {i} length {l} exceeds cap {cap}"));
            }
            let lo = i * cap;
            for k in 0..l {
                let c = cols[lo + k];
                if c as usize >= n {
                    return Err(format!("sparse store: row {i} column {c} out of range"));
                }
                if k > 0 && cols[lo + k - 1] >= c {
                    return Err(format!("sparse store: row {i} columns not ascending"));
                }
            }
        }
        let mut store = Self {
            n,
            t,
            cap,
            len,
            cols,
            vals,
            col_sums: Vec::new(),
            lsh: None,
            lsh_candidates: 0,
            adapt_floor: None,
        };
        store.recompute_col_sums();
        Ok(store)
    }

    /// Rebuild the per-column sums with the dense `singleton` fold order:
    /// ascending row index, f64 accumulation (absent entries contribute an
    /// exact `+0.0`, so skipping them preserves the bits).
    fn recompute_col_sums(&mut self) {
        self.col_sums.clear();
        self.col_sums.resize(self.n, 0.0);
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                self.col_sums[c as usize] += v as f64;
            }
        }
    }
}

/// Exact top-`t` of row `i` against rows `0..hi` of `feats` (minus the
/// diagonal, which is appended pinned at `1.0`), sorted by column.
fn row_topt(feats: &FeatureMatrix, i: usize, t: usize, hi: usize) -> Vec<(u32, f32)> {
    let xi = feats.row(i);
    let mut sel: Vec<(u32, f32)> = Vec::with_capacity(t.min(hi));
    for u in 0..hi {
        if u == i {
            continue;
        }
        let s = cosine(xi, feats.row(u)).max(0.0);
        topt_push(&mut sel, t, u as u32, s);
    }
    sel.push((i as u32, 1.0));
    sel.sort_unstable_by_key(|&(c, _)| c);
    sel
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = rng.f32() - 0.3;
            }
        }
        m
    }

    fn dense_sim(f: &FeatureMatrix) -> Vec<f32> {
        let n = f.n();
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            sim[i * n + i] = 1.0;
            for u in 0..n {
                if u != i {
                    sim[i * n + u] = cosine(f.row(i), f.row(u)).max(0.0);
                }
            }
        }
        sim
    }

    #[test]
    fn full_t_reproduces_the_dense_matrix_bitwise() {
        let f = feats(40, 6, 1);
        let dense = dense_sim(&f);
        let s = SparseSimStore::from_features(&f, 39);
        for i in 0..40 {
            for u in 0..40 {
                assert_eq!(
                    s.get(i, u).to_bits(),
                    dense[i * 40 + u].to_bits(),
                    "entry ({i},{u})"
                );
            }
        }
        assert_eq!(s.entries(), 40 * 40);
    }

    #[test]
    fn truncated_rows_keep_the_exact_topt_and_the_diagonal() {
        let f = feats(30, 5, 2);
        let dense = dense_sim(&f);
        let t = 4;
        let s = SparseSimStore::from_features(&f, t);
        for i in 0..30 {
            let (cols, vals) = s.row(i);
            assert!(cols.len() <= t + 1);
            assert!(cols.windows(2).all(|w| w[0] < w[1]), "columns ascending");
            assert!(cols.contains(&(i as u32)), "diagonal pinned");
            // every kept entry matches the dense value bit-for-bit
            for (&c, &v) in cols.iter().zip(vals) {
                assert_eq!(v.to_bits(), dense[i * 30 + c as usize].to_bits());
            }
            // nothing outside the list beats the worst kept non-diag entry
            let kept: Vec<(u32, f32)> = cols
                .iter()
                .zip(vals)
                .filter(|&(&c, _)| c != i as u32)
                .map(|(&c, &v)| (c, v))
                .collect();
            if kept.len() == t {
                let worst =
                    kept.iter().copied().reduce(|a, b| if beats(a.1, a.0, b.1, b.0) { b } else { a });
                let (wc, wv) = worst.unwrap();
                for u in 0..30u32 {
                    if u as usize == i || cols.contains(&u) {
                        continue;
                    }
                    let dv = dense[i * 30 + u as usize];
                    assert!(
                        !beats(dv, u, wv, wc),
                        "excluded ({u}, {dv}) beats kept worst ({wc}, {wv}) in row {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn pooled_build_matches_serial_build() {
        let f = feats(61, 7, 3);
        let serial = SparseSimStore::from_features(&f, 6);
        let pool = ThreadPool::new(3, 16);
        for shards in [1usize, 2, 7, 64] {
            let pooled = SparseSimStore::from_features_pooled(&f, 6, &pool, shards);
            assert_eq!(pooled.len, serial.len, "shards={shards}");
            assert_eq!(pooled.cols, serial.cols);
            assert_eq!(
                pooled.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            for v in 0..61 {
                assert_eq!(pooled.col_sum(v).to_bits(), serial.col_sum(v).to_bits());
            }
        }
    }

    #[test]
    fn append_grown_store_matches_fresh_build() {
        let f = feats(50, 6, 4);
        for t in [3usize, 10, 49] {
            let fresh = SparseSimStore::from_features(&f, t);
            let mut grown = SparseSimStore::from_features(&f.gather(&[0]), t);
            let mut partial = f.gather(&[0]);
            for i in 1..50 {
                partial.push_row(f.row(i));
                grown.append_row(&partial);
            }
            assert_eq!(grown.len, fresh.len, "t={t}");
            assert_eq!(grown.cols, fresh.cols, "t={t}");
            assert_eq!(
                grown.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                fresh.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "t={t}"
            );
            for v in 0..50 {
                assert_eq!(grown.col_sum(v).to_bits(), fresh.col_sum(v).to_bits(), "t={t}");
            }
        }
    }

    #[test]
    fn retain_compacts_columns_and_preserves_survivor_values() {
        let f = feats(35, 5, 5);
        let mut s = SparseSimStore::from_features(&f, 8);
        let before = s.clone();
        let keep: Vec<usize> = (0..35).filter(|i| i % 3 != 1).collect();
        s.retain(&keep);
        assert_eq!(s.n(), keep.len());
        for (ni, &oi) in keep.iter().enumerate() {
            let (cols, vals) = s.row(ni);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
            assert!(cols.contains(&(ni as u32)), "diagonal survives");
            for (&c, &v) in cols.iter().zip(vals) {
                let old_c = keep[c as usize];
                assert_eq!(v.to_bits(), before.get(oi, old_c).to_bits());
            }
            // exactly the surviving columns of the old row remain
            let want: usize = {
                let (ocols, _) = before.row(oi);
                ocols.iter().filter(|&&c| keep.binary_search(&(c as usize)).is_ok()).count()
            };
            assert_eq!(cols.len(), want);
        }
    }

    #[test]
    fn row_top2_matches_a_dense_scan() {
        let f = feats(25, 4, 6);
        let dense = dense_sim(&f);
        for t in [2usize, 6, 24] {
            let s = SparseSimStore::from_features(&f, t);
            for i in 0..25 {
                // dense reference over the store's effective row
                let row: Vec<f32> = (0..25).map(|u| s.get(i, u)).collect();
                let (mut w1, mut wa, mut w2) = (f32::NEG_INFINITY, usize::MAX, f32::NEG_INFINITY);
                for (u, &v) in row.iter().enumerate() {
                    if v > w1 {
                        w2 = w1;
                        w1 = v;
                        wa = u;
                    } else if v > w2 {
                        w2 = v;
                    }
                }
                let (g1, ga, g2) = s.row_top2(i);
                assert_eq!((g1.to_bits(), ga, g2.to_bits()), (w1.to_bits(), wa, w2.to_bits()));
                if t == 24 {
                    // full rows: also the true dense matrix scan
                    let drow = &dense[i * 25..(i + 1) * 25];
                    assert_eq!(g1.to_bits(), drow.iter().fold(f32::MIN, |a, &b| a.max(b)).to_bits());
                }
            }
        }
    }

    fn assert_stores_bit_identical(a: &SparseSimStore, b: &SparseSimStore, tag: &str) {
        assert_eq!(a.n, b.n, "{tag}: n");
        assert_eq!(a.t, b.t, "{tag}: t");
        assert_eq!(a.len, b.len, "{tag}: len");
        assert_eq!(a.cols, b.cols, "{tag}: cols");
        assert_eq!(
            a.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{tag}: vals"
        );
        for v in 0..a.n {
            assert_eq!(a.col_sum(v).to_bits(), b.col_sum(v).to_bits(), "{tag}: col_sum({v})");
        }
    }

    #[test]
    fn saturated_lsh_build_is_bit_identical_to_exact() {
        // bits = 0: every row lands in bucket 0, candidates = all pairs,
        // and the unique-top-t argument forces the exact builder's lists.
        let f = feats(83, 6, 9);
        for t in [0usize, 5, 82] {
            let exact = SparseSimStore::from_features(&f, t);
            let lsh = SparseSimStore::from_features_lsh(&f, t, None, 1, 0);
            assert_stores_bit_identical(&lsh, &exact, &format!("serial t={t}"));
            assert_eq!(lsh.lsh_params(), Some((1, 0)));
            let (cands, bmax) = lsh.lsh_stats().unwrap();
            assert_eq!(cands, 83 * 82, "all pairs scored under saturation");
            assert_eq!(bmax, 83);
            let pool = ThreadPool::new(3, 16);
            for shards in [1usize, 2, 7] {
                let pooled =
                    SparseSimStore::from_features_lsh_pooled(&f, t, None, 1, 0, &pool, shards);
                assert_stores_bit_identical(&pooled, &exact, &format!("t={t} shards={shards}"));
            }
        }
    }

    #[test]
    fn multi_table_lsh_keeps_only_candidate_pairs_and_stays_exact_within_them() {
        let f = feats(70, 5, 10);
        let s = SparseSimStore::from_features_lsh(&f, 8, None, 4, 3);
        let dense = dense_sim(&f);
        // every kept entry is the true similarity, bit-for-bit
        for i in 0..70 {
            let (cols, vals) = s.row(i);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
            assert!(cols.contains(&(i as u32)), "diagonal pinned");
            for (&c, &v) in cols.iter().zip(vals) {
                assert_eq!(v.to_bits(), dense[i * 70 + c as usize].to_bits());
            }
        }
        let (cands, bmax) = s.lsh_stats().unwrap();
        assert!(cands > 0 && cands < 70 * 69, "bucketing pruned the pair space: {cands}");
        assert!(bmax >= 1 && bmax <= 70);
        // the index is priced into the footprint
        let exact = SparseSimStore::from_features(&f, 8);
        assert!(s.resident_bytes() > exact.resident_bytes());
    }

    #[test]
    fn lsh_append_grown_store_matches_fresh_lsh_build() {
        let f = feats(60, 6, 11);
        for (tables, bits) in [(1u32, 0u32), (4, 3), (8, 5)] {
            let t = 7;
            let mut partial = f.gather(&[0]);
            let mut grown = SparseSimStore::from_features_lsh(&partial, t, None, tables, bits);
            for i in 1..60 {
                partial.push_row(f.row(i));
                grown.append_row(&partial);
                if [2usize, 17, 59].contains(&i) {
                    let fresh =
                        SparseSimStore::from_features_lsh(&partial, t, None, tables, bits);
                    assert_stores_bit_identical(
                        &grown,
                        &fresh,
                        &format!("tables={tables} bits={bits} prefix={}", i + 1),
                    );
                }
            }
        }
    }

    #[test]
    fn lsh_retain_then_append_matches_fresh_build_of_survivors() {
        let f = feats(50, 5, 12);
        let (tables, bits, t) = (4u32, 2u32, 6usize);
        let mut s = SparseSimStore::from_features_lsh(&f, t, None, tables, bits);
        let keep: Vec<usize> = (0..50).filter(|i| i % 4 != 1).collect();
        s.retain(&keep);
        // grow past the compaction: appended rows must probe the compacted
        // buckets exactly as a fresh index over the survivors would
        let mut survivors = f.gather(&keep);
        let extra = feats(3, 5, 13);
        for e in 0..3 {
            survivors.push_row(extra.row(e));
            s.append_row(&survivors);
        }
        let fresh = SparseSimStore::from_features_lsh(&survivors, t, None, tables, bits);
        // retain drops evicted *columns* without refilling slots, so row
        // contents can legitimately differ from a fresh build — but the
        // bucket index must not: verify via each appended row's list,
        // whose candidates were generated purely from the compacted index.
        for j in keep.len()..survivors.n() {
            let (gc, gv) = s.row(j);
            let (fc, fv) = fresh.row(j);
            assert_eq!(gc, fc, "appended row {j} columns");
            assert_eq!(
                gv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                fv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "appended row {j} values"
            );
        }
    }

    #[test]
    fn adaptive_truncate_keeps_the_smallest_phi_mass_prefix() {
        // concentrated: one dominant neighbor carries >90% of the mass →
        // shrink to the floor
        let mut sel = vec![(4u32, 0.001f32), (9, 0.9), (2, 0.001), (7, 0.002)];
        adaptive_truncate(&mut sel, 2);
        assert_eq!(sel, vec![(9, 0.9), (7, 0.002)]);
        // flat head, thin tail: the equal-mass head is kept whole (ties
        // broken by ascending column, deterministically), the tail drops
        let mut sel: Vec<(u32, f32)> = (0..8u32).map(|c| (c, 0.5)).collect();
        sel.push((8, 0.1));
        sel.push((9, 0.1));
        adaptive_truncate(&mut sel, 2);
        assert_eq!(sel, (0..8u32).map(|c| (c, 0.5)).collect::<Vec<_>>());
        // at or below the floor: untouched
        let mut sel = vec![(3u32, 0.2f32), (1, 0.7)];
        adaptive_truncate(&mut sel, 2);
        assert_eq!(sel, vec![(3, 0.2), (1, 0.7)]);
    }

    #[test]
    fn adaptive_lsh_append_matches_fresh_adaptive_build() {
        // the adaptive rule is applied per arriving row from the same
        // candidate sets, so append ≡ fresh holds for it too
        let f = feats(40, 5, 14);
        let mut partial = f.gather(&[0]);
        let mut grown = SparseSimStore::from_features_lsh(&partial, 20, Some(3), 1, 0);
        for i in 1..40 {
            partial.push_row(f.row(i));
            grown.append_row(&partial);
        }
        let fresh = SparseSimStore::from_features_lsh(&f, 20, Some(3), 1, 0);
        // appended rows were truncated by the same rule at their arrival;
        // earlier rows may have *grown* since (border accepts fill free
        // slots), so compare the newest row only — and check every row
        // respects the floor ∪ cap envelope.
        let (gc, _) = grown.row(39);
        let (fc, _) = fresh.row(39);
        assert_eq!(gc, fc, "newest row's adaptive selection");
        for i in 0..40 {
            let l = grown.row(i).0.len();
            assert!(l <= 21, "row {i} exceeds cap");
        }
        assert_eq!(grown.adapt_floor(), Some(3));
    }

    #[test]
    fn attach_lsh_reproduces_the_builders_index() {
        let f = feats(45, 6, 15);
        let built = SparseSimStore::from_features_lsh(&f, 5, None, 4, 3);
        let (n, t, len, cols, vals) = built.export_parts();
        let mut restored = SparseSimStore::from_parts(n, t, len, cols, vals).unwrap();
        assert!(restored.lsh_params().is_none(), "parts carry no index");
        restored.attach_lsh(4, 3, None, &f);
        assert_eq!(restored.lsh_params(), Some((4, 3)));
        // identical buckets → identical candidate probes → identical appends
        let mut fa = f.clone();
        let extra = feats(2, 6, 16);
        let mut grown_built = built;
        for e in 0..2 {
            fa.push_row(extra.row(e));
            let u1 = grown_built.append_row(&fa);
            let u2 = restored.append_row(&fa);
            assert_eq!(u1, u2, "update counts diverge after attach");
        }
        assert_stores_bit_identical(&grown_built, &restored, "post-attach appends");
    }

    #[test]
    fn col_sums_track_mutations() {
        let f = feats(20, 4, 7);
        let mut s = SparseSimStore::from_features(&f, 5);
        let check = |s: &SparseSimStore| {
            for v in 0..s.n() {
                let want: f64 = (0..s.n()).map(|i| s.get(i, v) as f64).sum();
                assert_eq!(s.col_sum(v).to_bits(), want.to_bits(), "column {v}");
            }
        };
        check(&s);
        s.retain(&(0..20).filter(|i| i % 4 != 2).collect::<Vec<_>>());
        check(&s);
    }
}
