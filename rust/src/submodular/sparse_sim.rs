//! **Sparse similarity store** — CSR-style per-row top-`t` neighbor lists
//! backing [`FacilityLocation`](super::FacilityLocation) at scale.
//!
//! Lindgren et al., *Leveraging Sparsity for Efficient Submodular Data
//! Summarization* (PAPERS.md), observe that facility location only needs
//! each ground element's strongest few neighbors to preserve greedy
//! quality. This store keeps, per row `i`, at most `t` non-diagonal
//! entries `(u, sim(i, u))` — the exact clamped-cosine top-`t` — plus the
//! pinned diagonal `(i, 1.0)`. Every absent entry reads as `0.0`, which is
//! a *lower bound* on the true (non-negative) similarity, so the induced
//! objective stays monotone submodular and under-approximates the dense
//! one; at `t = n − 1` no entry is absent and the store reproduces the
//! dense matrix bit-for-bit.
//!
//! Layout: fixed-capacity row slots (`cap = t + 1` entries each) in two
//! flat arrays, columns ascending within a row. The slotted layout is what
//! makes the two mutation paths in-place:
//!
//! * **row-border append** ([`append_row`](SparseSimStore::append_row)):
//!   a new element scans the live rows once (`O(n·d)`), simultaneously
//!   selecting its own top-`t` and candidate-updating each existing row's
//!   list (the new column index is the largest, so an accepted candidate
//!   lands at the row's end — no interior shift);
//! * **retain compaction** ([`retain`](SparseSimStore::retain)): an
//!   `IdRemap`-style old→new column rewrite walks surviving rows forward,
//!   dropping entries whose column was evicted.
//!
//! Selection uses the total order *(value descending, column ascending)*,
//! so the top-`t` set of any candidate stream is unique — which is exactly
//! why incremental appends land on the same lists as a fresh batch build
//! (pinned by `rust/tests/sparse_fl_equivalence.rs`).

use crate::util::pool::ThreadPool;
use crate::util::vecmath::{cosine, FeatureMatrix};

/// Sentinel for "column evicted" in the retain rewrite map.
const GONE: u32 = u32::MAX;

/// Per-row top-`t` neighbor lists over clamped-cosine similarities, with a
/// pinned diagonal. See the module docs for the layout and mutation model.
#[derive(Clone, Debug)]
pub struct SparseSimStore {
    n: usize,
    /// max non-diagonal neighbors per row (the `t` of "top-t")
    t: usize,
    /// slot width per row: `t` neighbors + the pinned diagonal
    cap: usize,
    /// live entries per row (`len[i] <= cap`)
    len: Vec<u32>,
    /// column indices, ascending within row slot `[i*cap, i*cap + len[i])`
    cols: Vec<u32>,
    /// values aligned to `cols`
    vals: Vec<f32>,
    /// per-column sums `Σ_i sim(i, v)` (ascending-`i` f64 fold — the exact
    /// add sequence of the dense `singleton` loop), refreshed after every
    /// mutation batch
    col_sums: Vec<f64>,
}

/// `(new, old)` beats `(old_v, old_c)` under the selection total order:
/// value descending, column ascending as the tiebreak.
#[inline]
fn beats(av: f32, ac: u32, bv: f32, bc: u32) -> bool {
    av > bv || (av == bv && ac < bc)
}

/// Candidate-stream top-`t` selection into `sel` (unsorted), maintaining
/// exactly the top-`t` of everything pushed so far under [`beats`].
#[inline]
fn topt_push(sel: &mut Vec<(u32, f32)>, t: usize, c: u32, v: f32) -> bool {
    if sel.len() < t {
        sel.push((c, v));
        return true;
    }
    if t == 0 {
        return false;
    }
    // find the worst live entry (the one every other entry beats)
    let mut worst = 0usize;
    for (k, &(kc, kv)) in sel.iter().enumerate().skip(1) {
        let (wc, wv) = (sel[worst].0, sel[worst].1);
        if beats(wv, wc, kv, kc) {
            worst = k;
        }
    }
    let (wc, wv) = sel[worst];
    if beats(v, c, wv, wc) {
        sel[worst] = (c, v);
        return true;
    }
    false
}

impl SparseSimStore {
    /// Exact top-`t` build over clamped-cosine similarities of `feats`,
    /// serial. Rows with fewer than `t` candidates simply hold them all;
    /// the capacity stays `t` so the store can grow past the initial `n`
    /// by row-border appends.
    pub fn from_features(feats: &FeatureMatrix, t: usize) -> Self {
        Self::build(feats, t, None)
    }

    /// Shard-parallel exact top-`t` build: rows are independent, so each
    /// pool shard fills a disjoint range of them. Bit-identical to the
    /// serial build (per-row work is untouched by the sharding).
    pub fn from_features_pooled(
        feats: &FeatureMatrix,
        t: usize,
        pool: &ThreadPool,
        shards: usize,
    ) -> Self {
        Self::build(feats, t, Some((pool, shards)))
    }

    fn build(feats: &FeatureMatrix, t: usize, pooled: Option<(&ThreadPool, usize)>) -> Self {
        let n = feats.n();
        let cap = t + 1;
        let mut tmp: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n];
        let fill = |lo: usize, _hi: usize, chunk: &mut [Vec<(u32, f32)>]| {
            for (slot, i) in chunk.iter_mut().zip(lo..) {
                *slot = row_topt(feats, i, t, n);
            }
        };
        match pooled {
            Some((pool, shards)) if n > 0 => pool.parallel_ranges_into(&mut tmp[..], shards, fill),
            _ => fill(0, n, &mut tmp[..]),
        }
        let mut store = Self {
            n,
            t,
            cap,
            len: vec![0; n],
            cols: vec![0; n * cap],
            vals: vec![0.0; n * cap],
            col_sums: Vec::new(),
        };
        for (i, row) in tmp.into_iter().enumerate() {
            debug_assert!(row.len() <= cap);
            store.len[i] = row.len() as u32;
            for (k, (c, v)) in row.into_iter().enumerate() {
                store.cols[i * cap + k] = c;
                store.vals[i * cap + k] = v;
            }
        }
        store.recompute_col_sums();
        store
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Max non-diagonal neighbors per row.
    pub fn t(&self) -> usize {
        self.t
    }

    /// Live `(cols, vals)` of row `i`, columns ascending.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let lo = i * self.cap;
        let hi = lo + self.len[i] as usize;
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// Point lookup `sim(i, u)`; absent entries read `0.0`.
    #[inline]
    pub fn get(&self, i: usize, u: usize) -> f32 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(u as u32)) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// Column sum `Σ_i sim(i, v)` — the sparse `singleton` closed form.
    #[inline]
    pub fn col_sum(&self, v: usize) -> f64 {
        self.col_sums[v]
    }

    /// Total live entries across all rows.
    pub fn entries(&self) -> usize {
        self.len.iter().map(|&l| l as usize).sum()
    }

    /// Resident heap bytes of the store (slots + lengths + column sums) —
    /// the `O(n·t)` footprint the memory tests and benches assert against
    /// the dense `O(n²)` matrix.
    pub fn resident_bytes(&self) -> usize {
        self.cols.capacity() * std::mem::size_of::<u32>()
            + self.vals.capacity() * std::mem::size_of::<f32>()
            + self.len.capacity() * std::mem::size_of::<u32>()
            + self.col_sums.capacity() * std::mem::size_of::<f64>()
    }

    /// Top-2 scan of row `i` over (present entries ∪ implicit zeros),
    /// replicating the dense strict-`>` promotion scan exactly: `arg1` is
    /// the first ground index attaining the row maximum, `top2` the best
    /// of the rest (duplicates of the max count). Implicit zeros beyond
    /// the first two encountered cannot change the state (`top1 ≥ 0` after
    /// the first, `top2 ≥ 0` after the second), so the scan is `O(len)`.
    pub fn row_top2(&self, i: usize) -> (f32, usize, f32) {
        let (cols, vals) = self.row(i);
        let (mut top1, mut arg1, mut top2) = (f32::NEG_INFINITY, usize::MAX, f32::NEG_INFINITY);
        let mut step = |u: usize, s: f32| {
            if s > top1 {
                top2 = top1;
                top1 = s;
                arg1 = u;
            } else if s > top2 {
                top2 = s;
            }
        };
        let mut u = 0usize;
        let mut zeros = 0u32;
        for (k, &c) in cols.iter().enumerate() {
            let c = c as usize;
            while u < c && zeros < 2 {
                step(u, 0.0);
                zeros += 1;
                u += 1;
            }
            step(c, vals[k]);
            u = c + 1;
        }
        while u < self.n && zeros < 2 {
            step(u, 0.0);
            zeros += 1;
            u += 1;
        }
        (top1, arg1, top2)
    }

    /// Row-border append: element `j = n` arrives with its feature row as
    /// the last row of `feats`. One pass over the live rows computes
    /// `s_i = max(0, cos(x_i, x_j))`, feeding both the new row's top-`t`
    /// selection and a candidate update of each existing row (the new
    /// column is the largest index, so accepted candidates append at the
    /// row end). Returns the number of existing-row neighbor-list updates
    /// (the `neighbor_updates` counter).
    pub fn append_row(&mut self, feats: &FeatureMatrix) -> u64 {
        let j = self.n;
        assert_eq!(feats.n(), j + 1, "feats must contain exactly the live rows plus the new one");
        let cap = self.cap;
        self.cols.resize((j + 1) * cap, 0);
        self.vals.resize((j + 1) * cap, 0.0);
        self.len.push(0);
        let xj = feats.row(j);
        let mut sel: Vec<(u32, f32)> = Vec::with_capacity(self.t);
        let mut updates = 0u64;
        for i in 0..j {
            let s = cosine(feats.row(i), xj).max(0.0);
            if self.row_accept_border(i, j as u32, s) {
                updates += 1;
            }
            topt_push(&mut sel, self.t, i as u32, s);
        }
        sel.sort_unstable_by_key(|&(c, _)| c);
        let lo = j * cap;
        for (k, &(c, v)) in sel.iter().enumerate() {
            self.cols[lo + k] = c;
            self.vals[lo + k] = v;
        }
        // pinned diagonal: j is the largest column, so it goes last
        self.cols[lo + sel.len()] = j as u32;
        self.vals[lo + sel.len()] = 1.0;
        self.len[j] = (sel.len() + 1) as u32;
        self.n = j + 1;
        self.recompute_col_sums();
        updates
    }

    /// Candidate-update row `i` with the border column `(c, v)`, where `c`
    /// is strictly larger than every column in the row. Accepts when the
    /// row has a free slot or when `(v, c)` beats the worst non-diagonal
    /// entry under the selection order — the same rule [`topt_push`]
    /// applies at build time, so append-grown rows match fresh builds.
    fn row_accept_border(&mut self, i: usize, c: u32, v: f32) -> bool {
        let cap = self.cap;
        let lo = i * cap;
        let l = self.len[i] as usize;
        debug_assert!(l >= 1, "every row holds at least its diagonal");
        debug_assert!(self.cols[lo + l - 1] < c, "border column must be the largest");
        if l < cap {
            self.cols[lo + l] = c;
            self.vals[lo + l] = v;
            self.len[i] = (l + 1) as u32;
            return true;
        }
        // full: find the worst non-diagonal entry
        let diag = i as u32;
        let mut worst = usize::MAX;
        for k in 0..l {
            if self.cols[lo + k] == diag {
                continue;
            }
            if worst == usize::MAX
                || beats(
                    self.vals[lo + worst],
                    self.cols[lo + worst],
                    self.vals[lo + k],
                    self.cols[lo + k],
                )
            {
                worst = k;
            }
        }
        if worst == usize::MAX {
            return false; // t == 0: nothing but the diagonal is ever stored
        }
        if !beats(v, c, self.vals[lo + worst], self.cols[lo + worst]) {
            return false;
        }
        // drop the worst entry (shift the tail left one slot), append (c, v)
        for k in worst..l - 1 {
            self.cols[lo + k] = self.cols[lo + k + 1];
            self.vals[lo + k] = self.vals[lo + k + 1];
        }
        self.cols[lo + l - 1] = c;
        self.vals[lo + l - 1] = v;
        true
    }

    /// In-place compaction to the surviving elements in `keep` (ascending,
    /// distinct): survivor `keep[i]` becomes row and column `i`; entries
    /// whose column was evicted are dropped (their slots are *not*
    /// refilled — absent reads stay `0.0`, the documented lower bound).
    /// Rows move forward only (`old ≥ new`), so the walk never reads an
    /// overwritten slot.
    pub fn retain(&mut self, keep: &[usize]) {
        let n = self.n;
        let m = keep.len();
        let mut map = vec![GONE; n];
        let mut prev = None;
        for (new, &old) in keep.iter().enumerate() {
            assert!(old < n, "retain index {old} out of range (n={n})");
            assert!(prev.map_or(true, |p| p < old), "retain requires ascending indices");
            prev = Some(old);
            map[old] = new as u32;
        }
        let cap = self.cap;
        for (ni, &oi) in keep.iter().enumerate() {
            let (src, dst) = (oi * cap, ni * cap);
            let l = self.len[oi] as usize;
            let mut w = 0usize;
            for k in 0..l {
                let mapped = map[self.cols[src + k] as usize];
                if mapped != GONE {
                    // ascending columns stay ascending: the map is
                    // monotone on survivors
                    self.cols[dst + w] = mapped;
                    self.vals[dst + w] = self.vals[src + k];
                    w += 1;
                }
            }
            self.len[ni] = w as u32;
        }
        self.len.truncate(m);
        self.cols.truncate(m * cap);
        self.vals.truncate(m * cap);
        self.n = m;
        self.recompute_col_sums();
    }

    /// Clone out the complete durable state: `(n, t, len, cols, vals)`.
    /// Neighbor lists are *history* — after an eviction they are not
    /// reproducible from the surviving feature rows (dropped entries are
    /// gone, not refilled) — so checkpoints must carry them verbatim.
    /// `col_sums` is deliberately excluded: it is a pure function of the
    /// lists (see [`from_parts`](Self::from_parts)).
    pub fn export_parts(&self) -> (usize, usize, Vec<u32>, Vec<u32>, Vec<f32>) {
        (self.n, self.t, self.len.clone(), self.cols.clone(), self.vals.clone())
    }

    /// Rebuild from [`export_parts`](Self::export_parts) output,
    /// revalidating the layout invariants (slot bounds, ascending
    /// columns) and recomputing `col_sums` with the exact fold order —
    /// so the restored store is bit-identical to the exported one, and
    /// corrupt checkpoint bytes surface as a typed error, not a panic.
    pub fn from_parts(
        n: usize,
        t: usize,
        len: Vec<u32>,
        cols: Vec<u32>,
        vals: Vec<f32>,
    ) -> Result<Self, String> {
        let cap = t + 1;
        if len.len() != n {
            return Err(format!("sparse store: {} row lengths for n={n}", len.len()));
        }
        if cols.len() != n * cap || vals.len() != n * cap {
            return Err(format!(
                "sparse store: slot arrays {}x{} don't match n*cap={}",
                cols.len(),
                vals.len(),
                n * cap
            ));
        }
        for (i, &l) in len.iter().enumerate() {
            let l = l as usize;
            if l > cap {
                return Err(format!("sparse store: row {i} length {l} exceeds cap {cap}"));
            }
            let lo = i * cap;
            for k in 0..l {
                let c = cols[lo + k];
                if c as usize >= n {
                    return Err(format!("sparse store: row {i} column {c} out of range"));
                }
                if k > 0 && cols[lo + k - 1] >= c {
                    return Err(format!("sparse store: row {i} columns not ascending"));
                }
            }
        }
        let mut store = Self {
            n,
            t,
            cap,
            len,
            cols,
            vals,
            col_sums: Vec::new(),
        };
        store.recompute_col_sums();
        Ok(store)
    }

    /// Rebuild the per-column sums with the dense `singleton` fold order:
    /// ascending row index, f64 accumulation (absent entries contribute an
    /// exact `+0.0`, so skipping them preserves the bits).
    fn recompute_col_sums(&mut self) {
        self.col_sums.clear();
        self.col_sums.resize(self.n, 0.0);
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                self.col_sums[c as usize] += v as f64;
            }
        }
    }
}

/// Exact top-`t` of row `i` against rows `0..hi` of `feats` (minus the
/// diagonal, which is appended pinned at `1.0`), sorted by column.
fn row_topt(feats: &FeatureMatrix, i: usize, t: usize, hi: usize) -> Vec<(u32, f32)> {
    let xi = feats.row(i);
    let mut sel: Vec<(u32, f32)> = Vec::with_capacity(t.min(hi));
    for u in 0..hi {
        if u == i {
            continue;
        }
        let s = cosine(xi, feats.row(u)).max(0.0);
        topt_push(&mut sel, t, u as u32, s);
    }
    sel.push((i as u32, 1.0));
    sel.sort_unstable_by_key(|&(c, _)| c);
    sel
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = rng.f32() - 0.3;
            }
        }
        m
    }

    fn dense_sim(f: &FeatureMatrix) -> Vec<f32> {
        let n = f.n();
        let mut sim = vec![0.0f32; n * n];
        for i in 0..n {
            sim[i * n + i] = 1.0;
            for u in 0..n {
                if u != i {
                    sim[i * n + u] = cosine(f.row(i), f.row(u)).max(0.0);
                }
            }
        }
        sim
    }

    #[test]
    fn full_t_reproduces_the_dense_matrix_bitwise() {
        let f = feats(40, 6, 1);
        let dense = dense_sim(&f);
        let s = SparseSimStore::from_features(&f, 39);
        for i in 0..40 {
            for u in 0..40 {
                assert_eq!(
                    s.get(i, u).to_bits(),
                    dense[i * 40 + u].to_bits(),
                    "entry ({i},{u})"
                );
            }
        }
        assert_eq!(s.entries(), 40 * 40);
    }

    #[test]
    fn truncated_rows_keep_the_exact_topt_and_the_diagonal() {
        let f = feats(30, 5, 2);
        let dense = dense_sim(&f);
        let t = 4;
        let s = SparseSimStore::from_features(&f, t);
        for i in 0..30 {
            let (cols, vals) = s.row(i);
            assert!(cols.len() <= t + 1);
            assert!(cols.windows(2).all(|w| w[0] < w[1]), "columns ascending");
            assert!(cols.contains(&(i as u32)), "diagonal pinned");
            // every kept entry matches the dense value bit-for-bit
            for (&c, &v) in cols.iter().zip(vals) {
                assert_eq!(v.to_bits(), dense[i * 30 + c as usize].to_bits());
            }
            // nothing outside the list beats the worst kept non-diag entry
            let kept: Vec<(u32, f32)> = cols
                .iter()
                .zip(vals)
                .filter(|&(&c, _)| c != i as u32)
                .map(|(&c, &v)| (c, v))
                .collect();
            if kept.len() == t {
                let worst =
                    kept.iter().copied().reduce(|a, b| if beats(a.1, a.0, b.1, b.0) { b } else { a });
                let (wc, wv) = worst.unwrap();
                for u in 0..30u32 {
                    if u as usize == i || cols.contains(&u) {
                        continue;
                    }
                    let dv = dense[i * 30 + u as usize];
                    assert!(
                        !beats(dv, u, wv, wc),
                        "excluded ({u}, {dv}) beats kept worst ({wc}, {wv}) in row {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn pooled_build_matches_serial_build() {
        let f = feats(61, 7, 3);
        let serial = SparseSimStore::from_features(&f, 6);
        let pool = ThreadPool::new(3, 16);
        for shards in [1usize, 2, 7, 64] {
            let pooled = SparseSimStore::from_features_pooled(&f, 6, &pool, shards);
            assert_eq!(pooled.len, serial.len, "shards={shards}");
            assert_eq!(pooled.cols, serial.cols);
            assert_eq!(
                pooled.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            for v in 0..61 {
                assert_eq!(pooled.col_sum(v).to_bits(), serial.col_sum(v).to_bits());
            }
        }
    }

    #[test]
    fn append_grown_store_matches_fresh_build() {
        let f = feats(50, 6, 4);
        for t in [3usize, 10, 49] {
            let fresh = SparseSimStore::from_features(&f, t);
            let mut grown = SparseSimStore::from_features(&f.gather(&[0]), t);
            let mut partial = f.gather(&[0]);
            for i in 1..50 {
                partial.push_row(f.row(i));
                grown.append_row(&partial);
            }
            assert_eq!(grown.len, fresh.len, "t={t}");
            assert_eq!(grown.cols, fresh.cols, "t={t}");
            assert_eq!(
                grown.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                fresh.vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "t={t}"
            );
            for v in 0..50 {
                assert_eq!(grown.col_sum(v).to_bits(), fresh.col_sum(v).to_bits(), "t={t}");
            }
        }
    }

    #[test]
    fn retain_compacts_columns_and_preserves_survivor_values() {
        let f = feats(35, 5, 5);
        let mut s = SparseSimStore::from_features(&f, 8);
        let before = s.clone();
        let keep: Vec<usize> = (0..35).filter(|i| i % 3 != 1).collect();
        s.retain(&keep);
        assert_eq!(s.n(), keep.len());
        for (ni, &oi) in keep.iter().enumerate() {
            let (cols, vals) = s.row(ni);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
            assert!(cols.contains(&(ni as u32)), "diagonal survives");
            for (&c, &v) in cols.iter().zip(vals) {
                let old_c = keep[c as usize];
                assert_eq!(v.to_bits(), before.get(oi, old_c).to_bits());
            }
            // exactly the surviving columns of the old row remain
            let want: usize = {
                let (ocols, _) = before.row(oi);
                ocols.iter().filter(|&&c| keep.binary_search(&(c as usize)).is_ok()).count()
            };
            assert_eq!(cols.len(), want);
        }
    }

    #[test]
    fn row_top2_matches_a_dense_scan() {
        let f = feats(25, 4, 6);
        let dense = dense_sim(&f);
        for t in [2usize, 6, 24] {
            let s = SparseSimStore::from_features(&f, t);
            for i in 0..25 {
                // dense reference over the store's effective row
                let row: Vec<f32> = (0..25).map(|u| s.get(i, u)).collect();
                let (mut w1, mut wa, mut w2) = (f32::NEG_INFINITY, usize::MAX, f32::NEG_INFINITY);
                for (u, &v) in row.iter().enumerate() {
                    if v > w1 {
                        w2 = w1;
                        w1 = v;
                        wa = u;
                    } else if v > w2 {
                        w2 = v;
                    }
                }
                let (g1, ga, g2) = s.row_top2(i);
                assert_eq!((g1.to_bits(), ga, g2.to_bits()), (w1.to_bits(), wa, w2.to_bits()));
                if t == 24 {
                    // full rows: also the true dense matrix scan
                    let drow = &dense[i * 25..(i + 1) * 25];
                    assert_eq!(g1.to_bits(), drow.iter().fold(f32::MIN, |a, &b| a.max(b)).to_bits());
                }
            }
        }
    }

    #[test]
    fn col_sums_track_mutations() {
        let f = feats(20, 4, 7);
        let mut s = SparseSimStore::from_features(&f, 5);
        let check = |s: &SparseSimStore| {
            for v in 0..s.n() {
                let want: f64 = (0..s.n()).map(|i| s.get(i, v) as f64).sum();
                assert_eq!(s.col_sum(v).to_bits(), want.to_bits(), "column {v}");
            }
        };
        check(&s);
        s.retain(&(0..20).filter(|i| i % 4 != 2).collect::<Vec<_>>());
        check(&s);
    }
}
