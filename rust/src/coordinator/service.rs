//! Summarization-as-a-service: the leader/worker deployment shape of SS,
//! redesigned around **one job abstraction**.
//!
//! Every unit of work the service performs — a batch summarize request, a
//! copy-on-snapshot stream summary — is a *job*: it enters the bounded
//! queue (blocking [`submit`] / [`submit_snapshot`], or shedding
//! [`try_submit`] / [`try_submit_snapshot`]), request-worker threads drain
//! it, and the caller tracks it through a typed [`Ticket<T>`] with
//! `wait` / `wait_timeout` / `try_wait` / `cancel` and an optional
//! deadline ([`JobOptions`]). Every fallible call returns one typed
//! [`ServiceError`] — `QueueFull` hands the rejected payload back,
//! `ServiceDown` / `UnknownStream` / `Rejected` are terminal, `Cancelled`
//! / `DeadlineExceeded` report shed work. There is no `anyhow` anywhere on
//! the public surface.
//!
//! **Shedding never burns the pool.** Cancellation and deadlines are
//! checked twice: at dequeue (an expired or cancelled job resolves without
//! touching the compute pool) and between SS rounds (a running job
//! abandons at the next round boundary via the
//! [`sparsify_candidates_with`](crate::algorithms::sparsify_candidates_with)
//! probe). The `cancelled` / `deadline_exceeded` counters meter both.
//!
//! **Streams.** [`open_stream`] / [`append`] front a
//! [`StreamSession`](crate::stream::StreamSession) per stream id, each
//! behind its own lock. Snapshots are **jobs, not calls**:
//! [`submit_snapshot`] clones the bounded retained core under a short
//! lock hold ([`SnapshotCore`](crate::stream::SnapshotCore) — the remap
//! spine isolates external ids from storage, so the clone is
//! self-contained) and runs SS + maximizer on the worker pool while
//! appends keep landing; the summary is bit-identical to an in-place
//! snapshot at the moment of the clone. Closing a stream is a
//! linearization point: appends racing a [`close`] either land before it
//! (and are counted in the returned stats) or observe the closed session
//! and shed `ServiceDown` — never both, never neither.
//!
//! Objectives: batch requests and streams share one
//! [`ObjectiveSpec`](crate::submodular::ObjectiveSpec); [`Objective`]
//! additionally carries pre-materialized payloads (dense similarity
//! matrices, mixtures). PJRT acceleration applies to the feature-based
//! core; other objectives compute on the CPU shard kernels transparently.
//!
//! **Durability.** [`open_stream_durable`] opens a session whose admitted
//! batches and eviction decisions are logged to a write-ahead log on a
//! caller-supplied [`DurableStore`](crate::stream::DurableStore), with
//! periodic checkpoints; [`recover_stream`] rebuilds such a session —
//! bit-identical to the uninterrupted one — from the store after a crash.
//! Checkpoints are jobs too: [`submit_checkpoint`] runs one on the worker
//! pool under a short session-lock hold. A durable session whose store
//! fails (I/O error, checksum mismatch) **quarantines**: every later
//! mutating call reports [`ServiceError::Rejected`] with the original
//! failure, the in-memory state stays readable, and nothing panics. The
//! same quarantine shape covers lock poisoning: if an operation panicked
//! while holding a session's lock, later calls on that stream resolve
//! `Rejected` instead of propagating the panic to unrelated callers.
//!
//! [`submit`]: SummarizationService::submit
//! [`open_stream_durable`]: SummarizationService::open_stream_durable
//! [`recover_stream`]: SummarizationService::recover_stream
//! [`submit_checkpoint`]: SummarizationService::submit_checkpoint
//! [`try_submit`]: SummarizationService::try_submit
//! [`submit_snapshot`]: SummarizationService::submit_snapshot
//! [`try_submit_snapshot`]: SummarizationService::try_submit_snapshot
//! [`open_stream`]: SummarizationService::open_stream
//! [`append`]: SummarizationService::append
//! [`close`]: SummarizationService::close

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::algorithms::{sparsify_traced, GainRoute, Interrupt, MaximizerEngine, SsParams};
use crate::runtime::TiledRuntime;
use crate::trace::{EventKind, Tracer};
use crate::stream::{
    CheckpointInfo, DurabilityConfig, DurableStore, RecoveryReport, SnapshotCore, SnapshotMode,
    StreamAppend, StreamConfig, StreamSession, StreamStats, StreamSummary,
};
use crate::submodular::{
    BatchedDivergence, FacilityLocation, FeatureBased, Mixture, ObjectiveSpec,
};
use crate::util::pool::ThreadPool;
use crate::util::stats::Timer;
use crate::util::vecmath::FeatureMatrix;

use super::job::{job_channel, Responder};
use super::metrics::Metrics;
use super::sharded::{Compute, ShardedBackend};

pub use super::job::{JobOptions, ServiceError, Ticket};

/// Handle to an open streaming session (see
/// [`SummarizationService::open_stream`]).
pub type StreamId = u64;

/// Former error type of the submit-shaped calls — kept one release as an
/// alias (same default type parameter as the old enum) so external call
/// sites migrate mechanically (see the migration table in EXPERIMENTS.md;
/// note `ServiceDown` no longer carries the payload — only backpressure
/// hands it back).
#[deprecated(since = "0.2.0", note = "renamed to `ServiceError`")]
pub type SubmitError<R = SummarizeRequest> = ServiceError<R>;

/// Ring capacity of each stream's flight recorder: enough for the last
/// few windows' worth of spans (WAL flushes, SS rounds, checkpoints) at a
/// fixed ~64 KiB per stream, old events overwritten FIFO.
const FLIGHT_RECORDER_CAP: usize = 1024;

/// Map entry for an open stream: the session plus its row width, kept
/// outside the session lock so input validation can panic (caller bug)
/// *before* the mutex is taken — a poisoned session lock would brick the
/// stream for every later call.
#[derive(Clone)]
struct StreamEntry {
    d: usize,
    /// whether the session's objective requires non-negative features
    /// (feature-based coverage); facility location accepts signed rows
    nonneg: bool,
    session: Arc<Mutex<StreamSession>>,
    /// the session's **flight recorder**: the always-on tracer ring of its
    /// scoped [`Metrics`], held outside the session mutex so the last
    /// events before a failure stay dumpable *after* quarantine — a
    /// poisoned lock (or a quarantined durable store) cannot take the
    /// evidence down with it
    recorder: Arc<Tracer>,
}

/// What to summarize: the objective payload of a [`SummarizeRequest`].
pub enum Objective {
    /// Feature-based concave-over-modular (√ scalarizer) over hashed item
    /// features — the paper's news objective; PJRT-accelerable. For other
    /// scalarizers use [`Objective::from_rows`] with
    /// [`ObjectiveSpec::Features`].
    Features(FeatureMatrix),
    /// Facility location over a dense similarity matrix — video-style
    /// representativeness; computed on the blocked CPU kernel.
    FacilityLocation(FacilityLocation),
    /// Weighted mixture of objectives (coverage vs diversity trade-offs).
    Mixture(Mixture),
    /// Spec + rows — the unified form shared with streaming sessions:
    /// exactly the objective a stream opened with the same spec maintains
    /// over the same rows (bit-identical by the stream-equivalence suite).
    Spec { spec: ObjectiveSpec, rows: FeatureMatrix },
}

impl Objective {
    /// Pair an [`ObjectiveSpec`] (the type streams open with) with a
    /// materialized row matrix — the one construction both front-ends
    /// share.
    pub fn from_rows(spec: ObjectiveSpec, rows: FeatureMatrix) -> Self {
        Objective::Spec { spec, rows }
    }

    /// Ground-set size |V|.
    pub fn n(&self) -> usize {
        match self {
            Objective::Features(feats) => feats.n(),
            Objective::FacilityLocation(fl) => fl.n(),
            Objective::Mixture(m) => m.n(),
            Objective::Spec { rows, .. } => rows.n(),
        }
    }

    /// Materialize the objective handle the pipeline runs on.
    fn into_fn(self) -> Arc<dyn BatchedDivergence> {
        match self {
            Objective::Features(feats) => Arc::new(FeatureBased::sqrt(feats)),
            Objective::FacilityLocation(fl) => Arc::new(fl),
            Objective::Mixture(m) => Arc::new(m),
            Objective::Spec { spec, rows } => spec.build(rows),
        }
    }
}

pub struct SummarizeRequest {
    pub objective: Objective,
    /// summary budget
    pub k: usize,
    pub params: SsParams,
    /// route divergence batches through PJRT (requires service started with
    /// a runtime; only accelerates feature-based objectives — others fall
    /// back to CPU shards)
    pub use_pjrt: bool,
}

impl SummarizeRequest {
    /// News-style request: feature-based objective over `feats`.
    pub fn features(feats: FeatureMatrix, k: usize, params: SsParams) -> Self {
        Self { objective: Objective::Features(feats), k, params, use_pjrt: false }
    }

    /// Spec-form request — see [`Objective::from_rows`].
    pub fn from_rows(spec: ObjectiveSpec, rows: FeatureMatrix, k: usize, params: SsParams) -> Self {
        Self { objective: Objective::from_rows(spec, rows), k, params, use_pjrt: false }
    }

    pub fn with_pjrt(mut self, use_pjrt: bool) -> Self {
        self.use_pjrt = use_pjrt;
        self
    }
}

#[derive(Clone, Debug)]
pub struct SummarizeResponse {
    pub summary: Vec<usize>,
    pub value: f64,
    /// |V| in
    pub n: usize,
    /// |V'| after SS
    pub reduced: usize,
    pub ss_rounds: usize,
    /// end-to-end latency including queueing
    pub latency_s: f64,
    /// time spent queued before a worker picked it up
    pub queue_s: f64,
}

/// A shard-local SS pass: prune `rows` under `spec` and return the
/// surviving *local* indices — no maximizer. This is the worker half of
/// the cluster's two-round scheme (shard → prune → union survivors →
/// finish centrally); the coordinator maps the survivors back to global
/// ids and runs the final SS + maximizer itself.
pub struct PruneRequest {
    pub spec: ObjectiveSpec,
    pub rows: FeatureMatrix,
    pub params: SsParams,
    /// Shard index, carried only for the `ShardPrune` trace span.
    pub shard: u64,
}

#[derive(Clone, Debug)]
pub struct PruneResponse {
    /// Surviving indices, local to the request's rows, ascending.
    pub kept: Vec<usize>,
    pub rounds: usize,
    /// Shard size in.
    pub n: usize,
}

/// One queued unit of work. Both kinds carry their enqueue timestamp (for
/// `queue_wait`) and the responder whose `Drop` guarantees the ticket
/// resolves even if the job never runs (shutdown tear-down, worker panic).
enum Job {
    Summarize {
        req: SummarizeRequest,
        enqueued: Timer,
        responder: Responder<SummarizeResponse>,
    },
    /// Shard prune for the cluster path — SS only, no maximizer.
    Prune {
        req: PruneRequest,
        enqueued: Timer,
        responder: Responder<PruneResponse>,
    },
    Snapshot {
        core: Arc<SnapshotCore>,
        mode: SnapshotMode,
        enqueued: Timer,
        responder: Responder<StreamSummary>,
    },
    /// Write a durable session's checkpoint on the worker pool — the lock
    /// hold is short (encode + one atomic store write), but the caller
    /// keeps ticket semantics (deadline, cancel-at-dequeue) for free.
    Checkpoint {
        session: Arc<Mutex<StreamSession>>,
        recorder: Arc<Tracer>,
        enqueued: Timer,
        responder: Responder<CheckpointInfo>,
    },
    /// Dump a stream's flight recorder. Deliberately touches **only** the
    /// recorder handle — never the session mutex — so it succeeds on a
    /// quarantined (even lock-poisoned) stream, which is exactly when the
    /// dump matters.
    FlightDump {
        recorder: Arc<Tracer>,
        enqueued: Timer,
        responder: Responder<crate::util::json::Json>,
    },
}

/// Take a session's lock, mapping poisoning — some earlier operation
/// panicked while holding it — to a typed, non-retryable rejection
/// instead of propagating the panic into an unrelated caller. The
/// in-memory session behind a poisoned lock is suspect; quarantining the
/// stream (every later call resolves `Rejected`) matches what a durable
/// session does on a failed store. Each poisoned acquisition drops a
/// [`EventKind::Quarantine`] marker on the stream's flight recorder,
/// which stays dumpable ([`SummarizationService::submit_flight_dump`])
/// because the recorder lives outside the mutex.
fn lock_session<'a>(
    session: &'a Mutex<StreamSession>,
    recorder: &Tracer,
) -> Result<std::sync::MutexGuard<'a, StreamSession>, ServiceError> {
    session.lock().map_err(|_| {
        recorder.record_now(EventKind::Quarantine, 0, 0, 0, 0);
        ServiceError::Rejected {
            reason: "stream quarantined: an operation panicked while holding its session lock"
                .into(),
        }
    })
}

#[derive(Clone)]
pub struct ServiceConfig {
    /// request-worker threads
    pub workers: usize,
    /// bounded request-queue depth (backpressure point)
    pub queue_depth: usize,
    /// compute-pool threads shared by all requests' SS rounds
    pub compute_threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { workers: 2, queue_depth: 32, compute_threads: 2 }
    }
}

pub struct SummarizationService {
    tx: SyncSender<Job>,
    metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    /// compute pool shared by request workers and streaming sessions
    pool: Arc<ThreadPool>,
    /// open streaming sessions; each behind its own lock so sessions
    /// don't serialize against each other
    streams: Mutex<HashMap<StreamId, StreamEntry>>,
    next_stream: AtomicU64,
    /// set by shutdown: streaming calls fail fast afterwards
    down: AtomicBool,
}

impl SummarizationService {
    pub fn start(config: ServiceConfig, runtime: Option<Arc<TiledRuntime>>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Job>(config.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let pool = Arc::new(ThreadPool::new(config.compute_threads.max(1), 64));
        let workers = (0..config.workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let metrics = Arc::clone(&metrics);
                let pool = Arc::clone(&pool);
                let runtime = runtime.clone();
                std::thread::Builder::new()
                    .name(format!("ss-svc-{i}"))
                    .spawn(move || worker_main(&rx, &metrics, &pool, runtime.as_ref()))
                    .expect("spawn service worker")
            })
            .collect();
        Self {
            tx,
            metrics,
            workers,
            pool,
            streams: Mutex::new(HashMap::new()),
            next_stream: AtomicU64::new(0),
            down: AtomicBool::new(false),
        }
    }

    /// Blocking submit (backpressure) with default [`JobOptions`]. After
    /// [`Self::shutdown`] the ticket resolves
    /// [`ServiceError::ServiceDown`] instead of blocking or panicking.
    pub fn submit(&self, req: SummarizeRequest) -> Ticket<SummarizeResponse> {
        self.submit_with(req, JobOptions::default())
    }

    /// [`submit`](Self::submit) with per-job options (deadline).
    pub fn submit_with(&self, req: SummarizeRequest, opts: JobOptions) -> Ticket<SummarizeResponse> {
        let (ticket, responder) = job_channel(opts);
        let job = Job::Summarize { req, enqueued: Timer::new(), responder };
        if self.tx.send(job).is_ok() {
            self.metrics.add(&self.metrics.counters.requests, 1);
        }
        // on send failure the job (and its responder) was dropped with the
        // SendError, which already resolved the ticket ServiceDown
        ticket
    }

    /// Non-blocking submit with default [`JobOptions`].
    /// [`ServiceError::QueueFull`] = shed load, request handed back, retry
    /// later; [`ServiceError::ServiceDown`] = the workers are gone and no
    /// retry against this instance can succeed.
    pub fn try_submit(
        &self,
        req: SummarizeRequest,
    ) -> Result<Ticket<SummarizeResponse>, ServiceError<SummarizeRequest>> {
        self.try_submit_with(req, JobOptions::default())
    }

    /// [`try_submit`](Self::try_submit) with per-job options (deadline).
    pub fn try_submit_with(
        &self,
        req: SummarizeRequest,
        opts: JobOptions,
    ) -> Result<Ticket<SummarizeResponse>, ServiceError<SummarizeRequest>> {
        let (ticket, responder) = job_channel(opts);
        match self.tx.try_send(Job::Summarize { req, enqueued: Timer::new(), responder }) {
            Ok(()) => {
                self.metrics.add(&self.metrics.counters.requests, 1);
                Ok(ticket)
            }
            Err(TrySendError::Full(Job::Summarize { req, .. })) => {
                Err(ServiceError::QueueFull(req))
            }
            Err(TrySendError::Disconnected(_)) => Err(ServiceError::ServiceDown),
            Err(TrySendError::Full(_)) => {
                unreachable!("a rejected summarize send returns the summarize job")
            }
        }
    }

    /// Submit a shard-local SS prune (see [`PruneRequest`]) with default
    /// options. Same ticket semantics as [`submit`](Self::submit).
    pub fn submit_prune(&self, req: PruneRequest) -> Ticket<PruneResponse> {
        self.submit_prune_with(req, JobOptions::default())
    }

    /// [`submit_prune`](Self::submit_prune) with per-job options.
    pub fn submit_prune_with(&self, req: PruneRequest, opts: JobOptions) -> Ticket<PruneResponse> {
        let (ticket, responder) = job_channel(opts);
        let job = Job::Prune { req, enqueued: Timer::new(), responder };
        if self.tx.send(job).is_ok() {
            self.metrics.add(&self.metrics.counters.requests, 1);
        }
        ticket
    }

    /// Per-stream observability scope: a [`Metrics`] labeled `stream-{id}`
    /// whose tracer is enabled from birth as the stream's flight recorder
    /// (bounded ring, [`FLIGHT_RECORDER_CAP`] events, oldest overwritten).
    fn stream_scope(id: StreamId) -> Arc<Metrics> {
        let label = format!("stream-{id}");
        let metrics = Arc::new(Metrics::scoped(&label));
        metrics.tracer().enable(&label, FLIGHT_RECORDER_CAP);
        metrics
    }

    /// Open a streaming session: append-only ingestion with sieve
    /// admission and windowed re-sparsification (see
    /// [`crate::stream::StreamSession`]). The session runs on the
    /// service's compute pool with its own [`Metrics`] scope (labeled
    /// `stream-{id}`, flight recorder armed); the stream counters are
    /// mirrored onto the service-wide metrics so dashboards see every
    /// session's traffic in one place.
    pub fn open_stream(
        &self,
        objective: ObjectiveSpec,
        d: usize,
        cfg: StreamConfig,
    ) -> Result<StreamId, ServiceError> {
        if self.down.load(Ordering::SeqCst) {
            return Err(ServiceError::ServiceDown);
        }
        let id = self.next_stream.fetch_add(1, Ordering::Relaxed);
        let metrics = Self::stream_scope(id);
        let recorder = Arc::clone(metrics.tracer());
        let session =
            StreamSession::new(objective, d, cfg, Arc::clone(&self.pool), metrics)?;
        let nonneg = objective.needs_nonneg();
        self.streams.lock().unwrap_or_else(|e| e.into_inner()).insert(
            id,
            StreamEntry { d, nonneg, session: Arc::new(Mutex::new(session)), recorder },
        );
        Ok(id)
    }

    /// [`open_stream`](Self::open_stream) with durability: every admitted
    /// batch is logged to `store`'s write-ahead log **before** the session
    /// mutates, eviction decisions are logged after each re-sparsification,
    /// and a checkpoint is written at open (and then every
    /// [`DurabilityConfig::checkpoint_interval`] logged records). A session
    /// crashed mid-stream is rebuilt — bit-identical — by
    /// [`recover_stream`](Self::recover_stream) over the same store.
    pub fn open_stream_durable(
        &self,
        objective: ObjectiveSpec,
        d: usize,
        cfg: StreamConfig,
        store: Box<dyn DurableStore>,
        dcfg: DurabilityConfig,
    ) -> Result<StreamId, ServiceError> {
        if self.down.load(Ordering::SeqCst) {
            return Err(ServiceError::ServiceDown);
        }
        let id = self.next_stream.fetch_add(1, Ordering::Relaxed);
        let metrics = Self::stream_scope(id);
        let recorder = Arc::clone(metrics.tracer());
        let session = StreamSession::open_durable(
            objective,
            d,
            cfg,
            Arc::clone(&self.pool),
            metrics,
            store,
            dcfg,
        )?;
        self.metrics.add(&self.metrics.counters.checkpoints, 1); // the open checkpoint
        let nonneg = objective.needs_nonneg();
        self.streams.lock().unwrap_or_else(|e| e.into_inner()).insert(
            id,
            StreamEntry { d, nonneg, session: Arc::new(Mutex::new(session)), recorder },
        );
        Ok(id)
    }

    /// Rebuild a crashed durable session from its store — checkpoint +
    /// WAL-tail replay, bit-identical to the uninterrupted session (ids,
    /// retained rows, sieve state, snapshot values) — and mount it under a
    /// fresh stream id. Torn tails are truncated; a checksum-corrupt
    /// record or checkpoint reports [`ServiceError::Rejected`] (never a
    /// panic). Returns the id plus what recovery found and replayed.
    pub fn recover_stream(
        &self,
        store: Box<dyn DurableStore>,
        dcfg: DurabilityConfig,
    ) -> Result<(StreamId, RecoveryReport), ServiceError> {
        if self.down.load(Ordering::SeqCst) {
            return Err(ServiceError::ServiceDown);
        }
        let id = self.next_stream.fetch_add(1, Ordering::Relaxed);
        let metrics = Self::stream_scope(id);
        let recorder = Arc::clone(metrics.tracer());
        let (session, report) =
            StreamSession::recover_with_report(Arc::clone(&self.pool), metrics, store, dcfg)?;
        self.metrics.add(&self.metrics.counters.recoveries, 1);
        self.metrics
            .add(&self.metrics.counters.torn_tail_truncations, report.torn_tail_truncations);
        let d = session.d();
        let nonneg = session.needs_nonneg();
        self.streams.lock().unwrap_or_else(|e| e.into_inner()).insert(
            id,
            StreamEntry { d, nonneg, session: Arc::new(Mutex::new(session)), recorder },
        );
        Ok((id, report))
    }

    /// Append a batch of rows to an open stream. Backpressure surfaces as
    /// [`ServiceError::QueueFull`] (session live-set cap; recover by
    /// splitting into smaller batches — eviction only happens through
    /// windowed re-sparsification, which an over-cap retained core can no
    /// longer trigger). An id that was never opened (or whose stream is
    /// closed) reports [`ServiceError::UnknownStream`], a shut-down
    /// service [`ServiceError::ServiceDown`] — and an append racing a
    /// [`close`](Self::close) that observes the already-closed session
    /// sheds [`ServiceError::ServiceDown`] too (the session itself is
    /// gone, retrying the id cannot succeed). A misaligned or
    /// invalid-valued batch is a caller bug and panics **before** the
    /// session lock is taken, so it cannot poison the stream.
    pub fn append(&self, id: StreamId, rows: &[f32]) -> Result<StreamAppend, ServiceError<()>> {
        let Some(entry) = self.stream(id) else {
            return Err(self.gone(id));
        };
        // one validation scan, before the lock — a caller-bug panic here
        // cannot poison the session mutex, and the O(n·d) scan stays out
        // of the critical section
        StreamSession::validate_batch(rows, entry.d, entry.nonneg);
        let mut session = lock_session(&entry.session, &entry.recorder)?;
        // mirror the session-scoped counters service-wide by delta, so
        // work done on error paths (a forced re-sparsification before a
        // QueueFull shed evicts elements and runs SS rounds) is accounted
        // identically in both scopes
        let snap = |s: &StreamSession| {
            let c = &s.metrics().counters;
            (
                c.wal_appends.load(Ordering::Relaxed),
                c.checkpoints.load(Ordering::Relaxed),
            )
        };
        let before = session.stats();
        let (wal_before, ckpt_before) = snap(&session);
        let result = session.append_prevalidated(rows);
        let after = session.stats();
        let (wal_after, ckpt_after) = snap(&session);
        drop(session);
        self.metrics.add(&self.metrics.counters.stream_appends, after.appends - before.appends);
        self.metrics
            .add(&self.metrics.counters.stream_admitted, after.admitted - before.admitted);
        self.metrics
            .add(&self.metrics.counters.resparsify_rounds, after.ss_rounds - before.ss_rounds);
        self.metrics
            .add(&self.metrics.counters.evicted_elements, after.evicted - before.evicted);
        // durable-session traffic (WAL records, auto-interval checkpoints)
        self.metrics.add(&self.metrics.counters.wal_appends, wal_after - wal_before);
        self.metrics.add(&self.metrics.counters.checkpoints, ckpt_after - ckpt_before);
        result
    }

    /// Submit a snapshot **job** with default [`JobOptions`]: clone the
    /// stream's bounded retained core under a short lock hold and run the
    /// summary ([`SnapshotMode::Intermediate`] = cheap stochastic-greedy
    /// refresh, [`SnapshotMode::Final`] = exact batch-equivalent
    /// `sparsify → lazy greedy`) on the worker pool — appends keep landing
    /// on the session while the job runs, and the summary reflects the
    /// stream exactly as of this call. Blocks only for queue space.
    pub fn submit_snapshot(
        &self,
        id: StreamId,
        mode: SnapshotMode,
    ) -> Result<Ticket<StreamSummary>, ServiceError> {
        self.submit_snapshot_with(id, mode, JobOptions::default())
    }

    /// [`submit_snapshot`](Self::submit_snapshot) with per-job options
    /// (deadline).
    pub fn submit_snapshot_with(
        &self,
        id: StreamId,
        mode: SnapshotMode,
        opts: JobOptions,
    ) -> Result<Ticket<StreamSummary>, ServiceError> {
        let core = self.clone_core(id)?;
        let (ticket, responder) = job_channel(opts);
        let job = Job::Snapshot { core, mode, enqueued: Timer::new(), responder };
        if self.tx.send(job).is_ok() {
            self.metrics.add(&self.metrics.counters.snapshot_jobs, 1);
        }
        // send failure dropped the responder → ticket reads ServiceDown
        Ok(ticket)
    }

    /// Non-blocking [`submit_snapshot`](Self::submit_snapshot) with
    /// default [`JobOptions`]: [`ServiceError::QueueFull`] sheds the job
    /// (the cloned core is dropped — re-cloning on retry is cheap and
    /// picks up newer appends).
    pub fn try_submit_snapshot(
        &self,
        id: StreamId,
        mode: SnapshotMode,
    ) -> Result<Ticket<StreamSummary>, ServiceError> {
        self.try_submit_snapshot_with(id, mode, JobOptions::default())
    }

    /// [`try_submit_snapshot`](Self::try_submit_snapshot) with per-job
    /// options (deadline).
    pub fn try_submit_snapshot_with(
        &self,
        id: StreamId,
        mode: SnapshotMode,
        opts: JobOptions,
    ) -> Result<Ticket<StreamSummary>, ServiceError> {
        let core = self.clone_core(id)?;
        let (ticket, responder) = job_channel(opts);
        match self.tx.try_send(Job::Snapshot { core, mode, enqueued: Timer::new(), responder }) {
            Ok(()) => {
                self.metrics.add(&self.metrics.counters.snapshot_jobs, 1);
                Ok(ticket)
            }
            Err(TrySendError::Full(_)) => Err(ServiceError::QueueFull(())),
            Err(TrySendError::Disconnected(_)) => Err(ServiceError::ServiceDown),
        }
    }

    /// Copy-on-snapshot: resolve the stream and clone its core under a
    /// short session-lock hold (O(live·d) — the facility-location O(m²·d)
    /// similarity build happens inside the job, not here).
    fn clone_core(&self, id: StreamId) -> Result<Arc<SnapshotCore>, ServiceError> {
        let entry = self.stream(id).ok_or_else(|| self.gone::<()>(id))?;
        let core = lock_session(&entry.session, &entry.recorder)?.snapshot_core()?;
        Ok(core)
    }

    /// Submit a checkpoint **job** for a durable stream with default
    /// [`JobOptions`]: the worker encodes the session's full recoverable
    /// state under a short lock hold, writes it atomically to the durable
    /// store, and truncates the WAL it covers. The ticket resolves with
    /// the covered WAL sequence and blob size. Streams opened without a
    /// store resolve [`ServiceError::Rejected`].
    pub fn submit_checkpoint(&self, id: StreamId) -> Result<Ticket<CheckpointInfo>, ServiceError> {
        self.submit_checkpoint_with(id, JobOptions::default())
    }

    /// [`submit_checkpoint`](Self::submit_checkpoint) with per-job options
    /// (deadline).
    pub fn submit_checkpoint_with(
        &self,
        id: StreamId,
        opts: JobOptions,
    ) -> Result<Ticket<CheckpointInfo>, ServiceError> {
        let entry = self.stream(id).ok_or_else(|| self.gone::<()>(id))?;
        let (ticket, responder) = job_channel(opts);
        let job = Job::Checkpoint {
            session: Arc::clone(&entry.session),
            recorder: Arc::clone(&entry.recorder),
            enqueued: Timer::new(),
            responder,
        };
        let _ = self.tx.send(job);
        // send failure dropped the responder → ticket reads ServiceDown
        Ok(ticket)
    }

    /// Submit a **flight-recorder dump** job with default [`JobOptions`]:
    /// fetch the stream's last [`FLIGHT_RECORDER_CAP`] trace events (SS
    /// rounds with shrink accounting, WAL flushes, checkpoints, windows,
    /// quarantine markers) as a self-describing JSON document — see
    /// [`crate::trace::export::flight_dump`] for the shape. The job reads
    /// only the recorder ring, **never the session lock**, so it works on
    /// a quarantined stream — poisoned lock or failed durable store — and
    /// that post-mortem read is the recorder's whole reason to exist.
    /// Closing the stream discards the recorder with the map entry.
    pub fn submit_flight_dump(
        &self,
        id: StreamId,
    ) -> Result<Ticket<crate::util::json::Json>, ServiceError> {
        self.submit_flight_dump_with(id, JobOptions::default())
    }

    /// [`submit_flight_dump`](Self::submit_flight_dump) with per-job
    /// options (deadline).
    pub fn submit_flight_dump_with(
        &self,
        id: StreamId,
        opts: JobOptions,
    ) -> Result<Ticket<crate::util::json::Json>, ServiceError> {
        let entry = self.stream(id).ok_or_else(|| self.gone::<()>(id))?;
        let (ticket, responder) = job_channel(opts);
        let job = Job::FlightDump {
            recorder: Arc::clone(&entry.recorder),
            enqueued: Timer::new(),
            responder,
        };
        let _ = self.tx.send(job);
        // send failure dropped the responder → ticket reads ServiceDown
        Ok(ticket)
    }

    /// One-release compat shim for the pre-job API: submit a snapshot job
    /// and block on its ticket. Prefer
    /// [`submit_snapshot`](Self::submit_snapshot) — it returns the ticket,
    /// so the caller keeps cancel/deadline/timeout control.
    #[deprecated(
        since = "0.2.0",
        note = "snapshots are jobs now: `submit_snapshot(id, mode)?.wait()`"
    )]
    pub fn snapshot_summary(
        &self,
        id: StreamId,
        mode: SnapshotMode,
    ) -> Result<StreamSummary, ServiceError> {
        self.submit_snapshot(id, mode)?.wait()
    }

    /// Per-session metrics snapshot (the session-scoped counters —
    /// divergence/gain evals of its windows, its stream counters).
    pub fn stream_metrics(&self, id: StreamId) -> Result<crate::util::json::Json, ServiceError> {
        let entry = self.stream(id).ok_or_else(|| self.gone::<()>(id))?;
        let s = lock_session(&entry.session, &entry.recorder)?;
        Ok(s.metrics().snapshot())
    }

    /// Close a stream and drop its storage, returning lifetime stats.
    ///
    /// This is a linearization point for the stream: the map entry is
    /// removed first (no *new* caller can reach the session), then the
    /// session is closed **under its own lock** — an in-flight append that
    /// cloned the entry earlier either acquired that lock before us (its
    /// rows land and are counted in the stats returned here) or acquires
    /// it after, observes the closed session, and sheds
    /// [`ServiceError::ServiceDown`]. No append can land after `close`
    /// returns. Snapshot jobs already queued keep their cloned cores and
    /// complete normally — they describe the stream as of their submit.
    pub fn close(&self, id: StreamId) -> Result<StreamStats, ServiceError> {
        let entry = self
            .streams
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(&id)
            .ok_or_else(|| self.gone::<()>(id))?;
        // a quarantined (lock-poisoned) session can't deliver stats; the
        // entry is removed either way — its storage drops with the Arc
        let stats = lock_session(&entry.session, &entry.recorder)?.close();
        Ok(stats)
    }

    fn stream(&self, id: StreamId) -> Option<StreamEntry> {
        self.streams.lock().unwrap_or_else(|e| e.into_inner()).get(&id).cloned()
    }

    /// Why an id failed to resolve: a shut-down service wins over (and
    /// explains) the emptied stream map.
    fn gone<R>(&self, id: StreamId) -> ServiceError<R> {
        if self.down.load(Ordering::SeqCst) {
            ServiceError::ServiceDown
        } else {
            ServiceError::UnknownStream(id)
        }
    }

    /// Graceful shutdown: close the queue (already-accepted jobs still
    /// complete), then join the workers; open streaming sessions are
    /// closed and dropped. Afterwards submits report
    /// [`ServiceError::ServiceDown`] (tickets from racing blocking submits
    /// resolve to the same) and stream calls fail fast. Called by `Drop`;
    /// idempotent.
    pub fn shutdown(&mut self) {
        self.down.store(true, Ordering::SeqCst);
        for (_, entry) in self.streams.lock().unwrap_or_else(|e| e.into_inner()).drain() {
            // a poisoned session is dropped as-is (close would re-panic the
            // shutdown path for state some other panic already abandoned)
            if let Ok(mut session) = entry.session.lock() {
                session.close();
            }
        }
        let (dead_tx, _) = sync_channel(1);
        let _ = std::mem::replace(&mut self.tx, dead_tx);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_json(&self) -> String {
        self.metrics.snapshot().pretty()
    }
}

impl Drop for SummarizationService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_main(
    rx: &Mutex<Receiver<Job>>,
    metrics: &Arc<Metrics>,
    pool: &Arc<ThreadPool>,
    runtime: Option<&Arc<TiledRuntime>>,
) {
    loop {
        let job = {
            let rx = rx.lock().unwrap();
            rx.recv()
        };
        let Ok(job) = job else { return };
        match job {
            Job::Summarize { req, enqueued, responder } => {
                let queue_s = enqueued.elapsed_s();
                metrics.queue_wait.record_secs(queue_s);
                // dequeue check: cancelled/expired work is shed without
                // touching the compute pool (or even materializing the
                // objective)
                if let Some(why) = responder.interrupt() {
                    let e = ServiceError::from(why);
                    meter_error(metrics, &e);
                    responder.resolve(Err(e));
                    continue;
                }
                let result =
                    handle(req, queue_s, metrics, pool, runtime, &mut || responder.interrupt());
                match &result {
                    Ok(resp) => {
                        metrics.add(&metrics.counters.completed, 1);
                        metrics.request_latency.record_secs(resp.latency_s);
                    }
                    Err(e) => meter_error(metrics, e),
                }
                responder.resolve(result);
            }
            Job::Prune { req, enqueued, responder } => {
                metrics.queue_wait.record_secs(enqueued.elapsed_s());
                if let Some(why) = responder.interrupt() {
                    let e = ServiceError::from(why);
                    meter_error(metrics, &e);
                    responder.resolve(Err(e));
                    continue;
                }
                let result = handle_prune(req, metrics, pool, &mut || responder.interrupt());
                match &result {
                    Ok(_) => metrics.add(&metrics.counters.completed, 1),
                    Err(e) => meter_error(metrics, e),
                }
                responder.resolve(result);
            }
            Job::Snapshot { core, mode, enqueued, responder } => {
                metrics.queue_wait.record_secs(enqueued.elapsed_s());
                if let Some(why) = responder.interrupt() {
                    let e = ServiceError::from(why);
                    meter_error(metrics, &e);
                    responder.resolve(Err(e));
                    continue;
                }
                let result = core
                    .run(mode, &mut || responder.interrupt())
                    .map_err(ServiceError::from);
                match &result {
                    Ok(_) => metrics.add(&metrics.counters.completed, 1),
                    Err(e) => meter_error(metrics, e),
                }
                responder.resolve(result);
            }
            Job::Checkpoint { session, recorder, enqueued, responder } => {
                metrics.queue_wait.record_secs(enqueued.elapsed_s());
                if let Some(why) = responder.interrupt() {
                    let e = ServiceError::from(why);
                    meter_error(metrics, &e);
                    responder.resolve(Err(e));
                    continue;
                }
                let result = match lock_session(&session, &recorder) {
                    Ok(mut s) => s.checkpoint_now(),
                    Err(e) => Err(e),
                };
                match &result {
                    Ok(_) => {
                        metrics.add(&metrics.counters.completed, 1);
                        metrics.add(&metrics.counters.checkpoints, 1);
                    }
                    Err(e) => meter_error(metrics, e),
                }
                responder.resolve(result);
            }
            Job::FlightDump { recorder, enqueued, responder } => {
                metrics.queue_wait.record_secs(enqueued.elapsed_s());
                if let Some(why) = responder.interrupt() {
                    let e = ServiceError::from(why);
                    meter_error(metrics, &e);
                    responder.resolve(Err(e));
                    continue;
                }
                // reads only the recorder ring — never the session mutex
                let dump = crate::trace::export::flight_dump(&recorder);
                metrics.add(&metrics.counters.completed, 1);
                responder.resolve(Ok(dump));
            }
        }
    }
}

/// Variant → counter mapping for every non-success job outcome, whether
/// shed at dequeue or failed mid-run — one place so the two shed sites
/// can never diverge.
fn meter_error(metrics: &Metrics, e: &ServiceError) {
    match e {
        ServiceError::Cancelled => metrics.add(&metrics.counters.cancelled, 1),
        ServiceError::DeadlineExceeded => metrics.add(&metrics.counters.deadline_exceeded, 1),
        _ => metrics.add(&metrics.counters.failed, 1),
    }
}

fn handle(
    req: SummarizeRequest,
    queue_s: f64,
    metrics: &Arc<Metrics>,
    pool: &Arc<ThreadPool>,
    runtime: Option<&Arc<TiledRuntime>>,
    check: &mut dyn FnMut() -> Option<Interrupt>,
) -> Result<SummarizeResponse, ServiceError> {
    let timer = Timer::new();
    let job_span = metrics.tracer().start();
    let n = req.objective.n();
    metrics.add(&metrics.counters.items_in, n as u64);
    let f: Arc<dyn BatchedDivergence> = req.objective.into_fn();
    let compute = if req.use_pjrt {
        let rt = runtime.ok_or_else(|| ServiceError::Rejected {
            reason: "service started without a PJRT runtime".into(),
        })?;
        Compute::Pjrt(Arc::clone(rt))
    } else {
        Compute::Cpu
    };
    let backend =
        ShardedBackend::new(Arc::clone(&f), Arc::clone(pool), compute.clone(), Arc::clone(metrics))
            .map_err(|e| ServiceError::Rejected { reason: e.to_string() })?;
    let round_timer = Timer::new();
    // the interrupt probe fires between SS rounds: a cancelled or
    // deadline-blown request abandons the pass at the next round boundary;
    // each round records an SsRound span on the service tracer (inert
    // while it is disabled — the default)
    let ss = sparsify_traced(&backend, &req.params, check, metrics.tracer())?;
    if ss.rounds > 0 {
        // only real rounds produce a sample — a small-n passthrough (0
        // rounds) must not log its sparsify wall time as one fake round
        metrics.round_latency.record_secs(round_timer.elapsed_s() / ss.rounds as f64);
    }
    metrics.add(&metrics.counters.items_pruned, (n - ss.kept.len()) as u64);
    // post-reduction maximizer through the batched engine. PJRT requests on
    // a feature-based objective take the marginal-gain artifact route
    // (f32 device gains, CPU fallback — same contract as the divergence
    // side); everything else routes cohorts through the sharded backend,
    // which fans large ones over the compute pool and meters `gain_evals`.
    // The same probe rides into the greedy epoch loop, so a cancel or
    // deadline that lands after the SS pass aborts within one cohort
    // dispatch instead of running the full huge-k maximization out.
    let sol = match &compute {
        Compute::Pjrt(rt) if f.as_feature_based().is_some() => {
            let mut eng = MaximizerEngine::new(f.as_submodular(), GainRoute::Pjrt(rt.as_ref()))
                .with_tracer(metrics.tracer());
            let sol = eng.lazy_greedy_with(&ss.kept, req.k, check);
            // the PJRT route dispatches cohorts straight at the artifact,
            // bypassing ShardedBackend::gains_into — meter it here so
            // accelerated requests account their maximizer work too
            // (including the cohorts an aborted run already spent)
            metrics.add(&metrics.counters.gain_evals, eng.stats().gain_evals);
            sol?
        }
        _ => MaximizerEngine::new(f.as_submodular(), GainRoute::Backend(&backend))
            .with_tracer(metrics.tracer())
            .lazy_greedy_with(&ss.kept, req.k, check)?,
    };
    // the whole-request span closes the hierarchy: job → rounds → cohorts
    // → kernel dispatches, payload [items_in, reduced, k, ss_rounds]
    metrics.tracer().record_since(
        EventKind::Job,
        job_span,
        n as u64,
        ss.kept.len() as u64,
        req.k as u64,
        ss.rounds as u64,
    );
    Ok(SummarizeResponse {
        summary: sol.set,
        value: sol.value,
        n,
        reduced: ss.kept.len(),
        ss_rounds: ss.rounds,
        latency_s: timer.elapsed_s() + queue_s,
        queue_s,
    })
}

/// The worker half of the cluster's two-round scheme: one SS pass over a
/// shard, no maximizer. Mirrors [`handle`]'s metering (items in/pruned,
/// per-round latency) and closes a [`EventKind::ShardPrune`] span —
/// payload `[shard, items_in, kept, ss_rounds]`.
fn handle_prune(
    req: PruneRequest,
    metrics: &Arc<Metrics>,
    pool: &Arc<ThreadPool>,
    check: &mut dyn FnMut() -> Option<Interrupt>,
) -> Result<PruneResponse, ServiceError> {
    let span = metrics.tracer().start();
    let n = req.rows.n();
    metrics.add(&metrics.counters.items_in, n as u64);
    let f = req.spec.build(req.rows);
    let backend =
        ShardedBackend::new(f, Arc::clone(pool), Compute::Cpu, Arc::clone(metrics))
            .map_err(|e| ServiceError::Rejected { reason: e.to_string() })?;
    let round_timer = Timer::new();
    let ss = sparsify_traced(&backend, &req.params, check, metrics.tracer())?;
    if ss.rounds > 0 {
        metrics.round_latency.record_secs(round_timer.elapsed_s() / ss.rounds as f64);
    }
    metrics.add(&metrics.counters.items_pruned, (n - ss.kept.len()) as u64);
    metrics.tracer().record_since(
        EventKind::ShardPrune,
        span,
        req.shard,
        n as u64,
        ss.kept.len() as u64,
        ss.rounds as u64,
    );
    Ok(PruneResponse { kept: ss.kept, rounds: ss.rounds, n })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        m
    }

    fn req(n: usize, seed: u64) -> SummarizeRequest {
        SummarizeRequest::features(feats(n, 16, seed), 8, SsParams::default().with_seed(seed))
    }

    #[test]
    fn roundtrip_single_request() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let resp = svc.submit(req(300, 1)).wait().unwrap();
        assert_eq!(resp.summary.len(), 8);
        assert_eq!(resp.n, 300);
        assert!(resp.reduced < 300);
        assert!(resp.value > 0.0);
        assert!(resp.latency_s >= resp.queue_s);
    }

    #[test]
    fn spec_form_request_matches_feature_variant() {
        use crate::submodular::Concave;
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let a = svc.submit(req(260, 6)).wait().unwrap();
        let b = svc
            .submit(SummarizeRequest::from_rows(
                ObjectiveSpec::Features(Concave::Sqrt),
                feats(260, 16, 6),
                8,
                SsParams::default().with_seed(6),
            ))
            .wait()
            .unwrap();
        assert_eq!(a.summary, b.summary, "unified spec must build the identical objective");
        assert_eq!(a.value.to_bits(), b.value.to_bits());
    }

    #[test]
    fn maximizer_gain_evals_are_metered() {
        // the post-reduction maximizer routes cohorts through the sharded
        // backend, so its per-element evaluations land on `gain_evals`
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let resp = svc.submit(req(300, 4)).wait().unwrap();
        assert_eq!(resp.summary.len(), 8);
        let m = svc.metrics().snapshot();
        assert!(
            m.get("gain_evals").unwrap().as_f64().unwrap() > 0.0,
            "engine gain route must be metered"
        );
    }

    #[test]
    fn facility_location_roundtrip() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let fl = FacilityLocation::from_features(&feats(300, 16, 2));
        let resp = svc
            .submit(SummarizeRequest {
                objective: Objective::FacilityLocation(fl),
                k: 8,
                params: SsParams::default().with_seed(2),
                use_pjrt: false,
            })
            .wait()
            .unwrap();
        assert_eq!(resp.summary.len(), 8);
        assert_eq!(resp.n, 300);
        assert!(resp.reduced < 300);
        assert!(resp.value > 0.0);
    }

    #[test]
    fn concurrent_requests_route_correctly() {
        // responses must correspond to their own request (different n's)
        let svc = SummarizationService::start(
            ServiceConfig { workers: 3, queue_depth: 16, compute_threads: 2 },
            None,
        );
        let sizes = [150usize, 220, 310, 180, 260, 400];
        let tickets: Vec<(usize, Ticket<SummarizeResponse>)> =
            sizes.iter().map(|&n| (n, svc.submit(req(n, n as u64)))).collect();
        for (n, t) in tickets {
            let resp = t.wait().unwrap();
            assert_eq!(resp.n, n, "response routed to wrong request");
            assert_eq!(resp.summary.len(), 8);
        }
        let m = svc.metrics().snapshot();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(6.0));
        assert_eq!(m.get("failed").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn try_submit_sheds_load_when_full() {
        let svc = SummarizationService::start(
            ServiceConfig { workers: 1, queue_depth: 1, compute_threads: 1 },
            None,
        );
        let mut accepted = 0;
        let mut shed = 0;
        let mut tickets = Vec::new();
        for i in 0..20 {
            match svc.try_submit(req(400, i)) {
                Ok(t) => {
                    accepted += 1;
                    tickets.push(t);
                }
                Err(e @ ServiceError::QueueFull(_)) => {
                    assert!(e.is_retryable());
                    let r = e.into_payload().expect("backpressure hands the request back");
                    assert_eq!(r.objective.n(), 400);
                    shed += 1;
                }
                Err(other) => panic!("live service must shed with QueueFull, got {other:?}"),
            }
        }
        assert!(accepted >= 1);
        assert!(shed >= 1, "queue depth 1 must shed some of 20 rapid submits");
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn try_submit_distinguishes_dead_service_from_backpressure() {
        let mut svc = SummarizationService::start(ServiceConfig::default(), None);
        svc.shutdown();
        match svc.try_submit(req(50, 1)) {
            Err(e @ ServiceError::ServiceDown) => assert!(!e.is_retryable()),
            Err(ServiceError::QueueFull(_)) => {
                panic!("dead service must not masquerade as backpressure")
            }
            Err(other) => panic!("expected ServiceDown, got {other:?}"),
            Ok(_) => panic!("dead service accepted a request"),
        }
        // blocking submit must not panic either: the ticket resolves typed
        match svc.submit(req(50, 2)).wait() {
            Err(e @ ServiceError::ServiceDown) => {
                assert!(e.to_string().contains("down"), "{e}");
            }
            other => panic!("expected ServiceDown ticket, got {other:?}"),
        }
        assert_eq!(
            svc.metrics().counters.requests.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "rejected requests must not count as accepted"
        );
    }

    #[test]
    fn passthrough_request_records_no_round_latency() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        // n = 20 ≤ r·log₂n probes ⇒ SS passes the ground set through in 0
        // rounds; that must not contribute a round-latency sample
        let resp = svc.submit(req(20, 3)).wait().unwrap();
        assert_eq!(resp.ss_rounds, 0, "small n must pass through un-pruned");
        assert_eq!(resp.reduced, 20);
        assert_eq!(
            svc.metrics().round_latency.count(),
            0,
            "0-round passthrough must not record a fake round latency"
        );
        // a real request does produce samples
        let resp = svc.submit(req(300, 3)).wait().unwrap();
        assert!(resp.ss_rounds > 0);
        assert!(svc.metrics().round_latency.count() > 0);
    }

    #[test]
    fn pjrt_request_without_runtime_fails_cleanly() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let r = req(100, 9).with_pjrt(true);
        match svc.submit(r).wait() {
            Err(ServiceError::Rejected { reason }) => assert!(reason.contains("PJRT"), "{reason}"),
            other => panic!("expected a typed rejection, got {other:?}"),
        }
        assert_eq!(
            svc.metrics().counters.failed.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn deterministic_given_params() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let a = svc.submit(req(250, 5)).wait().unwrap();
        let b = svc.submit(req(250, 5)).wait().unwrap();
        assert_eq!(a.summary, b.summary);
        assert_eq!(a.value, b.value);
    }

    #[test]
    fn stream_lifecycle_through_service() {
        use crate::stream::{SnapshotMode, StreamConfig};
        use crate::submodular::Concave;
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let cfg = StreamConfig::new(6)
            .with_ss(SsParams::default().with_seed(7))
            .with_high_water(150);
        let id = svc.open_stream(ObjectiveSpec::Features(Concave::Sqrt), 12, cfg).unwrap();
        let day1 = feats(400, 12, 21);
        let day2 = feats(300, 12, 22);
        let r1 = svc.append(id, day1.data()).unwrap();
        assert_eq!(r1.appended, 400);
        assert!(r1.resparsifies >= 1, "400 appends over hw=150 must re-sparsify");
        let mid = svc.try_submit_snapshot(id, SnapshotMode::Intermediate).unwrap().wait().unwrap();
        assert_eq!(mid.summary.len(), 6);
        let r2 = svc.append(id, day2.data()).unwrap();
        assert_eq!(r2.first_ext, 400, "external ids continue across batches");
        let fin = svc.submit_snapshot(id, SnapshotMode::Final).unwrap().wait().unwrap();
        assert_eq!(fin.summary.len(), 6);
        assert!(fin.value > 0.0);
        assert!(fin.live < 700, "windowing must have bounded the live set");
        // service-wide mirror of the session counters + the job counter
        let m = svc.metrics().snapshot();
        assert_eq!(m.get("stream_appends").unwrap().as_f64(), Some(700.0));
        assert!(m.get("evicted_elements").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(m.get("snapshot_jobs").unwrap().as_f64(), Some(2.0));
        // per-session scope sees the same traffic
        let sm = svc.stream_metrics(id).unwrap();
        assert_eq!(sm.get("stream_appends").unwrap().as_f64(), Some(700.0));
        assert!(sm.get("divergence_evals").unwrap().as_f64().unwrap() > 0.0);
        let stats = svc.close(id).unwrap();
        assert_eq!(stats.appends, 700);
        assert_eq!(stats.windows as usize, r1.resparsifies + r2.resparsifies);
        // closed stream on a live service: the id is simply unknown now
        match svc.append(id, day1.data()) {
            Err(e @ ServiceError::UnknownStream(got)) => {
                assert_eq!(got, id);
                assert!(!e.is_retryable());
            }
            other => panic!("closed stream must report UnknownStream, got {other:?}"),
        }
        match svc.submit_snapshot(id, SnapshotMode::Final) {
            Err(ServiceError::UnknownStream(_)) => {}
            other => panic!("snapshot on closed stream must fail typed, got {other:?}"),
        }
        match svc.try_submit_snapshot(id, SnapshotMode::Final) {
            Err(ServiceError::UnknownStream(_)) => {}
            other => panic!("try-snapshot on closed stream must fail typed, got {other:?}"),
        }
        match svc.close(id) {
            Err(ServiceError::UnknownStream(_)) => {}
            other => panic!("double close must report UnknownStream, got {other:?}"),
        }
    }

    #[test]
    fn stream_backpressure_and_shutdown() {
        use crate::stream::StreamConfig;
        use crate::submodular::Concave;
        let mut svc = SummarizationService::start(ServiceConfig::default(), None);
        let cfg = StreamConfig::new(4)
            .with_ss(SsParams::default().with_seed(3))
            .with_high_water(80)
            .with_max_live(200);
        let id = svc.open_stream(ObjectiveSpec::Features(Concave::Sqrt), 8, cfg).unwrap();
        let ok = feats(150, 8, 31);
        svc.append(id, ok.data()).unwrap();
        let too_big = feats(300, 8, 32);
        match svc.append(id, too_big.data()) {
            Err(e @ ServiceError::QueueFull(())) => assert!(e.is_retryable()),
            _ => panic!("over-cap batch must shed with QueueFull"),
        }
        svc.shutdown();
        match svc.open_stream(ObjectiveSpec::Features(Concave::Sqrt), 8, StreamConfig::new(4)) {
            Err(ServiceError::ServiceDown) => {}
            other => panic!("shut-down service must refuse streams, got {other:?}"),
        }
        match svc.append(id, ok.data()) {
            Err(ServiceError::ServiceDown) => {}
            _ => panic!("shut-down service must fail stream appends fast"),
        }
        match svc.submit_snapshot(id, SnapshotMode::Final) {
            Err(ServiceError::ServiceDown) => {}
            other => panic!("shut-down service must refuse snapshot jobs, got {other:?}"),
        }
    }

    #[test]
    #[allow(deprecated)]
    fn compat_shims_still_work() {
        // the one-release migration surface: the SubmitError alias resolves
        // to ServiceError, and the blocking snapshot_summary shim rides the
        // job path (metered as a snapshot job)
        use crate::stream::StreamConfig;
        use crate::submodular::Concave;
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let id = svc
            .open_stream(
                ObjectiveSpec::Features(Concave::Sqrt),
                8,
                StreamConfig::new(4).with_ss(SsParams::default().with_seed(11)),
            )
            .unwrap();
        svc.append(id, feats(120, 8, 41).data()).unwrap();
        let snap = svc.snapshot_summary(id, SnapshotMode::Final).unwrap();
        assert_eq!(snap.summary.len(), 4);
        let m = svc.metrics().snapshot();
        assert_eq!(m.get("snapshot_jobs").unwrap().as_f64(), Some(1.0));
        // alias in an error position
        let e: SubmitError<()> = ServiceError::ServiceDown;
        assert!(!e.is_retryable());
    }

    #[test]
    fn poisoned_session_lock_quarantines_the_stream() {
        use crate::stream::StreamConfig;
        use crate::submodular::Concave;
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let id = svc
            .open_stream(
                ObjectiveSpec::Features(Concave::Sqrt),
                8,
                StreamConfig::new(4).with_ss(SsParams::default().with_seed(13)),
            )
            .unwrap();
        let rows = feats(40, 8, 51);
        svc.append(id, rows.data()).unwrap();
        // poison the session mutex: a thread panics while holding it
        let session = Arc::clone(&svc.stream(id).unwrap().session);
        let poisoner = std::thread::spawn(move || {
            let _guard = session.lock().unwrap();
            panic!("simulated panic while holding the session lock");
        });
        assert!(poisoner.join().is_err(), "the poisoning thread must have panicked");
        // every path resolves typed — the panic never propagates to callers
        match svc.append(id, rows.data()) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("quarantined"), "{reason}");
            }
            other => panic!("poisoned stream must reject appends typed, got {other:?}"),
        }
        match svc.submit_snapshot(id, SnapshotMode::Final) {
            Err(ServiceError::Rejected { .. }) => {}
            other => panic!("poisoned stream must reject snapshot jobs typed, got {other:?}"),
        }
        match svc.stream_metrics(id) {
            Err(ServiceError::Rejected { .. }) => {}
            other => panic!("poisoned stream must reject metrics typed, got {other:?}"),
        }
        match svc.close(id) {
            Err(ServiceError::Rejected { .. }) => {}
            other => panic!("poisoned stream must reject close typed, got {other:?}"),
        }
        // close removed the entry regardless: the id is simply unknown now,
        // and shutdown (via Drop) must not re-panic on what remains
        match svc.append(id, rows.data()) {
            Err(ServiceError::UnknownStream(_)) => {}
            other => panic!("closed quarantined stream must be unknown, got {other:?}"),
        }
    }

    #[test]
    fn flight_recorder_survives_poisoned_lock_quarantine() {
        use crate::stream::{DurabilityConfig, MemStore, StreamConfig};
        use crate::submodular::Concave;
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let id = svc
            .open_stream_durable(
                ObjectiveSpec::Features(Concave::Sqrt),
                8,
                StreamConfig::new(4)
                    .with_ss(SsParams::default().with_seed(19))
                    .with_high_water(60),
                Box::new(MemStore::new()),
                DurabilityConfig::default(),
            )
            .unwrap();
        let rows = feats(150, 8, 71);
        svc.append(id, rows.data()).unwrap();

        // poison the session mutex: a thread panics while holding it
        let session = Arc::clone(&svc.stream(id).unwrap().session);
        let poisoner = std::thread::spawn(move || {
            let _guard = session.lock().unwrap();
            panic!("simulated panic while holding the session lock");
        });
        assert!(poisoner.join().is_err());
        match svc.append(id, rows.data()) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("quarantined"), "{reason}");
            }
            other => panic!("poisoned stream must reject appends typed, got {other:?}"),
        }

        // the dump job never touches the session lock, so the recorder is
        // retrievable exactly when every session-locking path is bricked
        let dump = svc.submit_flight_dump(id).unwrap().wait().unwrap();
        assert_eq!(dump.get("scope").unwrap().as_str(), Some(format!("stream-{id}").as_str()));
        let events = dump.get("events").unwrap().as_arr().unwrap();
        assert!(!events.is_empty(), "quarantined stream must still dump its history");
        let kinds: Vec<&str> =
            events.iter().filter_map(|e| e.get("event").and_then(|k| k.as_str())).collect();
        assert!(kinds.contains(&"wal_flush"), "durable appends leave WAL spans: {kinds:?}");
        assert!(
            kinds.contains(&"ss_round") && kinds.contains(&"window"),
            "the high-water re-sparsification leaves round + window spans: {kinds:?}"
        );
        assert!(
            kinds.contains(&"quarantine"),
            "the poisoned acquisition drops a quarantine marker: {kinds:?}"
        );

        // close removes the entry (and the recorder with it)
        let _ = svc.close(id);
        match svc.submit_flight_dump(id) {
            Err(ServiceError::UnknownStream(_)) => {}
            other => panic!("dump after close must be UnknownStream, got {other:?}"),
        }
    }

    #[test]
    fn flight_recorder_captures_durable_store_quarantine() {
        use crate::stream::{DurabilityConfig, FaultStore, MemStore, StreamConfig};
        use crate::submodular::Concave;
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        // generous op budget so the open checkpoint succeeds; the first
        // over-budget store write errors and quarantines the session
        let store = FaultStore::new(Box::new(MemStore::new())).fail_after(64).with_error_on_fault();
        let id = svc
            .open_stream_durable(
                ObjectiveSpec::Features(Concave::Sqrt),
                6,
                StreamConfig::new(4).with_ss(SsParams::default().with_seed(23)),
                Box::new(store),
                DurabilityConfig::default(),
            )
            .unwrap();
        let row = feats(1, 6, 81);
        let mut quarantined = false;
        for _ in 0..200 {
            match svc.append(id, row.data()) {
                Ok(_) => {}
                Err(ServiceError::Rejected { reason }) => {
                    assert!(reason.contains("quarantined") || !reason.is_empty());
                    quarantined = true;
                    break;
                }
                Err(other) => panic!("store fault must surface as Rejected, got {other:?}"),
            }
        }
        assert!(quarantined, "the fault budget must trip within 200 single-row appends");

        let dump = svc.submit_flight_dump(id).unwrap().wait().unwrap();
        let events = dump.get("events").unwrap().as_arr().unwrap();
        let kinds: Vec<&str> =
            events.iter().filter_map(|e| e.get("event").and_then(|k| k.as_str())).collect();
        assert!(kinds.contains(&"wal_flush"), "pre-fault appends left WAL spans: {kinds:?}");
        assert!(
            kinds.contains(&"quarantine"),
            "the failed store write drops a quarantine marker: {kinds:?}"
        );
    }

    #[test]
    fn durable_stream_lifecycle_and_recovery_through_the_service() {
        use crate::stream::{DurabilityConfig, MemStore, StreamConfig};
        use crate::submodular::Concave;
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let store = MemStore::new();
        let cfg = StreamConfig::new(6)
            .with_ss(SsParams::default().with_seed(17))
            .with_high_water(120);
        let id = svc
            .open_stream_durable(
                ObjectiveSpec::Features(Concave::Sqrt),
                12,
                cfg,
                Box::new(store.clone()),
                DurabilityConfig::default(),
            )
            .unwrap();
        let day = feats(200, 12, 61);
        svc.append(id, day.data()).unwrap();
        let info = svc.submit_checkpoint(id).unwrap().wait().unwrap();
        assert!(info.bytes > 0);
        assert!(info.seq >= 1, "one logged batch must advance the covered sequence");
        let live = svc.submit_snapshot(id, SnapshotMode::Final).unwrap().wait().unwrap();

        // "crash": recover from the surviving bytes while the original keeps
        // running — the recovered session must match it bit-exactly
        let (rid, report) =
            svc.recover_stream(Box::new(store.clone()), DurabilityConfig::default()).unwrap();
        assert_ne!(rid, id, "recovery mounts under a fresh id");
        assert_eq!(report.checkpoint_seq, info.seq);
        assert_eq!(report.replayed_records, 0, "explicit checkpoint left no WAL tail");
        let rec = svc.submit_snapshot(rid, SnapshotMode::Final).unwrap().wait().unwrap();
        assert_eq!(live.summary, rec.summary);
        assert_eq!(live.value.to_bits(), rec.value.to_bits());
        assert_eq!(live.live, rec.live);

        let m = svc.metrics().snapshot();
        assert!(m.get("wal_appends").unwrap().as_f64().unwrap() >= 1.0);
        // the open checkpoint + the explicit job
        assert!(m.get("checkpoints").unwrap().as_f64().unwrap() >= 2.0);
        assert_eq!(m.get("recoveries").unwrap().as_f64(), Some(1.0));
        svc.close(id).unwrap();
        svc.close(rid).unwrap();
    }
}
