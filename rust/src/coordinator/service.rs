//! Summarization-as-a-service: the leader/worker deployment shape of SS.
//!
//! Requests (a feature matrix + budget + SS params) enter a bounded queue;
//! request-worker threads drain it, run the SS → lazy-greedy pipeline
//! (optionally through the shared PJRT runtime, which batches tile jobs
//! *across* concurrent requests at the executor), and deliver responses
//! through per-request channels. Backpressure: `submit` blocks when the
//! queue is full; `try_submit` fails fast — callers choose.


use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::algorithms::{lazy_greedy, sparsify, SsParams};
use crate::runtime::TiledRuntime;
use crate::submodular::FeatureBased;
use crate::util::pool::ThreadPool;
use crate::util::stats::Timer;
use crate::util::vecmath::FeatureMatrix;

use super::metrics::Metrics;
use super::sharded::{Compute, ShardedBackend};

pub struct SummarizeRequest {
    /// item features (rows = ground elements)
    pub feats: FeatureMatrix,
    /// summary budget
    pub k: usize,
    pub params: SsParams,
    /// route divergence batches through PJRT (requires service started with
    /// a runtime); false = CPU shards
    pub use_pjrt: bool,
}

#[derive(Clone, Debug)]
pub struct SummarizeResponse {
    pub summary: Vec<usize>,
    pub value: f64,
    /// |V| in
    pub n: usize,
    /// |V'| after SS
    pub reduced: usize,
    pub ss_rounds: usize,
    /// end-to-end latency including queueing
    pub latency_s: f64,
    /// time spent queued before a worker picked it up
    pub queue_s: f64,
}

struct QueuedJob {
    req: SummarizeRequest,
    enqueued: Timer,
    reply: SyncSender<Result<SummarizeResponse>>,
}

/// Ticket for an in-flight request.
pub struct Ticket {
    rx: Receiver<Result<SummarizeResponse>>,
}

impl Ticket {
    /// Block until the response is ready.
    pub fn wait(self) -> Result<SummarizeResponse> {
        self.rx.recv().map_err(|_| anyhow!("service worker dropped the request"))?
    }
}

pub struct ServiceConfig {
    /// request-worker threads
    pub workers: usize,
    /// bounded request-queue depth (backpressure point)
    pub queue_depth: usize,
    /// compute-pool threads shared by all requests' SS rounds
    pub compute_threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { workers: 2, queue_depth: 32, compute_threads: 2 }
    }
}

pub struct SummarizationService {
    tx: SyncSender<QueuedJob>,
    metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
}

impl SummarizationService {
    pub fn start(config: ServiceConfig, runtime: Option<Arc<TiledRuntime>>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<QueuedJob>(config.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let pool = Arc::new(ThreadPool::new(config.compute_threads.max(1), 64));
        let workers = (0..config.workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let metrics = Arc::clone(&metrics);
                let pool = Arc::clone(&pool);
                let runtime = runtime.clone();
                std::thread::Builder::new()
                    .name(format!("ss-svc-{i}"))
                    .spawn(move || worker_main(&rx, &metrics, &pool, runtime.as_ref()))
                    .expect("spawn service worker")
            })
            .collect();
        Self { tx, metrics, workers }
    }

    /// Blocking submit (backpressure).
    pub fn submit(&self, req: SummarizeRequest) -> Ticket {
        self.metrics.add(&self.metrics.counters.requests, 1);
        let (rtx, rrx) = sync_channel(1);
        let job = QueuedJob { req, enqueued: Timer::new(), reply: rtx };
        self.tx.send(job).expect("service is down");
        Ticket { rx: rrx }
    }

    /// Non-blocking submit; `Err` = queue full (shed load).
    pub fn try_submit(&self, req: SummarizeRequest) -> std::result::Result<Ticket, SummarizeRequest> {
        let (rtx, rrx) = sync_channel(1);
        let job = QueuedJob { req, enqueued: Timer::new(), reply: rtx };
        match self.tx.try_send(job) {
            Ok(()) => {
                self.metrics.add(&self.metrics.counters.requests, 1);
                Ok(Ticket { rx: rrx })
            }
            Err(TrySendError::Full(job)) | Err(TrySendError::Disconnected(job)) => Err(job.req),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_json(&self) -> String {
        self.metrics.snapshot().pretty()
    }
}

impl Drop for SummarizationService {
    fn drop(&mut self) {
        // close the queue; workers exit when drained
        let (dead_tx, _) = sync_channel(1);
        let _ = std::mem::replace(&mut self.tx, dead_tx);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_main(
    rx: &Mutex<Receiver<QueuedJob>>,
    metrics: &Arc<Metrics>,
    pool: &Arc<ThreadPool>,
    runtime: Option<&Arc<TiledRuntime>>,
) {
    loop {
        let job = {
            let rx = rx.lock().unwrap();
            rx.recv()
        };
        let Ok(job) = job else { return };
        let queue_s = job.enqueued.elapsed_s();
        metrics.queue_wait.record_secs(queue_s);
        let result = handle(job.req, queue_s, metrics, pool, runtime);
        match &result {
            Ok(_) => metrics.add(&metrics.counters.completed, 1),
            Err(_) => metrics.add(&metrics.counters.failed, 1),
        }
        let _ = job.reply.send(result);
    }
}

fn handle(
    req: SummarizeRequest,
    queue_s: f64,
    metrics: &Arc<Metrics>,
    pool: &Arc<ThreadPool>,
    runtime: Option<&Arc<TiledRuntime>>,
) -> Result<SummarizeResponse> {
    let timer = Timer::new();
    let n = req.feats.n();
    metrics.add(&metrics.counters.items_in, n as u64);
    let f = Arc::new(FeatureBased::sqrt(req.feats));
    let compute = if req.use_pjrt {
        let rt = runtime.ok_or_else(|| anyhow!("service started without a PJRT runtime"))?;
        Compute::Pjrt(Arc::clone(rt))
    } else {
        Compute::Cpu
    };
    let backend =
        ShardedBackend::new(Arc::clone(&f), Arc::clone(pool), compute, Arc::clone(metrics))?;
    let round_timer = Timer::new();
    let ss = sparsify(&backend, &req.params);
    metrics.round_latency.record_secs(round_timer.elapsed_s() / ss.rounds.max(1) as f64);
    metrics.add(&metrics.counters.items_pruned, (n - ss.kept.len()) as u64);
    let sol = lazy_greedy(f.as_ref(), &ss.kept, req.k);
    Ok(SummarizeResponse {
        summary: sol.set,
        value: sol.value,
        n,
        reduced: ss.kept.len(),
        ss_rounds: ss.rounds,
        latency_s: timer.elapsed_s() + queue_s,
        queue_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        m
    }

    fn req(n: usize, seed: u64) -> SummarizeRequest {
        SummarizeRequest {
            feats: feats(n, 16, seed),
            k: 8,
            params: SsParams::default().with_seed(seed),
            use_pjrt: false,
        }
    }

    #[test]
    fn roundtrip_single_request() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let resp = svc.submit(req(300, 1)).wait().unwrap();
        assert_eq!(resp.summary.len(), 8);
        assert_eq!(resp.n, 300);
        assert!(resp.reduced < 300);
        assert!(resp.value > 0.0);
        assert!(resp.latency_s >= resp.queue_s);
    }

    #[test]
    fn concurrent_requests_route_correctly() {
        // responses must correspond to their own request (different n's)
        let svc = SummarizationService::start(
            ServiceConfig { workers: 3, queue_depth: 16, compute_threads: 2 },
            None,
        );
        let sizes = [150usize, 220, 310, 180, 260, 400];
        let tickets: Vec<(usize, Ticket)> =
            sizes.iter().map(|&n| (n, svc.submit(req(n, n as u64)))).collect();
        for (n, t) in tickets {
            let resp = t.wait().unwrap();
            assert_eq!(resp.n, n, "response routed to wrong request");
            assert_eq!(resp.summary.len(), 8);
        }
        let m = svc.metrics().snapshot();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(6.0));
        assert_eq!(m.get("failed").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn try_submit_sheds_load_when_full() {
        let svc = SummarizationService::start(
            ServiceConfig { workers: 1, queue_depth: 1, compute_threads: 1 },
            None,
        );
        let mut accepted = 0;
        let mut shed = 0;
        let mut tickets = Vec::new();
        for i in 0..20 {
            match svc.try_submit(req(400, i)) {
                Ok(t) => {
                    accepted += 1;
                    tickets.push(t);
                }
                Err(_) => shed += 1,
            }
        }
        assert!(accepted >= 1);
        assert!(shed >= 1, "queue depth 1 must shed some of 20 rapid submits");
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn pjrt_request_without_runtime_fails_cleanly() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let mut r = req(100, 9);
        r.use_pjrt = true;
        let err = svc.submit(r).wait().unwrap_err().to_string();
        assert!(err.contains("PJRT"), "{err}");
        assert_eq!(
            svc.metrics().counters.failed.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn deterministic_given_params() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let a = svc.submit(req(250, 5)).wait().unwrap();
        let b = svc.submit(req(250, 5)).wait().unwrap();
        assert_eq!(a.summary, b.summary);
        assert_eq!(a.value, b.value);
    }
}
