//! Summarization-as-a-service: the leader/worker deployment shape of SS.
//!
//! Requests (an [`Objective`] + budget + SS params) enter a bounded queue;
//! request-worker threads drain it, run the SS → lazy-greedy pipeline
//! (optionally through the shared PJRT runtime, which batches tile jobs
//! *across* concurrent requests at the executor), and deliver responses
//! through per-request channels. Backpressure: `submit` blocks when the
//! queue is full; `try_submit` fails fast and distinguishes a full queue
//! ([`SubmitError::QueueFull`], retryable) from a dead service
//! ([`SubmitError::ServiceDown`], not retryable) — callers choose.
//!
//! Objectives: the service is generic over the crate's objective library
//! via [`BatchedDivergence`] — news-style feature-based requests, dense
//! facility-location (video representativeness) requests, and weighted
//! mixtures all run the same sharded pipeline. PJRT acceleration applies
//! to the feature-based core; other objectives compute on the CPU shard
//! kernels transparently.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::algorithms::{sparsify, GainRoute, MaximizerEngine, SsParams};
use crate::runtime::TiledRuntime;
use crate::submodular::{BatchedDivergence, FacilityLocation, FeatureBased, Mixture};
use crate::util::pool::ThreadPool;
use crate::util::stats::Timer;
use crate::util::vecmath::FeatureMatrix;

use super::metrics::Metrics;
use super::sharded::{Compute, ShardedBackend};

/// What to summarize: the objective payload of a [`SummarizeRequest`].
pub enum Objective {
    /// Feature-based concave-over-modular (√ scalarizer) over hashed item
    /// features — the paper's news objective; PJRT-accelerable.
    Features(FeatureMatrix),
    /// Facility location over a dense similarity matrix — video-style
    /// representativeness; computed on the blocked CPU kernel.
    FacilityLocation(FacilityLocation),
    /// Weighted mixture of objectives (coverage vs diversity trade-offs).
    Mixture(Mixture),
}

impl Objective {
    /// Ground-set size |V|.
    pub fn n(&self) -> usize {
        match self {
            Objective::Features(feats) => feats.n(),
            Objective::FacilityLocation(fl) => fl.n(),
            Objective::Mixture(m) => m.n(),
        }
    }

    /// Materialize the objective handle the pipeline runs on.
    fn into_fn(self) -> Arc<dyn BatchedDivergence> {
        match self {
            Objective::Features(feats) => Arc::new(FeatureBased::sqrt(feats)),
            Objective::FacilityLocation(fl) => Arc::new(fl),
            Objective::Mixture(m) => Arc::new(m),
        }
    }
}

pub struct SummarizeRequest {
    pub objective: Objective,
    /// summary budget
    pub k: usize,
    pub params: SsParams,
    /// route divergence batches through PJRT (requires service started with
    /// a runtime; only accelerates `Objective::Features` — other objectives
    /// fall back to CPU shards)
    pub use_pjrt: bool,
}

impl SummarizeRequest {
    /// News-style request: feature-based objective over `feats`.
    pub fn features(feats: FeatureMatrix, k: usize, params: SsParams) -> Self {
        Self { objective: Objective::Features(feats), k, params, use_pjrt: false }
    }

    pub fn with_pjrt(mut self, use_pjrt: bool) -> Self {
        self.use_pjrt = use_pjrt;
        self
    }
}

#[derive(Clone, Debug)]
pub struct SummarizeResponse {
    pub summary: Vec<usize>,
    pub value: f64,
    /// |V| in
    pub n: usize,
    /// |V'| after SS
    pub reduced: usize,
    pub ss_rounds: usize,
    /// end-to-end latency including queueing
    pub latency_s: f64,
    /// time spent queued before a worker picked it up
    pub queue_s: f64,
}

/// Why [`SummarizationService::try_submit`] rejected a request. Both
/// variants hand the request back so the caller can retry or reroute.
pub enum SubmitError {
    /// Bounded queue is full — backpressure; retrying later can succeed.
    QueueFull(SummarizeRequest),
    /// The service's workers are gone (shut down or crashed) — retrying
    /// against this instance can never succeed.
    ServiceDown(SummarizeRequest),
}

impl SubmitError {
    /// Recover the rejected request.
    pub fn into_request(self) -> SummarizeRequest {
        match self {
            SubmitError::QueueFull(r) | SubmitError::ServiceDown(r) => r,
        }
    }

    pub fn is_retryable(&self) -> bool {
        matches!(self, SubmitError::QueueFull(_))
    }
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(_) => f.write_str("SubmitError::QueueFull(..)"),
            SubmitError::ServiceDown(_) => f.write_str("SubmitError::ServiceDown(..)"),
        }
    }
}

struct QueuedJob {
    req: SummarizeRequest,
    enqueued: Timer,
    reply: SyncSender<Result<SummarizeResponse>>,
}

/// Ticket for an in-flight request.
pub struct Ticket {
    rx: Receiver<Result<SummarizeResponse>>,
}

impl Ticket {
    /// Block until the response is ready.
    pub fn wait(self) -> Result<SummarizeResponse> {
        self.rx.recv().map_err(|_| anyhow!("service worker dropped the request"))?
    }
}

pub struct ServiceConfig {
    /// request-worker threads
    pub workers: usize,
    /// bounded request-queue depth (backpressure point)
    pub queue_depth: usize,
    /// compute-pool threads shared by all requests' SS rounds
    pub compute_threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { workers: 2, queue_depth: 32, compute_threads: 2 }
    }
}

pub struct SummarizationService {
    tx: SyncSender<QueuedJob>,
    metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
}

impl SummarizationService {
    pub fn start(config: ServiceConfig, runtime: Option<Arc<TiledRuntime>>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<QueuedJob>(config.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let pool = Arc::new(ThreadPool::new(config.compute_threads.max(1), 64));
        let workers = (0..config.workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let metrics = Arc::clone(&metrics);
                let pool = Arc::clone(&pool);
                let runtime = runtime.clone();
                std::thread::Builder::new()
                    .name(format!("ss-svc-{i}"))
                    .spawn(move || worker_main(&rx, &metrics, &pool, runtime.as_ref()))
                    .expect("spawn service worker")
            })
            .collect();
        Self { tx, metrics, workers }
    }

    /// Blocking submit (backpressure). After [`Self::shutdown`] the ticket
    /// resolves to an error instead of blocking or panicking.
    pub fn submit(&self, req: SummarizeRequest) -> Ticket {
        let (rtx, rrx) = sync_channel(1);
        let job = QueuedJob { req, enqueued: Timer::new(), reply: rtx };
        match self.tx.send(job) {
            Ok(()) => self.metrics.add(&self.metrics.counters.requests, 1),
            Err(dead) => {
                // workers are gone: fail the ticket, don't panic the caller
                let _ = dead.0.reply.send(Err(anyhow!("service is down")));
            }
        }
        Ticket { rx: rrx }
    }

    /// Non-blocking submit. [`SubmitError::QueueFull`] = shed load / retry
    /// later; [`SubmitError::ServiceDown`] = the workers are gone and no
    /// retry against this instance can succeed.
    pub fn try_submit(
        &self,
        req: SummarizeRequest,
    ) -> std::result::Result<Ticket, SubmitError> {
        let (rtx, rrx) = sync_channel(1);
        let job = QueuedJob { req, enqueued: Timer::new(), reply: rtx };
        match self.tx.try_send(job) {
            Ok(()) => {
                self.metrics.add(&self.metrics.counters.requests, 1);
                Ok(Ticket { rx: rrx })
            }
            Err(TrySendError::Full(job)) => Err(SubmitError::QueueFull(job.req)),
            Err(TrySendError::Disconnected(job)) => Err(SubmitError::ServiceDown(job.req)),
        }
    }

    /// Graceful shutdown: close the queue (already-accepted requests still
    /// complete), then join the workers. Afterwards `try_submit` reports
    /// [`SubmitError::ServiceDown`]. Called by `Drop`; idempotent.
    pub fn shutdown(&mut self) {
        let (dead_tx, _) = sync_channel(1);
        let _ = std::mem::replace(&mut self.tx, dead_tx);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_json(&self) -> String {
        self.metrics.snapshot().pretty()
    }
}

impl Drop for SummarizationService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_main(
    rx: &Mutex<Receiver<QueuedJob>>,
    metrics: &Arc<Metrics>,
    pool: &Arc<ThreadPool>,
    runtime: Option<&Arc<TiledRuntime>>,
) {
    loop {
        let job = {
            let rx = rx.lock().unwrap();
            rx.recv()
        };
        let Ok(job) = job else { return };
        let queue_s = job.enqueued.elapsed_s();
        metrics.queue_wait.record_secs(queue_s);
        let result = handle(job.req, queue_s, metrics, pool, runtime);
        match &result {
            Ok(resp) => {
                metrics.add(&metrics.counters.completed, 1);
                metrics.request_latency.record_secs(resp.latency_s);
            }
            Err(_) => metrics.add(&metrics.counters.failed, 1),
        }
        let _ = job.reply.send(result);
    }
}

fn handle(
    req: SummarizeRequest,
    queue_s: f64,
    metrics: &Arc<Metrics>,
    pool: &Arc<ThreadPool>,
    runtime: Option<&Arc<TiledRuntime>>,
) -> Result<SummarizeResponse> {
    let timer = Timer::new();
    let n = req.objective.n();
    metrics.add(&metrics.counters.items_in, n as u64);
    let f: Arc<dyn BatchedDivergence> = req.objective.into_fn();
    let compute = if req.use_pjrt {
        let rt = runtime.ok_or_else(|| anyhow!("service started without a PJRT runtime"))?;
        Compute::Pjrt(Arc::clone(rt))
    } else {
        Compute::Cpu
    };
    let backend =
        ShardedBackend::new(Arc::clone(&f), Arc::clone(pool), compute.clone(), Arc::clone(metrics))?;
    let round_timer = Timer::new();
    let ss = sparsify(&backend, &req.params);
    if ss.rounds > 0 {
        // only real rounds produce a sample — a small-n passthrough (0
        // rounds) must not log its sparsify wall time as one fake round
        metrics.round_latency.record_secs(round_timer.elapsed_s() / ss.rounds as f64);
    }
    metrics.add(&metrics.counters.items_pruned, (n - ss.kept.len()) as u64);
    // post-reduction maximizer through the batched engine. PJRT requests on
    // a feature-based objective take the marginal-gain artifact route
    // (f32 device gains, CPU fallback — same contract as the divergence
    // side); everything else routes cohorts through the sharded backend,
    // which fans large ones over the compute pool and meters `gain_evals`.
    let sol = match &compute {
        Compute::Pjrt(rt) if f.as_feature_based().is_some() => {
            let mut eng =
                MaximizerEngine::new(f.as_submodular(), GainRoute::Pjrt(rt.as_ref()));
            let sol = eng.lazy_greedy(&ss.kept, req.k);
            // the PJRT route dispatches cohorts straight at the artifact,
            // bypassing ShardedBackend::gains_into — meter it here so
            // accelerated requests account their maximizer work too
            metrics.add(&metrics.counters.gain_evals, eng.stats().gain_evals);
            sol
        }
        _ => MaximizerEngine::new(f.as_submodular(), GainRoute::Backend(&backend))
            .lazy_greedy(&ss.kept, req.k),
    };
    Ok(SummarizeResponse {
        summary: sol.set,
        value: sol.value,
        n,
        reduced: ss.kept.len(),
        ss_rounds: ss.rounds,
        latency_s: timer.elapsed_s() + queue_s,
        queue_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        m
    }

    fn req(n: usize, seed: u64) -> SummarizeRequest {
        SummarizeRequest::features(feats(n, 16, seed), 8, SsParams::default().with_seed(seed))
    }

    #[test]
    fn roundtrip_single_request() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let resp = svc.submit(req(300, 1)).wait().unwrap();
        assert_eq!(resp.summary.len(), 8);
        assert_eq!(resp.n, 300);
        assert!(resp.reduced < 300);
        assert!(resp.value > 0.0);
        assert!(resp.latency_s >= resp.queue_s);
    }

    #[test]
    fn maximizer_gain_evals_are_metered() {
        // the post-reduction maximizer routes cohorts through the sharded
        // backend, so its per-element evaluations land on `gain_evals`
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let resp = svc.submit(req(300, 4)).wait().unwrap();
        assert_eq!(resp.summary.len(), 8);
        let m = svc.metrics().snapshot();
        assert!(
            m.get("gain_evals").unwrap().as_f64().unwrap() > 0.0,
            "engine gain route must be metered"
        );
    }

    #[test]
    fn facility_location_roundtrip() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let fl = FacilityLocation::from_features(&feats(300, 16, 2));
        let resp = svc
            .submit(SummarizeRequest {
                objective: Objective::FacilityLocation(fl),
                k: 8,
                params: SsParams::default().with_seed(2),
                use_pjrt: false,
            })
            .wait()
            .unwrap();
        assert_eq!(resp.summary.len(), 8);
        assert_eq!(resp.n, 300);
        assert!(resp.reduced < 300);
        assert!(resp.value > 0.0);
    }

    #[test]
    fn concurrent_requests_route_correctly() {
        // responses must correspond to their own request (different n's)
        let svc = SummarizationService::start(
            ServiceConfig { workers: 3, queue_depth: 16, compute_threads: 2 },
            None,
        );
        let sizes = [150usize, 220, 310, 180, 260, 400];
        let tickets: Vec<(usize, Ticket)> =
            sizes.iter().map(|&n| (n, svc.submit(req(n, n as u64)))).collect();
        for (n, t) in tickets {
            let resp = t.wait().unwrap();
            assert_eq!(resp.n, n, "response routed to wrong request");
            assert_eq!(resp.summary.len(), 8);
        }
        let m = svc.metrics().snapshot();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(6.0));
        assert_eq!(m.get("failed").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn try_submit_sheds_load_when_full() {
        let svc = SummarizationService::start(
            ServiceConfig { workers: 1, queue_depth: 1, compute_threads: 1 },
            None,
        );
        let mut accepted = 0;
        let mut shed = 0;
        let mut tickets = Vec::new();
        for i in 0..20 {
            match svc.try_submit(req(400, i)) {
                Ok(t) => {
                    accepted += 1;
                    tickets.push(t);
                }
                Err(e @ SubmitError::QueueFull(_)) => {
                    assert!(e.is_retryable());
                    shed += 1;
                }
                Err(SubmitError::ServiceDown(_)) => {
                    panic!("live service must report backpressure, not ServiceDown")
                }
            }
        }
        assert!(accepted >= 1);
        assert!(shed >= 1, "queue depth 1 must shed some of 20 rapid submits");
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn try_submit_distinguishes_dead_service_from_backpressure() {
        let mut svc = SummarizationService::start(ServiceConfig::default(), None);
        svc.shutdown();
        match svc.try_submit(req(50, 1)) {
            Err(e @ SubmitError::ServiceDown(_)) => {
                assert!(!e.is_retryable());
                assert_eq!(e.into_request().objective.n(), 50, "request must be handed back");
            }
            Err(SubmitError::QueueFull(_)) => {
                panic!("dead service must not masquerade as backpressure")
            }
            Ok(_) => panic!("dead service accepted a request"),
        }
        // blocking submit must not panic either: the ticket resolves to Err
        let err = svc.submit(req(50, 2)).wait().unwrap_err().to_string();
        assert!(err.contains("down"), "{err}");
        assert_eq!(
            svc.metrics().counters.requests.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "rejected requests must not count as accepted"
        );
    }

    #[test]
    fn passthrough_request_records_no_round_latency() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        // n = 20 ≤ r·log₂n probes ⇒ SS passes the ground set through in 0
        // rounds; that must not contribute a round-latency sample
        let resp = svc.submit(req(20, 3)).wait().unwrap();
        assert_eq!(resp.ss_rounds, 0, "small n must pass through un-pruned");
        assert_eq!(resp.reduced, 20);
        assert_eq!(
            svc.metrics().round_latency.count(),
            0,
            "0-round passthrough must not record a fake round latency"
        );
        // a real request does produce samples
        let resp = svc.submit(req(300, 3)).wait().unwrap();
        assert!(resp.ss_rounds > 0);
        assert!(svc.metrics().round_latency.count() > 0);
    }

    #[test]
    fn pjrt_request_without_runtime_fails_cleanly() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let r = req(100, 9).with_pjrt(true);
        let err = svc.submit(r).wait().unwrap_err().to_string();
        assert!(err.contains("PJRT"), "{err}");
        assert_eq!(
            svc.metrics().counters.failed.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn deterministic_given_params() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let a = svc.submit(req(250, 5)).wait().unwrap();
        let b = svc.submit(req(250, 5)).wait().unwrap();
        assert_eq!(a.summary, b.summary);
        assert_eq!(a.value, b.value);
    }
}
