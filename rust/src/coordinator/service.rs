//! Summarization-as-a-service: the leader/worker deployment shape of SS.
//!
//! Requests (an [`Objective`] + budget + SS params) enter a bounded queue;
//! request-worker threads drain it, run the SS → lazy-greedy pipeline
//! (optionally through the shared PJRT runtime, which batches tile jobs
//! *across* concurrent requests at the executor), and deliver responses
//! through per-request channels. Backpressure: `submit` blocks when the
//! queue is full; `try_submit` fails fast and distinguishes a full queue
//! ([`SubmitError::QueueFull`], retryable) from a dead service
//! ([`SubmitError::ServiceDown`], not retryable) — callers choose.
//!
//! Objectives: the service is generic over the crate's objective library
//! via [`BatchedDivergence`] — news-style feature-based requests, dense
//! facility-location (video representativeness) requests, and weighted
//! mixtures all run the same sharded pipeline. PJRT acceleration applies
//! to the feature-based core; other objectives compute on the CPU shard
//! kernels transparently.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::algorithms::{sparsify, GainRoute, MaximizerEngine, SsParams};
use crate::runtime::TiledRuntime;
use crate::stream::{
    SnapshotMode, StreamAppend, StreamConfig, StreamObjective, StreamSession, StreamStats,
    StreamSummary,
};
use crate::submodular::{BatchedDivergence, FacilityLocation, FeatureBased, Mixture};
use crate::util::pool::ThreadPool;
use crate::util::stats::Timer;
use crate::util::vecmath::FeatureMatrix;

use super::metrics::Metrics;
use super::sharded::{Compute, ShardedBackend};

/// Handle to an open streaming session (see
/// [`SummarizationService::open_stream`]).
pub type StreamId = u64;

/// Map entry for an open stream: the session plus its row width, kept
/// outside the session lock so input validation can panic (caller bug)
/// *before* the mutex is taken — a poisoned session lock would brick the
/// stream for every later call.
#[derive(Clone)]
struct StreamEntry {
    d: usize,
    /// whether the session's objective requires non-negative features
    /// (feature-based coverage); facility location accepts signed rows
    nonneg: bool,
    session: Arc<Mutex<StreamSession>>,
}

/// What to summarize: the objective payload of a [`SummarizeRequest`].
pub enum Objective {
    /// Feature-based concave-over-modular (√ scalarizer) over hashed item
    /// features — the paper's news objective; PJRT-accelerable.
    Features(FeatureMatrix),
    /// Facility location over a dense similarity matrix — video-style
    /// representativeness; computed on the blocked CPU kernel.
    FacilityLocation(FacilityLocation),
    /// Weighted mixture of objectives (coverage vs diversity trade-offs).
    Mixture(Mixture),
}

impl Objective {
    /// Ground-set size |V|.
    pub fn n(&self) -> usize {
        match self {
            Objective::Features(feats) => feats.n(),
            Objective::FacilityLocation(fl) => fl.n(),
            Objective::Mixture(m) => m.n(),
        }
    }

    /// Materialize the objective handle the pipeline runs on.
    fn into_fn(self) -> Arc<dyn BatchedDivergence> {
        match self {
            Objective::Features(feats) => Arc::new(FeatureBased::sqrt(feats)),
            Objective::FacilityLocation(fl) => Arc::new(fl),
            Objective::Mixture(m) => Arc::new(m),
        }
    }
}

pub struct SummarizeRequest {
    pub objective: Objective,
    /// summary budget
    pub k: usize,
    pub params: SsParams,
    /// route divergence batches through PJRT (requires service started with
    /// a runtime; only accelerates `Objective::Features` — other objectives
    /// fall back to CPU shards)
    pub use_pjrt: bool,
}

impl SummarizeRequest {
    /// News-style request: feature-based objective over `feats`.
    pub fn features(feats: FeatureMatrix, k: usize, params: SsParams) -> Self {
        Self { objective: Objective::Features(feats), k, params, use_pjrt: false }
    }

    pub fn with_pjrt(mut self, use_pjrt: bool) -> Self {
        self.use_pjrt = use_pjrt;
        self
    }
}

#[derive(Clone, Debug)]
pub struct SummarizeResponse {
    pub summary: Vec<usize>,
    pub value: f64,
    /// |V| in
    pub n: usize,
    /// |V'| after SS
    pub reduced: usize,
    pub ss_rounds: usize,
    /// end-to-end latency including queueing
    pub latency_s: f64,
    /// time spent queued before a worker picked it up
    pub queue_s: f64,
}

/// Why a submit-shaped call was rejected, generic over the payload handed
/// back to the caller: [`SummarizationService::try_submit`] returns the
/// whole [`SummarizeRequest`] (the default), the streaming `append` path
/// returns `SubmitError<()>` (the caller still owns its rows). Both
/// variants mean "this work was not accepted"; only [`QueueFull`] is worth
/// retrying.
///
/// [`QueueFull`]: SubmitError::QueueFull
pub enum SubmitError<R = SummarizeRequest> {
    /// Bounded queue (or session live-set cap) is full — backpressure;
    /// retrying later can succeed.
    QueueFull(R),
    /// The service's workers are gone, or the session is closed —
    /// retrying against this instance can never succeed.
    ServiceDown(R),
}

impl<R> SubmitError<R> {
    /// Recover the rejected payload.
    pub fn into_request(self) -> R {
        match self {
            SubmitError::QueueFull(r) | SubmitError::ServiceDown(r) => r,
        }
    }

    pub fn is_retryable(&self) -> bool {
        matches!(self, SubmitError::QueueFull(_))
    }
}

impl<R> std::fmt::Debug for SubmitError<R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(_) => f.write_str("SubmitError::QueueFull(..)"),
            SubmitError::ServiceDown(_) => f.write_str("SubmitError::ServiceDown(..)"),
        }
    }
}

struct QueuedJob {
    req: SummarizeRequest,
    enqueued: Timer,
    reply: SyncSender<Result<SummarizeResponse>>,
}

/// Ticket for an in-flight request.
pub struct Ticket {
    rx: Receiver<Result<SummarizeResponse>>,
}

impl Ticket {
    /// Block until the response is ready.
    pub fn wait(self) -> Result<SummarizeResponse> {
        self.rx.recv().map_err(|_| anyhow!("service worker dropped the request"))?
    }
}

pub struct ServiceConfig {
    /// request-worker threads
    pub workers: usize,
    /// bounded request-queue depth (backpressure point)
    pub queue_depth: usize,
    /// compute-pool threads shared by all requests' SS rounds
    pub compute_threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { workers: 2, queue_depth: 32, compute_threads: 2 }
    }
}

pub struct SummarizationService {
    tx: SyncSender<QueuedJob>,
    metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    /// compute pool shared by request workers and streaming sessions
    pool: Arc<ThreadPool>,
    /// open streaming sessions; each behind its own lock so sessions
    /// don't serialize against each other
    streams: Mutex<HashMap<StreamId, StreamEntry>>,
    next_stream: AtomicU64,
    /// set by shutdown: streaming calls fail fast afterwards
    down: AtomicBool,
}

impl SummarizationService {
    pub fn start(config: ServiceConfig, runtime: Option<Arc<TiledRuntime>>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<QueuedJob>(config.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let pool = Arc::new(ThreadPool::new(config.compute_threads.max(1), 64));
        let workers = (0..config.workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let metrics = Arc::clone(&metrics);
                let pool = Arc::clone(&pool);
                let runtime = runtime.clone();
                std::thread::Builder::new()
                    .name(format!("ss-svc-{i}"))
                    .spawn(move || worker_main(&rx, &metrics, &pool, runtime.as_ref()))
                    .expect("spawn service worker")
            })
            .collect();
        Self {
            tx,
            metrics,
            workers,
            pool,
            streams: Mutex::new(HashMap::new()),
            next_stream: AtomicU64::new(0),
            down: AtomicBool::new(false),
        }
    }

    /// Blocking submit (backpressure). After [`Self::shutdown`] the ticket
    /// resolves to an error instead of blocking or panicking.
    pub fn submit(&self, req: SummarizeRequest) -> Ticket {
        let (rtx, rrx) = sync_channel(1);
        let job = QueuedJob { req, enqueued: Timer::new(), reply: rtx };
        match self.tx.send(job) {
            Ok(()) => self.metrics.add(&self.metrics.counters.requests, 1),
            Err(dead) => {
                // workers are gone: fail the ticket, don't panic the caller
                let _ = dead.0.reply.send(Err(anyhow!("service is down")));
            }
        }
        Ticket { rx: rrx }
    }

    /// Non-blocking submit. [`SubmitError::QueueFull`] = shed load / retry
    /// later; [`SubmitError::ServiceDown`] = the workers are gone and no
    /// retry against this instance can succeed.
    pub fn try_submit(
        &self,
        req: SummarizeRequest,
    ) -> std::result::Result<Ticket, SubmitError> {
        let (rtx, rrx) = sync_channel(1);
        let job = QueuedJob { req, enqueued: Timer::new(), reply: rtx };
        match self.tx.try_send(job) {
            Ok(()) => {
                self.metrics.add(&self.metrics.counters.requests, 1);
                Ok(Ticket { rx: rrx })
            }
            Err(TrySendError::Full(job)) => Err(SubmitError::QueueFull(job.req)),
            Err(TrySendError::Disconnected(job)) => Err(SubmitError::ServiceDown(job.req)),
        }
    }

    /// Open a streaming session: append-only ingestion with sieve
    /// admission and windowed re-sparsification (see
    /// [`crate::stream::StreamSession`]). The session runs on the
    /// service's compute pool with its own [`Metrics`] scope; the four
    /// stream counters are mirrored onto the service-wide metrics so
    /// dashboards see every session's traffic in one place.
    pub fn open_stream(
        &self,
        objective: StreamObjective,
        d: usize,
        cfg: StreamConfig,
    ) -> Result<StreamId> {
        if self.down.load(Ordering::SeqCst) {
            return Err(anyhow!("service is down"));
        }
        let session = StreamSession::new(
            objective,
            d,
            cfg,
            Arc::clone(&self.pool),
            Arc::new(Metrics::new()),
        )?;
        let id = self.next_stream.fetch_add(1, Ordering::Relaxed);
        let nonneg = matches!(objective, StreamObjective::Features(_));
        self.streams
            .lock()
            .unwrap()
            .insert(id, StreamEntry { d, nonneg, session: Arc::new(Mutex::new(session)) });
        Ok(id)
    }

    /// Append a batch of rows to an open stream. Backpressure surfaces as
    /// [`SubmitError::QueueFull`] (session live-set cap; recover by
    /// splitting into smaller batches — eviction only happens through
    /// windowed re-sparsification, which an over-cap retained core can no
    /// longer trigger); an unknown/closed stream or a shut-down service
    /// reports [`SubmitError::ServiceDown`]. A misaligned or
    /// invalid-valued batch is a caller bug and panics **before** the
    /// session lock is taken, so it cannot poison the stream.
    pub fn append(
        &self,
        id: StreamId,
        rows: &[f32],
    ) -> std::result::Result<StreamAppend, SubmitError<()>> {
        let Some(entry) = self.stream(id) else {
            return Err(SubmitError::ServiceDown(()));
        };
        // one validation scan, before the lock — a caller-bug panic here
        // cannot poison the session mutex, and the O(n·d) scan stays out
        // of the critical section
        StreamSession::validate_batch(rows, entry.d, entry.nonneg);
        let mut session = entry.session.lock().unwrap();
        // mirror the session-scoped counters service-wide by delta, so
        // work done on error paths (a forced re-sparsification before a
        // QueueFull shed evicts elements and runs SS rounds) is accounted
        // identically in both scopes
        let before = session.stats();
        let result = session.append_prevalidated(rows);
        let after = session.stats();
        drop(session);
        self.metrics.add(&self.metrics.counters.stream_appends, after.appends - before.appends);
        self.metrics
            .add(&self.metrics.counters.stream_admitted, after.admitted - before.admitted);
        self.metrics
            .add(&self.metrics.counters.resparsify_rounds, after.ss_rounds - before.ss_rounds);
        self.metrics
            .add(&self.metrics.counters.evicted_elements, after.evicted - before.evicted);
        result
    }

    /// Summarize a stream's current live set —
    /// [`SnapshotMode::Intermediate`] for the cheap stochastic-greedy
    /// refresh, [`SnapshotMode::Final`] for the exact batch-equivalent
    /// `sparsify → lazy greedy` pass.
    pub fn snapshot_summary(&self, id: StreamId, mode: SnapshotMode) -> Result<StreamSummary> {
        let entry = self.stream(id).ok_or_else(|| anyhow!("unknown or closed stream {id}"))?;
        let mut s = entry.session.lock().unwrap();
        s.snapshot_summary(mode)
    }

    /// Per-session metrics snapshot (the session-scoped counters —
    /// divergence/gain evals of its windows, its stream counters).
    pub fn stream_metrics(&self, id: StreamId) -> Result<crate::util::json::Json> {
        let entry = self.stream(id).ok_or_else(|| anyhow!("unknown or closed stream {id}"))?;
        let s = entry.session.lock().unwrap();
        Ok(s.metrics().snapshot())
    }

    /// Close a stream and drop its storage, returning lifetime stats.
    pub fn close(&self, id: StreamId) -> Result<StreamStats> {
        let entry = self
            .streams
            .lock()
            .unwrap()
            .remove(&id)
            .ok_or_else(|| anyhow!("unknown or closed stream {id}"))?;
        let mut s = entry.session.lock().unwrap();
        Ok(s.close())
    }

    fn stream(&self, id: StreamId) -> Option<StreamEntry> {
        self.streams.lock().unwrap().get(&id).cloned()
    }

    /// Graceful shutdown: close the queue (already-accepted requests still
    /// complete), then join the workers; open streaming sessions are
    /// closed and dropped. Afterwards `try_submit` reports
    /// [`SubmitError::ServiceDown`] and stream calls fail fast. Called by
    /// `Drop`; idempotent.
    pub fn shutdown(&mut self) {
        self.down.store(true, Ordering::SeqCst);
        for (_, entry) in self.streams.lock().unwrap().drain() {
            entry.session.lock().unwrap().close();
        }
        let (dead_tx, _) = sync_channel(1);
        let _ = std::mem::replace(&mut self.tx, dead_tx);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_json(&self) -> String {
        self.metrics.snapshot().pretty()
    }
}

impl Drop for SummarizationService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_main(
    rx: &Mutex<Receiver<QueuedJob>>,
    metrics: &Arc<Metrics>,
    pool: &Arc<ThreadPool>,
    runtime: Option<&Arc<TiledRuntime>>,
) {
    loop {
        let job = {
            let rx = rx.lock().unwrap();
            rx.recv()
        };
        let Ok(job) = job else { return };
        let queue_s = job.enqueued.elapsed_s();
        metrics.queue_wait.record_secs(queue_s);
        let result = handle(job.req, queue_s, metrics, pool, runtime);
        match &result {
            Ok(resp) => {
                metrics.add(&metrics.counters.completed, 1);
                metrics.request_latency.record_secs(resp.latency_s);
            }
            Err(_) => metrics.add(&metrics.counters.failed, 1),
        }
        let _ = job.reply.send(result);
    }
}

fn handle(
    req: SummarizeRequest,
    queue_s: f64,
    metrics: &Arc<Metrics>,
    pool: &Arc<ThreadPool>,
    runtime: Option<&Arc<TiledRuntime>>,
) -> Result<SummarizeResponse> {
    let timer = Timer::new();
    let n = req.objective.n();
    metrics.add(&metrics.counters.items_in, n as u64);
    let f: Arc<dyn BatchedDivergence> = req.objective.into_fn();
    let compute = if req.use_pjrt {
        let rt = runtime.ok_or_else(|| anyhow!("service started without a PJRT runtime"))?;
        Compute::Pjrt(Arc::clone(rt))
    } else {
        Compute::Cpu
    };
    let backend =
        ShardedBackend::new(Arc::clone(&f), Arc::clone(pool), compute.clone(), Arc::clone(metrics))?;
    let round_timer = Timer::new();
    let ss = sparsify(&backend, &req.params);
    if ss.rounds > 0 {
        // only real rounds produce a sample — a small-n passthrough (0
        // rounds) must not log its sparsify wall time as one fake round
        metrics.round_latency.record_secs(round_timer.elapsed_s() / ss.rounds as f64);
    }
    metrics.add(&metrics.counters.items_pruned, (n - ss.kept.len()) as u64);
    // post-reduction maximizer through the batched engine. PJRT requests on
    // a feature-based objective take the marginal-gain artifact route
    // (f32 device gains, CPU fallback — same contract as the divergence
    // side); everything else routes cohorts through the sharded backend,
    // which fans large ones over the compute pool and meters `gain_evals`.
    let sol = match &compute {
        Compute::Pjrt(rt) if f.as_feature_based().is_some() => {
            let mut eng =
                MaximizerEngine::new(f.as_submodular(), GainRoute::Pjrt(rt.as_ref()));
            let sol = eng.lazy_greedy(&ss.kept, req.k);
            // the PJRT route dispatches cohorts straight at the artifact,
            // bypassing ShardedBackend::gains_into — meter it here so
            // accelerated requests account their maximizer work too
            metrics.add(&metrics.counters.gain_evals, eng.stats().gain_evals);
            sol
        }
        _ => MaximizerEngine::new(f.as_submodular(), GainRoute::Backend(&backend))
            .lazy_greedy(&ss.kept, req.k),
    };
    Ok(SummarizeResponse {
        summary: sol.set,
        value: sol.value,
        n,
        reduced: ss.kept.len(),
        ss_rounds: ss.rounds,
        latency_s: timer.elapsed_s() + queue_s,
        queue_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        m
    }

    fn req(n: usize, seed: u64) -> SummarizeRequest {
        SummarizeRequest::features(feats(n, 16, seed), 8, SsParams::default().with_seed(seed))
    }

    #[test]
    fn roundtrip_single_request() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let resp = svc.submit(req(300, 1)).wait().unwrap();
        assert_eq!(resp.summary.len(), 8);
        assert_eq!(resp.n, 300);
        assert!(resp.reduced < 300);
        assert!(resp.value > 0.0);
        assert!(resp.latency_s >= resp.queue_s);
    }

    #[test]
    fn maximizer_gain_evals_are_metered() {
        // the post-reduction maximizer routes cohorts through the sharded
        // backend, so its per-element evaluations land on `gain_evals`
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let resp = svc.submit(req(300, 4)).wait().unwrap();
        assert_eq!(resp.summary.len(), 8);
        let m = svc.metrics().snapshot();
        assert!(
            m.get("gain_evals").unwrap().as_f64().unwrap() > 0.0,
            "engine gain route must be metered"
        );
    }

    #[test]
    fn facility_location_roundtrip() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let fl = FacilityLocation::from_features(&feats(300, 16, 2));
        let resp = svc
            .submit(SummarizeRequest {
                objective: Objective::FacilityLocation(fl),
                k: 8,
                params: SsParams::default().with_seed(2),
                use_pjrt: false,
            })
            .wait()
            .unwrap();
        assert_eq!(resp.summary.len(), 8);
        assert_eq!(resp.n, 300);
        assert!(resp.reduced < 300);
        assert!(resp.value > 0.0);
    }

    #[test]
    fn concurrent_requests_route_correctly() {
        // responses must correspond to their own request (different n's)
        let svc = SummarizationService::start(
            ServiceConfig { workers: 3, queue_depth: 16, compute_threads: 2 },
            None,
        );
        let sizes = [150usize, 220, 310, 180, 260, 400];
        let tickets: Vec<(usize, Ticket)> =
            sizes.iter().map(|&n| (n, svc.submit(req(n, n as u64)))).collect();
        for (n, t) in tickets {
            let resp = t.wait().unwrap();
            assert_eq!(resp.n, n, "response routed to wrong request");
            assert_eq!(resp.summary.len(), 8);
        }
        let m = svc.metrics().snapshot();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(6.0));
        assert_eq!(m.get("failed").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn try_submit_sheds_load_when_full() {
        let svc = SummarizationService::start(
            ServiceConfig { workers: 1, queue_depth: 1, compute_threads: 1 },
            None,
        );
        let mut accepted = 0;
        let mut shed = 0;
        let mut tickets = Vec::new();
        for i in 0..20 {
            match svc.try_submit(req(400, i)) {
                Ok(t) => {
                    accepted += 1;
                    tickets.push(t);
                }
                Err(e @ SubmitError::QueueFull(_)) => {
                    assert!(e.is_retryable());
                    shed += 1;
                }
                Err(SubmitError::ServiceDown(_)) => {
                    panic!("live service must report backpressure, not ServiceDown")
                }
            }
        }
        assert!(accepted >= 1);
        assert!(shed >= 1, "queue depth 1 must shed some of 20 rapid submits");
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn try_submit_distinguishes_dead_service_from_backpressure() {
        let mut svc = SummarizationService::start(ServiceConfig::default(), None);
        svc.shutdown();
        match svc.try_submit(req(50, 1)) {
            Err(e @ SubmitError::ServiceDown(_)) => {
                assert!(!e.is_retryable());
                assert_eq!(e.into_request().objective.n(), 50, "request must be handed back");
            }
            Err(SubmitError::QueueFull(_)) => {
                panic!("dead service must not masquerade as backpressure")
            }
            Ok(_) => panic!("dead service accepted a request"),
        }
        // blocking submit must not panic either: the ticket resolves to Err
        let err = svc.submit(req(50, 2)).wait().unwrap_err().to_string();
        assert!(err.contains("down"), "{err}");
        assert_eq!(
            svc.metrics().counters.requests.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "rejected requests must not count as accepted"
        );
    }

    #[test]
    fn passthrough_request_records_no_round_latency() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        // n = 20 ≤ r·log₂n probes ⇒ SS passes the ground set through in 0
        // rounds; that must not contribute a round-latency sample
        let resp = svc.submit(req(20, 3)).wait().unwrap();
        assert_eq!(resp.ss_rounds, 0, "small n must pass through un-pruned");
        assert_eq!(resp.reduced, 20);
        assert_eq!(
            svc.metrics().round_latency.count(),
            0,
            "0-round passthrough must not record a fake round latency"
        );
        // a real request does produce samples
        let resp = svc.submit(req(300, 3)).wait().unwrap();
        assert!(resp.ss_rounds > 0);
        assert!(svc.metrics().round_latency.count() > 0);
    }

    #[test]
    fn pjrt_request_without_runtime_fails_cleanly() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let r = req(100, 9).with_pjrt(true);
        let err = svc.submit(r).wait().unwrap_err().to_string();
        assert!(err.contains("PJRT"), "{err}");
        assert_eq!(
            svc.metrics().counters.failed.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn deterministic_given_params() {
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let a = svc.submit(req(250, 5)).wait().unwrap();
        let b = svc.submit(req(250, 5)).wait().unwrap();
        assert_eq!(a.summary, b.summary);
        assert_eq!(a.value, b.value);
    }

    #[test]
    fn stream_lifecycle_through_service() {
        use crate::stream::{SnapshotMode, StreamConfig, StreamObjective};
        use crate::submodular::Concave;
        let svc = SummarizationService::start(ServiceConfig::default(), None);
        let cfg = StreamConfig::new(6)
            .with_ss(SsParams::default().with_seed(7))
            .with_high_water(150);
        let id = svc.open_stream(StreamObjective::Features(Concave::Sqrt), 12, cfg).unwrap();
        let day1 = feats(400, 12, 21);
        let day2 = feats(300, 12, 22);
        let r1 = svc.append(id, day1.data()).unwrap();
        assert_eq!(r1.appended, 400);
        assert!(r1.resparsifies >= 1, "400 appends over hw=150 must re-sparsify");
        let mid = svc.snapshot_summary(id, SnapshotMode::Intermediate).unwrap();
        assert_eq!(mid.summary.len(), 6);
        let r2 = svc.append(id, day2.data()).unwrap();
        assert_eq!(r2.first_ext, 400, "external ids continue across batches");
        let fin = svc.snapshot_summary(id, SnapshotMode::Final).unwrap();
        assert_eq!(fin.summary.len(), 6);
        assert!(fin.value > 0.0);
        assert!(fin.live < 700, "windowing must have bounded the live set");
        // service-wide mirror of the session counters
        let m = svc.metrics().snapshot();
        assert_eq!(m.get("stream_appends").unwrap().as_f64(), Some(700.0));
        assert!(m.get("evicted_elements").unwrap().as_f64().unwrap() > 0.0);
        // per-session scope sees the same traffic
        let sm = svc.stream_metrics(id).unwrap();
        assert_eq!(sm.get("stream_appends").unwrap().as_f64(), Some(700.0));
        assert!(sm.get("divergence_evals").unwrap().as_f64().unwrap() > 0.0);
        let stats = svc.close(id).unwrap();
        assert_eq!(stats.appends, 700);
        assert_eq!(stats.windows as usize, r1.resparsifies + r2.resparsifies);
        // closed stream: append sheds as ServiceDown, snapshot/close error
        match svc.append(id, day1.data()) {
            Err(e @ SubmitError::ServiceDown(())) => assert!(!e.is_retryable()),
            _ => panic!("closed stream must report ServiceDown"),
        }
        assert!(svc.snapshot_summary(id, SnapshotMode::Final).is_err());
        assert!(svc.close(id).is_err());
    }

    #[test]
    fn stream_backpressure_and_shutdown() {
        use crate::stream::{StreamConfig, StreamObjective};
        use crate::submodular::Concave;
        let mut svc = SummarizationService::start(ServiceConfig::default(), None);
        let cfg = StreamConfig::new(4)
            .with_ss(SsParams::default().with_seed(3))
            .with_high_water(80)
            .with_max_live(200);
        let id = svc.open_stream(StreamObjective::Features(Concave::Sqrt), 8, cfg).unwrap();
        let ok = feats(150, 8, 31);
        svc.append(id, ok.data()).unwrap();
        let too_big = feats(300, 8, 32);
        match svc.append(id, too_big.data()) {
            Err(e @ SubmitError::QueueFull(())) => assert!(e.is_retryable()),
            _ => panic!("over-cap batch must shed with QueueFull"),
        }
        svc.shutdown();
        assert!(svc.open_stream(StreamObjective::Features(Concave::Sqrt), 8,
            StreamConfig::new(4)).is_err());
        match svc.append(id, ok.data()) {
            Err(SubmitError::ServiceDown(())) => {}
            _ => panic!("shut-down service must fail stream appends fast"),
        }
    }
}
