//! Coordinator metrics: atomic counters + latency histograms, snapshotted
//! to JSON for the service endpoint and the bench harness.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

#[derive(Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub items_in: AtomicU64,
    pub items_pruned: AtomicU64,
    /// Pairwise `w_{uv}` evaluations (probes × items per divergence batch)
    /// — the same unit `SsResult::divergence_evals` reports, so service
    /// metrics and algorithm accounting agree.
    pub divergence_evals: AtomicU64,
    /// Importance-weight evaluations (one `f(u) + f(u|V∖u)` per live item
    /// per importance-sampled round) — a separate counter because the unit
    /// is per-item, not pairwise.
    pub importance_evals: AtomicU64,
    /// Marginal-gain evaluations dispatched through the batched-gain route
    /// (one `f(v|S)` per cohort element) — the post-reduction maximizer's
    /// work, in the same per-element unit as `Solution::oracle_calls`.
    pub gain_evals: AtomicU64,
    pub tiles_dispatched: AtomicU64,
    /// Elements appended to streaming sessions (admitted or not).
    pub stream_appends: AtomicU64,
    /// Appended elements the sieve admission stage let into a session's
    /// candidate buffer (== `stream_appends` when the filter is off).
    pub stream_admitted: AtomicU64,
    /// SS rounds run by windowed re-sparsifications (snapshot-time SS
    /// passes are *not* counted here — they evict nothing).
    pub resparsify_rounds: AtomicU64,
    /// Elements evicted (storage compacted away) by re-sparsifications.
    pub evicted_elements: AtomicU64,
    /// Jobs resolved [`Cancelled`](crate::coordinator::ServiceError::Cancelled)
    /// — shed at dequeue or aborted at an SS round boundary.
    pub cancelled: AtomicU64,
    /// Jobs resolved
    /// [`DeadlineExceeded`](crate::coordinator::ServiceError::DeadlineExceeded)
    /// — expired in the queue (shed without touching the compute pool) or
    /// overrun mid-flight and aborted at an SS round boundary.
    pub deadline_exceeded: AtomicU64,
    /// Copy-on-snapshot stream jobs accepted onto the worker queue.
    pub snapshot_jobs: AtomicU64,
    /// Ground-set rows currently backed by a sparse top-t neighbor store
    /// (0 when the objective is dense or feature-only). Gauge-style: set
    /// at backend construction, not accumulated.
    pub sparse_rows: AtomicU64,
    /// Existing neighbor-list entries displaced or inserted by streaming
    /// row-border appends into a sparse similarity store — the incremental
    /// work that replaces the O(m²·d) per-window rebuild.
    pub neighbor_updates: AtomicU64,
    /// Batches logged to durable sessions' write-ahead logs (one record
    /// per append batch, flushed before the session mutates).
    pub wal_appends: AtomicU64,
    /// Checkpoints written (auto-interval and explicit alike).
    pub checkpoints: AtomicU64,
    /// Sessions rebuilt from a durable store (checkpoint + WAL replay).
    pub recoveries: AtomicU64,
    /// Torn WAL tails truncated away during recovery (at most one per
    /// recovery — a crash tears at most the final record).
    pub torn_tail_truncations: AtomicU64,
    /// Candidate pairs actually scored by an LSH-bucketed neighbor build
    /// (batch build plus every incremental append since). Gauge-style like
    /// `sparse_rows`: set when a backend (re)binds its objective. Compare
    /// against n·(n−1) to read the pruning ratio the hash tables bought.
    pub lsh_candidates: AtomicU64,
    /// Largest hash-bucket occupancy across the LSH index's tables — the
    /// skew gauge: a bucket near n means the projections aren't splitting
    /// the data and the build is degenerating toward all-pairs.
    pub lsh_bucket_max: AtomicU64,
}

impl Counters {
    /// Every counter with its snapshot key — the single authoritative
    /// list [`Metrics::snapshot`] and [`Self::reset`] both iterate, so a
    /// counter added here is automatically snapshotted *and* reset (the
    /// two can never drift apart).
    fn named(&self) -> [(&'static str, &AtomicU64); 24] {
        [
            ("requests", &self.requests),
            ("completed", &self.completed),
            ("failed", &self.failed),
            ("items_in", &self.items_in),
            ("items_pruned", &self.items_pruned),
            ("divergence_evals", &self.divergence_evals),
            ("importance_evals", &self.importance_evals),
            ("gain_evals", &self.gain_evals),
            ("tiles_dispatched", &self.tiles_dispatched),
            ("stream_appends", &self.stream_appends),
            ("stream_admitted", &self.stream_admitted),
            ("resparsify_rounds", &self.resparsify_rounds),
            ("evicted_elements", &self.evicted_elements),
            ("cancelled", &self.cancelled),
            ("deadline_exceeded", &self.deadline_exceeded),
            ("snapshot_jobs", &self.snapshot_jobs),
            ("sparse_rows", &self.sparse_rows),
            ("neighbor_updates", &self.neighbor_updates),
            ("wal_appends", &self.wal_appends),
            ("checkpoints", &self.checkpoints),
            ("recoveries", &self.recoveries),
            ("torn_tail_truncations", &self.torn_tail_truncations),
            ("lsh_candidates", &self.lsh_candidates),
            ("lsh_bucket_max", &self.lsh_bucket_max),
        ]
    }

    /// Zero every counter — the per-session / per-window metrics scope for
    /// long-lived streaming sessions, which would otherwise conflate
    /// windows over a process lifetime. Relaxed stores: concurrent
    /// increments may land on either side of the reset.
    pub fn reset(&self) {
        for (_, c) in self.named() {
            c.store(0, Ordering::Relaxed);
        }
    }
}

pub struct Metrics {
    pub counters: Counters,
    pub request_latency: LatencyHistogram,
    pub queue_wait: LatencyHistogram,
    pub round_latency: LatencyHistogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            counters: Counters::default(),
            request_latency: LatencyHistogram::new(),
            queue_wait: LatencyHistogram::new(),
            round_latency: LatencyHistogram::new(),
        }
    }

    pub fn add(&self, c: &AtomicU64, v: u64) {
        c.fetch_add(v, Ordering::Relaxed);
    }

    /// Zero all counters and histograms — see [`Counters::reset`].
    pub fn reset(&self) {
        self.counters.reset();
        self.request_latency.reset();
        self.queue_wait.reset();
        self.round_latency.reset();
    }

    pub fn snapshot(&self) -> Json {
        let hist = |h: &LatencyHistogram| {
            Json::obj(vec![
                ("count", Json::Num(h.count() as f64)),
                ("p50_s", Json::Num(h.percentile_secs(50.0))),
                ("p95_s", Json::Num(h.percentile_secs(95.0))),
                ("p99_s", Json::Num(h.percentile_secs(99.0))),
            ])
        };
        let mut fields: Vec<(&str, Json)> = self
            .counters
            .named()
            .into_iter()
            .map(|(name, c)| (name, Json::Num(c.load(Ordering::Relaxed) as f64)))
            .collect();
        fields.push(("request_latency", hist(&self.request_latency)));
        fields.push(("queue_wait", hist(&self.queue_wait)));
        fields.push(("round_latency", hist(&self.round_latency)));
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_shape() {
        let m = Metrics::new();
        m.add(&m.counters.requests, 3);
        m.request_latency.record_secs(0.01);
        let s = m.snapshot();
        assert_eq!(s.get("requests").unwrap().as_f64(), Some(3.0));
        assert!(s.get("request_latency").unwrap().get("p50_s").unwrap().as_f64().unwrap() > 0.0);
        // serializes cleanly
        let text = s.pretty();
        assert!(crate::util::json::parse(&text).is_ok());
    }

    #[test]
    fn reset_zeroes_counters_and_histograms() {
        let m = Metrics::new();
        m.add(&m.counters.requests, 3);
        m.add(&m.counters.stream_appends, 7);
        m.add(&m.counters.evicted_elements, 2);
        m.request_latency.record_secs(0.01);
        m.round_latency.record_secs(0.02);
        m.reset();
        let s = m.snapshot();
        assert_eq!(s.get("requests").unwrap().as_f64(), Some(0.0));
        assert_eq!(s.get("stream_appends").unwrap().as_f64(), Some(0.0));
        assert_eq!(s.get("evicted_elements").unwrap().as_f64(), Some(0.0));
        assert_eq!(m.request_latency.count(), 0);
        assert_eq!(m.round_latency.count(), 0);
        // usable again after the reset
        m.add(&m.counters.stream_admitted, 1);
        assert_eq!(m.snapshot().get("stream_admitted").unwrap().as_f64(), Some(1.0));
    }
}
