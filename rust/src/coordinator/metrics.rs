//! Coordinator metrics: atomic counters + latency histograms, organized
//! into **labeled scopes** and snapshotted to JSON for the service
//! endpoint and the bench harness.
//!
//! # Scoped metrics
//!
//! A [`Metrics`] value is one *scope*: a label, a full [`Counters`]
//! block, the three latency histograms, and a [`Tracer`]. The service
//! owns one scope per deployment surface — the `"service"` scope for the
//! whole instance, plus one `"stream-{id}"` scope per open streaming
//! session — so counters attribute to sessions instead of accumulating
//! into one global pile (the per-tenant model ROADMAP's QoS direction
//! builds on). Stream traffic is *mirrored* onto the service scope by
//! delta (see `SummarizationService::append`), so dashboards still get
//! the one-stop aggregate view.
//!
//! # Counters vs gauges
//!
//! [`Counters`] holds two families with different reset semantics:
//!
//! * **counters** — monotone within a metering window (`requests`,
//!   `divergence_evals`, …); [`reset`](Counters::reset) zeroes them, the
//!   per-window scoping long-lived sessions rely on.
//! * **gauges** — *current-state* readings set at backend (re)bind time
//!   (`sparse_rows`, `lsh_candidates`, `lsh_bucket_max`,
//!   `resident_bytes`); a reset must **not** zero them, because nothing
//!   re-stores them until the next bind — a post-reset snapshot would
//!   misreport store residency as 0.
//!
//! Both families appear in [`Metrics::snapshot`]; only the counter
//! family is cleared by [`Metrics::reset`].
//!
//! # Tracing
//!
//! Each scope's tracer collects [`TraceEvent`](crate::trace::TraceEvent)
//! spans for the work metered under it — disabled (and free) by default,
//! enabled per-scope (`metrics.tracer().enable(label, cap)`). Stream
//! scopes are opened with tracing *on*: their ring doubles as the
//! quarantine flight recorder (see [`crate::trace`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::trace::Tracer;
use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

#[derive(Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub items_in: AtomicU64,
    pub items_pruned: AtomicU64,
    /// Pairwise `w_{uv}` evaluations (probes × items per divergence batch)
    /// — the same unit `SsResult::divergence_evals` reports, so service
    /// metrics and algorithm accounting agree.
    pub divergence_evals: AtomicU64,
    /// Importance-weight evaluations (one `f(u) + f(u|V∖u)` per live item
    /// per importance-sampled round) — a separate counter because the unit
    /// is per-item, not pairwise.
    pub importance_evals: AtomicU64,
    /// Marginal-gain evaluations dispatched through the batched-gain route
    /// (one `f(v|S)` per cohort element) — the post-reduction maximizer's
    /// work, in the same per-element unit as `Solution::oracle_calls`.
    pub gain_evals: AtomicU64,
    pub tiles_dispatched: AtomicU64,
    /// Elements appended to streaming sessions (admitted or not).
    pub stream_appends: AtomicU64,
    /// Appended elements the sieve admission stage let into a session's
    /// candidate buffer (== `stream_appends` when the filter is off).
    pub stream_admitted: AtomicU64,
    /// SS rounds run by windowed re-sparsifications (snapshot-time SS
    /// passes are *not* counted here — they evict nothing).
    pub resparsify_rounds: AtomicU64,
    /// Elements evicted (storage compacted away) by re-sparsifications.
    pub evicted_elements: AtomicU64,
    /// Jobs resolved [`Cancelled`](crate::coordinator::ServiceError::Cancelled)
    /// — shed at dequeue or aborted at an SS round boundary.
    pub cancelled: AtomicU64,
    /// Jobs resolved
    /// [`DeadlineExceeded`](crate::coordinator::ServiceError::DeadlineExceeded)
    /// — expired in the queue (shed without touching the compute pool) or
    /// overrun mid-flight and aborted at an SS round boundary.
    pub deadline_exceeded: AtomicU64,
    /// Copy-on-snapshot stream jobs accepted onto the worker queue.
    pub snapshot_jobs: AtomicU64,
    /// Existing neighbor-list entries displaced or inserted by streaming
    /// row-border appends into a sparse similarity store — the incremental
    /// work that replaces the O(m²·d) per-window rebuild.
    pub neighbor_updates: AtomicU64,
    /// Batches logged to durable sessions' write-ahead logs (one record
    /// per append batch, flushed before the session mutates).
    pub wal_appends: AtomicU64,
    /// Checkpoints written (auto-interval and explicit alike).
    pub checkpoints: AtomicU64,
    /// Sessions rebuilt from a durable store (checkpoint + WAL replay).
    pub recoveries: AtomicU64,
    /// Torn WAL tails truncated away during recovery (at most one per
    /// recovery — a crash tears at most the final record).
    pub torn_tail_truncations: AtomicU64,

    // -- cluster family: the wire + fan-out path (coordinator and worker
    // -- runtimes meter into their own scopes) ---------------------------
    /// Framed protocol messages written to peers.
    pub rpc_frames_sent: AtomicU64,
    /// Framed protocol messages read from peers.
    pub rpc_frames_recv: AtomicU64,
    /// Wire bytes written (frame envelope included).
    pub rpc_bytes_sent: AtomicU64,
    /// Wire bytes read (frame envelope included).
    pub rpc_bytes_recv: AtomicU64,
    /// Shard assignments dispatched to workers, retries included.
    pub shards_dispatched: AtomicU64,
    /// Shard attempts re-dispatched after a failure or straggler timeout.
    pub shard_retries: AtomicU64,
    /// Worker connections declared dead (transport failure or corrupt
    /// stream) and excluded from further dispatch.
    pub worker_deaths: AtomicU64,
    /// Frames that failed to decode (corrupt / truncated / reordered) —
    /// every one of these also surfaced as a typed error to the caller.
    pub wire_decode_errors: AtomicU64,

    // -- gauge family (reset-exempt; see the module docs) ----------------
    /// Ground-set rows currently backed by a sparse top-t neighbor store
    /// (0 when the objective is dense or feature-only). Gauge: set at
    /// backend construction, not accumulated.
    pub sparse_rows: AtomicU64,
    /// Candidate pairs actually scored by an LSH-bucketed neighbor build
    /// (batch build plus every incremental append since). Gauge like
    /// `sparse_rows`: set when a backend (re)binds its objective. Compare
    /// against n·(n−1) to read the pruning ratio the hash tables bought.
    pub lsh_candidates: AtomicU64,
    /// Largest hash-bucket occupancy across the LSH index's tables — the
    /// skew gauge: a bucket near n means the projections aren't splitting
    /// the data and the build is degenerating toward all-pairs.
    pub lsh_bucket_max: AtomicU64,
    /// Bytes resident in the bound objective's similarity/feature store
    /// (dense matrix or sparse neighbor lists) — the memory-footprint
    /// gauge behind capacity planning. Set at backend (re)bind, like the
    /// other store-shape gauges.
    pub resident_bytes: AtomicU64,
}

impl Counters {
    /// Every true counter with its snapshot key — the authoritative list
    /// [`Metrics::snapshot`] and [`Self::reset`] both iterate, so a
    /// counter added here is automatically snapshotted *and* reset (the
    /// two can never drift apart).
    fn named_counters(&self) -> [(&'static str, &AtomicU64); 29] {
        [
            ("requests", &self.requests),
            ("completed", &self.completed),
            ("failed", &self.failed),
            ("items_in", &self.items_in),
            ("items_pruned", &self.items_pruned),
            ("divergence_evals", &self.divergence_evals),
            ("importance_evals", &self.importance_evals),
            ("gain_evals", &self.gain_evals),
            ("tiles_dispatched", &self.tiles_dispatched),
            ("stream_appends", &self.stream_appends),
            ("stream_admitted", &self.stream_admitted),
            ("resparsify_rounds", &self.resparsify_rounds),
            ("evicted_elements", &self.evicted_elements),
            ("cancelled", &self.cancelled),
            ("deadline_exceeded", &self.deadline_exceeded),
            ("snapshot_jobs", &self.snapshot_jobs),
            ("neighbor_updates", &self.neighbor_updates),
            ("wal_appends", &self.wal_appends),
            ("checkpoints", &self.checkpoints),
            ("recoveries", &self.recoveries),
            ("torn_tail_truncations", &self.torn_tail_truncations),
            ("rpc_frames_sent", &self.rpc_frames_sent),
            ("rpc_frames_recv", &self.rpc_frames_recv),
            ("rpc_bytes_sent", &self.rpc_bytes_sent),
            ("rpc_bytes_recv", &self.rpc_bytes_recv),
            ("shards_dispatched", &self.shards_dispatched),
            ("shard_retries", &self.shard_retries),
            ("worker_deaths", &self.worker_deaths),
            ("wire_decode_errors", &self.wire_decode_errors),
        ]
    }

    /// The gauge family: current-state store-shape readings, snapshotted
    /// alongside the counters but **exempt from [`reset`](Self::reset)**
    /// — nothing re-stores a gauge until the next backend bind, so
    /// zeroing it would misreport residency for the whole window.
    fn named_gauges(&self) -> [(&'static str, &AtomicU64); 4] {
        [
            ("sparse_rows", &self.sparse_rows),
            ("lsh_candidates", &self.lsh_candidates),
            ("lsh_bucket_max", &self.lsh_bucket_max),
            ("resident_bytes", &self.resident_bytes),
        ]
    }

    /// Zero every *counter* — the per-session / per-window metrics scope
    /// for long-lived streaming sessions, which would otherwise conflate
    /// windows over a process lifetime. Gauges keep their values (they
    /// describe the store as it is now, not work done this window).
    /// Relaxed stores: concurrent increments may land on either side of
    /// the reset.
    pub fn reset(&self) {
        for (_, c) in self.named_counters() {
            c.store(0, Ordering::Relaxed);
        }
    }
}

/// One labeled metrics scope — see the module docs.
pub struct Metrics {
    label: String,
    pub counters: Counters,
    pub request_latency: LatencyHistogram,
    pub queue_wait: LatencyHistogram,
    pub round_latency: LatencyHistogram,
    tracer: Arc<Tracer>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// The service-wide scope (label `"service"`), tracing disabled.
    pub fn new() -> Self {
        Self::scoped("service")
    }

    /// A fresh scope under `label` (e.g. `"stream-3"`, a tenant id).
    /// Tracing starts disabled; enable it via
    /// [`tracer`](Self::tracer)`.enable(label, cap)`.
    pub fn scoped(label: &str) -> Self {
        Self {
            label: label.to_string(),
            counters: Counters::default(),
            request_latency: LatencyHistogram::new(),
            queue_wait: LatencyHistogram::new(),
            round_latency: LatencyHistogram::new(),
            tracer: Arc::new(Tracer::disabled()),
        }
    }

    /// The scope's label, as emitted under the snapshot's `"scope"` key.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// The scope's span collector (shared handle — the service clones it
    /// out as the per-stream flight recorder).
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    pub fn add(&self, c: &AtomicU64, v: u64) {
        c.fetch_add(v, Ordering::Relaxed);
    }

    /// Zero all counters and histograms (gauges persist — see
    /// [`Counters::reset`]).
    pub fn reset(&self) {
        self.counters.reset();
        self.request_latency.reset();
        self.queue_wait.reset();
        self.round_latency.reset();
    }

    pub fn snapshot(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![("scope", Json::Str(self.label.clone()))];
        fields.extend(
            self.counters
                .named_counters()
                .into_iter()
                .map(|(name, c)| (name, Json::Num(c.load(Ordering::Relaxed) as f64))),
        );
        fields.extend(
            self.counters
                .named_gauges()
                .into_iter()
                .map(|(name, g)| (name, Json::Num(g.load(Ordering::Relaxed) as f64))),
        );
        fields.push(("request_latency", self.request_latency.snapshot_json()));
        fields.push(("queue_wait", self.queue_wait.snapshot_json()));
        fields.push(("round_latency", self.round_latency.snapshot_json()));
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_shape() {
        let m = Metrics::new();
        m.add(&m.counters.requests, 3);
        m.request_latency.record_secs(0.01);
        let s = m.snapshot();
        assert_eq!(s.get("scope").unwrap().as_str(), Some("service"));
        assert_eq!(s.get("requests").unwrap().as_f64(), Some(3.0));
        assert!(s.get("request_latency").unwrap().get("p50_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(s.get("request_latency").unwrap().get("p99_s").is_some());
        // serializes cleanly
        let text = s.pretty();
        assert!(crate::util::json::parse(&text).is_ok());
    }

    #[test]
    fn scoped_metrics_carry_their_label() {
        let m = Metrics::scoped("stream-7");
        assert_eq!(m.label(), "stream-7");
        assert_eq!(m.snapshot().get("scope").unwrap().as_str(), Some("stream-7"));
        assert!(!m.tracer().is_enabled(), "scopes start with tracing off");
    }

    #[test]
    fn reset_zeroes_counters_and_histograms_but_not_gauges() {
        let m = Metrics::new();
        m.add(&m.counters.requests, 3);
        m.add(&m.counters.stream_appends, 7);
        m.add(&m.counters.evicted_elements, 2);
        // gauges: stored at backend bind, must survive a window reset
        m.counters.sparse_rows.store(160, Ordering::Relaxed);
        m.counters.lsh_candidates.store(900, Ordering::Relaxed);
        m.counters.lsh_bucket_max.store(12, Ordering::Relaxed);
        m.counters.resident_bytes.store(4096, Ordering::Relaxed);
        m.request_latency.record_secs(0.01);
        m.round_latency.record_secs(0.02);
        m.reset();
        let s = m.snapshot();
        assert_eq!(s.get("requests").unwrap().as_f64(), Some(0.0));
        assert_eq!(s.get("stream_appends").unwrap().as_f64(), Some(0.0));
        assert_eq!(s.get("evicted_elements").unwrap().as_f64(), Some(0.0));
        assert_eq!(m.request_latency.count(), 0);
        assert_eq!(m.round_latency.count(), 0);
        // the gauge family is reset-exempt
        assert_eq!(s.get("sparse_rows").unwrap().as_f64(), Some(160.0));
        assert_eq!(s.get("lsh_candidates").unwrap().as_f64(), Some(900.0));
        assert_eq!(s.get("lsh_bucket_max").unwrap().as_f64(), Some(12.0));
        assert_eq!(s.get("resident_bytes").unwrap().as_f64(), Some(4096.0));
        // usable again after the reset
        m.add(&m.counters.stream_admitted, 1);
        assert_eq!(m.snapshot().get("stream_admitted").unwrap().as_f64(), Some(1.0));
    }
}
