//! Coordinator metrics: atomic counters + latency histograms, snapshotted
//! to JSON for the service endpoint and the bench harness.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

#[derive(Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub items_in: AtomicU64,
    pub items_pruned: AtomicU64,
    /// Pairwise `w_{uv}` evaluations (probes × items per divergence batch)
    /// — the same unit `SsResult::divergence_evals` reports, so service
    /// metrics and algorithm accounting agree.
    pub divergence_evals: AtomicU64,
    /// Importance-weight evaluations (one `f(u) + f(u|V∖u)` per live item
    /// per importance-sampled round) — a separate counter because the unit
    /// is per-item, not pairwise.
    pub importance_evals: AtomicU64,
    /// Marginal-gain evaluations dispatched through the batched-gain route
    /// (one `f(v|S)` per cohort element) — the post-reduction maximizer's
    /// work, in the same per-element unit as `Solution::oracle_calls`.
    pub gain_evals: AtomicU64,
    pub tiles_dispatched: AtomicU64,
}

pub struct Metrics {
    pub counters: Counters,
    pub request_latency: LatencyHistogram,
    pub queue_wait: LatencyHistogram,
    pub round_latency: LatencyHistogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            counters: Counters::default(),
            request_latency: LatencyHistogram::new(),
            queue_wait: LatencyHistogram::new(),
            round_latency: LatencyHistogram::new(),
        }
    }

    pub fn add(&self, c: &AtomicU64, v: u64) {
        c.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Json {
        let g = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        let hist = |h: &LatencyHistogram| {
            Json::obj(vec![
                ("count", Json::Num(h.count() as f64)),
                ("p50_s", Json::Num(h.percentile_secs(50.0))),
                ("p95_s", Json::Num(h.percentile_secs(95.0))),
                ("p99_s", Json::Num(h.percentile_secs(99.0))),
            ])
        };
        Json::obj(vec![
            ("requests", g(&self.counters.requests)),
            ("completed", g(&self.counters.completed)),
            ("failed", g(&self.counters.failed)),
            ("items_in", g(&self.counters.items_in)),
            ("items_pruned", g(&self.counters.items_pruned)),
            ("divergence_evals", g(&self.counters.divergence_evals)),
            ("importance_evals", g(&self.counters.importance_evals)),
            ("gain_evals", g(&self.counters.gain_evals)),
            ("tiles_dispatched", g(&self.counters.tiles_dispatched)),
            ("request_latency", hist(&self.request_latency)),
            ("queue_wait", hist(&self.queue_wait)),
            ("round_latency", hist(&self.round_latency)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_shape() {
        let m = Metrics::new();
        m.add(&m.counters.requests, 3);
        m.request_latency.record_secs(0.01);
        let s = m.snapshot();
        assert_eq!(s.get("requests").unwrap().as_f64(), Some(3.0));
        assert!(s.get("request_latency").unwrap().get("p50_s").unwrap().as_f64().unwrap() > 0.0);
        // serializes cleanly
        let text = s.pretty();
        assert!(crate::util::json::parse(&text).is_ok());
    }
}
