//! The service's job primitives: one typed error for every fallible call,
//! and one ticket shape for every unit of work.
//!
//! Everything [`SummarizationService`](super::SummarizationService) accepts
//! — a batch summarize request, a copy-on-snapshot stream summary — is a
//! *job*: submitted (blocking or `try_`), tracked by a [`Ticket<T>`], and
//! resolved exactly once with `Result<T, ServiceError>`. The ticket owns
//! the caller half of a tiny one-shot state machine
//! (pending → ready → taken, a mutex + condvar — no channel, so a timed
//! wait can expire *without* consuming the eventual response); the worker
//! half is the crate-private [`Responder`], whose `Drop` guarantees a
//! ticket can never hang: a responder dropped unresolved (worker panic,
//! queue torn down at shutdown) resolves the ticket
//! [`ServiceError::ServiceDown`].
//!
//! Cancellation and deadlines are cooperative and cheap: [`Ticket::cancel`]
//! flips an atomic flag, [`JobOptions::with_deadline`] pins an instant, and
//! workers poll both — once at dequeue (so shed work never touches the
//! compute pool) and between SS rounds (so shed work stops burning it),
//! via [`Responder::interrupt`] feeding the round-boundary probe of
//! [`sparsify_candidates_with`](crate::algorithms::sparsify_candidates_with).

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::algorithms::Interrupt;

use super::service::StreamId;

/// Why a service call failed, typed — the *only* error the public service
/// surface speaks. Generic over the payload handed back on backpressure:
/// [`try_submit`](super::SummarizationService::try_submit) returns the
/// whole `SummarizeRequest` so shed load is never lost, the streaming
/// `append` path returns `ServiceError<()>` (the caller still owns its
/// rows). Only [`QueueFull`](Self::QueueFull) is worth retrying.
#[derive(Clone, PartialEq)]
pub enum ServiceError<R = ()> {
    /// Bounded queue (or session live-set cap) is full — backpressure; the
    /// rejected payload is handed back and retrying later can succeed.
    QueueFull(R),
    /// The service's workers are gone, or the session is closed — retrying
    /// against this instance can never succeed.
    ServiceDown,
    /// No open stream has this id (never opened, or already closed).
    UnknownStream(StreamId),
    /// The request itself is unservable (e.g. a PJRT request on a service
    /// started without a runtime, or an invalid session config) — retrying
    /// the identical call can never succeed.
    Rejected {
        reason: String,
    },
    /// The job's ticket was cancelled before it completed.
    Cancelled,
    /// The job's deadline passed before it completed — expired jobs are
    /// shed at dequeue (never touching the compute pool) or abandoned at
    /// the next SS round boundary.
    DeadlineExceeded,
}

impl<R> ServiceError<R> {
    /// Retrying the same call later can succeed (backpressure only).
    pub fn is_retryable(&self) -> bool {
        matches!(self, ServiceError::QueueFull(_))
    }

    /// Recover the rejected payload ([`QueueFull`](Self::QueueFull) only —
    /// the other variants never took ownership of anything).
    pub fn into_payload(self) -> Option<R> {
        match self {
            ServiceError::QueueFull(r) => Some(r),
            _ => None,
        }
    }
}

impl<R> fmt::Display for ServiceError<R> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::QueueFull(_) => f.write_str("queue full (backpressure; retry later)"),
            ServiceError::ServiceDown => f.write_str("service is down"),
            ServiceError::UnknownStream(id) => write!(f, "unknown or closed stream {id}"),
            ServiceError::Rejected { reason } => write!(f, "rejected: {reason}"),
            ServiceError::Cancelled => f.write_str("cancelled"),
            ServiceError::DeadlineExceeded => f.write_str("deadline exceeded"),
        }
    }
}

// Manual Debug so the payload (a whole request, possibly megabytes of
// features) is elided rather than required to be Debug itself.
impl<R> fmt::Debug for ServiceError<R> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::QueueFull(_) => f.write_str("ServiceError::QueueFull(..)"),
            ServiceError::ServiceDown => f.write_str("ServiceError::ServiceDown"),
            ServiceError::UnknownStream(id) => write!(f, "ServiceError::UnknownStream({id})"),
            ServiceError::Rejected { reason } => {
                write!(f, "ServiceError::Rejected {{ reason: {reason:?} }}")
            }
            ServiceError::Cancelled => f.write_str("ServiceError::Cancelled"),
            ServiceError::DeadlineExceeded => f.write_str("ServiceError::DeadlineExceeded"),
        }
    }
}

impl<R> std::error::Error for ServiceError<R> {}

impl<R> From<Interrupt> for ServiceError<R> {
    fn from(why: Interrupt) -> Self {
        match why {
            Interrupt::Cancelled => ServiceError::Cancelled,
            Interrupt::DeadlineExceeded => ServiceError::DeadlineExceeded,
        }
    }
}

/// Per-job submit options (all submit paths have a `_with` form taking
/// one; the plain forms use `JobOptions::default()` — no deadline).
#[derive(Clone, Copy, Debug, Default)]
pub struct JobOptions {
    /// Absolute deadline: a job still queued past it is shed at dequeue
    /// without touching the compute pool; a job already running is
    /// abandoned at the next SS round boundary. Either way its ticket
    /// resolves [`ServiceError::DeadlineExceeded`].
    pub deadline: Option<Instant>,
}

impl JobOptions {
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Deadline relative to now.
    pub fn with_timeout(self, timeout: Duration) -> Self {
        self.with_deadline(Instant::now() + timeout)
    }
}

/// One-shot result slot shared by a [`Ticket`] and its [`Responder`].
enum Slot<T> {
    Pending,
    Ready(Result<T, ServiceError>),
    /// A `&mut` accessor already handed the result out.
    Taken,
}

struct Shared<T> {
    slot: Mutex<Slot<T>>,
    ready: Condvar,
    cancelled: AtomicBool,
    deadline: Option<Instant>,
}

/// Create the two halves of a job: the caller's ticket and the worker's
/// responder.
pub(crate) fn job_channel<T>(opts: JobOptions) -> (Ticket<T>, Responder<T>) {
    let shared = Arc::new(Shared {
        slot: Mutex::new(Slot::Pending),
        ready: Condvar::new(),
        cancelled: AtomicBool::new(false),
        deadline: opts.deadline,
    });
    (Ticket { shared: Arc::clone(&shared) }, Responder { shared, resolved: false })
}

/// Handle to an in-flight job. Every submitted unit of work — batch
/// summarize, stream snapshot — returns one, parameterized by its response
/// type.
///
/// * [`wait`](Self::wait) blocks until the job resolves (consuming the
///   ticket);
/// * [`wait_timeout`](Self::wait_timeout) / [`try_wait`](Self::try_wait)
///   poll without forfeiting a late response — a timed-out wait leaves the
///   ticket live, and the response is retrievable by any later wait;
/// * [`cancel`](Self::cancel) requests cooperative cancellation (a no-op
///   once the job completed);
/// * a deadline set at submit time ([`JobOptions`]) sheds the job without
///   any caller involvement.
///
/// A ticket can never hang: if the worker side disappears before
/// resolving (shutdown tear-down, worker panic), the responder's `Drop`
/// resolves it [`ServiceError::ServiceDown`].
pub struct Ticket<T> {
    shared: Arc<Shared<T>>,
}

impl<T> fmt::Debug for Ticket<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Ticket")
            .field("done", &self.is_done())
            .field("deadline", &self.shared.deadline)
            .finish()
    }
}

impl<T> Ticket<T> {
    /// Block until the job resolves and take the result.
    pub fn wait(self) -> Result<T, ServiceError> {
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            match std::mem::replace(&mut *slot, Slot::Taken) {
                Slot::Pending => {
                    *slot = Slot::Pending;
                    slot = self.shared.ready.wait(slot).unwrap();
                }
                Slot::Ready(result) => return result,
                Slot::Taken => {
                    // a &mut accessor already handed the result out — a
                    // caller bug, reported rather than hung on
                    return Err(ServiceError::Rejected {
                        reason: "ticket result was already taken".into(),
                    });
                }
            }
        }
    }

    /// Wait at most `timeout` for the result. `None` = not ready yet — the
    /// ticket stays live and a late response is **never lost**: it stays
    /// retrievable by any subsequent `wait`/`wait_timeout`/`try_wait`.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Option<Result<T, ServiceError>> {
        let deadline = Instant::now() + timeout;
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            match std::mem::replace(&mut *slot, Slot::Taken) {
                Slot::Ready(result) => return Some(result),
                Slot::Taken => {
                    return Some(Err(ServiceError::Rejected {
                        reason: "ticket result was already taken".into(),
                    }))
                }
                Slot::Pending => {
                    *slot = Slot::Pending;
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (guard, _timed_out) =
                        self.shared.ready.wait_timeout(slot, deadline - now).unwrap();
                    slot = guard;
                    // loop re-checks the slot: spurious wakeups and
                    // timeout races both resolve by inspection, so a
                    // response that lands exactly at the deadline is
                    // returned, not dropped
                }
            }
        }
    }

    /// Non-blocking poll. `None` = still in flight (ticket stays live).
    pub fn try_wait(&mut self) -> Option<Result<T, ServiceError>> {
        self.wait_timeout(Duration::ZERO)
    }

    /// Whether the job has resolved (the result may already be taken).
    pub fn is_done(&self) -> bool {
        !matches!(*self.shared.slot.lock().unwrap(), Slot::Pending)
    }

    /// Request cooperative cancellation: a still-queued job is shed at
    /// dequeue (never touching the compute pool), a running job is
    /// abandoned at the next SS round boundary; either way the ticket
    /// resolves [`ServiceError::Cancelled`]. After completion this is a
    /// no-op — the result stays available.
    pub fn cancel(&self) {
        self.shared.cancelled.store(true, Ordering::Relaxed);
    }

    /// The deadline this job was submitted with, if any.
    pub fn deadline(&self) -> Option<Instant> {
        self.shared.deadline
    }
}

/// Worker half of a job: resolves the ticket exactly once and exposes the
/// cancellation/deadline probe. Dropping it unresolved (worker panic,
/// queue tear-down) resolves the ticket [`ServiceError::ServiceDown`] so
/// callers never hang.
pub(crate) struct Responder<T> {
    shared: Arc<Shared<T>>,
    resolved: bool,
}

impl<T> Responder<T> {
    /// The job's cancel/deadline state — the dequeue check and the SS
    /// round-boundary probe. Cancellation wins over an expired deadline
    /// (the caller explicitly asked).
    pub(crate) fn interrupt(&self) -> Option<Interrupt> {
        if self.shared.cancelled.load(Ordering::Relaxed) {
            return Some(Interrupt::Cancelled);
        }
        match self.shared.deadline {
            Some(d) if Instant::now() >= d => Some(Interrupt::DeadlineExceeded),
            _ => None,
        }
    }

    /// Resolve the ticket. First resolution wins; the drop safety-net
    /// then stands down.
    pub(crate) fn resolve(mut self, result: Result<T, ServiceError>) {
        self.set(result);
    }

    fn set(&mut self, result: Result<T, ServiceError>) {
        self.resolved = true;
        let mut slot = self.shared.slot.lock().unwrap();
        if matches!(*slot, Slot::Pending) {
            *slot = Slot::Ready(result);
            self.shared.ready.notify_all();
        }
    }
}

impl<T> Drop for Responder<T> {
    fn drop(&mut self) {
        if !self.resolved {
            self.set(Err(ServiceError::ServiceDown));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_then_wait() {
        let (ticket, responder) = job_channel::<u32>(JobOptions::default());
        responder.resolve(Ok(7));
        assert!(ticket.is_done());
        assert_eq!(ticket.wait().unwrap(), 7);
    }

    #[test]
    fn wait_blocks_until_resolved() {
        let (ticket, responder) = job_channel::<u32>(JobOptions::default());
        let t = std::thread::spawn(move || ticket.wait().unwrap());
        std::thread::sleep(Duration::from_millis(20));
        responder.resolve(Ok(42));
        assert_eq!(t.join().unwrap(), 42);
    }

    #[test]
    fn wait_timeout_expires_then_late_response_is_kept() {
        let (mut ticket, responder) = job_channel::<u32>(JobOptions::default());
        assert!(ticket.wait_timeout(Duration::from_millis(10)).is_none());
        assert!(ticket.try_wait().is_none());
        responder.resolve(Ok(9));
        // the late response was not lost by the expired waits
        assert_eq!(ticket.wait_timeout(Duration::from_millis(10)).unwrap().unwrap(), 9);
        // but it can only be taken once
        match ticket.try_wait() {
            Some(Err(ServiceError::Rejected { .. })) => {}
            other => panic!("double-take must be reported, got {other:?}"),
        }
    }

    #[test]
    fn dropped_responder_resolves_service_down() {
        let (ticket, responder) = job_channel::<u32>(JobOptions::default());
        drop(responder);
        match ticket.wait() {
            Err(ServiceError::ServiceDown) => {}
            other => panic!("expected ServiceDown, got {other:?}"),
        }
    }

    #[test]
    fn cancel_and_deadline_drive_the_interrupt_probe() {
        let (ticket, responder) = job_channel::<u32>(JobOptions::default());
        assert_eq!(responder.interrupt(), None);
        ticket.cancel();
        assert_eq!(responder.interrupt(), Some(Interrupt::Cancelled));

        let (ticket, responder) =
            job_channel::<u32>(JobOptions::default().with_timeout(Duration::ZERO));
        assert!(ticket.deadline().is_some());
        assert_eq!(responder.interrupt(), Some(Interrupt::DeadlineExceeded));
        // cancellation wins over an expired deadline
        ticket.cancel();
        assert_eq!(responder.interrupt(), Some(Interrupt::Cancelled));
    }

    #[test]
    fn cancel_after_completion_is_a_noop() {
        let (mut ticket, responder) = job_channel::<u32>(JobOptions::default());
        responder.resolve(Ok(5));
        ticket.cancel();
        assert_eq!(ticket.try_wait().unwrap().unwrap(), 5);
    }

    #[test]
    fn error_display_and_payload_recovery() {
        let e: ServiceError<Vec<u8>> = ServiceError::QueueFull(vec![1, 2, 3]);
        assert!(e.is_retryable());
        assert_eq!(e.into_payload().unwrap(), vec![1, 2, 3]);
        let e: ServiceError<()> = ServiceError::UnknownStream(4);
        assert!(!e.is_retryable());
        assert_eq!(e.to_string(), "unknown or closed stream 4");
        assert!(e.into_payload().is_none());
        let e: ServiceError = ServiceError::Rejected { reason: "no runtime".into() };
        assert_eq!(e.to_string(), "rejected: no runtime");
        assert_eq!(ServiceError::<()>::from(Interrupt::Cancelled).to_string(), "cancelled");
        assert_eq!(
            ServiceError::<()>::from(Interrupt::DeadlineExceeded).to_string(),
            "deadline exceeded"
        );
    }
}
