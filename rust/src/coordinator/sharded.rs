//! The SS leader's parallel divergence backend: shards each round's item
//! set across the worker pool, with each shard computing divergences either
//! on CPU or through the shared PJRT tiled runtime.
//!
//! Works over **any** [`BatchedDivergence`] objective: each CPU shard
//! dispatches through the trait, so feature-based, facility-location and
//! mixture objectives all get their blocked kernels, and everything else
//! rides the scalar `pair_gain` default. The PJRT route is the
//! [`FeatureBased`]-only fast path (the AOT artifacts encode the
//! concave-coverage kernels); objectives without artifacts fall back to the
//! CPU kernels transparently, so a `Compute::Pjrt` backend never fails on
//! an unsupported objective — it just computes on CPU.
//!
//! Determinism: shards are gathered positionally ([`ThreadPool::parallel_ranges`])
//! and the per-item min is order-invariant, so the coordinator produces the
//! same pruning decisions as the single-threaded reference backend — a
//! property `rust/tests/coordinator_e2e.rs` asserts bit-for-bit for every
//! objective kind.
//!
//! [`FeatureBased`]: crate::submodular::FeatureBased

use std::sync::{Arc, Mutex};

use crate::algorithms::DivergenceBackend;
use crate::runtime::TiledRuntime;
use crate::submodular::{BatchedDivergence, SolState};
use crate::util::pool::ThreadPool;

use super::metrics::Metrics;

/// Gain-cohort size above which the batched-gain route fans out over the
/// pool — below it the job-dispatch overhead beats the kernel win (lazy
/// greedy's steady-state cohorts stay inline; the big initial fill and
/// naive-greedy sweeps shard).
const GAIN_SHARD_THRESHOLD: usize = 256;

/// Ground-set size above which the maximizer commit step fans the state's
/// per-element bookkeeping walk over the pool
/// ([`DivergenceBackend::commit`] → `SolState::add_pooled`) — below it the
/// walk is a few microseconds and job dispatch would dominate.
const COMMIT_SHARD_MIN: usize = 4096;

/// Refresh the store-shape gauges from the objective: `sparse_rows`,
/// `lsh_candidates`, `lsh_bucket_max`, `resident_bytes`. Stored rather
/// than accumulated — they describe the backend's *current* objective,
/// and every site that (re)binds one goes through here (construction,
/// adopt, resume). The gauge family is reset-exempt in
/// [`Metrics::reset`], so a per-window counter reset between binds
/// cannot misreport store residency.
fn refresh_store_gauges(metrics: &Metrics, f: &dyn BatchedDivergence) {
    use std::sync::atomic::Ordering::Relaxed;
    let c = &metrics.counters;
    c.sparse_rows.store(f.sparse_rows() as u64, Relaxed);
    let (cands, bmax) = f.lsh_stats();
    c.lsh_candidates.store(cands, Relaxed);
    c.lsh_bucket_max.store(bmax, Relaxed);
    c.resident_bytes.store(f.resident_bytes() as u64, Relaxed);
}

/// Where a shard's divergences are computed.
#[derive(Clone)]
pub enum Compute {
    /// blocked/scalar CPU kernels via [`BatchedDivergence`] (reference;
    /// also the fallback for objectives without AOT artifacts)
    Cpu,
    /// tiled PJRT executor (the AOT Pallas kernels) — used when the
    /// objective exposes a [`FeatureBased`](crate::submodular::FeatureBased)
    /// core, CPU fallback otherwise
    Pjrt(Arc<TiledRuntime>),
}

pub struct ShardedBackend {
    f: Arc<dyn BatchedDivergence>,
    sing: Arc<Vec<f64>>,
    pool: Arc<ThreadPool>,
    compute: Compute,
    shards: usize,
    metrics: Arc<Metrics>,
    /// reused probe-singleton gather. The buffer is *taken out* of the
    /// mutex for the duration of a batch (lock held only for the swap), so
    /// concurrent callers on a shared backend never serialize on it; warm
    /// capacity after round 1 since P is constant within a run
    probe_sing: Mutex<Vec<f64>>,
}

impl ShardedBackend {
    pub fn new(
        f: Arc<dyn BatchedDivergence>,
        pool: Arc<ThreadPool>,
        compute: Compute,
        metrics: Arc<Metrics>,
    ) -> anyhow::Result<Self> {
        let shards = pool.threads() * 2;
        let sing = Self::compute_singletons(&f, &pool, &compute, shards)?;
        // gauge: how much of the ground set rides a sparse neighbor store,
        // and how much candidate work an LSH-bucketed build did to get it
        refresh_store_gauges(&metrics, f.as_ref());
        Ok(Self {
            f,
            sing: Arc::new(sing),
            pool,
            compute,
            shards,
            metrics,
            probe_sing: Mutex::new(Vec::new()),
        })
    }

    /// Singleton complements once, through the same compute path (PJRT
    /// only has the feature-based singleton artifact). On the CPU route
    /// the precompute shards over the pool: per-element-decomposable
    /// objectives split the output range; whole-vector objectives with a
    /// pooled variant (facility location's top-2 scan, mixtures holding
    /// one) shard their reduction dimension and merge in row order —
    /// both bit-identical to the serial forms. Only objectives with
    /// neither keep the serial scan.
    fn compute_singletons(
        f: &Arc<dyn BatchedDivergence>,
        pool: &ThreadPool,
        compute: &Compute,
        shards: usize,
    ) -> anyhow::Result<Vec<f64>> {
        Ok(match (compute, f.as_feature_based()) {
            (Compute::Pjrt(rt), Some(fb)) => {
                let items: Vec<usize> = (0..f.n()).collect();
                rt.singleton_complements(fb.feats(), fb.total_mass(), &items)?
            }
            _ if f.singleton_complements_decomposable() => {
                let items: Vec<usize> = (0..f.n()).collect();
                let mut sing = vec![0.0f64; f.n()];
                let fref = f.as_ref();
                pool.parallel_ranges_into(&mut sing[..], shards, |lo, hi, chunk| {
                    fref.singleton_complements_into(&items[lo..hi], chunk);
                });
                sing
            }
            _ => match f.singleton_complements_pooled(pool, shards) {
                Some(sing) => sing,
                None => f.singleton_complements(),
            },
        })
    }

    /// Re-point a live backend at a replacement objective — the streaming
    /// sessions' per-window path after `retain_elements` compaction or
    /// sparse appends mutate the ground set. Recomputes the
    /// singleton-complement precompute for the new objective through the
    /// same compute route (it is solution-independent state that any
    /// ground-set change invalidates), but keeps the pool binding, compute
    /// route, shard count, metrics handle and warmed probe scratch that a
    /// fresh construction would rebuild. Refreshes the store-shape gauges.
    pub fn adopt(&mut self, f: Arc<dyn BatchedDivergence>) -> anyhow::Result<()> {
        let sing = Self::compute_singletons(&f, &self.pool, &self.compute, self.shards)?;
        self.sing = Arc::new(sing);
        self.f = f;
        refresh_store_gauges(&self.metrics, self.f.as_ref());
        Ok(())
    }

    pub fn singletons(&self) -> &[f64] {
        &self.sing
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// Zero the metrics this backend meters into ([`Metrics::reset`]) —
    /// the per-window scope for long-lived streaming sessions, whose
    /// counters would otherwise accumulate across every re-sparsification
    /// for the life of the process. Affects every holder of the same
    /// [`Metrics`] handle, so sessions that want isolation are constructed
    /// with their own.
    pub fn reset_metrics(&self) {
        self.metrics.reset();
    }

    /// Park the backend between streaming windows: drops the objective
    /// handle and its singleton precompute (both are invalidated by the
    /// appends and compactions that happen between windows — and holding
    /// the `Arc` would rob the session of the exclusive storage access its
    /// in-place mutation paths need), keeping the pool wiring, compute
    /// route, shard count, metrics handle and warmed probe scratch for
    /// [`ParkedBackend::resume`].
    pub fn park(self) -> ParkedBackend {
        ParkedBackend {
            pool: self.pool,
            compute: self.compute,
            shards: self.shards,
            metrics: self.metrics,
            probe_sing: self.probe_sing.into_inner().unwrap(),
        }
    }
}

/// A [`ShardedBackend`] with its per-window state (objective handle +
/// singleton precompute) stripped — what a [`StreamSession`] keeps between
/// re-sparsification windows instead of constructing a fresh backend.
///
/// [`StreamSession`]: crate::stream::StreamSession
pub struct ParkedBackend {
    pool: Arc<ThreadPool>,
    compute: Compute,
    shards: usize,
    metrics: Arc<Metrics>,
    probe_sing: Vec<f64>,
}

impl ParkedBackend {
    /// Bring the backend back up over this window's objective: recomputes
    /// the singleton-complement precompute through the same compute route
    /// (bit-identical to a fresh construction's) and refreshes the
    /// store-shape gauges, reusing everything [`ShardedBackend::park`]
    /// kept.
    pub fn resume(self, f: Arc<dyn BatchedDivergence>) -> anyhow::Result<ShardedBackend> {
        let sing = ShardedBackend::compute_singletons(&f, &self.pool, &self.compute, self.shards)?;
        refresh_store_gauges(&self.metrics, f.as_ref());
        Ok(ShardedBackend {
            f,
            sing: Arc::new(sing),
            pool: self.pool,
            compute: self.compute,
            shards: self.shards,
            metrics: self.metrics,
            probe_sing: Mutex::new(self.probe_sing),
        })
    }
}

impl DivergenceBackend for ShardedBackend {
    fn n(&self) -> usize {
        self.f.n()
    }

    /// Commit step sharded over the pool for large ground sets: the
    /// state's per-element bookkeeping walk (facility location's
    /// best-similarity update is O(n)) was the last serial stretch of a
    /// maximizer round on this backend. `add_pooled` is contractually
    /// bit-identical to `add` — parallel gather, serial ascending fold —
    /// so the gate is pure scheduling, never semantics.
    fn commit(&self, state: &mut dyn SolState, v: usize) {
        if self.f.n() >= COMMIT_SHARD_MIN {
            state.add_pooled(v, &self.pool, self.shards);
        } else {
            state.add(v);
        }
    }

    fn divergences(&self, probes: &[usize], items: &[usize]) -> Vec<f32> {
        let mut out = vec![0.0f32; items.len()];
        self.divergences_into(probes, items, &mut out);
        out
    }

    /// The round hot path: shards write their divergences directly into
    /// disjoint slices of the caller's buffer via
    /// [`ThreadPool::parallel_ranges_into`] — no per-shard `Vec`, no
    /// flatten, and the borrow-safe scope means `probes`/`items` are
    /// shared by reference instead of cloned into `Arc<Vec>`s each round.
    fn divergences_into(&self, probes: &[usize], items: &[usize], out: &mut [f32]) {
        debug_assert_eq!(out.len(), items.len());
        let span = self.metrics.tracer().start();
        // take the scratch out of the mutex so the lock is held only for
        // the swap, not across the computation — a concurrent caller on a
        // shared backend gets a fresh (cold) buffer instead of serializing
        let mut ps = std::mem::take(&mut *self.probe_sing.lock().unwrap());
        ps.clear();
        ps.extend(probes.iter().map(|&u| self.sing[u]));
        let probe_sing: &[f64] = &ps;
        let f = self.f.as_ref();
        let compute = &self.compute;
        self.pool.parallel_ranges_into(out, self.shards, move |lo, hi, chunk_out| {
            let chunk = &items[lo..hi];
            match (compute, f.as_feature_based()) {
                (Compute::Pjrt(rt), Some(fb)) => rt
                    .divergences_into(fb.feats(), probes, probe_sing, chunk, chunk_out)
                    .expect("pjrt divergences"),
                _ => f.divergences_into(probes, probe_sing, chunk, chunk_out),
            }
        });
        *self.probe_sing.lock().unwrap() = ps;
        // pairwise w_{uv} evaluations — the same unit `sparsify_candidates`
        // accounts in `SsResult::divergence_evals`
        let evals = (probes.len() * items.len()) as u64;
        self.metrics.add(&self.metrics.counters.divergence_evals, evals);
        self.metrics.tracer().record_since(
            crate::trace::EventKind::KernelDispatch,
            span,
            probes.len() as u64,
            items.len() as u64,
            evals,
            0,
        );
    }

    fn importance_weights(&self, items: &[usize]) -> Vec<f64> {
        let mut out = Vec::with_capacity(items.len());
        self.importance_weights_into(items, &mut out);
        out
    }

    /// Importance weights `f(u) + f(u|V∖u)` sharded over the pool (they
    /// were the last serial per-round scan on this backend), written into
    /// disjoint slices of `out` and metered like the divergence batches.
    fn importance_weights_into(&self, items: &[usize], out: &mut Vec<f64>) {
        out.clear();
        out.resize(items.len(), 0.0);
        let f = self.f.as_ref();
        let sing: &[f64] = &self.sing;
        self.pool.parallel_ranges_into(&mut out[..], self.shards, move |lo, hi, chunk_out| {
            for (slot, &u) in chunk_out.iter_mut().zip(&items[lo..hi]) {
                *slot = f.singleton(u) + sing[u];
            }
        });
        // one singleton evaluation per item — tracked on its own counter
        // (the unit differs from the pairwise divergence_evals)
        self.metrics
            .add(&self.metrics.counters.importance_evals, items.len() as u64);
    }

    /// The batched-gain route: cohorts above [`GAIN_SHARD_THRESHOLD`] fan
    /// out over the pool into disjoint slices of the engine's gain buffer
    /// (per-element values are independent of the chunking, so sharding
    /// never changes a bit); smaller cohorts run inline. Every evaluation
    /// lands on the `gain_evals` counter.
    fn gains_into(&self, state: &dyn SolState, candidates: &[usize], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        let span = self.metrics.tracer().start();
        if candidates.len() >= GAIN_SHARD_THRESHOLD && self.shards > 1 {
            self.pool.parallel_ranges_into(out, self.shards, |lo, hi, chunk| {
                state.gains_into(&candidates[lo..hi], chunk);
            });
        } else {
            state.gains_into(candidates, out);
        }
        self.metrics.add(&self.metrics.counters.gain_evals, candidates.len() as u64);
        // a gain dispatch has no probe set: [0, cohort, evals, _]
        self.metrics.tracer().record_since(
            crate::trace::EventKind::KernelDispatch,
            span,
            0,
            candidates.len() as u64,
            candidates.len() as u64,
            0,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::CpuBackend;
    use crate::submodular::{FacilityLocation, FeatureBased};
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        m
    }

    fn instance(n: usize, d: usize, seed: u64) -> Arc<FeatureBased> {
        Arc::new(FeatureBased::sqrt(feats(n, d, seed)))
    }

    #[test]
    fn sharded_cpu_matches_reference_backend() {
        let f = instance(300, 16, 1);
        let pool = Arc::new(ThreadPool::new(4, 16));
        let metrics = Arc::new(Metrics::new());
        let sharded =
            ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, metrics).unwrap();
        let reference = CpuBackend::new(f.as_ref());
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            let probes = rng.sample_indices(300, 25);
            let items: Vec<usize> = (0..300).filter(|v| !probes.contains(v)).collect();
            let a = sharded.divergences(&probes, &items);
            let b = reference.divergences(&probes, &items);
            assert_eq!(a, b, "sharded result must be bit-identical to reference");
        }
    }

    #[test]
    fn sharded_facility_location_matches_reference_backend() {
        let fl = Arc::new(FacilityLocation::from_features(&feats(250, 12, 5)));
        let pool = Arc::new(ThreadPool::new(3, 16));
        let metrics = Arc::new(Metrics::new());
        let sharded =
            ShardedBackend::new(Arc::clone(&fl), pool, Compute::Cpu, metrics).unwrap();
        let reference = CpuBackend::new(fl.as_ref());
        let mut rng = Rng::new(6);
        for _ in 0..3 {
            let probes = rng.sample_indices(250, 20);
            let items: Vec<usize> = (0..250).filter(|v| !probes.contains(v)).collect();
            assert_eq!(
                sharded.divergences(&probes, &items),
                reference.divergences(&probes, &items),
                "facility-location sharding must be bit-identical to reference"
            );
        }
    }

    #[test]
    fn shard_count_does_not_change_results() {
        let f = instance(200, 8, 3);
        let pool = Arc::new(ThreadPool::new(3, 8));
        let metrics = Arc::new(Metrics::new());
        let one = ShardedBackend::new(
            Arc::clone(&f),
            Arc::clone(&pool),
            Compute::Cpu,
            Arc::clone(&metrics),
        )
        .unwrap()
        .with_shards(1);
        let many = ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, metrics)
            .unwrap()
            .with_shards(13);
        let probes: Vec<usize> = (0..20).collect();
        let items: Vec<usize> = (20..200).collect();
        assert_eq!(one.divergences(&probes, &items), many.divergences(&probes, &items));
    }

    #[test]
    fn metrics_count_evals() {
        let f = instance(100, 8, 4);
        let pool = Arc::new(ThreadPool::new(2, 8));
        let metrics = Arc::new(Metrics::new());
        let b = ShardedBackend::new(f, pool, Compute::Cpu, Arc::clone(&metrics)).unwrap();
        let _ = b.divergences(&[0, 1, 2], &(3..100).collect::<Vec<_>>());
        // pairwise evaluations: 3 probes × 97 items
        assert_eq!(
            metrics.counters.divergence_evals.load(std::sync::atomic::Ordering::Relaxed),
            291
        );
    }

    #[test]
    fn reset_metrics_scopes_counters_per_window() {
        let f = instance(100, 8, 14);
        let pool = Arc::new(ThreadPool::new(2, 8));
        let metrics = Arc::new(Metrics::new());
        let b = ShardedBackend::new(f, pool, Compute::Cpu, Arc::clone(&metrics)).unwrap();
        let _ = b.divergences(&[0, 1], &(2..50).collect::<Vec<_>>());
        assert!(metrics.counters.divergence_evals.load(std::sync::atomic::Ordering::Relaxed) > 0);
        b.reset_metrics();
        assert_eq!(
            metrics.counters.divergence_evals.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "reset must zero the window's counters"
        );
        // next window meters from zero
        let _ = b.divergences(&[0], &(1..21).collect::<Vec<_>>());
        assert_eq!(
            metrics.counters.divergence_evals.load(std::sync::atomic::Ordering::Relaxed),
            20
        );
    }

    #[test]
    fn adopt_repoints_a_live_backend_and_tracks_sparse_rows() {
        use crate::submodular::{BatchedDivergence, SubmodularFn};
        let m = feats(160, 10, 31);
        let fl: Arc<dyn BatchedDivergence> =
            Arc::new(FacilityLocation::from_features_sparse(&m, 12));
        let pool = Arc::new(ThreadPool::new(3, 16));
        let metrics = Arc::new(Metrics::new());
        let mut b =
            ShardedBackend::new(Arc::clone(&fl), pool, Compute::Cpu, Arc::clone(&metrics))
                .unwrap();
        assert_eq!(
            metrics.counters.sparse_rows.load(std::sync::atomic::Ordering::Relaxed),
            160,
            "construction must gauge the sparse residency"
        );
        assert_eq!(b.singletons(), &fl.singleton_complements()[..]);

        // compact the objective and re-point the same backend at it: the
        // precompute and gauge must match a fresh construction's bit-for-bit
        let keep: Vec<usize> = (0..160).filter(|v| v % 3 != 0).collect();
        let mut small = FacilityLocation::from_features_sparse(&m, 12);
        small.retain_elements(&keep);
        let small: Arc<dyn BatchedDivergence> = Arc::new(small);
        b.adopt(Arc::clone(&small)).unwrap();
        assert_eq!(b.n(), keep.len());
        assert_eq!(b.singletons(), &small.singleton_complements()[..]);
        assert_eq!(
            metrics.counters.sparse_rows.load(std::sync::atomic::Ordering::Relaxed),
            keep.len() as u64
        );

        // a dense objective gauges zero
        let dense: Arc<dyn BatchedDivergence> =
            Arc::new(FacilityLocation::from_features_dense(&feats(40, 6, 32)));
        b.adopt(dense).unwrap();
        assert_eq!(metrics.counters.sparse_rows.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn write_into_matches_allocating_path_and_reference() {
        let f = instance(300, 12, 7);
        let pool = Arc::new(ThreadPool::new(4, 16));
        let sharded =
            ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, Arc::new(Metrics::new()))
                .unwrap()
                .with_shards(9);
        let reference = CpuBackend::new(f.as_ref());
        let mut rng = Rng::new(12);
        let mut out = Vec::new();
        for _ in 0..4 {
            let probes = rng.sample_indices(300, 30);
            let items: Vec<usize> = (0..300).filter(|v| !probes.contains(v)).collect();
            out.clear();
            out.resize(items.len(), f32::NAN); // dirty buffer must be overwritten
            sharded.divergences_into(&probes, &items, &mut out);
            assert_eq!(out, reference.divergences(&probes, &items));
            assert_eq!(out, sharded.divergences(&probes, &items));
        }
    }

    #[test]
    fn sharded_singleton_precompute_bitwise_matches_serial() {
        use crate::submodular::{BatchedDivergence, Concave, Mixture};
        let m = feats(150, 10, 21);
        let fb: Arc<dyn BatchedDivergence> = Arc::new(FeatureBased::sqrt(m.clone()));
        // decomposable mixture → sharded; facility location → serial fallback
        let mix: Arc<dyn BatchedDivergence> = Arc::new(Mixture::new(vec![
            (0.6, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
            (0.4, Box::new(FeatureBased::new(m.clone(), Concave::Log1p))),
        ]));
        let fl: Arc<dyn BatchedDivergence> = Arc::new(FacilityLocation::from_features(&m));
        for f in [fb, mix, fl] {
            let want = f.singleton_complements();
            let pool = Arc::new(ThreadPool::new(3, 16));
            let b = ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, Arc::new(Metrics::new()))
                .unwrap();
            assert_eq!(
                b.singletons(),
                &want[..],
                "sharded singleton precompute must be bit-identical to serial"
            );
        }
    }

    #[test]
    fn sharded_gains_route_bitwise_matches_state_and_is_metered() {
        use crate::submodular::SubmodularFn;
        // 400 candidates crosses GAIN_SHARD_THRESHOLD → pool fan-out path;
        // a small cohort stays inline — both must equal the state's own
        // kernel bit-for-bit
        let f = instance(400, 10, 8);
        let pool = Arc::new(ThreadPool::new(3, 16));
        let metrics = Arc::new(Metrics::new());
        let b = ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, Arc::clone(&metrics))
            .unwrap()
            .with_shards(7);
        let mut st = f.state();
        st.add(3);
        st.add(91);
        let big: Vec<usize> = (0..400).collect();
        let small: Vec<usize> = (0..40).collect();
        for cands in [&big, &small] {
            let mut want = vec![0.0f64; cands.len()];
            st.gains_into(cands, &mut want);
            let mut got = vec![f64::NAN; cands.len()]; // dirty buffer
            b.gains_into(st.as_ref(), cands, &mut got);
            for (i, (a, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), w.to_bits(), "slot {i} (|cands|={})", cands.len());
            }
        }
        assert_eq!(
            metrics.counters.gain_evals.load(std::sync::atomic::Ordering::Relaxed),
            440,
            "gain_evals must count every cohort element"
        );
    }

    #[test]
    fn importance_weights_sharded_match_reference_and_are_metered() {
        let f = instance(220, 10, 9);
        let pool = Arc::new(ThreadPool::new(3, 16));
        let metrics = Arc::new(Metrics::new());
        let sharded = ShardedBackend::new(
            Arc::clone(&f),
            pool,
            Compute::Cpu,
            Arc::clone(&metrics),
        )
        .unwrap()
        .with_shards(6);
        let reference = CpuBackend::new(f.as_ref());
        let items: Vec<usize> = (0..220).step_by(3).collect();
        let want = reference.importance_weights(&items);
        assert_eq!(sharded.importance_weights(&items), want, "sharded weights must match");
        let mut out = vec![f64::NAN; 5]; // wrong size + dirty: must be reset
        sharded.importance_weights_into(&items, &mut out);
        assert_eq!(out, want);
        // two calls × one singleton eval per item
        assert_eq!(
            metrics.counters.importance_evals.load(std::sync::atomic::Ordering::Relaxed),
            2 * items.len() as u64
        );
    }
}
