//! The SS leader's parallel divergence backend: shards each round's item
//! set across the worker pool, with each shard computing divergences either
//! on CPU or through the shared PJRT tiled runtime.
//!
//! Determinism: shards are gathered positionally ([`ThreadPool::parallel_ranges`])
//! and the per-item min is order-invariant, so the coordinator produces the
//! same pruning decisions as the single-threaded reference backend — a
//! property `rust/tests/coordinator_e2e.rs` asserts bit-for-bit.

use std::sync::Arc;

use crate::algorithms::DivergenceBackend;
use crate::runtime::TiledRuntime;
use crate::submodular::{FeatureBased, SubmodularFn};
use crate::util::pool::ThreadPool;

use super::metrics::Metrics;

/// Where a shard's divergences are computed.
#[derive(Clone)]
pub enum Compute {
    /// vectorized CPU loops (reference; also the fallback without artifacts)
    Cpu,
    /// tiled PJRT executor (the AOT Pallas kernels)
    Pjrt(Arc<TiledRuntime>),
}

pub struct ShardedBackend {
    f: Arc<FeatureBased>,
    sing: Arc<Vec<f64>>,
    pool: Arc<ThreadPool>,
    compute: Compute,
    shards: usize,
    metrics: Arc<Metrics>,
}

impl ShardedBackend {
    pub fn new(
        f: Arc<FeatureBased>,
        pool: Arc<ThreadPool>,
        compute: Compute,
        metrics: Arc<Metrics>,
    ) -> anyhow::Result<Self> {
        // singleton complements once, through the same compute path
        let items: Vec<usize> = (0..f.n()).collect();
        let sing = match &compute {
            Compute::Cpu => f.singleton_complements(),
            Compute::Pjrt(rt) => rt.singleton_complements(f.feats(), f.total_mass(), &items)?,
        };
        let shards = pool.threads() * 2;
        Ok(Self { f, sing: Arc::new(sing), pool, compute, shards, metrics })
    }

    pub fn singletons(&self) -> &[f64] {
        &self.sing
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }
}

impl DivergenceBackend for ShardedBackend {
    fn n(&self) -> usize {
        self.f.n()
    }

    fn divergences(&self, probes: &[usize], items: &[usize]) -> Vec<f32> {
        let probes: Arc<Vec<usize>> = Arc::new(probes.to_vec());
        let items: Arc<Vec<usize>> = Arc::new(items.to_vec());
        let probe_sing: Arc<Vec<f64>> =
            Arc::new(probes.iter().map(|&u| self.sing[u]).collect());
        let f = Arc::clone(&self.f);
        let compute = self.compute.clone();
        let chunks = self.pool.parallel_ranges(items.len(), self.shards, move |lo, hi| {
            let chunk = &items[lo..hi];
            match &compute {
                Compute::Cpu => cpu_divergences(&f, &probes, &probe_sing, chunk),
                Compute::Pjrt(rt) => rt
                    .divergences(f.feats(), &probes, &probe_sing, chunk)
                    .expect("pjrt divergences"),
            }
        });
        let out: Vec<f32> = chunks.into_iter().flatten().collect();
        self.metrics.add(&self.metrics.counters.divergence_evals, out.len() as u64);
        out
    }

    fn importance_weights(&self, items: &[usize]) -> Vec<f64> {
        items.iter().map(|&u| self.f.singleton(u) + self.sing[u]).collect()
    }
}

/// CPU shard kernel — delegates to the blocked `FeatureBased` kernel with
/// per-probe cached `g(u)` rows (bit-identical to the naive reference; see
/// the perf log in EXPERIMENTS.md §Perf).
pub fn cpu_divergences(
    f: &FeatureBased,
    probes: &[usize],
    probe_sing: &[f64],
    items: &[usize],
) -> Vec<f32> {
    f.divergences_block(probes, probe_sing, items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::CpuBackend;
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn instance(n: usize, d: usize, seed: u64) -> Arc<FeatureBased> {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        Arc::new(FeatureBased::sqrt(m))
    }

    #[test]
    fn sharded_cpu_matches_reference_backend() {
        let f = instance(300, 16, 1);
        let pool = Arc::new(ThreadPool::new(4, 16));
        let metrics = Arc::new(Metrics::new());
        let sharded =
            ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, metrics).unwrap();
        let reference = CpuBackend::new(f.as_ref());
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            let probes = rng.sample_indices(300, 25);
            let items: Vec<usize> = (0..300).filter(|v| !probes.contains(v)).collect();
            let a = sharded.divergences(&probes, &items);
            let b = reference.divergences(&probes, &items);
            assert_eq!(a, b, "sharded result must be bit-identical to reference");
        }
    }

    #[test]
    fn shard_count_does_not_change_results() {
        let f = instance(200, 8, 3);
        let pool = Arc::new(ThreadPool::new(3, 8));
        let metrics = Arc::new(Metrics::new());
        let one = ShardedBackend::new(Arc::clone(&f), Arc::clone(&pool), Compute::Cpu, Arc::clone(&metrics))
            .unwrap()
            .with_shards(1);
        let many = ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, metrics)
            .unwrap()
            .with_shards(13);
        let probes: Vec<usize> = (0..20).collect();
        let items: Vec<usize> = (20..200).collect();
        assert_eq!(one.divergences(&probes, &items), many.divergences(&probes, &items));
    }

    #[test]
    fn metrics_count_evals() {
        let f = instance(100, 8, 4);
        let pool = Arc::new(ThreadPool::new(2, 8));
        let metrics = Arc::new(Metrics::new());
        let b = ShardedBackend::new(f, pool, Compute::Cpu, Arc::clone(&metrics)).unwrap();
        let _ = b.divergences(&[0, 1, 2], &(3..100).collect::<Vec<_>>());
        assert_eq!(
            metrics.counters.divergence_evals.load(std::sync::atomic::Ordering::Relaxed),
            97
        );
    }
}
