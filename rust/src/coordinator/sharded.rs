//! The SS leader's parallel divergence backend: shards each round's item
//! set across the worker pool, with each shard computing divergences either
//! on CPU or through the shared PJRT tiled runtime.
//!
//! Works over **any** [`BatchedDivergence`] objective: each CPU shard
//! dispatches through the trait, so feature-based, facility-location and
//! mixture objectives all get their blocked kernels, and everything else
//! rides the scalar `pair_gain` default. The PJRT route is the
//! [`FeatureBased`]-only fast path (the AOT artifacts encode the
//! concave-coverage kernels); objectives without artifacts fall back to the
//! CPU kernels transparently, so a `Compute::Pjrt` backend never fails on
//! an unsupported objective — it just computes on CPU.
//!
//! Determinism: shards are gathered positionally ([`ThreadPool::parallel_ranges`])
//! and the per-item min is order-invariant, so the coordinator produces the
//! same pruning decisions as the single-threaded reference backend — a
//! property `rust/tests/coordinator_e2e.rs` asserts bit-for-bit for every
//! objective kind.
//!
//! [`FeatureBased`]: crate::submodular::FeatureBased

use std::sync::Arc;

use crate::algorithms::DivergenceBackend;
use crate::runtime::TiledRuntime;
use crate::submodular::BatchedDivergence;
use crate::util::pool::ThreadPool;

use super::metrics::Metrics;

/// Where a shard's divergences are computed.
#[derive(Clone)]
pub enum Compute {
    /// blocked/scalar CPU kernels via [`BatchedDivergence`] (reference;
    /// also the fallback for objectives without AOT artifacts)
    Cpu,
    /// tiled PJRT executor (the AOT Pallas kernels) — used when the
    /// objective exposes a [`FeatureBased`](crate::submodular::FeatureBased)
    /// core, CPU fallback otherwise
    Pjrt(Arc<TiledRuntime>),
}

pub struct ShardedBackend {
    f: Arc<dyn BatchedDivergence>,
    sing: Arc<Vec<f64>>,
    pool: Arc<ThreadPool>,
    compute: Compute,
    shards: usize,
    metrics: Arc<Metrics>,
}

impl ShardedBackend {
    pub fn new(
        f: Arc<dyn BatchedDivergence>,
        pool: Arc<ThreadPool>,
        compute: Compute,
        metrics: Arc<Metrics>,
    ) -> anyhow::Result<Self> {
        // singleton complements once, through the same compute path (PJRT
        // only has the feature-based singleton artifact)
        let sing = match (&compute, f.as_feature_based()) {
            (Compute::Pjrt(rt), Some(fb)) => {
                let items: Vec<usize> = (0..f.n()).collect();
                rt.singleton_complements(fb.feats(), fb.total_mass(), &items)?
            }
            _ => f.singleton_complements(),
        };
        let shards = pool.threads() * 2;
        Ok(Self { f, sing: Arc::new(sing), pool, compute, shards, metrics })
    }

    pub fn singletons(&self) -> &[f64] {
        &self.sing
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }
}

impl DivergenceBackend for ShardedBackend {
    fn n(&self) -> usize {
        self.f.n()
    }

    fn divergences(&self, probes: &[usize], items: &[usize]) -> Vec<f32> {
        let n_probes = probes.len();
        let probes: Arc<Vec<usize>> = Arc::new(probes.to_vec());
        let items: Arc<Vec<usize>> = Arc::new(items.to_vec());
        let probe_sing: Arc<Vec<f64>> =
            Arc::new(probes.iter().map(|&u| self.sing[u]).collect());
        let f = Arc::clone(&self.f);
        let compute = self.compute.clone();
        let chunks = self.pool.parallel_ranges(items.len(), self.shards, move |lo, hi| {
            let chunk = &items[lo..hi];
            match (&compute, f.as_feature_based()) {
                (Compute::Pjrt(rt), Some(fb)) => rt
                    .divergences(fb.feats(), &probes, &probe_sing, chunk)
                    .expect("pjrt divergences"),
                _ => f.divergences_batch(&probes, &probe_sing, chunk),
            }
        });
        let out: Vec<f32> = chunks.into_iter().flatten().collect();
        // pairwise w_{uv} evaluations — the same unit `sparsify_candidates`
        // accounts in `SsResult::divergence_evals`
        self.metrics
            .add(&self.metrics.counters.divergence_evals, (n_probes * out.len()) as u64);
        out
    }

    fn importance_weights(&self, items: &[usize]) -> Vec<f64> {
        items.iter().map(|&u| self.f.singleton(u) + self.sing[u]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::CpuBackend;
    use crate::submodular::{FacilityLocation, FeatureBased};
    use crate::util::rng::Rng;
    use crate::util::vecmath::FeatureMatrix;

    fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
            }
        }
        m
    }

    fn instance(n: usize, d: usize, seed: u64) -> Arc<FeatureBased> {
        Arc::new(FeatureBased::sqrt(feats(n, d, seed)))
    }

    #[test]
    fn sharded_cpu_matches_reference_backend() {
        let f = instance(300, 16, 1);
        let pool = Arc::new(ThreadPool::new(4, 16));
        let metrics = Arc::new(Metrics::new());
        let sharded =
            ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, metrics).unwrap();
        let reference = CpuBackend::new(f.as_ref());
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            let probes = rng.sample_indices(300, 25);
            let items: Vec<usize> = (0..300).filter(|v| !probes.contains(v)).collect();
            let a = sharded.divergences(&probes, &items);
            let b = reference.divergences(&probes, &items);
            assert_eq!(a, b, "sharded result must be bit-identical to reference");
        }
    }

    #[test]
    fn sharded_facility_location_matches_reference_backend() {
        let fl = Arc::new(FacilityLocation::from_features(&feats(250, 12, 5)));
        let pool = Arc::new(ThreadPool::new(3, 16));
        let metrics = Arc::new(Metrics::new());
        let sharded =
            ShardedBackend::new(Arc::clone(&fl), pool, Compute::Cpu, metrics).unwrap();
        let reference = CpuBackend::new(fl.as_ref());
        let mut rng = Rng::new(6);
        for _ in 0..3 {
            let probes = rng.sample_indices(250, 20);
            let items: Vec<usize> = (0..250).filter(|v| !probes.contains(v)).collect();
            assert_eq!(
                sharded.divergences(&probes, &items),
                reference.divergences(&probes, &items),
                "facility-location sharding must be bit-identical to reference"
            );
        }
    }

    #[test]
    fn shard_count_does_not_change_results() {
        let f = instance(200, 8, 3);
        let pool = Arc::new(ThreadPool::new(3, 8));
        let metrics = Arc::new(Metrics::new());
        let one = ShardedBackend::new(
            Arc::clone(&f),
            Arc::clone(&pool),
            Compute::Cpu,
            Arc::clone(&metrics),
        )
        .unwrap()
        .with_shards(1);
        let many = ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, metrics)
            .unwrap()
            .with_shards(13);
        let probes: Vec<usize> = (0..20).collect();
        let items: Vec<usize> = (20..200).collect();
        assert_eq!(one.divergences(&probes, &items), many.divergences(&probes, &items));
    }

    #[test]
    fn metrics_count_evals() {
        let f = instance(100, 8, 4);
        let pool = Arc::new(ThreadPool::new(2, 8));
        let metrics = Arc::new(Metrics::new());
        let b = ShardedBackend::new(f, pool, Compute::Cpu, Arc::clone(&metrics)).unwrap();
        let _ = b.divergences(&[0, 1, 2], &(3..100).collect::<Vec<_>>());
        // pairwise evaluations: 3 probes × 97 items
        assert_eq!(
            metrics.counters.divergence_evals.load(std::sync::atomic::Ordering::Relaxed),
            291
        );
    }
}
