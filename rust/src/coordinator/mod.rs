//! Layer-3 coordinator: the deployment shape of submodular sparsification.
//!
//! The paper's per-round hot loop — `O(n log n)` pairwise divergences — is
//! "small and highly parallelizable" (§1.1); this module is that claim
//! realized as a system:
//!
//! * [`sharded`] — the SS leader's parallel [`DivergenceBackend`]: item
//!   shards fan out over a worker pool, each shard computing on CPU or via
//!   the shared PJRT tiled runtime, gathered deterministically;
//! * [`job`] — the service's job primitives: the typed [`ServiceError`],
//!   cancellable deadline-aware [`Ticket`]s, and the responder machinery
//!   that guarantees every accepted job resolves exactly once;
//! * [`service`] — summarization-as-a-service: every unit of work (batch
//!   summarize, copy-on-snapshot stream summary) is a job on the bounded
//!   queue, shed at dequeue or between SS rounds when cancelled/expired,
//!   with backpressure via blocking/shedding submits and the streaming
//!   session front-end (`open_stream` / `append` / `submit_snapshot` /
//!   `close` over [`crate::stream::StreamSession`]);
//! * [`metrics`] — **scoped** metrics: counters, gauges and latency
//!   histograms surfaced as JSON, one [`Metrics`] instance per scope.
//!
//! ## The scoped-metrics model
//!
//! A [`Metrics`] value is a *scope*: a label, a set of monotonic counters,
//! a set of level gauges (reset-exempt — they describe current state, not
//! traffic), latency histograms, and one [`crate::trace::Tracer`]. The
//! service owns the `"service"` scope; every stream opened through it gets
//! its own `"stream-{id}"` scope whose counters mirror into the service
//! scope on the shared hot paths. Because every layer (sharded backend,
//! stream session, service worker) already holds a `Metrics` handle, the
//! tracer rides along with zero extra plumbing: enabling a scope's tracer
//! turns on span recording for exactly that scope's work — service-wide
//! via [`SummarizationService::metrics`], per-stream via the always-on
//! bounded flight recorder that `submit_flight_dump` snapshots (even
//! after quarantine; see [`service`]). Span schema and exporters (JSON
//! Lines, Chrome trace-event) live in [`crate::trace`].
//!
//! The whole stack is objective-generic: backends and the service hold an
//! `Arc<dyn BatchedDivergence>` handle, so every objective in
//! [`crate::submodular`] — not just the paper's feature-based function —
//! runs sharded, metered and service-fronted. See
//! [`crate::submodular::batched`].
//!
//! [`DivergenceBackend`]: crate::algorithms::DivergenceBackend

pub mod job;
pub mod metrics;
pub mod service;
pub mod sharded;

pub use job::{JobOptions, ServiceError, Ticket};
pub use metrics::Metrics;
pub use service::{
    Objective, PruneRequest, PruneResponse, ServiceConfig, StreamId, SummarizationService,
    SummarizeRequest, SummarizeResponse,
};
pub use sharded::{Compute, ParkedBackend, ShardedBackend};

// One-release compat: keep the old `coordinator::SubmitError` path alive.
// The alias is defined (and deprecated) once, in `service`; uses through
// either path warn.
#[allow(deprecated)]
pub use service::SubmitError;
