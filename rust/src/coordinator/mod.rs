//! Layer-3 coordinator: the deployment shape of submodular sparsification.
//!
//! The paper's per-round hot loop — `O(n log n)` pairwise divergences — is
//! "small and highly parallelizable" (§1.1); this module is that claim
//! realized as a system:
//!
//! * [`sharded`] — the SS leader's parallel [`DivergenceBackend`]: item
//!   shards fan out over a worker pool, each shard computing on CPU or via
//!   the shared PJRT tiled runtime, gathered deterministically;
//! * [`service`] — summarization-as-a-service: bounded request queue,
//!   request workers, cross-request tile batching at the PJRT executor,
//!   backpressure via blocking/shedding submits;
//! * [`metrics`] — counters + latency histograms surfaced as JSON.
//!
//! [`DivergenceBackend`]: crate::algorithms::DivergenceBackend

pub mod metrics;
pub mod service;
pub mod sharded;

pub use metrics::Metrics;
pub use service::{ServiceConfig, SummarizationService, SummarizeRequest, SummarizeResponse};
pub use sharded::{Compute, ShardedBackend};
