//! Layer-3 coordinator: the deployment shape of submodular sparsification.
//!
//! The paper's per-round hot loop — `O(n log n)` pairwise divergences — is
//! "small and highly parallelizable" (§1.1); this module is that claim
//! realized as a system:
//!
//! * [`sharded`] — the SS leader's parallel [`DivergenceBackend`]: item
//!   shards fan out over a worker pool, each shard computing on CPU or via
//!   the shared PJRT tiled runtime, gathered deterministically;
//! * [`service`] — summarization-as-a-service: bounded request queue,
//!   request workers, cross-request tile batching at the PJRT executor,
//!   backpressure via blocking/shedding submits, plus the streaming
//!   session front-end (`open_stream` / `append` / `snapshot_summary` /
//!   `close` over [`crate::stream::StreamSession`]);
//! * [`metrics`] — counters + latency histograms surfaced as JSON.
//!
//! The whole stack is objective-generic: backends and the service hold an
//! `Arc<dyn BatchedDivergence>` handle, so every objective in
//! [`crate::submodular`] — not just the paper's feature-based function —
//! runs sharded, metered and service-fronted. See
//! [`crate::submodular::batched`].
//!
//! [`DivergenceBackend`]: crate::algorithms::DivergenceBackend

pub mod metrics;
pub mod service;
pub mod sharded;

pub use metrics::Metrics;
pub use service::{
    Objective, ServiceConfig, StreamId, SubmitError, SummarizationService, SummarizeRequest,
    SummarizeResponse,
};
pub use sharded::{Compute, ShardedBackend};
