//! Transports: the byte-stream abstraction frames travel over, with
//! three implementations — an in-memory loopback pipe (deterministic
//! tests, multi-worker clusters inside one process), a generic adapter
//! over any `std::io::Read`/`Write` pair (TCP sockets, child-process
//! stdio), and the [`FrameReader`]/[`FrameWriter`] pair that layers the
//! framed protocol on top of either.
//!
//! The loopback pipe deliberately supports two failure-injection knobs
//! the tests lean on: a *kill switch* that makes both directions fail
//! with [`WireError::Io`] mid-conversation (worker death), and a write
//! *chunk size* that splinters every write into tiny transport reads so
//! the decoder's reassembly path is exercised on every test run.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::frame::{encode_frame, Frame, FrameDecoder, WireError};
use super::msg::Message;

/// Blocking byte source for one direction of a connection. `Ok(0)` means
/// a clean EOF; transport failures map to [`WireError::Io`].
pub trait WireRead: Send {
    fn read_some(&mut self, buf: &mut [u8]) -> Result<usize, WireError>;
}

/// Blocking byte sink for one direction of a connection.
pub trait WireWrite: Send {
    fn write_all_bytes(&mut self, buf: &[u8]) -> Result<(), WireError>;
    fn flush_bytes(&mut self) -> Result<(), WireError>;
}

/// A full-duplex connection that can be split into its two directions so
/// a reader thread and a writer thread can own them independently.
pub trait Transport: Send {
    fn split(self: Box<Self>) -> (Box<dyn WireRead>, Box<dyn WireWrite>);
}

// ---------------------------------------------------------------------------
// Loopback: two in-memory pipes + a kill switch.
// ---------------------------------------------------------------------------

struct PipeState {
    buf: VecDeque<u8>,
    closed: bool,
}

/// One direction of the loopback: a bounded-by-nothing byte queue with
/// blocking reads. Closing (writer drop) wakes readers for EOF.
struct Pipe {
    state: Mutex<PipeState>,
    cv: Condvar,
}

impl Pipe {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(PipeState { buf: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        })
    }

    fn close(&self) {
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        s.closed = true;
        self.cv.notify_all();
    }
}

/// Shared failure injector for a loopback pair: once [`kill`](Self::kill)
/// fires, every read and write on either end fails with
/// [`WireError::Io`] — the in-process stand-in for a worker process
/// dying with its sockets.
#[derive(Clone)]
pub struct KillSwitch {
    dead: Arc<AtomicBool>,
    pipes: [Arc<Pipe>; 2],
}

impl KillSwitch {
    pub fn kill(&self) {
        self.dead.store(true, Ordering::SeqCst);
        for p in &self.pipes {
            p.cv.notify_all();
        }
    }

    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }
}

struct LoopbackRead {
    pipe: Arc<Pipe>,
    dead: Arc<AtomicBool>,
}

impl WireRead for LoopbackRead {
    fn read_some(&mut self, buf: &mut [u8]) -> Result<usize, WireError> {
        let mut s = self.pipe.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if self.dead.load(Ordering::SeqCst) {
                return Err(WireError::Io("loopback killed".into()));
            }
            if !s.buf.is_empty() {
                let n = buf.len().min(s.buf.len());
                for slot in buf.iter_mut().take(n) {
                    *slot = s.buf.pop_front().expect("len checked");
                }
                return Ok(n);
            }
            if s.closed {
                return Ok(0);
            }
            s = self.pipe.cv.wait(s).unwrap_or_else(|p| p.into_inner());
        }
    }
}

struct LoopbackWrite {
    pipe: Arc<Pipe>,
    dead: Arc<AtomicBool>,
    /// Bytes appended (and readers woken) per chunk — small values force
    /// the peer's decoder through its partial-frame reassembly path.
    chunk: usize,
}

impl WireWrite for LoopbackWrite {
    fn write_all_bytes(&mut self, buf: &[u8]) -> Result<(), WireError> {
        for piece in buf.chunks(self.chunk.max(1)) {
            let mut s = self.pipe.state.lock().unwrap_or_else(|p| p.into_inner());
            if self.dead.load(Ordering::SeqCst) {
                return Err(WireError::Io("loopback killed".into()));
            }
            if s.closed {
                return Err(WireError::Closed);
            }
            s.buf.extend(piece.iter().copied());
            self.pipe.cv.notify_all();
        }
        Ok(())
    }

    fn flush_bytes(&mut self) -> Result<(), WireError> {
        Ok(())
    }
}

impl Drop for LoopbackWrite {
    fn drop(&mut self) {
        self.pipe.close();
    }
}

/// One end of an in-memory duplex connection.
pub struct LoopbackEnd {
    read_from: Arc<Pipe>,
    write_to: Arc<Pipe>,
    dead: Arc<AtomicBool>,
    chunk: usize,
}

impl Transport for LoopbackEnd {
    fn split(self: Box<Self>) -> (Box<dyn WireRead>, Box<dyn WireWrite>) {
        (
            Box::new(LoopbackRead { pipe: self.read_from, dead: self.dead.clone() }),
            Box::new(LoopbackWrite { pipe: self.write_to, dead: self.dead, chunk: self.chunk }),
        )
    }
}

/// An in-memory duplex pair (plus its kill switch) with writes splintered
/// into `chunk`-byte pieces. `chunk = usize::MAX` writes whole buffers.
pub fn loopback_pair_chunked(chunk: usize) -> (LoopbackEnd, LoopbackEnd, KillSwitch) {
    let a_to_b = Pipe::new();
    let b_to_a = Pipe::new();
    let dead = Arc::new(AtomicBool::new(false));
    let a = LoopbackEnd {
        read_from: b_to_a.clone(),
        write_to: a_to_b.clone(),
        dead: dead.clone(),
        chunk,
    };
    let b = LoopbackEnd {
        read_from: a_to_b.clone(),
        write_to: b_to_a.clone(),
        dead: dead.clone(),
        chunk,
    };
    (a, b, KillSwitch { dead, pipes: [a_to_b, b_to_a] })
}

/// An in-memory duplex pair with unsplintered writes.
pub fn loopback_pair() -> (LoopbackEnd, LoopbackEnd, KillSwitch) {
    loopback_pair_chunked(usize::MAX)
}

// ---------------------------------------------------------------------------
// std::io adapter: TCP sockets, stdio, child-process pipes.
// ---------------------------------------------------------------------------

struct IoRead<R: Read + Send>(R);

impl<R: Read + Send> WireRead for IoRead<R> {
    fn read_some(&mut self, buf: &mut [u8]) -> Result<usize, WireError> {
        loop {
            match self.0.read(buf) {
                Ok(n) => return Ok(n),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(WireError::Io(e.to_string())),
            }
        }
    }
}

struct IoWrite<W: Write + Send>(W);

impl<W: Write + Send> WireWrite for IoWrite<W> {
    fn write_all_bytes(&mut self, buf: &[u8]) -> Result<(), WireError> {
        self.0.write_all(buf).map_err(|e| WireError::Io(e.to_string()))
    }

    fn flush_bytes(&mut self) -> Result<(), WireError> {
        self.0.flush().map_err(|e| WireError::Io(e.to_string()))
    }
}

/// [`Transport`] over any `Read`/`Write` pair: a TCP stream and its
/// clone, a child's stdout/stdin, or the process's own stdio.
pub struct IoConn<R: Read + Send + 'static, W: Write + Send + 'static> {
    r: R,
    w: W,
}

impl<R: Read + Send + 'static, W: Write + Send + 'static> IoConn<R, W> {
    pub fn new(r: R, w: W) -> Self {
        Self { r, w }
    }
}

impl<R: Read + Send + 'static, W: Write + Send + 'static> Transport for IoConn<R, W> {
    fn split(self: Box<Self>) -> (Box<dyn WireRead>, Box<dyn WireWrite>) {
        (Box::new(IoRead(self.r)), Box::new(IoWrite(self.w)))
    }
}

/// A TCP stream as a [`Transport`] (the stream is cloned for the read
/// half, as `std::net` requires for full duplex).
pub fn tcp_transport(stream: TcpStream) -> std::io::Result<IoConn<TcpStream, TcpStream>> {
    let read_half = stream.try_clone()?;
    Ok(IoConn::new(read_half, stream))
}

/// The process's own stdio as a [`Transport`] — the worker side of an
/// `ssctl worker --stdio` deployment. Anything the process logs must go
/// to stderr; stdout is the protocol channel.
pub fn stdio_transport() -> IoConn<std::io::Stdin, std::io::Stdout> {
    IoConn::new(std::io::stdin(), std::io::stdout())
}

// ---------------------------------------------------------------------------
// Framed endpoints: messages in/out of a transport half.
// ---------------------------------------------------------------------------

/// Writing half of a framed connection: owns the per-direction sequence
/// counter, so every message sent through it is framed in order.
pub struct FrameWriter {
    w: Box<dyn WireWrite>,
    next_seq: u64,
}

impl FrameWriter {
    pub fn new(w: Box<dyn WireWrite>) -> Self {
        Self { w, next_seq: 0 }
    }

    /// Frame, checksum and send one message; returns the wire size in
    /// bytes (for `rpc_bytes_*` accounting).
    pub fn send(&mut self, msg: &Message) -> Result<usize, WireError> {
        let payload = msg.encode();
        let wire = encode_frame(msg.tag(), self.next_seq, &payload);
        self.w.write_all_bytes(&wire)?;
        self.w.flush_bytes()?;
        self.next_seq += 1;
        Ok(wire.len())
    }
}

/// Reading half of a framed connection: blocking
/// [`recv`](Self::recv) drives the transport through the incremental
/// [`FrameDecoder`] and decodes complete frames into [`Message`]s.
pub struct FrameReader {
    r: Box<dyn WireRead>,
    dec: FrameDecoder,
}

impl FrameReader {
    pub fn new(r: Box<dyn WireRead>) -> Self {
        Self { r, dec: FrameDecoder::new() }
    }

    /// Next message and its wire size; `Ok(None)` on clean EOF. Corrupt,
    /// reordered or truncated input returns the typed [`WireError`]
    /// (and the underlying decoder stays poisoned — tear the
    /// connection down).
    pub fn recv(&mut self) -> Result<Option<(Message, usize)>, WireError> {
        let mut scratch = [0u8; 16 * 1024];
        loop {
            if let Some(Frame { tag, payload, .. }) = self.dec.next_frame()? {
                // len u32 + tag + seq + payload + fnv64
                let wire_len = 4 + 9 + payload.len() + 8;
                let msg = Message::decode(tag, &payload)?;
                return Ok(Some((msg, wire_len)));
            }
            let n = self.r.read_some(&mut scratch)?;
            if n == 0 {
                self.dec.finish()?;
                return Ok(None);
            }
            self.dec.push(&scratch[..n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_carries_framed_messages_both_ways() {
        let (a, b, _kill) = loopback_pair_chunked(3);
        let (ar, aw) = Box::new(a).split();
        let (br, bw) = Box::new(b).split();
        let (mut aw, mut bw) = (FrameWriter::new(aw), FrameWriter::new(bw));
        let (mut ar, mut br) = (FrameReader::new(ar), FrameReader::new(br));

        let ping = Message::HealthProbe { nonce: 77 };
        aw.send(&ping).unwrap();
        let t = std::thread::spawn(move || {
            let (got, _) = br.recv().unwrap().unwrap();
            assert_eq!(got, Message::HealthProbe { nonce: 77 });
            bw.send(&Message::HealthSnap {
                nonce: 77,
                jobs_done: 1,
                busy: 0,
                metrics_json: "{}".into(),
            })
            .unwrap();
        });
        let (snap, _) = ar.recv().unwrap().unwrap();
        assert!(matches!(snap, Message::HealthSnap { nonce: 77, .. }));
        t.join().unwrap();
        drop(aw);
        // writer drop closes the pipe: the peer sees clean EOF
        // (new reader for the now-closed a→b direction)
    }

    #[test]
    fn writer_drop_is_clean_eof_for_the_peer() {
        let (a, b, _kill) = loopback_pair();
        let (_ar, aw) = Box::new(a).split();
        let (br, _bw) = Box::new(b).split();
        let mut aw = FrameWriter::new(aw);
        aw.send(&Message::Shutdown).unwrap();
        drop(aw);
        let mut br = FrameReader::new(br);
        assert!(matches!(br.recv().unwrap(), Some((Message::Shutdown, _))));
        assert!(br.recv().unwrap().is_none(), "closed pipe is clean EOF");
    }

    #[test]
    fn kill_switch_fails_both_directions_typed() {
        let (a, b, kill) = loopback_pair();
        let (_ar, aw) = Box::new(a).split();
        let (br, _bw) = Box::new(b).split();
        let mut aw = FrameWriter::new(aw);
        let mut br = FrameReader::new(br);
        // reader blocked on an empty pipe wakes with Io when killed
        let t = std::thread::spawn(move || br.recv());
        std::thread::sleep(std::time::Duration::from_millis(20));
        kill.kill();
        assert!(matches!(t.join().unwrap(), Err(WireError::Io(_))));
        assert!(matches!(aw.send(&Message::Shutdown), Err(WireError::Io(_))));
    }
}
