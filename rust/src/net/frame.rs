//! Framing: the length-prefixed, checksummed envelope every cluster
//! message travels in, plus the incremental decoder that reassembles
//! frames from an arbitrary byte stream.
//!
//! Layout (all integers little-endian, same discipline as the WAL):
//!
//! ```text
//! [len u32][tag u8][seq u64][payload ...][fnv64 u64]
//!          |<------- body: len bytes ------->|
//! ```
//!
//! `len` counts the body (tag + seq + payload); the trailing checksum is
//! [`fnv1a64`](crate::stream::wal) over the body, the same function the
//! write-ahead log uses — one integrity primitive for the whole crate.
//! `seq` is assigned per *direction* of a connection, strictly
//! monotonically from 0; the decoder enforces it, so a reordered or
//! replayed frame surfaces as a typed [`WireError::Reorder`] instead of
//! silently corrupting protocol state.
//!
//! Decoding is incremental and never panics: bytes arrive in whatever
//! chunks the transport produces, [`FrameDecoder::next_frame`] returns
//! `Ok(None)` while a frame is incomplete, and every malformed input —
//! oversized length, checksum mismatch, truncated stream at EOF — maps to
//! a typed [`WireError`]. A decoder that has reported `Corrupt` or
//! `Reorder` is dead: resynchronizing inside a corrupt byte stream is
//! guesswork, so the connection is torn down instead.

use crate::stream::wal::{fnv1a64, put_u32, put_u64, put_u8};

/// Protocol version carried in the `Hello`/`HelloAck` handshake. Bump on
/// any frame- or message-layout change.
pub const PROTO_VERSION: u8 = 1;

/// Hard cap on a frame body. Shard payloads are row matrices (tens of MB
/// at production scale); anything past this is a corrupt length prefix,
/// not a real message — reject before allocating.
pub const MAX_FRAME: usize = 512 << 20;

/// Body bytes before the payload: tag (1) + seq (8).
const HEADER: usize = 9;

/// Typed failure of the wire layer. Everything the protocol can mismatch
/// on has its own variant so peers and tests can branch on the cause;
/// nothing here ever panics the process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Structurally invalid bytes: impossible length, checksum mismatch,
    /// unknown tag, payload that under- or over-runs its message schema.
    Corrupt(String),
    /// Frame sequence violation — a reordered, replayed or dropped frame.
    Reorder { expected: u64, got: u64 },
    /// Handshake version mismatch.
    Version { ours: u8, theirs: u8 },
    /// Transport failure (socket error, killed pipe).
    Io(String),
    /// The peer closed the connection (cleanly, or mid-frame — the
    /// decoder distinguishes via [`FrameDecoder::finish`]).
    Closed,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Corrupt(why) => write!(f, "corrupt frame: {why}"),
            WireError::Reorder { expected, got } => {
                write!(f, "frame reorder: expected seq {expected}, got {got}")
            }
            WireError::Version { ours, theirs } => {
                write!(f, "protocol version mismatch: ours {ours}, theirs {theirs}")
            }
            WireError::Io(why) => write!(f, "transport error: {why}"),
            WireError::Closed => f.write_str("connection closed"),
        }
    }
}

impl std::error::Error for WireError {}

/// A decoded frame: tag, per-direction sequence number, payload bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub tag: u8,
    pub seq: u64,
    pub payload: Vec<u8>,
}

/// Encode one frame: `[len u32][tag u8][seq u64][payload][fnv64]`.
pub fn encode_frame(tag: u8, seq: u64, payload: &[u8]) -> Vec<u8> {
    let mut body = Vec::with_capacity(HEADER + payload.len());
    put_u8(&mut body, tag);
    put_u64(&mut body, seq);
    body.extend_from_slice(payload);
    let mut out = Vec::with_capacity(4 + body.len() + 8);
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    put_u64(&mut out, fnv1a64(&body));
    out
}

/// Incremental frame reassembler for one direction of a connection.
///
/// Feed transport bytes with [`push`](Self::push), drain complete frames
/// with [`next_frame`](Self::next_frame) (`Ok(None)` = incomplete, wait
/// for more bytes). The decoder verifies the length bound, the body
/// checksum and the strict seq order; any violation returns a typed
/// [`WireError`] and poisons the decoder (further calls keep failing) —
/// a corrupt stream has no trustworthy resynchronization point.
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (compacted opportunistically).
    pos: usize,
    next_seq: u64,
    dead: Option<WireError>,
}

impl Default for FrameDecoder {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameDecoder {
    pub fn new() -> Self {
        Self { buf: Vec::new(), pos: 0, next_seq: 0, dead: None }
    }

    /// Append transport bytes (any chunking, including one byte at a time).
    pub fn push(&mut self, bytes: &[u8]) {
        // compact the consumed prefix before growing, so a long-lived
        // connection doesn't accrete every frame it ever saw
        if self.pos > 0 && (self.pos >= self.buf.len() || self.pos > 64 * 1024) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Next complete frame, `Ok(None)` while more bytes are needed.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, WireError> {
        if let Some(e) = &self.dead {
            return Err(e.clone());
        }
        match self.parse() {
            Ok(v) => Ok(v),
            Err(e) => {
                self.dead = Some(e.clone());
                Err(e)
            }
        }
    }

    fn parse(&mut self) -> Result<Option<Frame>, WireError> {
        let avail = &self.buf[self.pos..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]) as usize;
        if len < HEADER {
            return Err(WireError::Corrupt(format!("impossible body length {len}")));
        }
        if len > MAX_FRAME {
            return Err(WireError::Corrupt(format!(
                "body length {len} exceeds the {MAX_FRAME}-byte frame cap"
            )));
        }
        if avail.len() < 4 + len + 8 {
            return Ok(None);
        }
        let body = &avail[4..4 + len];
        let sum = u64::from_le_bytes(avail[4 + len..4 + len + 8].try_into().unwrap());
        if sum != fnv1a64(body) {
            return Err(WireError::Corrupt("body checksum mismatch".into()));
        }
        let tag = body[0];
        let seq = u64::from_le_bytes(body[1..9].try_into().unwrap());
        if seq != self.next_seq {
            return Err(WireError::Reorder { expected: self.next_seq, got: seq });
        }
        let payload = body[HEADER..].to_vec();
        self.next_seq += 1;
        self.pos += 4 + len + 8;
        Ok(Some(Frame { tag, seq, payload }))
    }

    /// Bytes buffered but not yet consumed by a complete frame.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Call at EOF: a connection that closed with a partial frame in the
    /// buffer was truncated mid-message — typed, not silently dropped.
    pub fn finish(&self) -> Result<(), WireError> {
        if let Some(e) = &self.dead {
            return Err(e.clone());
        }
        if self.pending_bytes() > 0 {
            return Err(WireError::Corrupt(format!(
                "stream truncated mid-frame ({} residual bytes)",
                self.pending_bytes()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_whole_and_byte_at_a_time() {
        let payload = b"shard bytes".to_vec();
        let wire = encode_frame(7, 0, &payload);
        // whole
        let mut d = FrameDecoder::new();
        d.push(&wire);
        let f = d.next_frame().unwrap().unwrap();
        assert_eq!((f.tag, f.seq, &f.payload), (7, 0, &payload));
        assert!(d.next_frame().unwrap().is_none());
        d.finish().unwrap();
        // byte at a time
        let mut d = FrameDecoder::new();
        for &b in &wire {
            d.push(&[b]);
        }
        let f = d.next_frame().unwrap().unwrap();
        assert_eq!(f.payload, payload);
    }

    #[test]
    fn seq_enforced_and_reorder_is_typed() {
        let a = encode_frame(1, 0, b"a");
        let b = encode_frame(1, 1, b"b");
        let mut d = FrameDecoder::new();
        d.push(&b);
        d.push(&a);
        match d.next_frame() {
            Err(WireError::Reorder { expected: 0, got: 1 }) => {}
            other => panic!("expected Reorder, got {other:?}"),
        }
        // the decoder is poisoned afterwards
        assert!(d.next_frame().is_err());
    }

    #[test]
    fn checksum_and_length_violations_are_typed() {
        let mut wire = encode_frame(3, 0, b"payload");
        // flip one payload byte — checksum catches it
        wire[8] ^= 0x40;
        let mut d = FrameDecoder::new();
        d.push(&wire);
        assert!(matches!(d.next_frame(), Err(WireError::Corrupt(_))));

        // impossible length prefix
        let mut d = FrameDecoder::new();
        d.push(&[3, 0, 0, 0, 9, 9, 9, 9, 9, 9, 9, 9, 9, 9, 9, 9, 9]);
        assert!(matches!(d.next_frame(), Err(WireError::Corrupt(_))));

        // over-cap length prefix rejected before buffering the body
        let mut d = FrameDecoder::new();
        d.push(&u32::MAX.to_le_bytes());
        assert!(matches!(d.next_frame(), Err(WireError::Corrupt(_))));
    }

    #[test]
    fn truncated_stream_is_incomplete_then_typed_at_eof() {
        let wire = encode_frame(2, 0, b"0123456789");
        for cut in 1..wire.len() {
            let mut d = FrameDecoder::new();
            d.push(&wire[..cut]);
            assert_eq!(d.next_frame().unwrap(), None, "prefix of {cut} bytes must be incomplete");
            assert!(matches!(d.finish(), Err(WireError::Corrupt(_))));
        }
    }
}
