//! Zero-dependency wire layer for the distributed SS cluster.
//!
//! Three stacked pieces, bottom-up:
//!
//! * [`frame`] — the `[len u32][tag u8][seq u64][payload][fnv64]`
//!   envelope and its incremental, never-panicking decoder; integrity
//!   rides on the same fnv1a64 the write-ahead log uses.
//! * [`msg`] — typed codecs for every protocol message (handshake,
//!   summarize jobs, shard assignments, survivor cores, health/metrics
//!   snapshots, the [`ServiceError`](crate::coordinator::ServiceError)
//!   family, cancel/shutdown).
//! * [`transport`] — the byte-stream trait pair plus loopback, TCP and
//!   stdio implementations, and the [`FrameReader`]/[`FrameWriter`]
//!   endpoints that move [`Message`]s over any of them.
//!
//! The cluster runtimes (`crate::cluster`) sit on top; nothing in this
//! module knows about jobs, shards or submodularity beyond their
//! serialized shapes.

pub mod frame;
pub mod msg;
pub mod transport;

pub use frame::{encode_frame, Frame, FrameDecoder, WireError, MAX_FRAME, PROTO_VERSION};
pub use msg::{tag, Message};
pub use transport::{
    loopback_pair, loopback_pair_chunked, stdio_transport, tcp_transport, FrameReader,
    FrameWriter, IoConn, KillSwitch, LoopbackEnd, Transport, WireRead, WireWrite,
};
