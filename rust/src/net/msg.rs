//! Message codecs: every payload the cluster speaks, hand-encoded on the
//! WAL's little-endian primitives ([`put_u32`]/[`Cursor`]) — zero
//! dependencies, and the same bounds-checked reader discipline, so a
//! malformed payload surfaces as [`WireError::Corrupt`] instead of a
//! panic or a partially-applied message.
//!
//! The vocabulary (one tag per variant, see [`Message::tag`]):
//!
//! * `Hello` / `HelloAck` — version handshake (coordinator speaks first);
//! * `SummarizeReq` / `SummarizeResp` — a whole summarize job shipped to
//!   one worker (the single-worker degenerate of the cluster path);
//! * `ShardAssign` / `ShardCore` — one logical shard out (global ids +
//!   gathered rows + per-shard SS params), its surviving core back;
//! * `HealthProbe` / `HealthSnap` — liveness + the worker's scoped
//!   metrics snapshot, JSON-encoded;
//! * `ErrorMsg` — the typed [`ServiceError`] family, encoded variant by
//!   variant so a worker-side failure arrives as the same type the local
//!   service would have returned;
//! * `Cancel` / `Shutdown` — cooperative job cancellation and clean
//!   worker teardown.
//!
//! Every decoder consumes its payload exactly ([`Cursor::done`]):
//! trailing bytes are corruption, not extensibility — extensibility is
//! what the handshake version is for.

use crate::algorithms::{Sampling, SsParams};
use crate::coordinator::ServiceError;
use crate::stream::wal::{put_f32, put_f64, put_u32, put_u64, put_u8, Cursor, WalError};
use crate::submodular::{BuildStrategy, Concave, ObjectiveSpec};
use crate::util::vecmath::FeatureMatrix;

use super::frame::WireError;

/// Frame tags, one per message kind.
pub mod tag {
    pub const HELLO: u8 = 1;
    pub const HELLO_ACK: u8 = 2;
    pub const SUMMARIZE_REQ: u8 = 3;
    pub const SUMMARIZE_RESP: u8 = 4;
    pub const SHARD_ASSIGN: u8 = 5;
    pub const SHARD_CORE: u8 = 6;
    pub const HEALTH_PROBE: u8 = 7;
    pub const HEALTH_SNAP: u8 = 8;
    pub const ERROR: u8 = 9;
    pub const CANCEL: u8 = 10;
    pub const SHUTDOWN: u8 = 11;
}

/// One decoded protocol message. See the module docs for the vocabulary.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    Hello { version: u8, peer_id: u64 },
    HelloAck { version: u8, peer_id: u64 },
    SummarizeReq { job: u64, spec: ObjectiveSpec, rows: FeatureMatrix, k: u32, params: SsParams },
    SummarizeResp { job: u64, summary: Vec<u64>, value: f64, n: u64, reduced: u64, ss_rounds: u32 },
    /// One logical shard: ascending global ids plus their gathered rows.
    ShardAssign {
        job: u64,
        shard: u32,
        spec: ObjectiveSpec,
        params: SsParams,
        ids: Vec<u64>,
        rows: FeatureMatrix,
    },
    /// The shard's SS survivors, as ascending global ids.
    ShardCore { job: u64, shard: u32, kept: Vec<u64>, rounds: u32 },
    HealthProbe { nonce: u64 },
    HealthSnap { nonce: u64, jobs_done: u64, busy: u32, metrics_json: String },
    /// A typed service failure for `job` (`job` 0 = connection-level).
    ErrorMsg { job: u64, err: ServiceError },
    Cancel { job: u64 },
    Shutdown,
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn get_str(c: &mut Cursor<'_>) -> Result<String, WalError> {
    let len = c.u32()? as usize;
    let bytes = c.take(len)?;
    String::from_utf8(bytes.to_vec())
        .map_err(|_| WalError::Corrupt("string payload is not valid UTF-8".into()))
}

fn put_spec(out: &mut Vec<u8>, spec: ObjectiveSpec) {
    match spec {
        ObjectiveSpec::Features(g) => {
            put_u8(out, 0);
            match g {
                Concave::Sqrt => put_u8(out, 0),
                Concave::Log1p => put_u8(out, 1),
                Concave::Pow(p) => {
                    put_u8(out, 2);
                    put_u32(out, p as u32);
                }
            }
        }
        ObjectiveSpec::FacilityLocation => put_u8(out, 1),
        ObjectiveSpec::FacilityLocationSparse { t, crossover, build } => {
            put_u8(out, 2);
            put_u32(out, t);
            put_u32(out, crossover);
            match build {
                BuildStrategy::Exact => put_u8(out, 0),
                BuildStrategy::Lsh { tables, bits } => {
                    put_u8(out, 1);
                    put_u32(out, tables);
                    put_u32(out, bits);
                }
                BuildStrategy::Auto => put_u8(out, 2),
            }
        }
    }
}

fn get_spec(c: &mut Cursor<'_>) -> Result<ObjectiveSpec, WalError> {
    match c.u8()? {
        0 => {
            let g = match c.u8()? {
                0 => Concave::Sqrt,
                1 => Concave::Log1p,
                2 => Concave::Pow(c.u32()? as u16),
                other => {
                    return Err(WalError::Corrupt(format!("unknown concave scalarizer {other}")))
                }
            };
            Ok(ObjectiveSpec::Features(g))
        }
        1 => Ok(ObjectiveSpec::FacilityLocation),
        2 => {
            let t = c.u32()?;
            let crossover = c.u32()?;
            let build = match c.u8()? {
                0 => BuildStrategy::Exact,
                1 => BuildStrategy::Lsh { tables: c.u32()?, bits: c.u32()? },
                2 => BuildStrategy::Auto,
                other => {
                    return Err(WalError::Corrupt(format!("unknown build strategy {other}")))
                }
            };
            Ok(ObjectiveSpec::FacilityLocationSparse { t, crossover, build })
        }
        other => Err(WalError::Corrupt(format!("unknown objective spec {other}"))),
    }
}

fn put_params(out: &mut Vec<u8>, p: &SsParams) {
    put_u32(out, p.r as u32);
    put_f64(out, p.c);
    put_u64(out, p.seed);
    put_u8(out, match p.sampling {
        Sampling::Uniform => 0,
        Sampling::Importance => 1,
    });
    put_u32(out, p.min_keep as u32);
}

fn get_params(c: &mut Cursor<'_>) -> Result<SsParams, WalError> {
    let r = c.u32()? as usize;
    let cc = c.f64()?;
    let seed = c.u64()?;
    let sampling = match c.u8()? {
        0 => Sampling::Uniform,
        1 => Sampling::Importance,
        other => return Err(WalError::Corrupt(format!("unknown sampling mode {other}"))),
    };
    let min_keep = c.u32()? as usize;
    Ok(SsParams { r, c: cc, seed, sampling, min_keep })
}

fn put_rows(out: &mut Vec<u8>, rows: &FeatureMatrix) {
    put_u32(out, rows.n() as u32);
    put_u32(out, rows.d as u32);
    for &v in rows.data() {
        put_f32(out, v);
    }
}

fn get_rows(c: &mut Cursor<'_>) -> Result<FeatureMatrix, WalError> {
    let n = c.u32()? as usize;
    let d = c.u32()? as usize;
    let total = n
        .checked_mul(d)
        .ok_or_else(|| WalError::Corrupt("row matrix dims overflow".into()))?;
    // bound the allocation by what the payload can actually hold — a
    // corrupt dim pair must not reserve gigabytes before the short read
    if total * 4 > c.remaining() {
        return Err(WalError::Corrupt(format!(
            "row matrix {n}x{d} overruns its payload ({} bytes left)",
            c.remaining()
        )));
    }
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = c.f32()?;
        }
    }
    Ok(m)
}

fn put_ids(out: &mut Vec<u8>, ids: &[u64]) {
    put_u32(out, ids.len() as u32);
    for &id in ids {
        put_u64(out, id);
    }
}

fn get_ids(c: &mut Cursor<'_>) -> Result<Vec<u64>, WalError> {
    let n = c.u32()? as usize;
    if n * 8 > c.remaining() {
        return Err(WalError::Corrupt(format!(
            "id list of {n} overruns its payload ({} bytes left)",
            c.remaining()
        )));
    }
    let mut ids = Vec::with_capacity(n);
    for _ in 0..n {
        ids.push(c.u64()?);
    }
    Ok(ids)
}

fn put_service_error<R>(out: &mut Vec<u8>, err: &ServiceError<R>) {
    match err {
        // the payload (if any) stays with the sender; backpressure over
        // the wire is a retry signal, not a payload hand-back
        ServiceError::QueueFull(_) => put_u8(out, 0),
        ServiceError::ServiceDown => put_u8(out, 1),
        ServiceError::UnknownStream(id) => {
            put_u8(out, 2);
            put_u64(out, *id);
        }
        ServiceError::Rejected { reason } => {
            put_u8(out, 3);
            put_str(out, reason);
        }
        ServiceError::Cancelled => put_u8(out, 4),
        ServiceError::DeadlineExceeded => put_u8(out, 5),
    }
}

fn get_service_error(c: &mut Cursor<'_>) -> Result<ServiceError, WalError> {
    Ok(match c.u8()? {
        0 => ServiceError::QueueFull(()),
        1 => ServiceError::ServiceDown,
        2 => ServiceError::UnknownStream(c.u64()?),
        3 => ServiceError::Rejected { reason: get_str(c)? },
        4 => ServiceError::Cancelled,
        5 => ServiceError::DeadlineExceeded,
        other => return Err(WalError::Corrupt(format!("unknown service error variant {other}"))),
    })
}

impl Message {
    /// The frame tag this message travels under.
    pub fn tag(&self) -> u8 {
        match self {
            Message::Hello { .. } => tag::HELLO,
            Message::HelloAck { .. } => tag::HELLO_ACK,
            Message::SummarizeReq { .. } => tag::SUMMARIZE_REQ,
            Message::SummarizeResp { .. } => tag::SUMMARIZE_RESP,
            Message::ShardAssign { .. } => tag::SHARD_ASSIGN,
            Message::ShardCore { .. } => tag::SHARD_CORE,
            Message::HealthProbe { .. } => tag::HEALTH_PROBE,
            Message::HealthSnap { .. } => tag::HEALTH_SNAP,
            Message::ErrorMsg { .. } => tag::ERROR,
            Message::Cancel { .. } => tag::CANCEL,
            Message::Shutdown => tag::SHUTDOWN,
        }
    }

    /// Encode the payload bytes (framing is [`encode_frame`]'s job).
    ///
    /// [`encode_frame`]: super::frame::encode_frame
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Message::Hello { version, peer_id } | Message::HelloAck { version, peer_id } => {
                put_u8(&mut out, *version);
                put_u64(&mut out, *peer_id);
            }
            Message::SummarizeReq { job, spec, rows, k, params } => {
                put_u64(&mut out, *job);
                put_spec(&mut out, *spec);
                put_u32(&mut out, *k);
                put_params(&mut out, params);
                put_rows(&mut out, rows);
            }
            Message::SummarizeResp { job, summary, value, n, reduced, ss_rounds } => {
                put_u64(&mut out, *job);
                put_ids(&mut out, summary);
                put_f64(&mut out, *value);
                put_u64(&mut out, *n);
                put_u64(&mut out, *reduced);
                put_u32(&mut out, *ss_rounds);
            }
            Message::ShardAssign { job, shard, spec, params, ids, rows } => {
                put_u64(&mut out, *job);
                put_u32(&mut out, *shard);
                put_spec(&mut out, *spec);
                put_params(&mut out, params);
                put_ids(&mut out, ids);
                put_rows(&mut out, rows);
            }
            Message::ShardCore { job, shard, kept, rounds } => {
                put_u64(&mut out, *job);
                put_u32(&mut out, *shard);
                put_ids(&mut out, kept);
                put_u32(&mut out, *rounds);
            }
            Message::HealthProbe { nonce } => put_u64(&mut out, *nonce),
            Message::HealthSnap { nonce, jobs_done, busy, metrics_json } => {
                put_u64(&mut out, *nonce);
                put_u64(&mut out, *jobs_done);
                put_u32(&mut out, *busy);
                put_str(&mut out, metrics_json);
            }
            Message::ErrorMsg { job, err } => {
                put_u64(&mut out, *job);
                put_service_error(&mut out, err);
            }
            Message::Cancel { job } => put_u64(&mut out, *job),
            Message::Shutdown => {}
        }
        out
    }

    /// Decode a frame's payload. Unknown tags, short payloads, trailing
    /// bytes and invalid enum discriminants all surface as
    /// [`WireError::Corrupt`] — never a panic, never a partial message.
    pub fn decode(frame_tag: u8, payload: &[u8]) -> Result<Message, WireError> {
        let mut c = Cursor::new(payload);
        let msg = match frame_tag {
            tag::HELLO => Message::Hello { version: c.u8()?, peer_id: c.u64()? },
            tag::HELLO_ACK => Message::HelloAck { version: c.u8()?, peer_id: c.u64()? },
            tag::SUMMARIZE_REQ => {
                let job = c.u64()?;
                let spec = get_spec(&mut c)?;
                let k = c.u32()?;
                let params = get_params(&mut c)?;
                let rows = get_rows(&mut c)?;
                Message::SummarizeReq { job, spec, rows, k, params }
            }
            tag::SUMMARIZE_RESP => Message::SummarizeResp {
                job: c.u64()?,
                summary: get_ids(&mut c)?,
                value: c.f64()?,
                n: c.u64()?,
                reduced: c.u64()?,
                ss_rounds: c.u32()?,
            },
            tag::SHARD_ASSIGN => {
                let job = c.u64()?;
                let shard = c.u32()?;
                let spec = get_spec(&mut c)?;
                let params = get_params(&mut c)?;
                let ids = get_ids(&mut c)?;
                let rows = get_rows(&mut c)?;
                if ids.len() != rows.n() {
                    return Err(WireError::Corrupt(format!(
                        "shard carries {} ids but {} rows",
                        ids.len(),
                        rows.n()
                    )));
                }
                Message::ShardAssign { job, shard, spec, params, ids, rows }
            }
            tag::SHARD_CORE => Message::ShardCore {
                job: c.u64()?,
                shard: c.u32()?,
                kept: get_ids(&mut c)?,
                rounds: c.u32()?,
            },
            tag::HEALTH_PROBE => Message::HealthProbe { nonce: c.u64()? },
            tag::HEALTH_SNAP => Message::HealthSnap {
                nonce: c.u64()?,
                jobs_done: c.u64()?,
                busy: c.u32()?,
                metrics_json: get_str(&mut c)?,
            },
            tag::ERROR => Message::ErrorMsg { job: c.u64()?, err: get_service_error(&mut c)? },
            tag::CANCEL => Message::Cancel { job: c.u64()? },
            tag::SHUTDOWN => Message::Shutdown,
            other => return Err(WireError::Corrupt(format!("unknown message tag {other}"))),
        };
        c.done()?;
        Ok(msg)
    }
}

// WAL reader errors (short reads, trailing bytes) are wire corruption
// when they happen inside a frame payload.
impl From<WalError> for WireError {
    fn from(e: WalError) -> Self {
        WireError::Corrupt(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_messages_roundtrip() {
        let mut rows = FeatureMatrix::zeros(3, 2);
        rows.row_mut(1)[0] = 0.5;
        rows.row_mut(2)[1] = -2.25;
        let msg = Message::ShardAssign {
            job: 9,
            shard: 2,
            spec: ObjectiveSpec::Features(Concave::Log1p),
            params: SsParams::default().with_seed(41),
            ids: vec![4, 17, 900],
            rows,
        };
        let back = Message::decode(msg.tag(), &msg.encode()).unwrap();
        assert_eq!(back, msg);

        let core = Message::ShardCore { job: 9, shard: 2, kept: vec![4, 900], rounds: 3 };
        assert_eq!(Message::decode(core.tag(), &core.encode()).unwrap(), core);
    }

    #[test]
    fn error_family_roundtrips_typed() {
        for err in [
            ServiceError::QueueFull(()),
            ServiceError::ServiceDown,
            ServiceError::UnknownStream(7),
            ServiceError::Rejected { reason: "no runtime".into() },
            ServiceError::Cancelled,
            ServiceError::DeadlineExceeded,
        ] {
            let msg = Message::ErrorMsg { job: 3, err };
            let back = Message::decode(msg.tag(), &msg.encode()).unwrap();
            match (&msg, &back) {
                (
                    Message::ErrorMsg { err: a, .. },
                    Message::ErrorMsg { err: b, .. },
                ) => assert_eq!(a.to_string(), b.to_string()),
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn trailing_bytes_and_bad_discriminants_are_corrupt() {
        let msg = Message::Cancel { job: 5 };
        let mut payload = msg.encode();
        payload.push(0xff);
        assert!(matches!(Message::decode(msg.tag(), &payload), Err(WireError::Corrupt(_))));
        // truncated payload
        assert!(matches!(Message::decode(msg.tag(), &[1, 2]), Err(WireError::Corrupt(_))));
        // unknown tag
        assert!(matches!(Message::decode(0xEE, &[]), Err(WireError::Corrupt(_))));
        // bad enum discriminant inside an error message
        assert!(matches!(
            Message::decode(tag::ERROR, &{
                let mut p = Vec::new();
                put_u64(&mut p, 1);
                put_u8(&mut p, 99);
                p
            }),
            Err(WireError::Corrupt(_))
        ));
    }

    #[test]
    fn oversized_dims_reject_before_allocating() {
        // SummarizeResp whose id count claims more than the payload holds
        let mut p = Vec::new();
        put_u64(&mut p, 1); // job
        put_u32(&mut p, u32::MAX); // summary len
        assert!(matches!(
            Message::decode(tag::SUMMARIZE_RESP, &p),
            Err(WireError::Corrupt(_))
        ));
    }
}
