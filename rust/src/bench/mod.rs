//! Bench harness (offline `criterion` substitute): warmup + timed iterations
//! with mean/median/p95 reporting, plus a row-oriented table printer for the
//! per-figure reproduction benches.
//!
//! All `rust/benches/*.rs` targets are `harness = false` binaries built on
//! this module; `cargo bench` runs them sequentially.

use crate::util::stats::{Samples, Timer};

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

/// Time `f` with `warmup` discarded runs and `iters` measured runs.
pub fn bench<R>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Samples::new();
    for _ in 0..iters.max(1) {
        let t = Timer::new();
        std::hint::black_box(f());
        samples.push(t.elapsed_s());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: samples.mean(),
        median_s: samples.median(),
        p95_s: samples.percentile(95.0),
        min_s: samples.percentile(0.0),
    };
    println!(
        "bench {name:<40} iters={iters:<3} mean={:.6}s median={:.6}s p95={:.6}s min={:.6}s",
        r.mean_s, r.median_s, r.p95_s, r.min_s,
        name = r.name,
        iters = r.iters,
    );
    r
}

/// Scale knob shared by all benches: `SS_FULL=1` runs paper-scale workloads,
/// default is CI-scale (same shapes, smaller n).
pub fn full_scale() -> bool {
    std::env::var("SS_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Markdown-ish table printer for figure/table reproductions.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}", w = w))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-|-"));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }

    /// Also serialize to JSON for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            ("header", Json::Arr(self.header.iter().map(|h| Json::Str(h.clone())).collect())),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Append the JSON form to `target/bench-results/<file>.json`.
    pub fn save(&self, file: &str) {
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let _ = std::fs::write(dir.join(file), self.to_json().pretty());
        println!("(saved to target/bench-results/{file})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.median_s <= r.p95_s + 1e-12);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("demo"));
        t.print();
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
