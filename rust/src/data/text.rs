//! Text pipeline substrate: synthetic vocabulary, tokenization, TF-IDF and
//! feature hashing — the replacement for the licensed corpora's
//! preprocessing stack (sklearn TF-IDF in the paper's setup).

use std::collections::HashMap;

use crate::util::rng::{zipf_cdf, Rng};
use crate::util::vecmath::{hash_str, FeatureMatrix, SparseVec};

/// Synthetic vocabulary: pronounceable word strings with a Zipf rank
/// distribution (so TF-IDF has realistic dynamics: a heavy head of
/// stop-word-ish tokens and a long informative tail).
pub struct Vocabulary {
    pub words: Vec<String>,
    cdf: Vec<f64>,
}

const ONSETS: [&str; 12] = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t"];
const NUCLEI: [&str; 6] = ["a", "e", "i", "o", "u", "ai"];
const CODAS: [&str; 8] = ["", "n", "r", "s", "t", "l", "m", "k"];

impl Vocabulary {
    pub fn new(size: usize, zipf_s: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut words = Vec::with_capacity(size);
        let mut seen = std::collections::HashSet::new();
        while words.len() < size {
            let syllables = 1 + rng.below(3);
            let mut w = String::new();
            for _ in 0..=syllables {
                w.push_str(ONSETS[rng.below(ONSETS.len())]);
                w.push_str(NUCLEI[rng.below(NUCLEI.len())]);
                w.push_str(CODAS[rng.below(CODAS.len())]);
            }
            if seen.insert(w.clone()) {
                words.push(w);
            }
        }
        Self { words, cdf: zipf_cdf(size, zipf_s) }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Draw a word id from the Zipf base distribution.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        rng.zipf(&self.cdf) as u32
    }
}

/// A sentence is a sequence of vocabulary ids.
pub type Sentence = Vec<u32>;

/// TF-IDF vectorizer over a collection of sentences ("documents" at the
/// granularity the paper uses: sentence selection over TF-IDF features).
pub struct TfIdf {
    /// document frequency per word id
    df: HashMap<u32, u32>,
    n_docs: usize,
}

impl TfIdf {
    pub fn fit(sentences: &[Sentence]) -> Self {
        let mut df: HashMap<u32, u32> = HashMap::new();
        for s in sentences {
            let mut seen = std::collections::HashSet::new();
            for &w in s {
                if seen.insert(w) {
                    *df.entry(w).or_insert(0) += 1;
                }
            }
        }
        Self { df, n_docs: sentences.len() }
    }

    /// Sparse TF-IDF vector: tf(w) · ln((1+N)/(1+df(w))) + 1-smoothed.
    pub fn transform(&self, s: &Sentence) -> SparseVec {
        let mut tf: HashMap<u32, f32> = HashMap::new();
        for &w in s {
            *tf.entry(w).or_insert(0.0) += 1.0;
        }
        let n = self.n_docs as f32;
        let pairs = tf
            .into_iter()
            .map(|(w, f)| {
                let dfw = self.df.get(&w).copied().unwrap_or(0) as f32;
                let idf = ((1.0 + n) / (1.0 + dfw)).ln() + 1.0;
                (w, f * idf)
            })
            .collect();
        SparseVec::from_pairs(pairs)
    }

    /// Dense hashed feature matrix for a sentence collection: the ground-set
    /// features the submodular objective consumes. Non-negative by
    /// construction; rows L2-scaled to tame length bias.
    pub fn features(&self, sentences: &[Sentence], d: usize) -> FeatureMatrix {
        let mut m = FeatureMatrix::zeros(sentences.len(), d);
        for (i, s) in sentences.iter().enumerate() {
            let sv = self.transform(s);
            sv.hash_into(d, m.row_mut(i));
            // normalize to unit L1 mass scaled by sqrt(len): keeps long
            // sentences slightly favored (as raw TF-IDF would) but bounded
            let mass: f32 = m.row(i).iter().sum();
            if mass > 0.0 {
                let scale = (s.len() as f32).sqrt() / mass;
                for x in m.row_mut(i) {
                    *x *= scale;
                }
            }
        }
        m
    }
}

/// Stable 32-bit id for an out-of-vocabulary token string (the service path
/// accepts raw text).
pub fn token_id(tok: &str) -> u32 {
    (hash_str(tok) & 0xffff_ffff) as u32
}

/// Tokenize raw text: lowercase alphanumeric runs.
pub fn tokenize(text: &str) -> Vec<u32> {
    let mut toks = Vec::new();
    let mut cur = String::new();
    for ch in text.chars() {
        if ch.is_alphanumeric() {
            cur.extend(ch.to_lowercase());
        } else if !cur.is_empty() {
            toks.push(token_id(&cur));
            cur.clear();
        }
    }
    if !cur.is_empty() {
        toks.push(token_id(&cur));
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocabulary_unique_and_sized() {
        let v = Vocabulary::new(500, 1.1, 1);
        assert_eq!(v.len(), 500);
        let set: std::collections::HashSet<_> = v.words.iter().collect();
        assert_eq!(set.len(), 500);
    }

    #[test]
    fn zipf_sampling_head_heavy() {
        let v = Vocabulary::new(200, 1.2, 2);
        let mut rng = Rng::new(3);
        let mut counts = vec![0usize; 200];
        for _ in 0..20_000 {
            counts[v.sample(&mut rng) as usize] += 1;
        }
        let head: usize = counts[..20].iter().sum();
        assert!(head > 20_000 / 3, "top-10% of vocab should dominate: {head}");
    }

    #[test]
    fn tfidf_downweights_common_words() {
        // word 0 in every sentence, word 1 in one sentence
        let sents: Vec<Sentence> = (0..10).map(|i| if i == 0 { vec![0, 1] } else { vec![0, 2] }).collect();
        let t = TfIdf::fit(&sents);
        let sv = t.transform(&vec![0, 1]);
        let w0 = sv.val[sv.idx.iter().position(|&i| i == 0).unwrap()];
        let w1 = sv.val[sv.idx.iter().position(|&i| i == 1).unwrap()];
        assert!(w1 > w0, "rare word must outweigh common word: {w1} vs {w0}");
    }

    #[test]
    fn features_nonnegative_and_shaped() {
        let mut rng = Rng::new(4);
        let v = Vocabulary::new(100, 1.1, 5);
        let sents: Vec<Sentence> =
            (0..30).map(|_| (0..12).map(|_| v.sample(&mut rng)).collect()).collect();
        let t = TfIdf::fit(&sents);
        let m = t.features(&sents, 64);
        assert_eq!((m.n(), m.d), (30, 64));
        assert!(m.data().iter().all(|&x| x >= 0.0));
        assert!(m.data().iter().any(|&x| x > 0.0));
    }

    #[test]
    fn near_duplicate_sentences_have_near_equal_features() {
        let v = Vocabulary::new(100, 1.1, 6);
        let mut rng = Rng::new(7);
        let s1: Sentence = (0..15).map(|_| v.sample(&mut rng)).collect();
        let mut s2 = s1.clone();
        s2[14] = v.sample(&mut rng); // one token differs
        let many: Vec<Sentence> =
            (0..20).map(|_| (0..15).map(|_| v.sample(&mut rng)).collect()).collect();
        let mut all = vec![s1.clone(), s2.clone()];
        all.extend(many);
        let t = TfIdf::fit(&all);
        let m = t.features(&all, 64);
        let sim = crate::util::vecmath::cosine(m.row(0), m.row(1));
        assert!(sim > 0.8, "near-duplicates must stay close: {sim}");
    }

    #[test]
    fn tokenizer_basic() {
        let toks = tokenize("Hello, World! hello");
        assert_eq!(toks.len(), 3);
        assert_eq!(toks[0], toks[2], "case-insensitive");
        assert_ne!(toks[0], toks[1]);
    }
}
