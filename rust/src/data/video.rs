//! Synthetic video-summarization substrate: the SumMe replacement
//! (DESIGN.md §3). Videos are piecewise-smooth trajectories in descriptor
//! space — segments model shots, random-walk jitter models camera motion —
//! preserving the property the paper's video experiments exploit: adjacent
//! frames are nearly identical, so huge fractions of V are prunable.
//!
//! 15 simulated users vote for frames near segment boundaries ("events")
//! plus personal points of interest; the ground-truth frame score is the
//! vote count, mirroring SumMe's protocol (Gygli et al., ECCV 2014).

use crate::util::rng::Rng;
use crate::util::vecmath::FeatureMatrix;

pub const NUM_USERS: usize = 15;

pub struct Video {
    pub name: String,
    pub feats: FeatureMatrix,
    /// per-user selected frame indices (sorted)
    pub user_selections: Vec<Vec<usize>>,
    /// vote count per frame (0..=NUM_USERS)
    pub gt_scores: Vec<u32>,
    /// segment boundaries (frame indices), for diagnostics
    pub boundaries: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct VideoParams {
    pub d: usize,
    /// mean frames per segment (shot length)
    pub seg_len: usize,
    /// random-walk jitter scale relative to segment center mass
    pub jitter: f32,
    /// fraction of frames each user selects
    pub user_frac: f64,
}

impl Default for VideoParams {
    fn default() -> Self {
        Self { d: 256, seg_len: 180, jitter: 0.02, user_frac: 0.12 }
    }
}

pub fn generate(name: &str, n_frames: usize, params: &VideoParams, seed: u64) -> Video {
    let mut rng = Rng::new(seed);
    let d = params.d;
    let mut feats = FeatureMatrix::zeros(n_frames, d);

    // --- segments ---
    let mut boundaries = vec![0usize];
    let mut t = 0usize;
    while t < n_frames {
        let len = (params.seg_len as f64 * (0.4 + 1.2 * rng.f64())) as usize;
        t += len.max(20);
        if t < n_frames {
            boundaries.push(t);
        }
    }

    // --- trajectory ---
    let mut seg_idx = 0usize;
    let mut center: Vec<f32> = (0..d)
        .map(|_| if rng.bool(0.15) { rng.f32() * 2.0 } else { 0.0 })
        .collect();
    let mut walk = center.clone();
    for i in 0..n_frames {
        if seg_idx + 1 < boundaries.len() && i == boundaries[seg_idx + 1] {
            // shot change: new center, reset walk
            seg_idx += 1;
            center = (0..d).map(|_| if rng.bool(0.15) { rng.f32() * 2.0 } else { 0.0 }).collect();
            walk = center.clone();
        }
        for j in 0..d {
            if center[j] > 0.0 {
                walk[j] = (walk[j] + params.jitter * (rng.f32() - 0.5)).max(0.0);
            }
        }
        feats.row_mut(i).copy_from_slice(&walk);
    }

    // --- users ---
    let per_user = ((n_frames as f64) * params.user_frac) as usize;
    let mut user_selections = Vec::with_capacity(NUM_USERS);
    let mut votes = vec![0u32; n_frames];
    for u in 0..NUM_USERS {
        let mut urng = rng.split(u as u64 + 1);
        let mut picks = std::collections::HashSet::new();
        // interest windows around a random subset of boundaries
        let mut bs: Vec<usize> = boundaries[1..].to_vec();
        urng.shuffle(&mut bs);
        let windows = bs.len().max(1).min(3 + urng.below(4));
        for &b in bs.iter().take(windows) {
            let w = 10 + urng.below(30);
            let lo = b.saturating_sub(w / 2);
            for f in lo..(lo + w).min(n_frames) {
                if picks.len() < per_user {
                    picks.insert(f);
                }
            }
        }
        // plus personal interest: a random contiguous chunk
        while picks.len() < per_user {
            let start = urng.below(n_frames);
            let len = 5 + urng.below(20);
            for f in start..(start + len).min(n_frames) {
                if picks.len() >= per_user {
                    break;
                }
                picks.insert(f);
            }
        }
        let mut sel: Vec<usize> = picks.into_iter().collect();
        sel.sort_unstable();
        for &f in &sel {
            votes[f] += 1;
        }
        user_selections.push(sel);
    }

    Video { name: name.to_string(), feats, user_selections, gt_scores: votes, boundaries }
}

/// The 25 SumMe-like videos with the paper's Table-2 frame counts.
pub fn summe_suite(params: &VideoParams, seed: u64) -> Vec<(String, usize)> {
    let _ = (params, seed);
    [
        ("Air Force One", 4494),
        ("Base jumping", 4729),
        ("Bearpark climbing", 3341),
        ("Bike polo", 3064),
        ("Bus in rock tunnel", 5131),
        ("Car over camera", 4382),
        ("Car railcrossing", 5075),
        ("Cockpit landing", 9046),
        ("Cooking", 1286),
        ("Eiffel tower", 4971),
        ("Excavators river crossing", 9721),
        ("Fire Domino", 1612),
        ("Jumps", 950),
        ("Kids playing in leaves", 3187),
        ("Notre Dame", 4608),
        ("Paintball", 6096),
        ("Paluma jump", 2574),
        ("Playing ball", 3120),
        ("Playing on water slide", 3065),
        ("Saving dolphines", 6683),
        ("Scuba", 2221),
        ("St Maarten Landing", 1751),
        ("Statue of Liberty", 3863),
        ("Uncut evening flight", 9672),
        ("Valparaiso downhill", 5178),
    ]
    .iter()
    .map(|&(n, f)| (n.to_string(), f))
    .collect()
}

/// F1/recall of a selected frame set against a reference frame set
/// (exact frame-level set overlap).
pub fn frame_f1(selected: &[usize], reference: &[usize]) -> (f64, f64) {
    frame_f1_tol(selected, reference, 0)
}

/// F1/recall with a matching tolerance of ±`tol` frames: a reference frame
/// is recalled if any selected frame lies within `tol`, and vice versa for
/// precision. SumMe-style evaluations match at the segment level; adjacent
/// frames are visually identical, and pruning methods legitimately return a
/// neighbor of the annotated frame. `tol = 0` is the exact protocol.
pub fn frame_f1_tol(selected: &[usize], reference: &[usize], tol: usize) -> (f64, f64) {
    if selected.is_empty() || reference.is_empty() {
        return (0.0, 0.0);
    }
    let near = |xs: &[usize], f: usize| -> bool {
        // xs sorted ascending: binary search the window [f-tol, f+tol]
        let lo = f.saturating_sub(tol);
        let i = xs.partition_point(|&x| x < lo);
        i < xs.len() && xs[i] <= f + tol
    };
    let mut sel = selected.to_vec();
    sel.sort_unstable();
    let mut refs = reference.to_vec();
    refs.sort_unstable();
    let hit_ref = refs.iter().filter(|&&f| near(&sel, f)).count();
    let hit_sel = sel.iter().filter(|&&f| near(&refs, f)).count();
    let recall = hit_ref as f64 / refs.len() as f64;
    let precision = hit_sel as f64 / sel.len() as f64;
    let f1 = if recall + precision == 0.0 {
        0.0
    } else {
        2.0 * precision * recall / (precision + recall)
    };
    (f1, recall)
}

/// Reference summary = top-p-fraction frames by ground-truth vote score
/// (ties broken toward earlier frames, deterministically).
pub fn reference_by_score(video: &Video, frac: f64) -> Vec<usize> {
    let n = video.gt_scores.len();
    let count = ((n as f64) * frac).round().max(1.0) as usize;
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        video.gt_scores[b].cmp(&video.gt_scores[a]).then(a.cmp(&b))
    });
    let mut out = idx[..count.min(n)].to_vec();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::vecmath::cosine;

    #[test]
    fn shapes_and_determinism() {
        let p = VideoParams { d: 64, ..Default::default() };
        let a = generate("test", 1000, &p, 1);
        let b = generate("test", 1000, &p, 1);
        assert_eq!(a.feats, b.feats);
        assert_eq!(a.user_selections, b.user_selections);
        assert_eq!(a.feats.n(), 1000);
        assert_eq!(a.user_selections.len(), NUM_USERS);
        assert!(a.gt_scores.iter().all(|&v| v as usize <= NUM_USERS));
    }

    #[test]
    fn adjacent_frames_nearly_identical() {
        let p = VideoParams { d: 64, ..Default::default() };
        let v = generate("smooth", 2000, &p, 2);
        let mut sims = Vec::new();
        for i in (1..2000).step_by(97) {
            if !v.boundaries.contains(&i) {
                sims.push(cosine(v.feats.row(i - 1), v.feats.row(i)));
            }
        }
        let avg: f32 = sims.iter().sum::<f32>() / sims.len() as f32;
        assert!(avg > 0.98, "intra-shot frames must be near-duplicates: {avg}");
    }

    #[test]
    fn cross_shot_frames_differ() {
        let p = VideoParams { d: 64, ..Default::default() };
        let v = generate("cuts", 2000, &p, 3);
        assert!(v.boundaries.len() >= 3);
        let (b1, b2) = (v.boundaries[1], v.boundaries[2]);
        let sim = cosine(v.feats.row(b1 - 1), v.feats.row((b1 + b2) / 2));
        assert!(sim < 0.9, "different shots must differ: {sim}");
    }

    #[test]
    fn votes_concentrate_near_boundaries() {
        let p = VideoParams { d: 32, ..Default::default() };
        let v = generate("votes", 3000, &p, 4);
        let near: u32 = v
            .boundaries
            .iter()
            .flat_map(|&b| b.saturating_sub(20)..(b + 20).min(3000))
            .map(|f| v.gt_scores[f])
            .sum();
        let total: u32 = v.gt_scores.iter().sum();
        assert!(
            near as f64 > 0.3 * total as f64,
            "boundary windows should attract votes: {near}/{total}"
        );
    }

    #[test]
    fn frame_f1_hand_example() {
        let (f1, recall) = frame_f1(&[1, 2, 3, 4], &[3, 4, 5, 6]);
        assert!((recall - 0.5).abs() < 1e-12);
        assert!((f1 - 0.5).abs() < 1e-12);
        assert_eq!(frame_f1(&[], &[1]), (0.0, 0.0));
    }

    #[test]
    fn reference_by_score_picks_top_voted() {
        let p = VideoParams { d: 32, ..Default::default() };
        let v = generate("ref", 1000, &p, 5);
        let r = reference_by_score(&v, 0.1);
        assert_eq!(r.len(), 100);
        let min_in: u32 = r.iter().map(|&f| v.gt_scores[f]).min().unwrap();
        let max_out: u32 =
            (0..1000).filter(|f| !r.contains(f)).map(|f| v.gt_scores[f]).max().unwrap();
        assert!(min_in >= max_out.saturating_sub(0).min(min_in), "top frames selected");
        assert!(min_in + 1 >= max_out, "selection ~ threshold on votes: {min_in} vs {max_out}");
    }

    #[test]
    fn suite_matches_table2() {
        let suite = summe_suite(&VideoParams::default(), 0);
        assert_eq!(suite.len(), 25);
        assert_eq!(suite[0], ("Air Force One".to_string(), 4494));
        assert_eq!(suite[12], ("Jumps".to_string(), 950));
    }
}
