//! Dataset substrates (DESIGN.md §3): synthetic stand-ins for the paper's
//! licensed/gated corpora, plus the text pipeline and the ROUGE scorer.
//!
//! * [`text`]   — vocabulary, tokenizer, TF-IDF, feature hashing;
//! * [`corpus`] — NYT-like daily news + DUC-like topic sets;
//! * [`video`]  — SumMe-like frame streams with 15 simulated annotators;
//! * [`rouge`]  — ROUGE-2 recall/precision/F1 from scratch.

pub mod corpus;
pub mod datasets;
pub mod rouge;
pub mod text;
pub mod video;

pub use corpus::{CorpusParams, NewsDay, NewsGenerator};
pub use datasets::DatasetCache;
pub use rouge::{rouge_2, rouge_n, truncate_to_words, RougeScore};
pub use video::{frame_f1, generate as generate_video, reference_by_score, Video, VideoParams};
