//! Synthetic news corpora: substitutes for the licensed NYT annotated
//! corpus (LDC2008T19) and the gated DUC 2001 sets (DESIGN.md §3 records
//! the substitution rationale).
//!
//! Generative model (topic mixture): each day/topic-set draws latent topics
//! over a shared Zipf vocabulary. A sentence picks a topic, then mixes
//! topic-specific words (coherence) with Zipf background words. Reference
//! "human" summaries are *freshly sampled* sentences from the same topics —
//! disjoint strings, overlapping n-grams, exactly the property ROUGE
//! measures. Near-duplicate sentences within a topic give the submodular
//! objective the redundancy structure the paper's experiments rely on.

use crate::util::rng::Rng;
use crate::util::vecmath::FeatureMatrix;

use super::text::{Sentence, TfIdf, Vocabulary};

/// One day of news (NYT-like) or one topic set (DUC-like).
pub struct NewsDay {
    /// the ground set: sentences to summarize
    pub sentences: Vec<Sentence>,
    /// reference summary (tokenized)
    pub reference: Vec<Sentence>,
    /// hashed TF-IDF features aligned with `sentences`
    pub feats: FeatureMatrix,
    /// budget = number of reference sentences (the paper's Figure-1 setup)
    pub k: usize,
    /// generation metadata for reports
    pub n_topics: usize,
}

#[derive(Clone, Debug)]
pub struct CorpusParams {
    pub vocab_size: usize,
    pub zipf_s: f64,
    /// hashed feature dims (matches artifact D by default)
    pub d: usize,
    /// words drawn per topic pool
    pub topic_pool: usize,
    /// probability a token comes from the topic pool (coherence)
    pub coherence: f64,
    pub sent_len: (usize, usize),
    pub ref_sents_per_topic: (usize, usize),
}

impl Default for CorpusParams {
    fn default() -> Self {
        Self {
            vocab_size: 5000,
            zipf_s: 1.07,
            d: 256,
            topic_pool: 60,
            coherence: 0.55,
            sent_len: (8, 30),
            ref_sents_per_topic: (1, 4),
        }
    }
}

impl CorpusParams {
    /// DUC-like: fewer, tighter topics (single-topic document sets).
    pub fn duc_like() -> Self {
        Self { coherence: 0.7, topic_pool: 90, ..Default::default() }
    }
}

/// A latent story: a word pool plus its stock collocations.
struct Topic {
    words: Vec<u32>,
    phrases: Vec<Vec<u32>>,
}

pub struct NewsGenerator {
    vocab: Vocabulary,
    params: CorpusParams,
}

impl NewsGenerator {
    pub fn new(params: CorpusParams, seed: u64) -> Self {
        Self { vocab: Vocabulary::new(params.vocab_size, params.zipf_s, seed), params }
    }

    /// The shared vocabulary (token id → word string) — lets consumers
    /// render generated sentences as readable text.
    pub fn vocab(&self) -> &Vocabulary {
        &self.vocab
    }

    fn topic_pools(&self, rng: &mut Rng, n_topics: usize) -> Vec<Topic> {
        (0..n_topics)
            .map(|_| {
                // topic words skew toward the informative tail of the vocab
                let words: Vec<u32> = (0..self.params.topic_pool)
                    .map(|_| {
                        let lo = self.params.vocab_size / 10;
                        rng.range(lo, self.params.vocab_size) as u32
                    })
                    .collect();
                // collocations: named entities / stock phrases of the story.
                // These are what gives sentences *bigram* overlap with the
                // (freshly sampled) reference — ROUGE-2's unit of credit.
                let phrases: Vec<Vec<u32>> = (0..self.params.topic_pool / 4)
                    .map(|_| {
                        let len = 2 + rng.below(2);
                        (0..len).map(|_| words[rng.below(words.len())]).collect()
                    })
                    .collect();
                Topic { words, phrases }
            })
            .collect()
    }

    fn sentence(&self, rng: &mut Rng, topic: &Topic) -> Sentence {
        let (lo, hi) = self.params.sent_len;
        let len = rng.range(lo, hi + 1);
        let mut out = Vec::with_capacity(len + 2);
        while out.len() < len {
            if rng.bool(self.params.coherence) {
                if rng.bool(0.55) {
                    // emit a whole collocation (consecutive tokens)
                    out.extend_from_slice(&topic.phrases[rng.below(topic.phrases.len())]);
                } else {
                    out.push(topic.words[rng.below(topic.words.len())]);
                }
            } else {
                out.push(self.vocab.sample(rng));
            }
        }
        out
    }

    /// Generate one day with ~`n` ground-set sentences and `n_topics` latent
    /// topics (0 = auto: 3–8 like real news days).
    pub fn day(&self, n: usize, n_topics: usize, seed: u64) -> NewsDay {
        let mut rng = Rng::new(seed ^ 0xDA1);
        // Story count scales with day size (the NYT reference summary for a
        // date concatenates every article's human summary, so big days have
        // proportionally bigger budgets k). 0 = auto.
        let n_topics = if n_topics == 0 {
            (rng.range(3, 9) + n / 600).min(40)
        } else {
            n_topics
        };
        let pools = self.topic_pools(&mut rng, n_topics);
        // mixture weights: a couple of dominant stories per day
        let mut weights: Vec<f64> = (0..n_topics).map(|_| rng.f64() + 0.2).collect();
        weights[0] += 1.0;
        let total_w: f64 = weights.iter().sum();

        let mut sentences = Vec::with_capacity(n);
        for _ in 0..n {
            let mut u = rng.f64() * total_w;
            let mut z = 0;
            for (t, &w) in weights.iter().enumerate() {
                if u < w {
                    z = t;
                    break;
                }
                u -= w;
            }
            sentences.push(self.sentence(&mut rng, &pools[z]));
        }

        // reference: fresh sentences per topic, more for dominant topics
        let mut reference = Vec::new();
        let (rlo, rhi) = self.params.ref_sents_per_topic;
        for pool in &pools {
            let m = rng.range(rlo, rhi + 1);
            for _ in 0..m {
                reference.push(self.sentence(&mut rng, pool));
            }
        }
        let k = reference.len();

        let tfidf = TfIdf::fit(&sentences);
        let feats = tfidf.features(&sentences, self.params.d);
        NewsDay { sentences, reference, feats, k, n_topics }
    }

    /// A stream of days with realistic size variation `n ∈ [n_lo, n_hi]`
    /// (the paper's NYT slice spans 2000–20000 sentences/day).
    pub fn days(&self, count: usize, n_lo: usize, n_hi: usize, seed: u64) -> Vec<NewsDay> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|i| {
                // log-uniform day sizes: many small days, few huge ones
                let t = rng.f64();
                let n = ((n_lo as f64).ln() + t * ((n_hi as f64).ln() - (n_lo as f64).ln())).exp()
                    as usize;
                self.day(n.max(n_lo), 0, seed.wrapping_add(i as u64 * 7919))
            })
            .collect()
    }

    /// DUC-like topic set: single dominant topic, four reference summaries
    /// worth of material (400 words; callers truncate to 200/100/50).
    pub fn duc_topic(&self, n: usize, seed: u64) -> NewsDay {
        let mut day = self.day(n, 1, seed);
        // DUC references are longer; regenerate until ~400 words available
        let mut rng = Rng::new(seed ^ 0xD0C);
        let pools = self.topic_pools(&mut rng, 1);
        while day.reference.iter().map(|s| s.len()).sum::<usize>() < 420 {
            day.reference.push(self.sentence(&mut rng, &pools[0]));
        }
        day.k = day.reference.len();
        day
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{greedy, lazy_greedy, sparsify, CpuBackend, SsParams};
    use crate::data::rouge::rouge_2;
    use crate::submodular::FeatureBased;

    fn generator(seed: u64) -> NewsGenerator {
        NewsGenerator::new(
            CorpusParams { vocab_size: 800, d: 64, ..Default::default() },
            seed,
        )
    }

    #[test]
    fn day_shapes_consistent() {
        let g = generator(1);
        let day = g.day(200, 0, 7);
        assert_eq!(day.sentences.len(), 200);
        assert_eq!(day.feats.n(), 200);
        assert_eq!(day.k, day.reference.len());
        assert!(day.k >= day.n_topics, "≥1 ref sentence per topic");
        assert!((3..=8).contains(&day.n_topics));
    }

    #[test]
    fn deterministic_given_seed() {
        let g = generator(2);
        let a = g.day(100, 0, 3);
        let b = g.day(100, 0, 3);
        assert_eq!(a.sentences, b.sentences);
        assert_eq!(a.reference, b.reference);
        assert_eq!(a.feats, b.feats);
    }

    #[test]
    fn reference_overlaps_ground_set_in_bigrams() {
        // the generative contract: selecting good sentences must be able to
        // achieve non-trivial ROUGE-2 against the fresh reference
        let g = generator(3);
        let day = g.day(300, 4, 11);
        let r_all = rouge_2(&day.sentences, &day.reference);
        assert!(
            r_all.recall > 0.3,
            "ground set must cover reference bigrams: {}",
            r_all.recall
        );
        // but individual random sentences shouldn't trivially saturate it
        let r_one = rouge_2(&day.sentences[..1], &day.reference);
        assert!(r_one.recall < 0.2);
    }

    #[test]
    fn greedy_beats_random_on_rouge() {
        // end-to-end sanity of the whole substrate: submodular selection on
        // TF-IDF features must beat a random summary on ROUGE-2
        let g = generator(4);
        let day = g.day(250, 4, 13);
        let f = FeatureBased::sqrt(day.feats.clone());
        let all: Vec<usize> = (0..250).collect();
        let sel = greedy(&f, &all, day.k);
        let chosen: Vec<_> = sel.set.iter().map(|&i| day.sentences[i].clone()).collect();
        let r_greedy = rouge_2(&chosen, &day.reference);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut r_rand_sum = 0.0;
        for _ in 0..5 {
            let idx = rng.sample_indices(250, day.k);
            let pick: Vec<_> = idx.iter().map(|&i| day.sentences[i].clone()).collect();
            r_rand_sum += rouge_2(&pick, &day.reference).recall;
        }
        let r_rand = r_rand_sum / 5.0;
        assert!(
            r_greedy.recall > r_rand,
            "greedy ROUGE {g} must beat random {r_rand}",
            g = r_greedy.recall
        );
    }

    #[test]
    fn ss_preserves_rouge_quality() {
        // the paper's headline effect, miniature edition
        let g = generator(5);
        let day = g.day(400, 4, 17);
        let f = FeatureBased::sqrt(day.feats.clone());
        let all: Vec<usize> = (0..400).collect();
        let full = lazy_greedy(&f, &all, day.k);
        let backend = CpuBackend::new(&f);
        let ss = sparsify(&backend, &SsParams::default().with_seed(1));
        let reduced = lazy_greedy(&f, &ss.kept, day.k);
        let rel = reduced.value / full.value;
        assert!(rel > 0.9, "relative utility after SS: {rel}");
    }

    #[test]
    fn duc_topic_reference_word_budget() {
        let g = generator(6);
        let t = g.duc_topic(150, 23);
        let words: usize = t.reference.iter().map(|s| s.len()).sum();
        assert!(words >= 400, "DUC reference must support 400-word truncation: {words}");
    }

    #[test]
    fn day_stream_size_variation() {
        let g = generator(7);
        let days = g.days(10, 100, 1000, 29);
        assert_eq!(days.len(), 10);
        let sizes: Vec<usize> = days.iter().map(|d| d.sentences.len()).collect();
        assert!(sizes.iter().all(|&n| (100..=1000).contains(&n)));
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        assert!(max > min, "sizes should vary: {sizes:?}");
    }
}
