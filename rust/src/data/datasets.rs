//! Dataset registry + binary disk cache.
//!
//! Benches and the service reuse generated datasets across runs; this
//! module gives them a content-addressed cache under `target/datasets/`
//! with a small versioned binary format (no serde offline — the format is
//! hand-rolled and round-trip tested).
//!
//! Format (little-endian):
//!   magic "SSDS" | u32 version | u32 section count |
//!   per section: u32 tag | u64 byte len | payload

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::vecmath::FeatureMatrix;

use super::corpus::NewsDay;
use super::text::Sentence;

const MAGIC: &[u8; 4] = b"SSDS";
const VERSION: u32 = 1;

mod tag {
    pub const FEATS: u32 = 1;
    pub const SENTENCES: u32 = 2;
    pub const REFERENCE: u32 = 3;
    pub const META: u32 = 4;
}

fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn get_u32(b: &[u8], pos: &mut usize) -> Result<u32> {
    let v = b
        .get(*pos..*pos + 4)
        .ok_or_else(|| anyhow!("truncated dataset file"))?
        .try_into()
        .unwrap();
    *pos += 4;
    Ok(u32::from_le_bytes(v))
}

fn get_u64(b: &[u8], pos: &mut usize) -> Result<u64> {
    let v = b
        .get(*pos..*pos + 8)
        .ok_or_else(|| anyhow!("truncated dataset file"))?
        .try_into()
        .unwrap();
    *pos += 8;
    Ok(u64::from_le_bytes(v))
}

fn encode_feats(m: &FeatureMatrix) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + m.data().len() * 4);
    put_u32(&mut out, m.n() as u32);
    put_u32(&mut out, m.d as u32);
    for &x in m.data() {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn decode_feats(b: &[u8]) -> Result<FeatureMatrix> {
    let mut pos = 0usize;
    let n = get_u32(b, &mut pos)? as usize;
    let d = get_u32(b, &mut pos)? as usize;
    if b.len() != 8 + n * d * 4 {
        bail!("feature payload size mismatch");
    }
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            let raw: [u8; 4] = b[pos..pos + 4].try_into().unwrap();
            m.row_mut(i)[j] = f32::from_le_bytes(raw);
            pos += 4;
        }
    }
    Ok(m)
}

fn encode_sentences(ss: &[Sentence]) -> Vec<u8> {
    let mut out = Vec::new();
    put_u32(&mut out, ss.len() as u32);
    for s in ss {
        put_u32(&mut out, s.len() as u32);
        for &w in s {
            put_u32(&mut out, w);
        }
    }
    out
}

fn decode_sentences(b: &[u8]) -> Result<Vec<Sentence>> {
    let mut pos = 0usize;
    let count = get_u32(b, &mut pos)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let len = get_u32(b, &mut pos)? as usize;
        let mut s = Vec::with_capacity(len);
        for _ in 0..len {
            s.push(get_u32(b, &mut pos)?);
        }
        out.push(s);
    }
    Ok(out)
}

/// Serialize a [`NewsDay`] to bytes.
pub fn encode_day(day: &NewsDay) -> Vec<u8> {
    let sections: Vec<(u32, Vec<u8>)> = vec![
        (tag::FEATS, encode_feats(&day.feats)),
        (tag::SENTENCES, encode_sentences(&day.sentences)),
        (tag::REFERENCE, encode_sentences(&day.reference)),
        (tag::META, {
            let mut m = Vec::new();
            put_u32(&mut m, day.k as u32);
            put_u32(&mut m, day.n_topics as u32);
            m
        }),
    ];
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, sections.len() as u32);
    for (t, payload) in sections {
        put_u32(&mut out, t);
        put_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&payload);
    }
    out
}

/// Deserialize a [`NewsDay`].
pub fn decode_day(bytes: &[u8]) -> Result<NewsDay> {
    if bytes.len() < 12 || &bytes[..4] != MAGIC {
        bail!("not a dataset file (bad magic)");
    }
    let mut pos = 4usize;
    let version = get_u32(bytes, &mut pos)?;
    if version != VERSION {
        bail!("unsupported dataset version {version}");
    }
    let sections = get_u32(bytes, &mut pos)? as usize;
    let mut feats = None;
    let mut sentences = None;
    let mut reference = None;
    let mut k = 0usize;
    let mut n_topics = 0usize;
    for _ in 0..sections {
        let t = get_u32(bytes, &mut pos)?;
        let len = get_u64(bytes, &mut pos)? as usize;
        let payload = bytes
            .get(pos..pos + len)
            .ok_or_else(|| anyhow!("truncated section {t}"))?;
        pos += len;
        match t {
            tag::FEATS => feats = Some(decode_feats(payload)?),
            tag::SENTENCES => sentences = Some(decode_sentences(payload)?),
            tag::REFERENCE => reference = Some(decode_sentences(payload)?),
            tag::META => {
                let mut p = 0usize;
                k = get_u32(payload, &mut p)? as usize;
                n_topics = get_u32(payload, &mut p)? as usize;
            }
            _ => {} // forward-compatible: unknown sections skipped
        }
    }
    Ok(NewsDay {
        feats: feats.ok_or_else(|| anyhow!("missing features section"))?,
        sentences: sentences.ok_or_else(|| anyhow!("missing sentences section"))?,
        reference: reference.ok_or_else(|| anyhow!("missing reference section"))?,
        k,
        n_topics,
    })
}

/// Content-addressed cache under `target/datasets/`.
pub struct DatasetCache {
    dir: PathBuf,
}

impl DatasetCache {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        std::fs::create_dir_all(dir.as_ref()).context("creating dataset cache dir")?;
        Ok(Self { dir: dir.as_ref().to_path_buf() })
    }

    pub fn default_location() -> Result<Self> {
        Self::new("target/datasets")
    }

    fn path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.ssds"))
    }

    pub fn contains(&self, key: &str) -> bool {
        self.path(key).exists()
    }

    pub fn store_day(&self, key: &str, day: &NewsDay) -> Result<()> {
        let bytes = encode_day(day);
        let tmp = self.path(key).with_extension("tmp");
        std::fs::File::create(&tmp)?.write_all(&bytes)?;
        std::fs::rename(&tmp, self.path(key))?; // atomic publish
        Ok(())
    }

    pub fn load_day(&self, key: &str) -> Result<NewsDay> {
        let mut bytes = Vec::new();
        std::fs::File::open(self.path(key))
            .with_context(|| format!("dataset '{key}' not cached"))?
            .read_to_end(&mut bytes)?;
        decode_day(&bytes)
    }

    /// Load-or-generate: the bench entry point.
    pub fn day_cached(
        &self,
        key: &str,
        generate: impl FnOnce() -> NewsDay,
    ) -> Result<NewsDay> {
        if self.contains(key) {
            return self.load_day(key);
        }
        let day = generate();
        self.store_day(key, &day)?;
        Ok(day)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CorpusParams, NewsGenerator};

    fn sample_day() -> NewsDay {
        NewsGenerator::new(CorpusParams { vocab_size: 400, d: 32, ..Default::default() }, 1)
            .day(80, 0, 2)
    }

    #[test]
    fn roundtrip_day() {
        let day = sample_day();
        let decoded = decode_day(&encode_day(&day)).unwrap();
        assert_eq!(decoded.feats, day.feats);
        assert_eq!(decoded.sentences, day.sentences);
        assert_eq!(decoded.reference, day.reference);
        assert_eq!(decoded.k, day.k);
        assert_eq!(decoded.n_topics, day.n_topics);
    }

    #[test]
    fn rejects_corrupt() {
        let day = sample_day();
        let mut bytes = encode_day(&day);
        bytes[0] = b'X';
        assert!(decode_day(&bytes).is_err());
        let truncated = &encode_day(&day)[..40];
        assert!(decode_day(truncated).is_err());
    }

    #[test]
    fn cache_store_load_and_generate_once() {
        let dir = std::env::temp_dir().join(format!("ssds-test-{}", std::process::id()));
        let cache = DatasetCache::new(&dir).unwrap();
        let mut generated = 0;
        for _ in 0..3 {
            let day = cache
                .day_cached("day-80-seed2", || {
                    generated += 1;
                    sample_day()
                })
                .unwrap();
            assert_eq!(day.feats.n(), 80);
        }
        assert_eq!(generated, 1, "generator must run exactly once");
        std::fs::remove_dir_all(&dir).ok();
    }
}
