//! ROUGE-N evaluation (Lin, 2004) implemented from scratch — the paper
//! reports ROUGE-2 recall and ROUGE-2 F1 for all news experiments.
//!
//! Definitions (multi-reference, per Lin §3: scores computed against the
//! concatenated reference, counts clipped):
//!   recall    = Σ_gram min(count_cand, count_ref) / Σ_gram count_ref
//!   precision = Σ_gram min(count_cand, count_ref) / Σ_gram count_cand
//!   F1        = 2PR / (P + R)

use std::collections::HashMap;

use super::text::Sentence;

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RougeScore {
    pub recall: f64,
    pub precision: f64,
    pub f1: f64,
}

fn ngram_counts(sents: &[Sentence], n: usize) -> HashMap<Vec<u32>, u32> {
    let mut counts = HashMap::new();
    for s in sents {
        if s.len() < n {
            continue;
        }
        for w in s.windows(n) {
            *counts.entry(w.to_vec()).or_insert(0) += 1;
        }
    }
    counts
}

/// ROUGE-N of a candidate summary against a reference summary; both are
/// sentence collections over token ids.
pub fn rouge_n(candidate: &[Sentence], reference: &[Sentence], n: usize) -> RougeScore {
    let cand = ngram_counts(candidate, n);
    let refs = ngram_counts(reference, n);
    let total_ref: u64 = refs.values().map(|&c| c as u64).sum();
    let total_cand: u64 = cand.values().map(|&c| c as u64).sum();
    let mut overlap: u64 = 0;
    for (gram, &rc) in &refs {
        if let Some(&cc) = cand.get(gram) {
            overlap += rc.min(cc) as u64;
        }
    }
    let recall = if total_ref == 0 { 0.0 } else { overlap as f64 / total_ref as f64 };
    let precision = if total_cand == 0 { 0.0 } else { overlap as f64 / total_cand as f64 };
    let f1 = if recall + precision == 0.0 {
        0.0
    } else {
        2.0 * precision * recall / (precision + recall)
    };
    RougeScore { recall, precision, f1 }
}

/// ROUGE-2, the paper's metric.
pub fn rouge_2(candidate: &[Sentence], reference: &[Sentence]) -> RougeScore {
    rouge_n(candidate, reference, 2)
}

/// Truncate a summary to a word budget (DUC-style 50/100/200/400-word
/// comparisons), cutting mid-sentence like the NIST evaluation does.
pub fn truncate_to_words(summary: &[Sentence], words: usize) -> Vec<Sentence> {
    let mut out = Vec::new();
    let mut used = 0usize;
    for s in summary {
        if used >= words {
            break;
        }
        let take = (words - used).min(s.len());
        out.push(s[..take].to_vec());
        used += take;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(xs: &[u32]) -> Sentence {
        xs.to_vec()
    }

    #[test]
    fn identical_summaries_score_one() {
        let summary = vec![s(&[1, 2, 3, 4]), s(&[5, 6, 7])];
        let r = rouge_2(&summary, &summary);
        assert!((r.recall - 1.0).abs() < 1e-12);
        assert!((r.precision - 1.0).abs() < 1e-12);
        assert!((r.f1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_summaries_score_zero() {
        let a = vec![s(&[1, 2, 3])];
        let b = vec![s(&[4, 5, 6])];
        assert_eq!(rouge_2(&a, &b), RougeScore { recall: 0.0, precision: 0.0, f1: 0.0 });
    }

    #[test]
    fn hand_computed_example() {
        // ref bigrams: (1,2),(2,3),(3,4) ; cand bigrams: (1,2),(2,3),(9,9)
        let reference = vec![s(&[1, 2, 3, 4])];
        let candidate = vec![s(&[1, 2, 3]), s(&[9, 9])];
        let r = rouge_2(&candidate, &reference);
        assert!((r.recall - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.precision - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.f1 - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn clipping_prevents_gaming_by_repetition() {
        // repeating the overlapping bigram must not inflate recall
        let reference = vec![s(&[1, 2, 9, 8, 7])];
        let spam = vec![s(&[1, 2]), s(&[1, 2]), s(&[1, 2]), s(&[1, 2])];
        let honest = vec![s(&[1, 2])];
        let r_spam = rouge_2(&spam, &reference);
        let r_honest = rouge_2(&honest, &reference);
        assert_eq!(r_spam.recall, r_honest.recall, "clipped recall");
        assert!(r_spam.precision < r_honest.precision, "spam hurts precision");
    }

    #[test]
    fn unigram_rouge1() {
        let reference = vec![s(&[1, 2, 3])];
        let candidate = vec![s(&[1, 4, 5])];
        let r = rouge_n(&candidate, &reference, 1);
        assert!((r.recall - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn truncation_budget() {
        let summary = vec![s(&[1, 2, 3]), s(&[4, 5, 6]), s(&[7, 8])];
        let t = truncate_to_words(&summary, 5);
        let total: usize = t.iter().map(|x| x.len()).sum();
        assert_eq!(total, 5);
        assert_eq!(t[1], vec![4, 5]);
        assert_eq!(truncate_to_words(&summary, 100).len(), 3);
    }

    #[test]
    fn short_sentences_skipped_for_bigrams() {
        let reference = vec![s(&[1])]; // no bigrams
        let candidate = vec![s(&[1, 2])];
        let r = rouge_2(&candidate, &reference);
        assert_eq!(r.recall, 0.0);
    }
}
