//! Ground-set remap layer — the streaming subsystem's spine.
//!
//! A [`StreamSession`](super::StreamSession) hands every arriving element a
//! **stable external id** (sequential, never reused) while the objective,
//! the feature/similarity storage and the SS round loop all work in a
//! **dense internal index space** `0..live` that is compacted on every
//! windowed re-sparsification. [`IdRemap`] is the bijection between the
//! two: external ids survive any number of evictions unchanged, internal
//! indices are always dense so kernels keep their contiguous row layout
//! and evicted elements' storage is actually dropped (not tombstoned).
//!
//! Memory note: stable-forever external ids cost one `u32` per arrival
//! (admitted or not) in `ext_to_int`, which only ever grows — ~4 MB per
//! million appends. That residue is deliberate (O(1) lookup, ids never
//! dangle) and negligible next to feature storage for day/week-scale
//! sessions, but it is *not* bounded by the retained core; sessions meant
//! to run for months should be rotated, or the dead prefix compacted
//! behind an id offset (tracked in ROADMAP).

/// Sentinel marking an external id whose element is no longer resident
/// (evicted by a re-sparsification, or never admitted by the filter).
const GONE: u32 = u32::MAX;

/// Stable external ids ↔ dense internal indices.
#[derive(Default)]
pub struct IdRemap {
    /// indexed by external id; `GONE` = evicted / never admitted
    ext_to_int: Vec<u32>,
    /// indexed by dense internal index
    int_to_ext: Vec<usize>,
}

impl IdRemap {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve for `additional` further external ids (all potentially
    /// admitted), so steady-state assignment never touches the allocator.
    pub fn reserve(&mut self, additional: usize) {
        self.ext_to_int.reserve(additional);
        self.int_to_ext.reserve(additional);
    }

    /// Assign the next external id and bind it to the next dense internal
    /// slot (the caller pushes the element's storage at the same position).
    pub fn admit(&mut self) -> (usize, usize) {
        let ext = self.ext_to_int.len();
        let int = self.int_to_ext.len();
        assert!(int < GONE as usize, "internal index space exhausted");
        self.ext_to_int.push(int as u32);
        self.int_to_ext.push(ext);
        (ext, int)
    }

    /// Assign the next external id without binding storage (the admission
    /// filter rejected the element; it was never resident).
    pub fn reject(&mut self) -> usize {
        let ext = self.ext_to_int.len();
        self.ext_to_int.push(GONE);
        ext
    }

    /// Compact the internal space to `keep` (ascending, distinct internal
    /// indices — the `kept` set of a re-sparsification): survivor
    /// `keep[i]` becomes internal index `i`, every other live element is
    /// marked evicted. External ids never change.
    pub fn compact(&mut self, keep: &[usize]) {
        let mut kp = 0usize;
        for old in 0..self.int_to_ext.len() {
            let ext = self.int_to_ext[old];
            if kp < keep.len() && keep[kp] == old {
                self.ext_to_int[ext] = kp as u32;
                self.int_to_ext[kp] = ext;
                kp += 1;
            } else {
                self.ext_to_int[ext] = GONE;
            }
        }
        assert_eq!(kp, keep.len(), "keep indices must be ascending, distinct and live");
        self.int_to_ext.truncate(keep.len());
    }

    /// Dense internal index of a live external id; `None` once evicted
    /// (or rejected), or for ids never assigned.
    pub fn internal(&self, ext: usize) -> Option<usize> {
        match self.ext_to_int.get(ext) {
            Some(&i) if i != GONE => Some(i as usize),
            _ => None,
        }
    }

    /// Stable external id of a live internal index.
    pub fn external(&self, int: usize) -> usize {
        self.int_to_ext[int]
    }

    /// Live (resident) element count.
    pub fn live(&self) -> usize {
        self.int_to_ext.len()
    }

    /// Total external ids ever assigned (admitted or not).
    pub fn assigned(&self) -> usize {
        self.ext_to_int.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_reject_compact_roundtrip() {
        let mut r = IdRemap::new();
        // ids 0..6: 0,1,2,4,5 admitted; 3 rejected
        for i in 0..6 {
            if i == 3 {
                assert_eq!(r.reject(), 3);
            } else {
                let (ext, int) = r.admit();
                assert_eq!(ext, i);
                assert_eq!(int, if i < 3 { i } else { i - 1 });
            }
        }
        assert_eq!(r.live(), 5);
        assert_eq!(r.assigned(), 6);
        assert_eq!(r.internal(3), None);
        assert_eq!(r.internal(4), Some(3));
        // evict internals 1 and 3 (ext 1 and ext 4)
        r.compact(&[0, 2, 4]);
        assert_eq!(r.live(), 3);
        assert_eq!(r.internal(0), Some(0));
        assert_eq!(r.internal(1), None);
        assert_eq!(r.internal(2), Some(1));
        assert_eq!(r.internal(4), None);
        assert_eq!(r.internal(5), Some(2));
        assert_eq!(r.external(0), 0);
        assert_eq!(r.external(1), 2);
        assert_eq!(r.external(2), 5);
        // keep appending after compaction: new internals bind past the tail
        let (ext, int) = r.admit();
        assert_eq!((ext, int), (6, 3));
        assert_eq!(r.external(3), 6);
        // second compaction keeps externals stable again
        r.compact(&[1, 3]);
        assert_eq!(r.internal(2), Some(0));
        assert_eq!(r.internal(6), Some(1));
        assert_eq!(r.internal(0), None);
        assert_eq!(r.internal(5), None);
    }

    #[test]
    fn identity_compact_is_noop() {
        let mut r = IdRemap::new();
        for _ in 0..4 {
            r.admit();
        }
        r.compact(&[0, 1, 2, 3]);
        assert_eq!(r.live(), 4);
        for i in 0..4 {
            assert_eq!(r.internal(i), Some(i));
            assert_eq!(r.external(i), i);
        }
    }

    #[test]
    fn out_of_range_lookup_is_none() {
        let r = IdRemap::new();
        assert_eq!(r.internal(0), None);
        assert_eq!(r.internal(99), None);
    }
}
