//! Ground-set remap layer — the streaming subsystem's spine.
//!
//! A [`StreamSession`](super::StreamSession) hands every arriving element a
//! **stable external id** (sequential, never reused) while the objective,
//! the feature/similarity storage and the SS round loop all work in a
//! **dense internal index space** `0..live` that is compacted on every
//! windowed re-sparsification. [`IdRemap`] is the bijection between the
//! two: external ids survive any number of evictions unchanged, internal
//! indices are always dense so kernels keep their contiguous row layout
//! and evicted elements' storage is actually dropped (not tombstoned).
//!
//! Memory: the forward map is *windowed*, not eternal. External ids below
//! the oldest live id form an all-dead prefix (everything there was
//! evicted or never admitted — ids are assigned in arrival order and
//! `int_to_ext` stays ascending, so liveness has a sharp left edge); each
//! [`compact`](IdRemap::compact) drops that prefix and remembers only its
//! length in [`base`](IdRemap::base). Lookups stay O(1): an id below
//! `base` is known-dead by construction, an id at or above it indexes
//! `ext_to_int[ext - base]`. The retained residue
//! ([`map_residue`](IdRemap::map_residue)) is bounded by the id *span* of
//! the live window — retained core + buffer + rejected arrivals since the
//! last window — instead of growing one `u32` per arrival forever (the
//! pre-compaction behavior: ~4 MB per million appends, unbounded for
//! months-long sessions; see ROADMAP history).

/// Sentinel marking an external id whose element is no longer resident
/// (evicted by a re-sparsification, or never admitted by the filter).
const GONE: u32 = u32::MAX;

/// Stable external ids ↔ dense internal indices.
#[derive(Default)]
pub struct IdRemap {
    /// external ids below this are all dead and their map entries have
    /// been compacted away; only ever grows
    base: usize,
    /// indexed by `ext - base`; `GONE` = evicted / never admitted
    ext_to_int: Vec<u32>,
    /// indexed by dense internal index; always ascending (ids are
    /// assigned in arrival order and compaction preserves order), which
    /// is what gives the dead prefix its sharp edge
    int_to_ext: Vec<usize>,
}

impl IdRemap {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve for `additional` further external ids (all potentially
    /// admitted), so steady-state assignment never touches the allocator.
    pub fn reserve(&mut self, additional: usize) {
        self.ext_to_int.reserve(additional);
        self.int_to_ext.reserve(additional);
    }

    /// Assign the next external id and bind it to the next dense internal
    /// slot (the caller pushes the element's storage at the same position).
    pub fn admit(&mut self) -> (usize, usize) {
        let ext = self.base + self.ext_to_int.len();
        let int = self.int_to_ext.len();
        assert!(int < GONE as usize, "internal index space exhausted");
        self.ext_to_int.push(int as u32);
        self.int_to_ext.push(ext);
        (ext, int)
    }

    /// Assign the next external id without binding storage (the admission
    /// filter rejected the element; it was never resident).
    pub fn reject(&mut self) -> usize {
        let ext = self.base + self.ext_to_int.len();
        self.ext_to_int.push(GONE);
        ext
    }

    /// Compact the internal space to `keep` (ascending, distinct internal
    /// indices — the `kept` set of a re-sparsification): survivor
    /// `keep[i]` becomes internal index `i`, every other live element is
    /// marked evicted, and the forward map's now-all-dead prefix (every
    /// id older than the oldest survivor) is dropped behind
    /// [`base`](Self::base). External ids never change meaning.
    pub fn compact(&mut self, keep: &[usize]) {
        let mut kp = 0usize;
        for old in 0..self.int_to_ext.len() {
            let ext = self.int_to_ext[old];
            if kp < keep.len() && keep[kp] == old {
                self.ext_to_int[ext - self.base] = kp as u32;
                self.int_to_ext[kp] = ext;
                kp += 1;
            } else {
                self.ext_to_int[ext - self.base] = GONE;
            }
        }
        assert_eq!(kp, keep.len(), "keep indices must be ascending, distinct and live");
        self.int_to_ext.truncate(keep.len());
        // drop the dead prefix: everything below the oldest live id (or
        // below the next id to assign, when nothing survived) is dead
        // forever. O(residue) memmove, amortized by the re-sparsification
        // that triggered the compaction; capacity is kept so the
        // steady-state append path stays allocation-free.
        let oldest_live =
            self.int_to_ext.first().copied().unwrap_or(self.base + self.ext_to_int.len());
        let cut = oldest_live - self.base;
        if cut > 0 {
            self.ext_to_int.drain(..cut);
            self.base = oldest_live;
        }
    }

    /// Dense internal index of a live external id; `None` once evicted
    /// (or rejected), or for ids never assigned.
    pub fn internal(&self, ext: usize) -> Option<usize> {
        if ext < self.base {
            return None; // compacted dead prefix
        }
        match self.ext_to_int.get(ext - self.base) {
            Some(&i) if i != GONE => Some(i as usize),
            _ => None,
        }
    }

    /// Stable external id of a live internal index.
    pub fn external(&self, int: usize) -> usize {
        self.int_to_ext[int]
    }

    /// Live (resident) element count.
    pub fn live(&self) -> usize {
        self.int_to_ext.len()
    }

    /// Total external ids ever assigned (admitted or not).
    pub fn assigned(&self) -> usize {
        self.base + self.ext_to_int.len()
    }

    /// Left edge of the forward map: external ids below this were
    /// compacted away as an all-dead prefix (and resolve to `None` in
    /// O(1) without storage).
    pub fn base(&self) -> usize {
        self.base
    }

    /// Forward-map entries currently resident — the memory the stable-id
    /// guarantee actually costs. Bounded by the id span of the live
    /// window (`assigned() - base()`), **not** by the stream length.
    pub fn map_residue(&self) -> usize {
        self.ext_to_int.len()
    }

    /// Borrow the complete durable state: `(base, ext_to_int, int_to_ext)`.
    /// `base` bounds what must be persisted — the compacted dead prefix
    /// needs no bytes at all, so a checkpoint costs O(map residue).
    /// `u32::MAX` entries in the forward map mean "dead" (evicted or
    /// never admitted).
    pub fn export_parts(&self) -> (usize, &[u32], &[usize]) {
        (self.base, &self.ext_to_int, &self.int_to_ext)
    }

    /// Rebuild from [`export_parts`](Self::export_parts) output,
    /// revalidating the structural invariants (ascending `int_to_ext`,
    /// forward/backward agreement) so corrupt checkpoint bytes surface
    /// as a typed error instead of a later panic or silent misroute.
    pub fn from_parts(
        base: usize,
        ext_to_int: Vec<u32>,
        int_to_ext: Vec<usize>,
    ) -> Result<Self, String> {
        if int_to_ext.windows(2).any(|w| w[0] >= w[1]) {
            return Err("id remap: int_to_ext not strictly ascending".into());
        }
        let mut live = 0usize;
        for (off, &e) in ext_to_int.iter().enumerate() {
            if e == GONE {
                continue;
            }
            match int_to_ext.get(e as usize) {
                Some(&ext) if ext == base + off => live += 1,
                _ => {
                    return Err(format!(
                        "id remap: forward entry {} -> {} disagrees with backward map",
                        base + off,
                        e
                    ))
                }
            }
        }
        if live != int_to_ext.len() {
            return Err(format!(
                "id remap: {} forward entries live but {} internal slots",
                live,
                int_to_ext.len()
            ));
        }
        Ok(Self {
            base,
            ext_to_int,
            int_to_ext,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_reject_compact_roundtrip() {
        let mut r = IdRemap::new();
        // ids 0..6: 0,1,2,4,5 admitted; 3 rejected
        for i in 0..6 {
            if i == 3 {
                assert_eq!(r.reject(), 3);
            } else {
                let (ext, int) = r.admit();
                assert_eq!(ext, i);
                assert_eq!(int, if i < 3 { i } else { i - 1 });
            }
        }
        assert_eq!(r.live(), 5);
        assert_eq!(r.assigned(), 6);
        assert_eq!(r.internal(3), None);
        assert_eq!(r.internal(4), Some(3));
        // evict internals 1 and 3 (ext 1 and ext 4)
        r.compact(&[0, 2, 4]);
        assert_eq!(r.live(), 3);
        assert_eq!(r.internal(0), Some(0));
        assert_eq!(r.internal(1), None);
        assert_eq!(r.internal(2), Some(1));
        assert_eq!(r.internal(4), None);
        assert_eq!(r.internal(5), Some(2));
        assert_eq!(r.external(0), 0);
        assert_eq!(r.external(1), 2);
        assert_eq!(r.external(2), 5);
        // ext 0 survived, so nothing was prefix-compacted yet
        assert_eq!(r.base(), 0);
        // keep appending after compaction: new internals bind past the tail
        let (ext, int) = r.admit();
        assert_eq!((ext, int), (6, 3));
        assert_eq!(r.external(3), 6);
        // second compaction keeps externals stable again, and drops the
        // dead prefix (ids 0 and 1 can never come back to life)
        r.compact(&[1, 3]);
        assert_eq!(r.internal(2), Some(0));
        assert_eq!(r.internal(6), Some(1));
        assert_eq!(r.internal(0), None);
        assert_eq!(r.internal(5), None);
        assert_eq!(r.base(), 2, "ids 0..2 are an all-dead prefix");
        assert_eq!(r.assigned(), 7);
        assert_eq!(r.map_residue(), 5, "only ids 2..7 keep entries");
    }

    #[test]
    fn identity_compact_is_noop() {
        let mut r = IdRemap::new();
        for _ in 0..4 {
            r.admit();
        }
        r.compact(&[0, 1, 2, 3]);
        assert_eq!(r.live(), 4);
        assert_eq!(r.base(), 0);
        for i in 0..4 {
            assert_eq!(r.internal(i), Some(i));
            assert_eq!(r.external(i), i);
        }
    }

    #[test]
    fn out_of_range_lookup_is_none() {
        let r = IdRemap::new();
        assert_eq!(r.internal(0), None);
        assert_eq!(r.internal(99), None);
    }

    #[test]
    fn dead_prefix_is_compacted_across_many_windows() {
        // A long-lived session shape: every window admits a batch, then a
        // re-sparsification keeps only the most recent few. The forward
        // map must keep its residue bounded by the live id span instead
        // of growing one entry per arrival — across well over 3
        // compactions, with lookups exact throughout.
        let mut r = IdRemap::new();
        let mut live_exts: Vec<usize> = Vec::new();
        let per_window = 100usize;
        for window in 0..8 {
            for i in 0..per_window {
                if i % 7 == 3 {
                    let ext = r.reject();
                    assert_eq!(ext, r.assigned() - 1);
                } else {
                    let (ext, _) = r.admit();
                    live_exts.push(ext);
                }
            }
            // keep the newest half of the live set (ascending internals)
            let keep: Vec<usize> = (r.live() / 2..r.live()).collect();
            live_exts = keep.iter().map(|&i| live_exts[i]).collect();
            r.compact(&keep);
            // full round-trip: internal ↔ external agree for survivors...
            assert_eq!(r.live(), live_exts.len());
            for (int, &ext) in live_exts.iter().enumerate() {
                assert_eq!(r.internal(ext), Some(int), "window {window}: ext {ext}");
                assert_eq!(r.external(int), ext);
            }
            // ...every other id ever assigned is dead, prefix or not
            let live_set: std::collections::HashSet<usize> = live_exts.iter().copied().collect();
            for ext in 0..r.assigned() {
                if !live_set.contains(&ext) {
                    assert_eq!(r.internal(ext), None, "window {window}: ext {ext} must be dead");
                }
            }
            // the dead prefix was dropped: residue is the live span only
            assert_eq!(r.base(), live_exts.first().copied().unwrap_or(r.assigned()));
            assert_eq!(r.map_residue(), r.assigned() - r.base());
            assert!(
                r.map_residue() <= 2 * per_window,
                "window {window}: residue {} outgrew the live span",
                r.map_residue()
            );
        }
        assert_eq!(r.assigned(), 8 * per_window);
        assert!(r.base() > 6 * per_window, "most of the id space must be behind base");
    }

    #[test]
    fn serialize_round_trip_across_window_compactions() {
        // Durability property (ISSUE 7 satellite): at *every* point of a
        // multi-window life — mid-batch, right after a compaction, after
        // trailing rejects — export_parts → from_parts reproduces a map
        // that answers identically through the live base()/map_residue()/
        // internal()/external() accessors. Deterministic LCG "randomness"
        // keeps the property reproducible.
        let mut state = 0x1234_5678_9abc_def0u64;
        let mut rng = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as usize
        };
        let mut r = IdRemap::new();
        let mut compactions = 0usize;
        let mut checkpoints = 0usize;
        let mut check = |r: &IdRemap| {
            let (base, fwd, bwd) = r.export_parts();
            let restored = IdRemap::from_parts(base, fwd.to_vec(), bwd.to_vec())
                .expect("live state must round-trip");
            assert_eq!(restored.base(), r.base());
            assert_eq!(restored.map_residue(), r.map_residue());
            assert_eq!(restored.live(), r.live());
            assert_eq!(restored.assigned(), r.assigned());
            for ext in 0..r.assigned() + 2 {
                assert_eq!(restored.internal(ext), r.internal(ext), "ext {ext}");
            }
            for int in 0..r.live() {
                assert_eq!(restored.external(int), r.external(int), "int {int}");
            }
        };
        for _window in 0..5 {
            for _ in 0..40 {
                if rng() % 4 == 0 {
                    r.reject();
                } else {
                    r.admit();
                }
                if rng() % 9 == 0 {
                    check(&r);
                    checkpoints += 1;
                }
            }
            // keep a random subset of the live internals (ascending)
            let keep: Vec<usize> = (0..r.live()).filter(|_| rng() % 3 != 0).collect();
            r.compact(&keep);
            compactions += 1;
            check(&r);
            checkpoints += 1;
        }
        assert!(compactions >= 3, "the property must span >= 3 compactions");
        assert!(checkpoints > compactions, "mid-window states must be covered too");
        assert!(r.base() > 0, "prefix compaction must actually have kicked in");
    }

    #[test]
    fn from_parts_rejects_inconsistent_state() {
        let mut r = IdRemap::new();
        for _ in 0..4 {
            r.admit();
        }
        r.reject();
        r.compact(&[1, 2, 3]);
        let (base, fwd, bwd) = r.export_parts();
        // descending backward map
        let mut bad = bwd.to_vec();
        bad.swap(0, 1);
        assert!(IdRemap::from_parts(base, fwd.to_vec(), bad).is_err());
        // forward entry pointing at the wrong internal slot
        let mut bad = fwd.to_vec();
        let live_off = bad.iter().position(|&e| e != GONE).unwrap();
        bad[live_off] = bad[live_off].wrapping_add(1);
        assert!(IdRemap::from_parts(base, bad, bwd.to_vec()).is_err());
        // more internal slots than live forward entries
        let mut bad = bwd.to_vec();
        bad.push(base + fwd.len() + 10);
        assert!(IdRemap::from_parts(base, fwd.to_vec(), bad).is_err());
    }

    #[test]
    fn compact_to_empty_drops_everything() {
        let mut r = IdRemap::new();
        for _ in 0..10 {
            r.admit();
        }
        r.compact(&[]);
        assert_eq!(r.live(), 0);
        assert_eq!(r.assigned(), 10);
        assert_eq!(r.base(), 10);
        assert_eq!(r.map_residue(), 0);
        for ext in 0..10 {
            assert_eq!(r.internal(ext), None);
        }
        // ids keep flowing from where they left off
        let (ext, int) = r.admit();
        assert_eq!((ext, int), (10, 0));
        assert_eq!(r.internal(10), Some(0));
        assert_eq!(r.external(0), 10);
    }
}
