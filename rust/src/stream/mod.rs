//! Streaming ingestion subsystem: append-only sessions feeding the arena
//! SS loop.
//!
//! The batch stack (sparsify → maximize, [`crate::algorithms::ss`]) assumes
//! a fully materialized ground set handed over at request time — the one
//! thing a production summarization service cannot assume. This module
//! turns the pipeline inside out for long-lived feeds (rolling news days,
//! video frames):
//!
//! * [`remap`] — the spine: stable external ids ↔ dense internal indices,
//!   so evicted elements' storage is genuinely compacted away while ids
//!   handed to callers stay valid forever (the map's all-dead prefix is
//!   itself compacted behind a base offset, so the id residue is bounded
//!   by the live window, not the stream length);
//! * the incremental [`SieveFilter`] (stage 1 of the retention policy) —
//!   the sieve-streaming threshold grid refactored into a reusable
//!   admission core; it lives in
//!   [`algorithms::sieve_filter`](crate::algorithms::sieve_filter) (it
//!   is a plain algorithm) and is re-exported here;
//! * [`session`] — [`StreamSession`]: append-only batches, windowed
//!   re-sparsification through the zero-allocation round arena (stage 2),
//!   snapshots through the batched maximizer engine — in place
//!   ([`StreamSession::snapshot_summary`]) or detached via the
//!   copy-on-snapshot [`SnapshotCore`], which is how the service runs
//!   Final summaries as pool jobs while appends continue.
//!
//! Sessions speak the crate-wide [`ObjectiveSpec`] (shared with batch
//! requests) and the service's typed
//! [`ServiceError`](crate::coordinator::ServiceError) — the front-end
//! ([`crate::coordinator::service`]) exposes them as `open_stream` /
//! `append` / `submit_snapshot` / `close` with per-session backpressure.
//!
//! Sessions can also be made **durable**: [`wal`] provides a hand-rolled
//! length-prefixed, checksummed write-ahead log plus periodic checkpoints
//! over a pluggable [`DurableStore`] (in-memory, on-disk, or the
//! deterministic fault-injecting [`FaultStore`] used by the crash-exactness
//! tests). A durable session logs every admitted batch *before* mutating
//! itself and every eviction decision *after* the SS pass picks survivors;
//! [`StreamSession::recover`] replays checkpoint + WAL tail into a session
//! bit-identical to the uninterrupted one. Torn tails are truncated,
//! checksum-corrupt records quarantine the session with a typed error —
//! recovery never panics on a damaged store.

pub mod remap;
pub mod session;
pub mod wal;

pub(crate) mod checkpoint;

pub use crate::algorithms::sieve_filter::{SieveFilter, SieveParams, SieveSet};
pub use crate::submodular::ObjectiveSpec;
pub use remap::IdRemap;
pub use session::{
    CheckpointInfo, RecoveryReport, SnapshotCore, SnapshotMode, StreamAppend, StreamConfig,
    StreamSession, StreamStats, StreamSummary,
};
pub use wal::{
    DurabilityConfig, DurableStore, FaultStore, FileStore, FlushPolicy, MemStore, WalError,
};

/// Former name of the unified [`ObjectiveSpec`] — kept one release so
/// existing call sites migrate mechanically (`StreamObjective::Features`
/// patterns resolve through the alias unchanged).
#[deprecated(since = "0.2.0", note = "renamed to `ObjectiveSpec`, shared with batch requests")]
pub type StreamObjective = ObjectiveSpec;
