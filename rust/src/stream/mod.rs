//! Streaming ingestion subsystem: append-only sessions feeding the arena
//! SS loop.
//!
//! The batch stack (sparsify → maximize, [`crate::algorithms::ss`]) assumes
//! a fully materialized ground set handed over at request time — the one
//! thing a production summarization service cannot assume. This module
//! turns the pipeline inside out for long-lived feeds (rolling news days,
//! video frames):
//!
//! * [`remap`] — the spine: stable external ids ↔ dense internal indices,
//!   so evicted elements' storage is genuinely compacted away while ids
//!   handed to callers stay valid forever (the map's all-dead prefix is
//!   itself compacted behind a base offset, so the id residue is bounded
//!   by the live window, not the stream length);
//! * the incremental [`SieveFilter`] (stage 1 of the retention policy) —
//!   the sieve-streaming threshold grid refactored into a reusable
//!   admission core; it lives in
//!   [`algorithms::sieve_filter`](crate::algorithms::sieve_filter) (it
//!   is a plain algorithm) and is re-exported here;
//! * [`session`] — [`StreamSession`]: append-only batches, windowed
//!   re-sparsification through the zero-allocation round arena (stage 2),
//!   snapshots through the batched maximizer engine — in place
//!   ([`StreamSession::snapshot_summary`]) or detached via the
//!   copy-on-snapshot [`SnapshotCore`], which is how the service runs
//!   Final summaries as pool jobs while appends continue.
//!
//! Sessions speak the crate-wide [`ObjectiveSpec`] (shared with batch
//! requests) and the service's typed
//! [`ServiceError`](crate::coordinator::ServiceError) — the front-end
//! ([`crate::coordinator::service`]) exposes them as `open_stream` /
//! `append` / `submit_snapshot` / `close` with per-session backpressure.

pub mod remap;
pub mod session;

pub use crate::algorithms::sieve_filter::{SieveFilter, SieveParams, SieveSet};
pub use crate::submodular::ObjectiveSpec;
pub use remap::IdRemap;
pub use session::{
    SnapshotCore, SnapshotMode, StreamAppend, StreamConfig, StreamSession, StreamStats,
    StreamSummary,
};

/// Former name of the unified [`ObjectiveSpec`] — kept one release so
/// existing call sites migrate mechanically (`StreamObjective::Features`
/// patterns resolve through the alias unchanged).
#[deprecated(since = "0.2.0", note = "renamed to `ObjectiveSpec`, shared with batch requests")]
pub type StreamObjective = ObjectiveSpec;
