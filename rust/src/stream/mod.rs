//! Streaming ingestion subsystem: append-only sessions feeding the arena
//! SS loop.
//!
//! The batch stack (sparsify → maximize, [`crate::algorithms::ss`]) assumes
//! a fully materialized ground set handed over at request time — the one
//! thing a production summarization service cannot assume. This module
//! turns the pipeline inside out for long-lived feeds (rolling news days,
//! video frames):
//!
//! * [`remap`] — the spine: stable external ids ↔ dense internal indices,
//!   so evicted elements' storage is genuinely compacted away while ids
//!   handed to callers stay valid forever;
//! * the incremental [`SieveFilter`] (stage 1 of the retention policy) —
//!   the sieve-streaming threshold grid refactored into a reusable
//!   admission core; it lives in
//!   [`algorithms::sieve_filter`](crate::algorithms::sieve_filter) (it
//!   is a plain algorithm) and is re-exported here;
//! * [`session`] — [`StreamSession`]: append-only batches, windowed
//!   re-sparsification through the zero-allocation round arena (stage 2),
//!   snapshots through the batched maximizer engine.
//!
//! The service front-end ([`crate::coordinator::service`]) exposes
//! sessions as `open_stream` / `append` / `snapshot_summary` / `close`
//! with per-session backpressure.

pub mod remap;
pub mod session;

pub use crate::algorithms::sieve_filter::{SieveFilter, SieveParams, SieveSet};
pub use remap::IdRemap;
pub use session::{
    SnapshotMode, StreamAppend, StreamConfig, StreamObjective, StreamSession, StreamStats,
    StreamSummary,
};
